from repro.models.config import ModelConfig, MLAArgs, Shape, SHAPES  # noqa: F401
