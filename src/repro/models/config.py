"""Architecture + input-shape configuration.

``ModelConfig`` is a frozen (hashable) dataclass so it can ride into jitted
step functions as a static argument. One config file per assigned
architecture lives in ``repro/configs/``; the four assigned input shapes
are global (``SHAPES``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.nn.moe import MoEArgs
from repro.nn.ssm import SSMArgs
from repro.nn.xlstm import XLSTMArgs

__all__ = ["MLAArgs", "ModelConfig", "Shape", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class MLAArgs:
    kv_lora: int = 512
    q_lora: int = 1536
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "silu"
    gated_mlp: bool = True
    rope_kind: str = "rope"       # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    abs_pos: bool = False         # sinusoidal absolute positions (whisper)
    tie_embeddings: bool = False

    # MoE
    moe: Optional[MoEArgs] = None
    first_k_dense: int = 0        # leading dense layers (deepseek-v2: 1)
    first_dense_ff: int = 0       # d_ff of those dense layers

    # MLA (deepseek-v2)
    mla: Optional[MLAArgs] = None

    # Encoder-decoder (whisper): n_layers = decoder depth
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500           # precomputed frame embeddings (stub frontend)

    # VLM stub frontend: patch embeddings prepended to the text stream
    n_patches: int = 0
    patch_grid: int = 16

    # SSM / hybrid / xlstm
    ssm: Optional[SSMArgs] = None
    attn_every: int = 0           # zamba2: shared attn block every k ssm layers
    xlstm: Optional[XLSTMArgs] = None
    slstm_every: int = 0          # xlstm: 1 sLSTM per k layers

    # parallelism: "tp" = TP/SP over the model axis + FSDP over data (the
    # default); "fsdp" = batch + weights sharded over ALL axes, no tensor
    # parallelism (weight-gather instead of activation-gather — wins for
    # dense archs at large per-chip token counts; §Perf iteration).
    parallelism: str = "tp"

    # numerics / implementation
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_impl: str = "blocked"    # blocked | pallas | naive
    attn_block_q: int = 512
    attn_block_k: int = 1024
    remat: bool = True
    logit_dtype: str = "float32"

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count N (for 6·N·D roofline bookkeeping)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.resolved_head_dim()
        n = V * d  # embeddings (untied lm head adds V*d below)
        n += V * d
        if self.xlstm is not None:
            a = self.xlstm
            per_m = (2 * d * a.d_inner + a.conv_kernel * a.d_inner
                     + 3 * a.n_heads * a.head_dim * a.head_dim
                     + 2 * a.d_inner * a.n_heads + a.d_inner * d)
            per_s = 4 * d * d + a.n_heads * a.s_head_dim * 4 * a.s_head_dim \
                + 3 * d * a.d_ffn
            n_s = L // max(self.slstm_every, 1) if self.slstm_every else 0
            return n + (L - n_s) * per_m + n_s * per_s
        if self.ssm is not None:
            a = self.ssm
            d_in_proj = 2 * a.d_inner + 2 * a.n_groups * a.d_state + a.n_heads
            per = d * d_in_proj + a.conv_kernel * a.conv_dim + a.d_inner * d
            n += L * per
            if self.attn_every:
                napp = 1  # weights shared across applications
                attn = d * (self.n_heads + 2 * self.n_kv) * hd \
                    + self.n_heads * hd * d
                mlp = 3 * d * self.d_ff
                n += napp * (attn + mlp)
            return n
        # attention
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora + m.q_lora * self.n_heads * (m.qk_nope + m.qk_rope)
                    + d * m.kv_lora + d * m.qk_rope
                    + m.kv_lora * self.n_heads * (m.qk_nope + m.v_dim)
                    + self.n_heads * m.v_dim * d)
        else:
            attn = d * (self.n_heads + 2 * self.n_kv) * hd + self.n_heads * hd * d
        # mlp / moe
        if self.moe is not None:
            e = self.moe
            mults = 3 if e.gated else 2
            per_moe = e.num_experts * mults * d * e.d_ff + d * e.num_experts
            per_moe += 3 * d * e.shared_experts * e.d_ff
            n_dense = self.first_k_dense
            dense_ff = self.first_dense_ff or self.d_ff
            n += (L - n_dense) * (attn + per_moe)
            n += n_dense * (attn + (3 if self.gated_mlp else 2) * d * dense_ff)
        else:
            mults = 3 if self.gated_mlp else 2
            n += L * (attn + mults * d * self.d_ff)
            if self.enc_dec:
                # encoder layers + decoder cross-attn
                n += self.n_enc_layers * (attn + mults * d * self.d_ff)
                n += L * (d * (self.n_heads + 2 * self.n_kv) * hd
                          + self.n_heads * hd * d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total = self.param_count()
        mults = 3 if e.gated else 2
        all_exp = (self.n_layers - self.first_k_dense) * e.num_experts * mults \
            * self.d_model * e.d_ff
        act_exp = (self.n_layers - self.first_k_dense) * e.top_k * mults \
            * self.d_model * e.d_ff
        return total - all_exp + act_exp


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: Shape) -> Tuple[bool, str]:
    """DESIGN.md §5: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, (
            "long_500k skipped: pure full-attention architecture "
            "(a 500k dense KV cache is outside the arch's regime)"
        )
    return True, ""
