"""Model assembly: init / forward / loss / serve steps for all 10 archs.

Layer stacks are scanned (``lax.scan`` over stacked params) so the HLO is
one layer body + a loop — essential for 60-layer dry-run compiles. Remat
wraps the scan body. Heterogeneous stacks are expressed as nested scans
over homogeneous groups:

* dense / moe / vlm:  [first_k_dense dense layers] + scan(L' uniform layers)
* whisper:            scan(enc) + scan(dec with cross-attention)
* zamba2:             scan over G groups of (scan over K mamba layers +
                      one SHARED attention/MLP block — same params every
                      application)
* xlstm:              scan over G groups of (scan over 7 mLSTM) + 1 sLSTM

Caches (decode) are pytrees stacked along the same grouping so the decode
step scans layers and caches together.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn import moe as M
from repro.nn import ssm as S
from repro.nn import xlstm as X
from repro.nn.layers import Param
from repro.nn.sharding import MeshAxes

__all__ = [
    "init_model", "forward", "lm_loss", "init_cache",
    "stack_params", "default_placements", "moe_capacity_for_shape",
]


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def stack_params(trees):
    """Stack a list of Param trees along a new leading (layer) axis."""
    def stack(*ps):
        return Param(jnp.stack([p.value for p in ps]),
                     (None,) + tuple(ps[0].logical))
    return jax.tree.map(stack, *trees, is_leaf=L.is_param)


def _norm_fns(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return L.init_layernorm, L.layernorm
    return L.init_rmsnorm, L.rmsnorm


def _shard(x, mesh: Optional[Mesh], *spec):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def _dp(mesh: Optional[Mesh]):
    if mesh is None:
        return None
    return MeshAxes.from_mesh(mesh).data


def _shard_act(x, mesh: Optional[Mesh], parallelism: str = "tp"):
    """Residual-stream constraint.

    tp:   batch → dp, seq → model (sequence parallelism — per-token ops
          run seq-sharded; GSPMD inserts gathers only where attention
          genuinely needs cross-token k/v).
    fsdp: batch → ALL axes, seq unsharded (weights are gathered instead)."""
    if mesh is None:
        return x
    axes = MeshAxes.from_mesh(mesh)
    b, t = x.shape[0], x.shape[1]
    if parallelism == "fsdp":
        all_axes = tuple(axes.data) + (axes.model,)
        sz = 1
        for a in all_axes:
            sz *= mesh.shape[a]
        bspec = all_axes if (b % sz == 0 and b > 1) else None
        return _shard(x, mesh, bspec, None, None)
    dpsz = 1
    for a in axes.data:
        dpsz *= mesh.shape[a]
    bspec = axes.data if (b % dpsz == 0 and b > 1) else None
    sspec = axes.model if (t % mesh.shape[axes.model] == 0 and t > 1) else None
    return _shard(x, mesh, bspec, sspec, None)


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, *, gated: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "up": L.init_linear(ks[0], d, d_ff, ("embed", "mlp"), dtype=dtype),
        "down": L.init_linear(ks[1], d_ff, d, ("mlp", "embed"), dtype=dtype),
    }
    if gated:
        p["gate"] = L.init_linear(ks[2], d, d_ff, ("embed", "mlp"), dtype=dtype)
    return p


def mlp(p, x, *, act: str, gated: bool):
    h = L.linear(p["up"], x)
    if gated:
        h = L.ACTIVATIONS[act](L.linear(p["gate"], x)) * h
    else:
        h = L.ACTIVATIONS[act](h)
    return L.linear(p["down"], h)


# ---------------------------------------------------------------------------
# Transformer decoder layer (self-attn [+cross] + mlp|moe)
# ---------------------------------------------------------------------------


def init_decoder_layer(key, cfg: ModelConfig, *, moe_layer: bool,
                       cross: bool = False, causal_self: bool = True,
                       d_ff_override: int = 0, mesh=None):
    dtype = _dt(cfg.param_dtype)
    init_norm, _ = _norm_fns(cfg)
    ks = jax.random.split(key, 6)
    hd = cfg.resolved_head_dim()
    p: Dict[str, Any] = {"ln1": init_norm(cfg.d_model, dtype)}
    if cfg.mla is not None:
        m = cfg.mla
        p["attn"] = A.init_mla(ks[0], cfg.d_model, cfg.n_heads,
                               kv_lora=m.kv_lora, q_lora=m.q_lora,
                               qk_nope=m.qk_nope, qk_rope=m.qk_rope,
                               v_dim=m.v_dim, dtype=dtype)
    else:
        p["attn"] = A.init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                     hd, bias=cfg.qkv_bias, dtype=dtype)
    if cross:
        p["ln_x"] = init_norm(cfg.d_model, dtype)
        p["xattn"] = A.init_attention(ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                      hd, bias=cfg.qkv_bias, dtype=dtype)
    p["ln2"] = init_norm(cfg.d_model, dtype)
    if moe_layer:
        p["moe"] = M.init_moe(ks[2], cfg.moe, mesh, dtype=dtype)
    else:
        p["mlp"] = init_mlp(ks[2], cfg.d_model, d_ff_override or cfg.d_ff,
                            gated=cfg.gated_mlp, dtype=dtype)
    return p


def decoder_layer(
    p, x, cfg: ModelConfig, *,
    moe_layer: bool, positions, mesh=None,
    cache=None, cache_pos=None, enc_kv=None, causal_self: bool = True,
    placement=None, moe_capacity=None,
):
    """Returns (x, new_cache, stats)."""
    _, norm = _norm_fns(cfg)
    hd = cfg.resolved_head_dim()
    # fsdp mode: batch is fully sharded, attention is embarrassingly
    # parallel per chip — no shard_map island / head constraints needed.
    amesh = None if cfg.parallelism == "fsdp" else mesh
    h = norm(p["ln1"], x)
    if cfg.mla is not None:
        m = cfg.mla
        attn_out, new_cache = A.mla_attention(
            p["attn"], h, n_heads=cfg.n_heads, kv_lora=m.kv_lora,
            qk_nope=m.qk_nope, qk_rope=m.qk_rope, v_dim=m.v_dim,
            positions=positions, rope_theta=cfg.rope_theta,
            causal=causal_self, cache=cache.get("self") if cache else None,
            cache_pos=cache_pos, impl=cfg.attn_impl,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k, mesh=amesh)
    else:
        attn_out, new_cache = A.attention(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=hd,
            positions=positions, rope_kind=cfg.rope_kind,
            rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
            causal=causal_self, cache=cache.get("self") if cache else None,
            cache_pos=cache_pos, impl=cfg.attn_impl,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k, mesh=amesh)
    x = x + attn_out
    out_cache = {"self": new_cache} if new_cache is not None else {}

    if enc_kv is not None:
        h = norm(p["ln_x"], x)
        xo, _ = A.attention(
            p["xattn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=hd,
            positions=None, rope_kind="none", causal=False,
            kv_override=enc_kv, impl=cfg.attn_impl,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k, mesh=amesh)
        x = x + xo

    h = norm(p["ln2"], x)
    stats = {}
    if moe_layer:
        y, stats = M.moe(p["moe"], h, args=cfg.moe, mesh=mesh,
                         placement=placement, capacity=moe_capacity)
    else:
        y = mlp(p["mlp"], h, act=cfg.act, gated=cfg.gated_mlp)
    x = x + y
    # Keep the residual stream (the scan carry that remat saves per layer)
    # sequence-sharded — the attention/MoE combines otherwise leave it
    # replicated over the model axis (16× the saved-activation memory).
    x = _shard_act(x, mesh, cfg.parallelism)
    return x, out_cache, stats


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def _positions(cfg: ModelConfig, b: int, t: int, start=0):
    """(B, T) or (B, T, 3) position ids. ``start`` may be a traced scalar
    or a per-lane (B,) vector (continuous batching)."""
    if jnp.ndim(start) > 0:
        base = start[:, None] + jnp.arange(t)    # (B, t)
    else:
        base = start + jnp.arange(t)             # (t,)
    if cfg.rope_kind != "mrope":
        return jnp.broadcast_to(base, (b, t))
    # M-RoPE: patches get (t=0, h, w) grid ids; text continues temporally.
    npch, g = cfg.n_patches, cfg.patch_grid
    idx = base  # absolute stream position
    is_text = idx >= npch
    t_pos = jnp.where(is_text, idx - npch + 1, 0)
    h_pos = jnp.where(is_text, idx - npch + 1, idx // g)
    w_pos = jnp.where(is_text, idx - npch + 1, idx % g)
    p3 = jnp.stack([t_pos, h_pos, w_pos], axis=-1)
    return jnp.broadcast_to(p3, (b, t, 3))


# ---------------------------------------------------------------------------
# init_model
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig, mesh: Optional[Mesh] = None):
    dtype = _dt(cfg.param_dtype)
    init_norm, _ = _norm_fns(cfg)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": L.init_embedding(ks[0], cfg.vocab, cfg.d_model, dtype=dtype),
        "final_norm": init_norm(cfg.d_model, dtype),
        "lm_head": L.init_linear(ks[1], cfg.d_model, cfg.vocab,
                                 ("embed", "vocab"), dtype=dtype),
    }

    if cfg.xlstm is not None:
        groups = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 1
        per = cfg.slstm_every or cfg.n_layers
        mkeys = jax.random.split(ks[2], groups * (per - 1))
        skeys = jax.random.split(ks[3], groups)
        mtrees, strees = [], []
        for g in range(groups):
            layer_trees = [init_mlstm_layer(mkeys[g * (per - 1) + i], cfg)
                           for i in range(per - 1)]
            mtrees.append(stack_params(layer_trees))
            strees.append(init_slstm_layer(skeys[g], cfg))
        p["mlstm"] = stack_params(mtrees)
        p["slstm"] = stack_params(strees)
        return p

    if cfg.ssm is not None:  # zamba2 hybrid
        k = cfg.attn_every or cfg.n_layers
        groups = cfg.n_layers // k
        mkeys = jax.random.split(ks[2], cfg.n_layers)
        gtrees = []
        for g in range(groups):
            layer_trees = [init_mamba_layer(mkeys[g * k + i], cfg)
                           for i in range(k)]
            gtrees.append(stack_params(layer_trees))
        p["mamba"] = stack_params(gtrees)
        if cfg.attn_every:
            p["shared_attn"] = init_decoder_layer(
                ks[3], cfg, moe_layer=False, mesh=mesh)
        return p

    if cfg.enc_dec:  # whisper
        enc_keys = jax.random.split(ks[2], cfg.n_enc_layers)
        dec_keys = jax.random.split(ks[3], cfg.n_layers)
        p["enc"] = stack_params([
            init_decoder_layer(k_, cfg, moe_layer=False, causal_self=False)
            for k_ in enc_keys])
        p["enc_norm"] = init_norm(cfg.d_model, dtype)
        p["dec"] = stack_params([
            init_decoder_layer(k_, cfg, moe_layer=False, cross=True)
            for k_ in dec_keys])
        return p

    # dense / moe / vlm decoder stack
    n_dense = cfg.first_k_dense if cfg.moe is not None else 0
    lkeys = jax.random.split(ks[2], cfg.n_layers)
    if n_dense:
        p["dense_layers"] = stack_params([
            init_decoder_layer(lkeys[i], cfg, moe_layer=False,
                               d_ff_override=cfg.first_dense_ff, mesh=mesh)
            for i in range(n_dense)])
    p["layers"] = stack_params([
        init_decoder_layer(lkeys[i], cfg, moe_layer=cfg.moe is not None,
                           mesh=mesh)
        for i in range(n_dense, cfg.n_layers)])
    return p


def init_mamba_layer(key, cfg: ModelConfig):
    init_norm, _ = _norm_fns(cfg)
    return {
        "ln": init_norm(cfg.d_model, _dt(cfg.param_dtype)),
        "mixer": S.init_mamba2(key, cfg.ssm, dtype=_dt(cfg.param_dtype)),
    }


def init_mlstm_layer(key, cfg: ModelConfig):
    init_norm, _ = _norm_fns(cfg)
    return {
        "ln": init_norm(cfg.d_model, _dt(cfg.param_dtype)),
        "mixer": X.init_mlstm(key, cfg.xlstm, dtype=_dt(cfg.param_dtype)),
    }


def init_slstm_layer(key, cfg: ModelConfig):
    init_norm, _ = _norm_fns(cfg)
    return {
        "ln": init_norm(cfg.d_model, _dt(cfg.param_dtype)),
        "mixer": X.init_slstm(key, cfg.xlstm, dtype=_dt(cfg.param_dtype)),
    }


# ---------------------------------------------------------------------------
# MoE plan helpers
# ---------------------------------------------------------------------------


def _n_moe_layers(cfg: ModelConfig) -> int:
    if cfg.moe is None:
        return 0
    return cfg.n_layers - cfg.first_k_dense


def default_placements(cfg: ModelConfig, mesh: Mesh):
    """(L_moe, 2, E) baseline placement table (eq. 3-1 class)."""
    n = _n_moe_layers(cfg)
    if n == 0:
        return None
    one = M.default_placement(cfg.moe, mesh)
    return jnp.broadcast_to(one, (n,) + one.shape)


def moe_capacity_for_shape(cfg: ModelConfig, shape_batch: int, shape_seq: int,
                           mesh: Mesh, max_load_ratio: float = 1.0) -> Optional[int]:
    """Static dispatch capacity for (batch, seq) — strategy-aware."""
    if cfg.moe is None:
        return None
    axes = MeshAxes.from_mesh(mesh)
    dp = 1
    for a in axes.data:
        dp *= mesh.shape[a]
    msize = mesh.shape[axes.model]
    a2a = (cfg.moe.strategy == "a2a" and cfg.moe.is_ep(mesh)
           and shape_seq % msize == 0 and shape_seq > 1
           and shape_batch % dp == 0)
    if a2a:
        tokens = (shape_batch // dp) * (shape_seq // msize)
    else:
        tokens = max(1, shape_batch // dp) * shape_seq
    cap = M.capacity_for(cfg.moe, tokens, mesh, max_load_ratio)
    return min(cap, tokens * cfg.moe.top_k)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ForwardOut:
    logits: jax.Array
    cache: Any = None
    stats: Optional[Dict[str, jax.Array]] = None


def _embed_inputs(params, cfg: ModelConfig, tokens, extra_embed, mesh):
    x = L.embedding(params["embed"], tokens).astype(_dt(cfg.compute_dtype))
    if cfg.n_patches and extra_embed is not None:
        x = jnp.concatenate([extra_embed.astype(x.dtype), x], axis=1)
    if cfg.abs_pos:
        t = x.shape[1]
        x = x + L.sinusoidal_positions(t, cfg.d_model).astype(x.dtype)
    return _shard_act(x, mesh, cfg.parallelism)


def forward(
    params, cfg: ModelConfig, *,
    tokens=None,                # (B, T_text) int32
    extra_embed=None,           # (B, P, d) vlm patches / (B, F, d) audio frames
    mesh: Optional[Mesh] = None,
    mode: str = "train",        # train | prefill | decode
    cache=None,
    cache_pos=None,             # scalar int32 (decode write position)
    placements=None,            # (L_moe, 2, E) from the OS4M balancer
    moe_capacity: Optional[int] = None,
) -> ForwardOut:
    assert mode in ("train", "prefill", "decode")
    if cfg.enc_dec:
        return _forward_whisper(params, cfg, tokens, extra_embed, mesh, mode,
                                cache, cache_pos)
    if cfg.xlstm is not None:
        return _forward_xlstm(params, cfg, tokens, mesh, mode, cache)
    if cfg.ssm is not None:
        return _forward_zamba(params, cfg, tokens, mesh, mode, cache, cache_pos)
    return _forward_decoder(params, cfg, tokens, extra_embed, mesh, mode,
                            cache, cache_pos, placements, moe_capacity)


def _lm_head(params, cfg, x, mesh):
    _, norm = _norm_fns(cfg)
    x = norm(params["final_norm"], x)
    logits = L.linear(params["lm_head"], x).astype(_dt(cfg.logit_dtype))
    if mesh is None:
        return logits
    axes = MeshAxes.from_mesh(mesh)
    if cfg.parallelism == "fsdp":
        b = logits.shape[0]
        all_axes = tuple(axes.data) + (axes.model,)
        sz = 1
        for a in all_axes:
            sz *= mesh.shape[a]
        bspec = all_axes if (b % sz == 0 and b > 1) else None
        return _shard(logits, mesh, bspec, None, None)
    return _shard(logits, mesh, axes.data, None, axes.model)


# -- dense / moe / vlm -------------------------------------------------------


def _forward_decoder(params, cfg, tokens, extra_embed, mesh, mode, cache,
                     cache_pos, placements, moe_capacity):
    x = _embed_inputs(params, cfg, tokens, extra_embed if mode != "decode"
                      else None, mesh)
    b, t, _ = x.shape
    is_moe = cfg.moe is not None
    n_dense = cfg.first_k_dense if is_moe else 0

    if mode == "decode":
        positions = _positions(cfg, b, t, start=cache_pos)
    else:
        positions = _positions(cfg, b, t)

    if is_moe and placements is None and mesh is not None:
        placements = default_placements(cfg, mesh)

    stats_acc = {"aux_loss": jnp.zeros((), jnp.float32)}
    new_dense_caches = None

    # leading dense layers (deepseek first_k_dense)
    if n_dense:
        def dense_body(x, inp):
            lp, lcache = inp
            x, ncache, _ = decoder_layer(
                lp, x, cfg, moe_layer=False, positions=positions, mesh=mesh,
                cache=lcache, cache_pos=cache_pos)
            return x, ncache
        dense_body = _remat(dense_body, cfg)
        dcache = None if cache is None else cache["dense"]
        x, new_dense_caches = jax.lax.scan(
            dense_body, x, (params["dense_layers"], dcache))

    def body(x, inp):
        lp, lcache, placement = inp
        x, ncache, st = decoder_layer(
            lp, x, cfg, moe_layer=is_moe, positions=positions, mesh=mesh,
            cache=lcache, cache_pos=cache_pos, placement=placement,
            moe_capacity=moe_capacity)
        return x, (ncache, st)

    body = _remat(body, cfg)
    lcaches = None if cache is None else cache["layers"]
    x, (ncaches, sts) = jax.lax.scan(
        body, x, (params["layers"], lcaches, placements if is_moe else None))

    if is_moe:
        stats_acc["aux_loss"] = sts["aux_loss"].sum()
        stats_acc["expert_counts"] = sts["counts"]        # (L_moe, E)
        stats_acc["overflow"] = sts["overflow"].sum()

    logits = _lm_head(params, cfg, x, mesh)
    new_cache = None
    if cache is not None:
        new_cache = {"layers": ncaches}
        if n_dense:
            new_cache["dense"] = new_dense_caches
    return ForwardOut(logits=logits, cache=new_cache, stats=stats_acc)


# -- whisper (enc-dec) -------------------------------------------------------


def _forward_whisper(params, cfg, tokens, frames, mesh, mode, cache, cache_pos):
    _, norm = _norm_fns(cfg)
    hd = cfg.resolved_head_dim()
    dtype = _dt(cfg.compute_dtype)

    if mode == "decode":
        enc_kv_all = cache["cross"]                      # (L, B, S_enc, kv, hd) x2
        enc_out = None
    else:
        enc = frames.astype(dtype)
        enc = enc + L.sinusoidal_positions(enc.shape[1], cfg.d_model).astype(dtype)
        enc = _shard(enc, mesh, _dp(mesh), None, None)

        def enc_body(x, lp):
            x, _, _ = decoder_layer(lp, x, cfg, moe_layer=False,
                                    positions=None, causal_self=False,
                                    mesh=mesh)
            return x, None
        enc_body = _remat(enc_body, cfg)
        enc, _ = jax.lax.scan(enc_body, enc, params["enc"])
        enc_out = norm(params["enc_norm"], enc)

        # Precompute per-decoder-layer cross k/v from the encoder output.
        def cross_kv(lp):
            k = L.linear(lp["xattn"]["k"], enc_out)
            v = L.linear(lp["xattn"]["v"], enc_out)
            b, s = k.shape[0], k.shape[1]
            return (k.reshape(b, s, cfg.n_kv, hd), v.reshape(b, s, cfg.n_kv, hd))
        enc_kv_all = jax.vmap(cross_kv)(params["dec"])

    x = L.embedding(params["embed"], tokens).astype(dtype)
    b, t, _ = x.shape
    if mode == "decode":
        # Dynamic gather into the (static max-len) sinusoidal table.
        max_len = int(cache["dec"]["self"]["k"].shape[2])
        if jnp.ndim(cache_pos) > 0:
            idx = cache_pos[:, None] + jnp.arange(t)
        else:
            idx = cache_pos + jnp.arange(t)
        pe = L.sinusoidal_positions(max_len, cfg.d_model)[idx]
        x = x + pe.astype(dtype)
    else:
        x = x + L.sinusoidal_positions(t, cfg.d_model).astype(dtype)
    x = _shard_act(x, mesh, cfg.parallelism)

    def dec_body(x, inp):
        lp, lcache, ekv = inp
        x, ncache, _ = decoder_layer(
            lp, x, cfg, moe_layer=False, positions=None, mesh=mesh,
            cache=lcache, cache_pos=cache_pos, enc_kv=ekv)
        return x, ncache

    dec_body = _remat(dec_body, cfg)
    lcaches = None if cache is None else cache["dec"]
    x, ncaches = jax.lax.scan(
        dec_body, x, (params["dec"], lcaches, enc_kv_all))

    logits = _lm_head(params, cfg, x, mesh)
    new_cache = None
    if cache is not None:
        new_cache = {"dec": ncaches, "cross": enc_kv_all}
    return ForwardOut(logits=logits, cache=new_cache, stats=None)


# -- zamba2 (mamba + shared attention) ----------------------------------------


def _forward_zamba(params, cfg, tokens, mesh, mode, cache, cache_pos):
    _, norm = _norm_fns(cfg)
    dtype = _dt(cfg.compute_dtype)
    x = L.embedding(params["embed"], tokens).astype(dtype)
    x = _shard_act(x, mesh, cfg.parallelism)
    b, t, _ = x.shape
    k = cfg.attn_every or cfg.n_layers
    decode = mode == "decode"
    positions = _positions(cfg, b, t, start=cache_pos if decode else 0)

    def mamba_body(x, inp):
        lp, lstate = inp
        h = norm(lp["ln"], x)
        if decode:
            y, nstate = S.mamba2_decode(lp["mixer"], h, cfg.ssm, lstate)
        elif mode == "prefill":
            y, nstate = S.mamba2(lp["mixer"], h, cfg.ssm, return_state=True)
        else:
            y = S.mamba2(lp["mixer"], h, cfg.ssm)
            nstate = None
        return _shard_act(x + y, mesh, cfg.parallelism), nstate

    mamba_body = _remat(mamba_body, cfg)

    def group_body(x, inp):
        gp, gstate, acache = inp
        x, nstates = jax.lax.scan(mamba_body, x, (gp, gstate))
        ncache = None
        if cfg.attn_every:
            x, ncache, _ = decoder_layer(
                params["shared_attn"], x, cfg, moe_layer=False,
                positions=positions, mesh=mesh, cache=acache,
                cache_pos=cache_pos)
        return x, (nstates, ncache)

    gstates = None if cache is None else cache["mamba"]
    acaches = None if cache is None else cache["attn"]
    x, (nstates, ncaches) = jax.lax.scan(
        group_body, x, (params["mamba"], gstates, acaches))

    logits = _lm_head(params, cfg, x, mesh)
    new_cache = None
    if cache is not None or mode == "prefill":
        new_cache = {"mamba": nstates, "attn": ncaches}
    return ForwardOut(logits=logits, cache=new_cache, stats=None)


# -- xlstm --------------------------------------------------------------------


def _forward_xlstm(params, cfg, tokens, mesh, mode, cache):
    _, norm = _norm_fns(cfg)
    dtype = _dt(cfg.compute_dtype)
    x = L.embedding(params["embed"], tokens).astype(dtype)
    x = _shard_act(x, mesh, cfg.parallelism)
    per = cfg.slstm_every or cfg.n_layers
    decode = mode == "decode"
    a = cfg.xlstm

    def m_body(x, inp):
        lp, lstate = inp
        h = norm(lp["ln"], x)
        if decode:
            y, nstate = X.mlstm_decode(lp["mixer"], h, a, lstate)
        elif mode == "prefill":
            y, nstate = X.mlstm(lp["mixer"], h, a, return_state=True)
        else:
            y, nstate = X.mlstm(lp["mixer"], h, a), None
        return _shard_act(x + y, mesh, cfg.parallelism), nstate

    m_body = _remat(m_body, cfg)

    def group_body(x, inp):
        gp_m, gp_s, mstate, sstate = inp
        x, nm = jax.lax.scan(m_body, x, (gp_m, mstate))
        h = norm(gp_s["ln"], x)
        if decode or mode == "prefill":
            y, ns = X.slstm(gp_s["mixer"], h, a, state=sstate, return_state=True)
        else:
            y, ns = X.slstm(gp_s["mixer"], h, a), None
        return _shard_act(x + y, mesh, cfg.parallelism), (nm, ns)

    mstates = None if cache is None else cache["mlstm"]
    sstates = None if cache is None else cache["slstm"]
    x, (nm, ns) = jax.lax.scan(
        group_body, x,
        (params["mlstm"], params["slstm"], mstates, sstates))

    logits = _lm_head(params, cfg, x, mesh)
    new_cache = None
    if cache is not None or mode == "prefill":
        new_cache = {"mlstm": nm, "slstm": ns}
    return ForwardOut(logits=logits, cache=new_cache, stats=None)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(logits, labels, mask=None):
    """Token cross-entropy in f32. labels: (B, T) int32; mask optional.

    The gold logit is read with a fused iota-compare reduction instead of
    ``take_along_axis`` — a gather along a model-sharded vocab axis would
    force GSPMD to replicate the full (B, T, V) logits per chip; the
    compare+select+reduce stays vocab-sharded (partial sum + all-reduce).
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], lg, 0.0), axis=-1)
    nll = lse - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Cache init (decode / prefill)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Zeroed cache pytree for ``batch`` sequences of up to ``max_len``."""
    hd = cfg.resolved_head_dim()

    def kv(layers, length):
        return {
            "self": {
                "k": jnp.zeros((layers, batch, length, cfg.n_kv, hd), dtype),
                "v": jnp.zeros((layers, batch, length, cfg.n_kv, hd), dtype),
            }
        }

    if cfg.xlstm is not None:
        a = cfg.xlstm
        per = cfg.slstm_every or cfg.n_layers
        groups = cfg.n_layers // per
        zero = jnp.zeros
        return {
            "mlstm": {
                "cell": (
                    zero((groups, per - 1, batch, a.n_heads, a.head_dim,
                          a.head_dim), jnp.float32),
                    zero((groups, per - 1, batch, a.n_heads, a.head_dim),
                         jnp.float32),
                    jnp.full((groups, per - 1, batch, a.n_heads), -1e30,
                             jnp.float32),
                ),
                "conv": zero((groups, per - 1, batch, a.conv_kernel - 1,
                              a.d_inner), jnp.float32),
            },
            "slstm": tuple(
                zero((groups, batch, a.n_heads, a.s_head_dim), jnp.float32)
                if i < 3 else
                jnp.full((groups, batch, a.n_heads, a.s_head_dim), -1e30,
                         jnp.float32)
                for i in range(4)
            ),
        }

    if cfg.ssm is not None:
        a = cfg.ssm
        k = cfg.attn_every or cfg.n_layers
        groups = cfg.n_layers // k
        out = {
            "mamba": {
                "ssm": jnp.zeros((groups, k, batch, a.n_heads, a.head_dim,
                                  a.d_state), jnp.float32),
                "conv": jnp.zeros((groups, k, batch, a.conv_kernel - 1,
                                   a.conv_dim), jnp.float32),
            },
            "attn": None,
        }
        if cfg.attn_every:
            out["attn"] = {
                "self": {
                    "k": jnp.zeros((groups, batch, max_len, cfg.n_kv, hd), dtype),
                    "v": jnp.zeros((groups, batch, max_len, cfg.n_kv, hd), dtype),
                }
            }
        return out

    if cfg.enc_dec:
        return {
            "dec": kv(cfg.n_layers, max_len),
            "cross": (
                jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_kv, hd), dtype),
                jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_kv, hd), dtype),
            ),
        }

    if cfg.mla is not None:
        m = cfg.mla
        n_dense = cfg.first_k_dense if cfg.moe is not None else 0
        mk = lambda layers: {
            "self": {
                "c_kv": jnp.zeros((layers, batch, max_len, m.kv_lora), dtype),
                "k_pe": jnp.zeros((layers, batch, max_len, m.qk_rope), dtype),
            }
        }
        out = {"layers": mk(cfg.n_layers - n_dense)}
        if n_dense:
            out["dense"] = mk(n_dense)
        return out

    n_dense = cfg.first_k_dense if cfg.moe is not None else 0
    out = {"layers": kv(cfg.n_layers - n_dense, max_len)}
    if n_dense:
        out["dense"] = kv(n_dense, max_len)
    return out
