# Serving: KV-cache management + continuous batching with OS4M lane
# scheduling.
