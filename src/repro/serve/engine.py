"""Continuous-batching serving engine with OS4M lane scheduling.

Requests are Reduce operations (load = prompt + remaining decode budget);
KV-cache lanes are slots. Admission solves the same Q||C_max the
scheduler core solves for Reduce tasks: lanes balanced *by finish time*
mean no lane idles while another still has a deep queue — and a lane on a
slow device (or with a configured handicap) is handed proportionally less
decode work. Lane speeds come from ``EngineConfig.lane_speeds`` (explicit
/ fault injection) or, with ``adaptive=True``, from the measured per-lane
decode throughput (EWMA over completed steps,
:class:`repro.core.slot_speeds.SlotSpeedEstimator`). Stragglers are
otherwise handled the OS4M way — a periodic *global* replan of the
waiting queue — not SkewTune-style migration of running work (migrating a
running lane would re-copy its KV cache, the 30-second-class cost the
paper's §7 argues against).

Mechanics: one shared cache pytree for all lanes with **per-lane write
positions** (vector ``cache_pos``), so lanes decode in lock-step while
being at different sequence depths — true continuous batching. Admission
prefills a lane and splices its rows into the shared cache.

Scope: attention-family caches (batch axis 1 by construction —
dense/moe/vlm/whisper). SSM/hybrid serving uses the state-based decode
directly (examples/).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import scheduler as sched_lib
from repro.core import stats_provider as sp
from repro.core.slot_speeds import SlotSpeedEstimator, speed_drift
from repro.models.config import ModelConfig
from repro.models.model import forward, init_cache

__all__ = ["Request", "EngineConfig", "Engine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    output: Optional[List[int]] = None
    lane: int = -1
    job: int = 0                  # owning job/tenant id (multi-job serving)

    @property
    def load(self) -> float:
        """Operation load: decode steps dominate lane occupancy."""
        return float(self.max_new + 0.1 * self.prompt.shape[0])


@dataclasses.dataclass
class EngineConfig:
    lanes: int = 8                # concurrent sequences (batch)
    max_len: int = 256            # lane KV capacity
    scheduler: str = "os4m"       # os4m | lpt | hash (eq. 3-1 baseline)
    eos: int = 2
    # Q||C_max lane admission: explicit relative lane speeds (fault
    # injection / known-heterogeneous devices), and/or adaptive weighting
    # by measured decode throughput. None + adaptive=False ≡ P||C_max.
    lane_speeds: Optional[Sequence[float]] = None
    adaptive: bool = False        # learn lane speeds from decode timings
    speed_ewma: float = 0.4       # EWMA weight of the newest measurement
    # Mid-run replanning (the OS4M answer to a lane slowing mid-serve):
    # with adaptive metering on, the decode loop periodically folds the
    # measured lane throughput into the meter and, when any lane's speed
    # moved more than max_speed_drift from the speeds the queues were
    # planned under, re-plans the WAITING queues globally — running
    # requests stay put (migrating a running lane would re-copy its KV
    # cache, the §7 cost the paper argues against).
    replan_on_drift: bool = False
    max_speed_drift: float = 0.25
    replan_check_every: int = 8   # decode steps between drift checks
    # Elastic mesh observer: called with one event dict per lane
    # join/leave/death ({"event": "lane_dead" | "lane_join", "lane": i,
    # "alive": k}) — the serve-side mirror of MapReduceJob.on_mesh_change.
    # The engine keeps the full log in ``Engine.mesh_events`` either way.
    on_mesh_change: Optional[Callable[[dict], None]] = None
    # Multi-job serving (R||C_max admission): requests carry a ``job`` id;
    # each job gets its own lane-speed row (per-job decode metering — the
    # engine's slice of the multi-job R-matrix), jobs are admitted in
    # weighted-completion-time order (Smith's rule, weight from
    # ``job_weights``, default 1.0), and at most ``max_concurrent_jobs``
    # jobs are interleaved on the lanes at once (None = no cap). Dead
    # lanes stay excluded from every job's row.
    max_concurrent_jobs: Optional[int] = None
    job_weights: Optional[Dict[int, float]] = None
    # Statistics source for admission planning (the serve-side mirror of
    # MapReduceConfig.stats): "exact" plans lanes from each request's
    # true load; "sketch" budgets lanes from a count-min estimate of the
    # waiting queue (core/stats_provider.CountMinParams) — estimates are
    # overestimate-only, so a lane's planned finish time can only be
    # pessimistic, never silently over-committed. Emulates a deployment
    # where the admission controller sees compressed queue statistics
    # rather than every request's exact token counts.
    stats: str = "exact"
    sketch_width: int = 256       # admission sketch columns (power of two)
    sketch_depth: int = 4         # admission sketch hash rows


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 mesh=None):
        assert cfg.ssm is None and cfg.xlstm is None, \
            "state-based archs use the decode step directly"
        self.cfg, self.params, self.ecfg, self.mesh = cfg, params, ecfg, mesh
        self.last_balance_ratio = 1.0
        self.last_finish_ratio = 1.0
        # Configured lane speeds are validated AND normalised to mean 1
        # exactly once, here, and the normalised vector is what every
        # plan sees. (Speeds are relative — the schedulers only consume
        # ratios — and the metered path already arrives mean-1; returning
        # the raw configured vector would hand the schedulers a different
        # scale per source. A uniform [2, 2, 2, 2] now plans identically
        # to None, as it should.)
        self._lane_speeds: Optional[np.ndarray] = None
        if ecfg.lane_speeds is not None:
            v = sched_lib.normalize_speeds(ecfg.lane_speeds, ecfg.lanes)
            self._lane_speeds = v / v.mean()
        # Measured decode throughput per lane (tokens/second, EWMA). Only
        # consulted when ecfg.adaptive — on homogeneous hardware the
        # measurements are ≈ equal and admission matches P||C_max anyway.
        self.lane_meter = SlotSpeedEstimator(ecfg.lanes, ewma=ecfg.speed_ewma)
        # Per-job decode metering: one estimator per job id — the rows of
        # the engine's R-matrix. A job's admission and mid-run replans use
        # its OWN row once it has observations; the global meter stays the
        # fallback for unmetered jobs (and the single-job fast path, where
        # the two see the same measurements).
        self.job_meters: Dict[int, SlotSpeedEstimator] = {}
        # Mid-run replan state: the speeds the live queue plan was built
        # under (global + per-job rows), and telemetry for the
        # drift-triggered replans.
        self._planned_speeds: Optional[np.ndarray] = None
        self._planned_job_speeds: Dict[int, np.ndarray] = {}
        self.replans = 0
        self.last_replan_drift: Optional[float] = None
        # Elastic mesh: lanes whose device vanished. A configured lane
        # speed of exact 0.0 seeds the mask (launch/serve --slot-slowdown
        # i:0 in engine mode); ``set_lane_failure`` flips it at runtime.
        # Dead lanes admit nothing, plan to nothing (the Q||C_max
        # schedulers compact onto the alive set at speed 0), and are
        # masked out of the throughput meter so they never re-inherit
        # work from a stale measurement.
        self._dead_lanes = np.zeros(ecfg.lanes, dtype=bool)
        self.mesh_events: List[dict] = []
        # Sketch-planned admission (EngineConfig.stats="sketch"): the
        # count-min hash family the admission loads are estimated
        # through, plus telemetry (#plans that used estimated loads).
        self._admission_sketch: Optional[sp.CountMinParams] = None
        if ecfg.stats not in ("exact", "sketch"):
            raise ValueError(
                f"EngineConfig.stats must be 'exact' or 'sketch', got"
                f" {ecfg.stats!r}")
        if ecfg.stats == "sketch":
            self._admission_sketch = sp.CountMinParams(
                width=ecfg.sketch_width, depth=ecfg.sketch_depth)
        self.sketch_admissions = 0
        if self._lane_speeds is not None and np.any(self._lane_speeds == 0.0):
            for lane in np.flatnonzero(self._lane_speeds == 0.0):
                self.set_lane_failure(int(lane))
        self._decode = jax.jit(self._decode_impl)

    # -- elastic mesh (lane accounting) -------------------------------------

    def set_lane_failure(self, lane: int, dead: bool = True) -> None:
        """Declare one lane dead (device vanished) or revived (join).

        Effective at the next plan: ``lane_speeds`` pins the lane to
        exact 0.0, so admission assigns it nothing, and the meter masks
        it out. With ``replan_on_drift`` the next drift check sees a
        dead-mask change — ``speed_drift`` reports ``inf`` on a mask
        mismatch — and re-plans the waiting queues off the lane
        immediately; running work is never migrated (§7). Emits a mesh
        event to ``EngineConfig.on_mesh_change`` / ``mesh_events``.
        """
        if not 0 <= lane < self.ecfg.lanes:
            raise ValueError(f"lane {lane} out of range [0, {self.ecfg.lanes})")
        if bool(self._dead_lanes[lane]) == bool(dead):
            return
        self._dead_lanes[lane] = dead
        if self._lane_speeds is not None:
            # Configured vectors get the overlay in-place: 0.0 while
            # dead; a revived lane rejoins at nominal speed.
            self._lane_speeds[lane] = 0.0 if dead else 1.0
        self.lane_meter.set_slot_failure(lane, dead=dead)
        for meter in self.job_meters.values():
            meter.set_slot_failure(lane, dead=dead)
        event = {
            "event": "lane_dead" if dead else "lane_join",
            "lane": int(lane),
            "lanes": int(self.ecfg.lanes),
            "alive": int(self.ecfg.lanes - int(self._dead_lanes.sum())),
        }
        self.mesh_events.append(event)
        if self.ecfg.on_mesh_change is not None:
            self.ecfg.on_mesh_change(event)

    @property
    def dead_lanes(self) -> np.ndarray:
        """Boolean mask of vanished lanes (copy)."""
        return self._dead_lanes.copy()

    # -- Q||C_max lane assignment (the §4.2 schedule, speed-aware) ----------

    def lane_speeds(self, job: Optional[int] = None) -> Optional[np.ndarray]:
        """Relative lane speeds admission plans under (None ≡ all nominal).

        Configured ``lane_speeds`` win (returned in their mean-1
        normalised form — normalisation happens once in ``__init__``);
        otherwise the measured decode throughput when ``adaptive`` and at
        least one run was metered. With a ``job`` id, that job's *own*
        metered row wins over the global meter once it has observations —
        the engine's slice of the multi-job R-matrix (different jobs can
        legitimately measure different relative lane speeds). Dead lanes
        read exact 0.0 from every source — and force a concrete vector
        even when neither source is configured, so a plan can never hand
        work to a vanished lane.
        """
        if self._lane_speeds is not None:
            return self._lane_speeds
        speeds = None
        if self.ecfg.adaptive:
            meter = self.job_meters.get(job) if job is not None else None
            if meter is not None and meter.observations > 0:
                speeds = meter.speeds()
            else:
                speeds = self.lane_meter.speeds()
        if np.any(self._dead_lanes):
            if speeds is None:
                speeds = np.ones(self.ecfg.lanes, np.float64)
            return np.where(self._dead_lanes, 0.0, speeds)
        return speeds

    def observe_job_lane_times(self, job: int, lane_tokens, lane_seconds
                               ) -> None:
        """Feed one job's measured per-lane (tokens, seconds) into its row.

        Creates the job's estimator on first use (inheriting the dead-lane
        mask) — the external hook for deployments where per-job decode
        timings arrive from the serving fabric rather than this process's
        own ``run`` loop.
        """
        meter = self.job_meters.get(job)
        if meter is None:
            meter = SlotSpeedEstimator(self.ecfg.lanes,
                                       ewma=self.ecfg.speed_ewma)
            for lane in np.flatnonzero(self._dead_lanes):
                meter.set_slot_failure(int(lane))
            self.job_meters[job] = meter
        meter.update(lane_tokens, lane_seconds)

    def job_weight(self, job: int) -> float:
        """The job's ΣwᵢCᵢ priority weight (default 1.0)."""
        if self.ecfg.job_weights is None:
            return 1.0
        return float(self.ecfg.job_weights.get(job, 1.0))

    def r_matrix(self, jobs: Sequence[int]) -> np.ndarray:
        """Per-(job, lane) processing times for unit work: ``1 / speeds``.

        Rows come from each job's own lane-speed row; a dead lane is
        ``+inf`` in every row. This is the matrix view multi-job
        admission reasons about (and tests inspect).
        """
        rows = []
        for j in jobs:
            row = self.lane_speeds(job=j)
            s = (np.ones(self.ecfg.lanes, np.float64) if row is None
                 else np.asarray(row, np.float64))
            out = np.full(self.ecfg.lanes, np.inf)
            out[s > 0.0] = 1.0 / s[s > 0.0]
            rows.append(out)
        return np.stack(rows) if rows else np.zeros((0, self.ecfg.lanes))

    def _admission_loads(self, requests: List[Request]) -> np.ndarray:
        """Per-request loads as admission sees them (exact or estimated).

        ``EngineConfig.stats == "sketch"``: the waiting queue's (rid,
        load) pairs are folded into a count-min sketch and each load is
        read back as an estimate — overestimate-only (count-min reads are
        ``true + non-negative collision mass``), so lane finish budgets
        are pessimistic but never over-committed. Exact mode returns the
        true loads unchanged (bit-pinned by the serving tests).
        """
        loads = np.asarray([r.load for r in requests], np.float64)
        cm = self._admission_sketch
        if cm is None or not requests:
            return loads
        counters = np.zeros((cm.depth, cm.width))
        rids = np.asarray([r.rid for r in requests], np.int64)
        cm.add_dense(counters, rids, loads)
        self.sketch_admissions += 1
        return cm.estimate(counters, rids)

    def plan(self, requests: List[Request]) -> Dict[int, List[Request]]:
        """Admit requests onto lanes: Q||C_max per job, R||C_max across jobs.

        Single-job traffic takes the original path unchanged (bit-pinned
        by the serving tests). With several job ids present, job groups
        are ordered by weighted completion time (Smith's rule on weight /
        total load) and placed group-by-group with earliest-finish-time
        onto the *cumulative* lane finish times, each group under its own
        lane-speed row — an R||C_max EFT where the row really can differ
        per job. ``max_concurrent_jobs`` caps how many jobs interleave:
        groups beyond the cap queue strictly behind the earlier wave.
        Under ``stats="sketch"`` both paths budget lanes from count-min
        load estimates (:meth:`_admission_loads`) instead of exact loads.
        """
        speeds = self.lane_speeds()
        self._planned_speeds = (np.ones(self.ecfg.lanes) if speeds is None
                                else np.asarray(speeds, np.float64))
        self._planned_job_speeds = {}
        job_ids = list(dict.fromkeys(r.job for r in requests))
        if len(job_ids) > 1:
            return self._plan_multi_job(requests, job_ids)
        loads = self._admission_loads(requests)
        if job_ids:
            row = self.lane_speeds(job=job_ids[0])
            if row is not None:
                speeds = row
                self._planned_job_speeds[job_ids[0]] = \
                    np.asarray(row, np.float64).copy()
        if self.ecfg.scheduler == "hash":
            sched = sched_lib.schedule_hash(
                loads, self.ecfg.lanes,
                keys=np.asarray([r.rid for r in requests]), speeds=speeds)
        elif self.ecfg.scheduler == "lpt":
            sched = sched_lib.schedule_lpt(loads, self.ecfg.lanes,
                                           speeds=speeds)
        else:
            sched = sched_lib.schedule_bss(loads, self.ecfg.lanes,
                                           speeds=speeds)
        by_lane: Dict[int, List[Request]] = {
            i: [] for i in range(self.ecfg.lanes)}
        for r, lane in zip(requests, sched.assignment):
            r.lane = int(lane)
            by_lane[int(lane)].append(r)
        for lane in by_lane:  # §4.4 order: increasing load first
            by_lane[lane].sort(key=lambda r: r.load)
        self.last_balance_ratio = sched.balance_ratio
        self.last_finish_ratio = sched.finish_ratio
        return by_lane

    def _plan_multi_job(
        self, requests: List[Request], job_ids: List[int]
    ) -> Dict[int, List[Request]]:
        """The R||C_max admission path (≥ 2 jobs present)."""
        from repro.core import simulator as sim

        groups: Dict[int, List[Request]] = {j: [] for j in job_ids}
        est_load = dict(zip(
            (id(r) for r in requests), self._admission_loads(requests)))
        for r in requests:
            groups[r.job].append(r)
        totals = np.asarray(
            [sum(est_load[id(r)] for r in groups[j]) for j in job_ids])
        weights = np.asarray([self.job_weight(j) for j in job_ids])
        admit = [job_ids[i] for i in sim.wspt_order(totals, weights)]
        cap = self.ecfg.max_concurrent_jobs or len(admit)
        cap = max(int(cap), 1)
        lanes = self.ecfg.lanes
        lane_finish = np.zeros(lanes)
        lane_loads = np.zeros(lanes)
        by_lane: Dict[int, List[Request]] = {i: [] for i in range(lanes)}
        admit_pos = {j: k for k, j in enumerate(admit)}
        for j in admit:
            row = self.lane_speeds(job=j)
            s = (np.ones(lanes, np.float64) if row is None
                 else np.asarray(row, np.float64))
            self._planned_job_speeds[j] = s.copy()
            alive = s > 0.0
            if not np.any(alive):
                raise RuntimeError("all lanes dead: cannot admit requests")
            for r in sorted(groups[j], key=lambda r: -est_load[id(r)]):
                with np.errstate(divide="ignore"):
                    cand = np.where(
                        alive,
                        lane_finish + est_load[id(r)] / np.where(alive, s, 1.0),
                        np.inf)
                lane = int(np.argmin(cand))
                r.lane = lane
                by_lane[lane].append(r)
                lane_finish[lane] = cand[lane]
                lane_loads[lane] += est_load[id(r)]
        for lane in by_lane:
            # Earlier-admitted jobs keep queue priority; within a job the
            # §4.4 increasing-load order stands (sort is stable).
            by_lane[lane].sort(key=lambda r: (admit_pos[r.job], r.load))
        alive_mask = lane_finish[np.isfinite(lane_finish)]
        ideal_load = lane_loads.sum() / max(lanes, 1)
        self.last_balance_ratio = (
            float(lane_loads.max() / ideal_load) if ideal_load > 0 else 1.0)
        mean_finish = alive_mask.mean() if alive_mask.size else 0.0
        self.last_finish_ratio = (
            float(lane_finish.max() / mean_finish) if mean_finish > 0
            else 1.0)
        return by_lane

    def maybe_replan_waiting(self, queues: Dict[int, List[Request]]) -> bool:
        """Re-plan the waiting queues if measured lane speeds drifted.

        The OS4M straggler response applied mid-serve: compare the
        current measured lane speeds against the speeds the live plan was
        built under (:func:`repro.core.slot_speeds.speed_drift`); past
        ``max_speed_drift``, pool every request still WAITING and run a
        fresh global plan under the fresh speeds, mutating ``queues`` in
        place. Every job with waiting requests is checked against **its
        own row** of the R-matrix (the speeds its part of the plan was
        actually built under) — a job whose slow lane sped up must
        replan even while the global average moved nowhere, and vice
        versa. Running requests are never migrated (their KV cache stays
        put). Returns True when a replan happened; telemetry in
        ``self.replans`` / ``self.last_replan_drift``.
        """
        fresh = self.lane_speeds()
        drift: Optional[float] = None
        if fresh is not None and self._planned_speeds is not None:
            drift = speed_drift(self._planned_speeds, fresh)
        waiting = [r for q in queues.values() for r in q]
        for j in sorted({r.job for r in waiting}):
            ref_j = self._planned_job_speeds.get(j)
            fresh_j = self.lane_speeds(job=j)
            if ref_j is not None and fresh_j is not None:
                d = speed_drift(ref_j, fresh_j)
                drift = d if drift is None else max(drift, d)
        if drift is None:   # nothing measured against nothing planned
            return False
        self.last_replan_drift = drift
        if drift <= self.ecfg.max_speed_drift:
            return False
        if not waiting:
            return False
        replanned = self.plan(waiting)   # also re-anchors the planned rows
        for lane in queues:
            queues[lane] = replanned.get(lane, [])
        self.replans += 1
        return True

    # -- jitted steps --------------------------------------------------------

    def _decode_impl(self, params, cache, tokens, pos_vec):
        out = forward(params, self.cfg, tokens=tokens, mesh=self.mesh,
                      mode="decode", cache=cache, cache_pos=pos_vec)
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        return out.cache, nxt

    @staticmethod
    def _merge_lane(cache, new_cache, lane: int):
        """Splice one lane's rows (batch axis 1) from new_cache into cache."""
        return jax.tree.map(
            lambda old, new: old.at[:, lane].set(new[:, lane]),
            cache, new_cache)

    # -- serving -------------------------------------------------------------

    def run(self, requests: List[Request], extra_embed=None) -> List[Request]:
        ecfg = self.ecfg
        queues = self.plan(requests)
        cache = init_cache(self.cfg, ecfg.lanes, ecfg.max_len,
                           dtype=jnp.float32)
        pos = np.zeros(ecfg.lanes, dtype=np.int64)
        budget = np.zeros(ecfg.lanes, dtype=np.int64)
        cur = np.zeros(ecfg.lanes, dtype=np.int32)
        active: Dict[int, Request] = {}
        done: List[Request] = []

        def admit(lane: int, cache):
            """Prefill the lane's next request; returns the updated cache."""
            # Belt-and-braces: the planner already routes nothing to a
            # lane with speed 0.0, but a lane that died *after* planning
            # must neither prefill nor strand its queue — hand the
            # waiting requests to the shortest surviving queue.
            if self._dead_lanes[lane]:
                if queues[lane]:
                    alive = np.flatnonzero(~self._dead_lanes)
                    if alive.size == 0:
                        raise RuntimeError(
                            "all lanes dead with requests still queued")
                    dest = int(min(alive, key=lambda a: len(queues[a])))
                    queues[dest].extend(queues[lane])
                    queues[lane].clear()
                return cache
            if not queues[lane]:
                return cache
            r = queues[lane].pop(0)
            r.output = []
            p = r.prompt.shape[0]
            toks = jnp.broadcast_to(
                jnp.asarray(r.prompt[None, :], jnp.int32), (ecfg.lanes, p))
            out = forward(self.params, self.cfg, tokens=toks,
                          extra_embed=extra_embed, mesh=self.mesh,
                          mode="prefill", cache=cache, cache_pos=jnp.int32(0))
            cache = self._merge_lane(cache, out.cache, lane)
            first = int(jnp.argmax(out.logits[0, -1]))
            active[lane] = r
            pos[lane] = p
            budget[lane] = r.max_new - 1
            cur[lane] = first
            r.output.append(first)
            return cache

        for lane in range(ecfg.lanes):
            cache = admit(lane, cache)

        # Per-lane decode throughput metering: tokens produced and wall
        # time while the lane was active. Feeds the next plan's lane
        # speeds when ecfg.adaptive. Two caveats: the first decode step
        # carries jit compilation and is excluded (it would bill
        # seconds-scale compile time to whichever lanes happen to be
        # active); and on a single-device lock-step batch every active
        # lane shares one step clock, so measured rates only separate
        # lanes when decode actually runs per-device (real mesh) — on
        # this container the meter reads ≈uniform and admission matches
        # P||C_max, while `lane_speeds` injection stays the
        # deterministic way to model a slow lane.
        lane_tokens = np.zeros(ecfg.lanes)
        lane_seconds = np.zeros(ecfg.lanes)
        # The same measurements split per job id: each job's share of the
        # decode clock builds that job's row of the R-matrix.
        job_tokens: Dict[int, np.ndarray] = {}
        job_seconds: Dict[int, np.ndarray] = {}

        def flush_meter():
            """Fold the accumulated per-lane (tokens, seconds) into the meter."""
            if lane_tokens.any():
                self.lane_meter.update(lane_tokens, lane_seconds)
                lane_tokens[:] = 0.0
                lane_seconds[:] = 0.0
            for j, toks_j in job_tokens.items():
                if toks_j.any():
                    self.observe_job_lane_times(j, toks_j, job_seconds[j])
                    toks_j[:] = 0.0
                    job_seconds[j][:] = 0.0

        step = 0
        while active:
            t0 = time.perf_counter()
            toks = jnp.asarray(cur[:, None], jnp.int32)
            cache, nxt = self._decode(
                self.params, cache, toks, jnp.asarray(pos, jnp.int32))
            nxt = np.asarray(jax.device_get(nxt))
            dt = time.perf_counter() - t0 if step > 0 else 0.0
            step += 1
            for lane, r in list(active.items()):
                token = int(nxt[lane])
                if dt > 0.0:
                    lane_tokens[lane] += 1
                    lane_seconds[lane] += dt
                    if r.job not in job_tokens:
                        job_tokens[r.job] = np.zeros(ecfg.lanes)
                        job_seconds[r.job] = np.zeros(ecfg.lanes)
                    job_tokens[r.job][lane] += 1
                    job_seconds[r.job][lane] += dt
                r.output.append(token)
                pos[lane] += 1
                budget[lane] -= 1
                cur[lane] = token
                if token == ecfg.eos or budget[lane] <= 0 \
                        or pos[lane] >= ecfg.max_len - 1:
                    done.append(r)
                    del active[lane]
                    cache = admit(lane, cache)
            # Mid-run replan: periodically fold the live measurements into
            # the meter and re-plan the waiting queues if a lane's measured
            # speed drifted past the threshold — instead of only reacting
            # at the next run() boundary.
            if (ecfg.replan_on_drift and ecfg.adaptive
                    and step % max(ecfg.replan_check_every, 1) == 0):
                flush_meter()
                self.maybe_replan_waiting(queues)
        flush_meter()
        return done
