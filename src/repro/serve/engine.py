"""Continuous-batching serving engine with OS4M lane scheduling.

Requests are Reduce operations (load = prompt + remaining decode budget);
KV-cache lanes are slots. Admission solves the same Q||C_max the
scheduler core solves for Reduce tasks: lanes balanced *by finish time*
mean no lane idles while another still has a deep queue — and a lane on a
slow device (or with a configured handicap) is handed proportionally less
decode work. Lane speeds come from ``EngineConfig.lane_speeds`` (explicit
/ fault injection) or, with ``adaptive=True``, from the measured per-lane
decode throughput (EWMA over completed steps,
:class:`repro.core.slot_speeds.SlotSpeedEstimator`). Stragglers are
otherwise handled the OS4M way — a periodic *global* replan of the
waiting queue — not SkewTune-style migration of running work (migrating a
running lane would re-copy its KV cache, the 30-second-class cost the
paper's §7 argues against).

Mechanics: one shared cache pytree for all lanes with **per-lane write
positions** (vector ``cache_pos``), so lanes decode in lock-step while
being at different sequence depths — true continuous batching. Admission
prefills a lane and splices its rows into the shared cache.

Scope: attention-family caches (batch axis 1 by construction —
dense/moe/vlm/whisper). SSM/hybrid serving uses the state-based decode
directly (examples/).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import scheduler as sched_lib
from repro.core.slot_speeds import SlotSpeedEstimator, speed_drift
from repro.models.config import ModelConfig
from repro.models.model import forward, init_cache

__all__ = ["Request", "EngineConfig", "Engine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    output: Optional[List[int]] = None
    lane: int = -1

    @property
    def load(self) -> float:
        """Operation load: decode steps dominate lane occupancy."""
        return float(self.max_new + 0.1 * self.prompt.shape[0])


@dataclasses.dataclass
class EngineConfig:
    lanes: int = 8                # concurrent sequences (batch)
    max_len: int = 256            # lane KV capacity
    scheduler: str = "os4m"       # os4m | lpt | hash (eq. 3-1 baseline)
    eos: int = 2
    # Q||C_max lane admission: explicit relative lane speeds (fault
    # injection / known-heterogeneous devices), and/or adaptive weighting
    # by measured decode throughput. None + adaptive=False ≡ P||C_max.
    lane_speeds: Optional[Sequence[float]] = None
    adaptive: bool = False        # learn lane speeds from decode timings
    speed_ewma: float = 0.4       # EWMA weight of the newest measurement
    # Mid-run replanning (the OS4M answer to a lane slowing mid-serve):
    # with adaptive metering on, the decode loop periodically folds the
    # measured lane throughput into the meter and, when any lane's speed
    # moved more than max_speed_drift from the speeds the queues were
    # planned under, re-plans the WAITING queues globally — running
    # requests stay put (migrating a running lane would re-copy its KV
    # cache, the §7 cost the paper argues against).
    replan_on_drift: bool = False
    max_speed_drift: float = 0.25
    replan_check_every: int = 8   # decode steps between drift checks
    # Elastic mesh observer: called with one event dict per lane
    # join/leave/death ({"event": "lane_dead" | "lane_join", "lane": i,
    # "alive": k}) — the serve-side mirror of MapReduceJob.on_mesh_change.
    # The engine keeps the full log in ``Engine.mesh_events`` either way.
    on_mesh_change: Optional[Callable[[dict], None]] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 mesh=None):
        assert cfg.ssm is None and cfg.xlstm is None, \
            "state-based archs use the decode step directly"
        self.cfg, self.params, self.ecfg, self.mesh = cfg, params, ecfg, mesh
        self.last_balance_ratio = 1.0
        self.last_finish_ratio = 1.0
        # Configured lane speeds are validated AND normalised to mean 1
        # exactly once, here, and the normalised vector is what every
        # plan sees. (Speeds are relative — the schedulers only consume
        # ratios — and the metered path already arrives mean-1; returning
        # the raw configured vector would hand the schedulers a different
        # scale per source. A uniform [2, 2, 2, 2] now plans identically
        # to None, as it should.)
        self._lane_speeds: Optional[np.ndarray] = None
        if ecfg.lane_speeds is not None:
            v = sched_lib.normalize_speeds(ecfg.lane_speeds, ecfg.lanes)
            self._lane_speeds = v / v.mean()
        # Measured decode throughput per lane (tokens/second, EWMA). Only
        # consulted when ecfg.adaptive — on homogeneous hardware the
        # measurements are ≈ equal and admission matches P||C_max anyway.
        self.lane_meter = SlotSpeedEstimator(ecfg.lanes, ewma=ecfg.speed_ewma)
        # Mid-run replan state: the speeds the live queue plan was built
        # under, and telemetry for the drift-triggered replans.
        self._planned_speeds: Optional[np.ndarray] = None
        self.replans = 0
        self.last_replan_drift: Optional[float] = None
        # Elastic mesh: lanes whose device vanished. A configured lane
        # speed of exact 0.0 seeds the mask (launch/serve --slot-slowdown
        # i:0 in engine mode); ``set_lane_failure`` flips it at runtime.
        # Dead lanes admit nothing, plan to nothing (the Q||C_max
        # schedulers compact onto the alive set at speed 0), and are
        # masked out of the throughput meter so they never re-inherit
        # work from a stale measurement.
        self._dead_lanes = np.zeros(ecfg.lanes, dtype=bool)
        self.mesh_events: List[dict] = []
        if self._lane_speeds is not None and np.any(self._lane_speeds == 0.0):
            for lane in np.flatnonzero(self._lane_speeds == 0.0):
                self.set_lane_failure(int(lane))
        self._decode = jax.jit(self._decode_impl)

    # -- elastic mesh (lane accounting) -------------------------------------

    def set_lane_failure(self, lane: int, dead: bool = True) -> None:
        """Declare one lane dead (device vanished) or revived (join).

        Effective at the next plan: ``lane_speeds`` pins the lane to
        exact 0.0, so admission assigns it nothing, and the meter masks
        it out. With ``replan_on_drift`` the next drift check sees a
        dead-mask change — ``speed_drift`` reports ``inf`` on a mask
        mismatch — and re-plans the waiting queues off the lane
        immediately; running work is never migrated (§7). Emits a mesh
        event to ``EngineConfig.on_mesh_change`` / ``mesh_events``.
        """
        if not 0 <= lane < self.ecfg.lanes:
            raise ValueError(f"lane {lane} out of range [0, {self.ecfg.lanes})")
        if bool(self._dead_lanes[lane]) == bool(dead):
            return
        self._dead_lanes[lane] = dead
        if self._lane_speeds is not None:
            # Configured vectors get the overlay in-place: 0.0 while
            # dead; a revived lane rejoins at nominal speed.
            self._lane_speeds[lane] = 0.0 if dead else 1.0
        self.lane_meter.set_slot_failure(lane, dead=dead)
        event = {
            "event": "lane_dead" if dead else "lane_join",
            "lane": int(lane),
            "lanes": int(self.ecfg.lanes),
            "alive": int(self.ecfg.lanes - int(self._dead_lanes.sum())),
        }
        self.mesh_events.append(event)
        if self.ecfg.on_mesh_change is not None:
            self.ecfg.on_mesh_change(event)

    @property
    def dead_lanes(self) -> np.ndarray:
        """Boolean mask of vanished lanes (copy)."""
        return self._dead_lanes.copy()

    # -- Q||C_max lane assignment (the §4.2 schedule, speed-aware) ----------

    def lane_speeds(self) -> Optional[np.ndarray]:
        """Relative lane speeds admission plans under (None ≡ all nominal).

        Configured ``lane_speeds`` win (returned in their mean-1
        normalised form — normalisation happens once in ``__init__``);
        otherwise the measured decode throughput when ``adaptive`` and at
        least one run was metered. Dead lanes read exact 0.0 from every
        source — and force a concrete vector even when neither source is
        configured, so a plan can never hand work to a vanished lane.
        """
        if self._lane_speeds is not None:
            return self._lane_speeds
        if self.ecfg.adaptive:
            speeds = self.lane_meter.speeds()
        else:
            speeds = None
        if np.any(self._dead_lanes):
            if speeds is None:
                speeds = np.ones(self.ecfg.lanes, np.float64)
            return np.where(self._dead_lanes, 0.0, speeds)
        return speeds

    def plan(self, requests: List[Request]) -> Dict[int, List[Request]]:
        loads = np.asarray([r.load for r in requests])
        speeds = self.lane_speeds()
        self._planned_speeds = (np.ones(self.ecfg.lanes) if speeds is None
                                else np.asarray(speeds, np.float64))
        if self.ecfg.scheduler == "hash":
            sched = sched_lib.schedule_hash(
                loads, self.ecfg.lanes,
                keys=np.asarray([r.rid for r in requests]), speeds=speeds)
        elif self.ecfg.scheduler == "lpt":
            sched = sched_lib.schedule_lpt(loads, self.ecfg.lanes,
                                           speeds=speeds)
        else:
            sched = sched_lib.schedule_bss(loads, self.ecfg.lanes,
                                           speeds=speeds)
        by_lane: Dict[int, List[Request]] = {
            i: [] for i in range(self.ecfg.lanes)}
        for r, lane in zip(requests, sched.assignment):
            r.lane = int(lane)
            by_lane[int(lane)].append(r)
        for lane in by_lane:  # §4.4 order: increasing load first
            by_lane[lane].sort(key=lambda r: r.load)
        self.last_balance_ratio = sched.balance_ratio
        self.last_finish_ratio = sched.finish_ratio
        return by_lane

    def maybe_replan_waiting(self, queues: Dict[int, List[Request]]) -> bool:
        """Re-plan the waiting queues if measured lane speeds drifted.

        The OS4M straggler response applied mid-serve: compare the
        current measured lane speeds against the speeds the live plan was
        built under (:func:`repro.core.slot_speeds.speed_drift`); past
        ``max_speed_drift``, pool every request still WAITING and run a
        fresh global plan under the fresh speeds, mutating ``queues`` in
        place. Running requests are never migrated (their KV cache stays
        put). Returns True when a replan happened; telemetry in
        ``self.replans`` / ``self.last_replan_drift``.
        """
        fresh = self.lane_speeds()
        if fresh is None or self._planned_speeds is None:
            return False
        drift = speed_drift(self._planned_speeds, fresh)
        self.last_replan_drift = drift
        if drift <= self.ecfg.max_speed_drift:
            return False
        waiting = [r for q in queues.values() for r in q]
        if not waiting:
            return False
        replanned = self.plan(waiting)   # also re-anchors _planned_speeds
        for lane in queues:
            queues[lane] = replanned.get(lane, [])
        self.replans += 1
        return True

    # -- jitted steps --------------------------------------------------------

    def _decode_impl(self, params, cache, tokens, pos_vec):
        out = forward(params, self.cfg, tokens=tokens, mesh=self.mesh,
                      mode="decode", cache=cache, cache_pos=pos_vec)
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        return out.cache, nxt

    @staticmethod
    def _merge_lane(cache, new_cache, lane: int):
        """Splice one lane's rows (batch axis 1) from new_cache into cache."""
        return jax.tree.map(
            lambda old, new: old.at[:, lane].set(new[:, lane]),
            cache, new_cache)

    # -- serving -------------------------------------------------------------

    def run(self, requests: List[Request], extra_embed=None) -> List[Request]:
        ecfg = self.ecfg
        queues = self.plan(requests)
        cache = init_cache(self.cfg, ecfg.lanes, ecfg.max_len,
                           dtype=jnp.float32)
        pos = np.zeros(ecfg.lanes, dtype=np.int64)
        budget = np.zeros(ecfg.lanes, dtype=np.int64)
        cur = np.zeros(ecfg.lanes, dtype=np.int32)
        active: Dict[int, Request] = {}
        done: List[Request] = []

        def admit(lane: int, cache):
            """Prefill the lane's next request; returns the updated cache."""
            # Belt-and-braces: the planner already routes nothing to a
            # lane with speed 0.0, but a lane that died *after* planning
            # must neither prefill nor strand its queue — hand the
            # waiting requests to the shortest surviving queue.
            if self._dead_lanes[lane]:
                if queues[lane]:
                    alive = np.flatnonzero(~self._dead_lanes)
                    if alive.size == 0:
                        raise RuntimeError(
                            "all lanes dead with requests still queued")
                    dest = int(min(alive, key=lambda a: len(queues[a])))
                    queues[dest].extend(queues[lane])
                    queues[lane].clear()
                return cache
            if not queues[lane]:
                return cache
            r = queues[lane].pop(0)
            r.output = []
            p = r.prompt.shape[0]
            toks = jnp.broadcast_to(
                jnp.asarray(r.prompt[None, :], jnp.int32), (ecfg.lanes, p))
            out = forward(self.params, self.cfg, tokens=toks,
                          extra_embed=extra_embed, mesh=self.mesh,
                          mode="prefill", cache=cache, cache_pos=jnp.int32(0))
            cache = self._merge_lane(cache, out.cache, lane)
            first = int(jnp.argmax(out.logits[0, -1]))
            active[lane] = r
            pos[lane] = p
            budget[lane] = r.max_new - 1
            cur[lane] = first
            r.output.append(first)
            return cache

        for lane in range(ecfg.lanes):
            cache = admit(lane, cache)

        # Per-lane decode throughput metering: tokens produced and wall
        # time while the lane was active. Feeds the next plan's lane
        # speeds when ecfg.adaptive. Two caveats: the first decode step
        # carries jit compilation and is excluded (it would bill
        # seconds-scale compile time to whichever lanes happen to be
        # active); and on a single-device lock-step batch every active
        # lane shares one step clock, so measured rates only separate
        # lanes when decode actually runs per-device (real mesh) — on
        # this container the meter reads ≈uniform and admission matches
        # P||C_max, while `lane_speeds` injection stays the
        # deterministic way to model a slow lane.
        lane_tokens = np.zeros(ecfg.lanes)
        lane_seconds = np.zeros(ecfg.lanes)

        def flush_meter():
            """Fold the accumulated per-lane (tokens, seconds) into the meter."""
            if lane_tokens.any():
                self.lane_meter.update(lane_tokens, lane_seconds)
                lane_tokens[:] = 0.0
                lane_seconds[:] = 0.0

        step = 0
        while active:
            t0 = time.perf_counter()
            toks = jnp.asarray(cur[:, None], jnp.int32)
            cache, nxt = self._decode(
                self.params, cache, toks, jnp.asarray(pos, jnp.int32))
            nxt = np.asarray(jax.device_get(nxt))
            dt = time.perf_counter() - t0 if step > 0 else 0.0
            step += 1
            for lane, r in list(active.items()):
                token = int(nxt[lane])
                if dt > 0.0:
                    lane_tokens[lane] += 1
                    lane_seconds[lane] += dt
                r.output.append(token)
                pos[lane] += 1
                budget[lane] -= 1
                cur[lane] = token
                if token == ecfg.eos or budget[lane] <= 0 \
                        or pos[lane] >= ecfg.max_len - 1:
                    done.append(r)
                    del active[lane]
                    cache = admit(lane, cache)
            # Mid-run replan: periodically fold the live measurements into
            # the meter and re-plan the waiting queues if a lane's measured
            # speed drifted past the threshold — instead of only reacting
            # at the next run() boundary.
            if (ecfg.replan_on_drift and ecfg.adaptive
                    and step % max(ecfg.replan_check_every, 1) == 0):
                flush_meter()
                self.maybe_replan_waiting(queues)
        flush_meter()
        return done
