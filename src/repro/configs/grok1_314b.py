"""grok-1-314b [moe] — 8 experts top-2, GQA kv=8.

64L d_model=6144 48H (kv=8) d_ff=32768/expert vocab=131072  [hf:xai-org/grok-1]

DESIGN.md §Arch-applicability: 8 experts < 16-way model axis ⇒ the MoE runs
in the TP regime (expert hidden dim sliced over the model axis, dropless).
Per-shard load is inherently balanced there, so OS4M *placement* is
degenerate for this arch; the technique still governs the data-pipeline
packing and the serving lane scheduler.
"""

import dataclasses

from repro.models.config import ModelConfig
from repro.nn.moe import MoEArgs

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=32768,
    vocab=131072,
    norm="rmsnorm",
    act="gelu",
    gated_mlp=True,
    rope_kind="rope",
    rope_theta=10_000.0,
    moe=MoEArgs(num_experts=8, top_k=2, d_model=6144, d_ff=32768,
                act="gelu", gated=True),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="grok-1-314b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=96, vocab=512,
    moe=MoEArgs(num_experts=4, top_k=2, d_model=64, d_ff=96,
                act="gelu", gated=True, capacity_factor=4.0),
    param_dtype="float32", compute_dtype="float32",
)
