"""starcoder2-3b [dense] — GQA kv=2, RoPE, layernorm+bias, non-gated GELU MLP.

30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152  [arXiv:2402.19173; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv=2,
    d_ff=12288,
    vocab=49152,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    rope_kind="rope",
    rope_theta=999999.4,  # published rope_theta
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="starcoder2-3b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=512,
    param_dtype="float32", compute_dtype="float32",
)
