"""xlstm-1.3b [ssm] — mLSTM + sLSTM blocks at 7:1 ratio; attention-free,
O(1)-state decode (runs long_500k).

48L d_model=2048 4H vocab=50304  [arXiv:2405.04517]

DESIGN.md §Arch-applicability: the paper's Reduce-operation scheduling has
no in-step analogue here (no routed/keyed units inside a layer); OS4M
applies via the data-pipeline packing only.
"""

import dataclasses

from repro.models.config import ModelConfig
from repro.nn.xlstm import XLSTMArgs

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv=4,
    d_ff=0,              # mLSTM blocks have no separate FFN
    vocab=50304,
    norm="rmsnorm",
    rope_kind="none",
    slstm_every=8,       # 7 mLSTM : 1 sLSTM
    # chunk=512: the 4-head × 1024² matrix memory makes the chunk-carry
    # stack the footprint driver; fewer, bigger chunks cut it 4×
    # (EXPERIMENTS.md §Dry-run).
    xlstm=XLSTMArgs(d_model=2048, n_heads=4, expand=2, chunk=512),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="xlstm-1.3b-smoke",
    n_layers=4, d_model=64, n_heads=2, slstm_every=2,
    vocab=512,
    xlstm=XLSTMArgs(d_model=64, n_heads=2, expand=2, chunk=16),
    param_dtype="float32", compute_dtype="float32",
)
