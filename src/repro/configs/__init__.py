"""Architecture registry: one module per assigned arch, exact dims from the
assignment block. Each module exports CONFIG (full) and SMOKE (reduced twin
of the same family for CPU tests)."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "starcoder2_3b",
    "qwen1_5_32b",
    "llama3_8b",
    "smollm_360m",
    "whisper_base",
    "qwen2_vl_7b",
    "xlstm_1_3b",
    "grok1_314b",
    "deepseek_v2_236b",
    "zamba2_2_7b",
]

# CLI names with dashes/dots map onto module ids.
ALIASES: Dict[str, str] = {
    "starcoder2-3b": "starcoder2_3b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llama3-8b": "llama3_8b",
    "smollm-360m": "smollm_360m",
    "whisper-base": "whisper_base",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "xlstm-1.3b": "xlstm_1_3b",
    "grok-1-314b": "grok1_314b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "zamba2-2.7b": "zamba2_2_7b",
}


def _module(name: str):
    mid = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if mid not in ARCH_IDS:
        raise ValueError(f"unknown arch {name!r}; options: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mid}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
