"""whisper-base [audio] — encoder-decoder backbone; conv frontend is a STUB
(input_specs() provides precomputed (B, 1500, 512) frame embeddings).

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865  [arXiv:2212.04356]

Decode shapes apply (enc-dec, not encoder-only): the decoder runs with its
self-KV cache plus the fixed 1500-frame cross-attention cache.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=51865,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    rope_kind="none",
    abs_pos=True,
    enc_dec=True,
    n_enc_layers=6,
    enc_len=1500,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="whisper-base-smoke",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
    vocab=512, enc_len=16,
    param_dtype="float32", compute_dtype="float32",
)
