"""smollm-360m [dense] — llama-arch small; also the base of the ~100M
end-to-end training example (examples/train_lm.py shrinks it further).

32L d_model=960 15H (kv=5) d_ff=2560 vocab=49152  [hf:HuggingFaceTB/SmolLM]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv=5,
    d_ff=2560,
    vocab=49152,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_kind="rope",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="smollm-360m-smoke",
    n_layers=2, d_model=60, n_heads=3, n_kv=1, d_ff=160, vocab=512,
    param_dtype="float32", compute_dtype="float32",
)
