"""zamba2-2.7b [hybrid] — Mamba2 backbone with a SHARED attention+MLP block
applied every 6 SSM layers (one set of weights, 9 applications). Runs
long_500k (SSM state + bounded shared-attn KV).

54L d_model=2560 (d_inner=5120, 80 heads × 64, state=64); shared block:
32H kv=32, d_ff=10240, vocab=32000  [arXiv:2411.15242; hf]
"""

import dataclasses

from repro.models.config import ModelConfig
from repro.nn.ssm import SSMArgs

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    d_ff=10240,
    vocab=32000,
    norm="rmsnorm",
    act="gelu",
    gated_mlp=True,
    rope_kind="rope",
    rope_theta=10_000.0,
    attn_every=6,
    ssm=SSMArgs(d_model=2560, d_inner=5120, head_dim=64, d_state=64,
                n_groups=1, conv_kernel=4, chunk=128),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="zamba2-2.7b-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=512,
    attn_every=2,
    ssm=SSMArgs(d_model=64, d_inner=128, head_dim=32, d_state=16,
                n_groups=1, conv_kernel=4, chunk=16),
    param_dtype="float32", compute_dtype="float32",
)
