"""llama3-8b [dense] — GQA kv=8, 128k vocab.

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=128256  [arXiv:2407.21783]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=128256,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_kind="rope",
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="llama3-8b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=512,
    param_dtype="float32", compute_dtype="float32",
)
