"""qwen2-vl-7b [vlm] — M-RoPE (sections 16/24/24), GQA kv=4; the vision
frontend is a STUB (input_specs() provides (B, 256, d) patch embeddings
prepended to the text stream — dynamic resolution reduced to a fixed grid).

28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064  [arXiv:2409.12191; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv=4,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_kind="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    n_patches=256,
    patch_grid=16,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen2-vl-7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=512,
    mrope_sections=(4, 2, 2), n_patches=4, patch_grid=2,
    param_dtype="float32", compute_dtype="float32",
)
