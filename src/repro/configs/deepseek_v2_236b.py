"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 160 routed experts top-6
with 2 shared experts; first layer dense. The PRIMARY OS4M application:
160 experts over a 16-way model axis = 10 operation clusters per slot,
a real P||C_max instance solved by the BSS balancer every rebalance
interval (repro.core.balancer).

60L d_model=5120 128H d_ff=1536/expert vocab=102400  [arXiv:2405.04434; hf]
"""

import dataclasses

from repro.models.config import ModelConfig, MLAArgs
from repro.nn.moe import MoEArgs

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv=128,
    d_ff=1536,
    vocab=102400,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_kind="rope",
    rope_theta=10_000.0,
    mla=MLAArgs(kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoEArgs(num_experts=160, top_k=6, d_model=5120, d_ff=1536,
                shared_experts=2),
    first_k_dense=1,
    first_dense_ff=12288,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="deepseek-v2-236b-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=48, vocab=512,
    mla=MLAArgs(kv_lora=16, q_lora=24, qk_nope=8, qk_rope=4, v_dim=8),
    moe=MoEArgs(num_experts=8, top_k=2, d_model=64, d_ff=48,
                shared_experts=1, capacity_factor=4.0),
    first_k_dense=1, first_dense_ff=128,
    param_dtype="float32", compute_dtype="float32",
)
