"""qwen1.5-32b [dense] — MHA (kv=40), QKV bias, gated SiLU.

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064  [hf:Qwen/Qwen1.5; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_kind="rope",
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen1.5-32b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=160, vocab=512,
    param_dtype="float32", compute_dtype="float32",
)
