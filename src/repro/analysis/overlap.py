"""Overlap certifier: the §4.4 copy/run contract + honest wave stamps.

Two rules over each traced phase-B graph:

**a2a-depends-on-a2a** — the pipelined engine's whole speedup is that
the all-to-all "copy" of chunk ``c+1`` is in flight while the "run" of
chunk ``c`` computes. That overlap exists iff XLA is *free* to schedule
them concurrently, i.e. iff no all-to-all equation transitively consumes
another all-to-all's output: every reduce of chunk ``c`` depends on
chunk ``c``'s all-to-all, so a ``reduce(c) → copy(c+1)`` edge would show
up as exactly such a path. (This also covers the coded wire: the packet
multicast is built from the sender's *own* spill, never from the replica
exchange's output.) On violation the finding's evidence is the offending
dependency chain, one equation per line.

**stamp-unanchored / stamp-pass-through-dropped** — a wave-timer stamp is
only honest if true buffer dependencies pin it on both sides (PR 5's
lesson: ``optimization_barrier`` and value-anchored pure callbacks do
not constrain XLA:CPU's latest-possible scheduler). Statically: every
stamp callback must (a) have an all-to-all among its ancestors — it
cannot fire before its wave's data exists — and (b) have its
*pass-through* output (output slot 0) on a path to the program's primary
outputs — the scheduler cannot defer it past the compute it precedes,
and the engine actually consumed the passed buffer rather than the
original (the "dropped stamp dependency" mutation).
"""

from __future__ import annotations

from typing import List, Sequence

from repro.analysis.jaxpr_graph import EqnGraph
from repro.analysis.report import Finding

_STAMP_PRIMS = ("io_callback", "pure_callback")


def check_overlap(targets: Sequence) -> List[Finding]:
    """Run both overlap rules over every traced target."""
    findings: List[Finding] = []
    for t in targets:
        findings.extend(_check_a2a_independence(t.name, t.graph))
        if t.timed:
            findings.extend(_check_stamps(t.name, t.graph))
    return findings


def _check_a2a_independence(name: str, g: EqnGraph) -> List[Finding]:
    findings: List[Finding] = []
    a2a_ids = [n.id for n in g.by_prim("all_to_all")]
    a2a_set = set(a2a_ids)
    for src in a2a_ids:
        hit = g.reachable_from([src]) & a2a_set
        if not hit:
            continue
        dst = min(hit)
        chain = g.find_path(src, dst)
        findings.append(Finding(
            checker="overlap",
            rule="a2a-depends-on-a2a",
            target=name,
            summary=(
                "an all_to_all transitively consumes another all_to_all's "
                "output — the next chunk's copy is serialized behind this "
                "chunk's pipeline (§4.4 overlap broken)"),
            evidence=g.describe_path(chain),
        ))
    return findings


def _check_stamps(name: str, g: EqnGraph) -> List[Finding]:
    findings: List[Finding] = []
    a2a_ids = {n.id for n in g.by_prim("all_to_all")}
    # Primary outputs = the reduce values + counts (slots 0 and 1); the
    # ticks output must NOT be what keeps a stamp alive.
    primary = g.output_producer_ids([0, 1])
    for s in (n for n in g.nodes if n.prim in _STAMP_PRIMS):
        ancestors = g.ancestors_of(s.id)
        if not (ancestors & a2a_ids):
            findings.append(Finding(
                checker="overlap",
                rule="stamp-unanchored",
                target=name,
                summary=(
                    "a wave-timer stamp has no all_to_all among its "
                    "ancestors — it can fire before its wave's data "
                    "exists"),
                evidence=[s.describe(),
                          "ancestor set contains no all_to_all equation"],
            ))
        # Pass-through pinning: output slot 0 (the passed primary buffer)
        # must feed the downstream compute — directly a primary output,
        # or on a path to one of its producers.
        direct = any(out is not None and out[0] == s.id and out[1] == 0
                     for out in (g.outputs[i] for i in (0, 1)
                                 if i < len(g.outputs)))
        consumers = g.consumers_of_output(s.id, 0)
        reach = set(consumers) | g.reachable_from(list(consumers))
        if not direct and not (reach & primary):
            findings.append(Finding(
                checker="overlap",
                rule="stamp-pass-through-dropped",
                target=name,
                summary=(
                    "a wave-timer stamp's pass-through output never "
                    "reaches the primary outputs — downstream compute "
                    "consumed the original buffer, so the scheduler may "
                    "defer the stamp past the wave it should precede"),
                evidence=[s.describe(),
                          f"pass-through consumers: "
                          f"{[g.nodes[c].describe() for c in consumers] or 'none'}",
                          "none of them reach output 0/1 producers"],
            ))
    return findings
