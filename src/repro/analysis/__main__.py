"""CLI: ``python -m repro.analysis --check all [--self-test]``.

Exit code is the checker bitmask from :mod:`repro.analysis.report`
(overlap 1, determinism 2, plan 4, conventions 8; a mutation self-test
failure adds 16) — a red CI run names the failing layer from the status
alone.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Optional, Sequence

from repro.analysis.report import CHECKERS, Report, SELF_TEST_BIT


def run(check: str = "all", self_test: bool = False,
        out=None) -> int:
    """Run the selected checker(s) on the repo's real targets.

    Returns the bitmask exit code; prints the human report to ``out``
    (current ``sys.stdout`` when None — resolved per call, not at import).
    """
    from repro.analysis import conventions, determinism, overlap, plan_checks
    from repro.analysis import targets as tgt

    if out is None:
        out = sys.stdout

    if check != "all" and check not in CHECKERS:
        raise ValueError(f"unknown checker {check!r}; use one of "
                         f"{('all',) + CHECKERS}")
    selected = CHECKERS if check == "all" else (check,)
    t0 = time.perf_counter()
    report = Report()

    traced = None
    if "overlap" in selected or "determinism" in selected:
        traced = tgt.phase_b_targets()
        print(f"traced {len(traced)} phase-B variants: "
              f"{', '.join(t.name for t in traced)}", file=out)
    if "overlap" in selected:
        report.extend("overlap", overlap.check_overlap(traced))
    if "determinism" in selected:
        report.extend("determinism", determinism.check_determinism(traced))
    if "plan" in selected:
        plans = tgt.plan_targets()
        print(f"validated {len(plans)} planner snapshots: "
              f"{', '.join(name for name, _ in plans)}", file=out)
        report.extend("plan", plan_checks.check_plans(plans))
    if "conventions" in selected:
        root = conventions.default_root()
        report.extend("conventions", conventions.lint_tree(root))
        print(f"linted package tree at {root}", file=out)

    code = report.exit_code()

    if self_test:
        from repro.analysis import mutations

        results = mutations.run_self_tests(
            progress=lambda line: print(f"self-test {line}", file=out))
        if not mutations.self_tests_ok(results):
            code |= SELF_TEST_BIT

    print(report.render(), file=out)
    print(f"exit code {code} ({time.perf_counter() - t0:.1f}s)", file=out)
    return code


def main(argv: Optional[Sequence[str]] = None) -> None:
    """Argparse entry point (see module docstring for the exit contract)."""
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Statically certify overlap, determinism, and plan "
                    "invariants of the OS4M engine before anything runs.")
    parser.add_argument("--check", default="all",
                        choices=("all",) + CHECKERS,
                        help="which checker to run (default: all)")
    parser.add_argument("--self-test", action="store_true",
                        help="also run the mutation self-tests (each "
                             "seeded violation must be caught)")
    ns = parser.parse_args(argv)
    sys.exit(run(check=ns.check, self_test=ns.self_test))


if __name__ == "__main__":
    main()
