"""Findings, reports, and the per-checker exit-code contract.

Every checker returns a list of :class:`Finding`; the CLI merges them
into a :class:`Report` whose exit code is a *bitmask* with one bit per
checker, so a red run names its checker(s) from the status alone::

    overlap      -> 1
    determinism  -> 2
    plan         -> 4
    conventions  -> 8
    (self-test failure adds 16)

A finding always carries non-empty ``evidence`` — for jaxpr checkers the
offending dependency chain rendered one equation per line, for plan
checkers the violated invariant with the concrete values, for the AST
lint the file:line source excerpt. "It failed" without a path is a bug
in the checker, and the mutation self-tests assert exactly that.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

CHECKERS = ("overlap", "determinism", "plan", "conventions")

# Exit-code bit per checker (CLI contract, see module docstring).
CHECKER_BITS: Dict[str, int] = {
    "overlap": 1,
    "determinism": 2,
    "plan": 4,
    "conventions": 8,
}
SELF_TEST_BIT = 16


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation: which checker, which rule, where, and why."""

    checker: str          # one of CHECKERS
    rule: str             # short rule id, e.g. "a2a-depends-on-a2a"
    target: str           # traced program / plan / file the rule ran on
    summary: str          # one-line human statement of the violation
    evidence: Sequence[str] = ()   # readable path/excerpt, one step per line

    def __post_init__(self):
        if self.checker not in CHECKER_BITS:
            raise ValueError(f"unknown checker {self.checker!r}")

    def render(self) -> str:
        """Multi-line human form: header + indented evidence chain."""
        head = f"[{self.checker}:{self.rule}] {self.target}: {self.summary}"
        if not self.evidence:
            return head
        return head + "\n" + "\n".join(f"    {line}" for line in self.evidence)


@dataclasses.dataclass
class Report:
    """All findings of one analyzer run + which checkers actually ran."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    checkers_run: List[str] = dataclasses.field(default_factory=list)

    def extend(self, checker: str, findings: Sequence[Finding]) -> None:
        """Record one checker's results (registers it as run)."""
        if checker not in self.checkers_run:
            self.checkers_run.append(checker)
        self.findings.extend(findings)

    @property
    def ok(self) -> bool:
        """True when no checker that ran produced a finding."""
        return not self.findings

    def exit_code(self) -> int:
        """OR of the failing checkers' bits (0 = everything passed)."""
        code = 0
        for f in self.findings:
            code |= CHECKER_BITS[f.checker]
        return code

    def render(self) -> str:
        """The full human report: per-checker verdicts, then findings."""
        lines = []
        failed = {f.checker for f in self.findings}
        for c in self.checkers_run:
            lines.append(f"{c:12s} {'FAIL' if c in failed else 'ok'}")
        for f in self.findings:
            lines.append(f.render())
        return "\n".join(lines)
