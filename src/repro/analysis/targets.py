"""Real analyzer targets: traced phase-B programs and host plan objects.

The analyzer never checks toy stand-ins — these builders trace the
engine's *actual* per-shard phase-B bodies (`repro.core.mapreduce.
_phase_b_shard` and friends) in every execution variant the repo ships:

* sequential (Hadoop-style single-shot) and pipelined (§4.4 chunk walk);
* the Pallas fused-kernel path (``use_kernels=True``);
* the coded r=2 XOR-multicast wire, plain and int8-quantized;
* the int8-quantized uncoded wire;
* the measured path (wave-timer stamps threaded through the same body,
  callback backend) in both sequential and pipelined form;
* the fenced per-wave copy/run programs the measured-fallback and
  checkpointed executors dispatch (module-level bodies in
  ``core.mapreduce``, traced verbatim);
* a whole shard_map-wrapped phase B when the host exposes enough
  devices (the exact program the shard_map backend jits — the vmap
  backend maps the identical per-shard body, which the other targets
  trace directly).

Tracing uses :func:`repro.analysis.jaxpr_graph.trace_sharded` — the
named-axis environment keeps ``all_to_all``/``psum`` first-class, so the
dependency structure the checkers certify is the one XLA schedules.

Plan targets come from the same host planner the job runs
(:meth:`MapReduceJob._plan`) on synthetic-but-realistic statistics,
including a straggler (Q||C_max) plan, a dead-slot plan, a coded r=2
plan, and the sketch-statistics plans (pure count-min and the
streaming-prefix two-step via :meth:`MapReduceJob._plan_prefixed`) whose
snapshots exercise the analyzer's overestimate-aware capacity rules.
A phase-A sketch target traces the provider collection step
(``_phase_a_shard`` with ``SketchStats.collect``) — it carries no
collectives, callbacks, or wire sorts, and the checkers certify that.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import compat
from repro.analysis import jaxpr_graph as jg
from repro.core import mapreduce as mr
from repro.core import schedule_cache as sc

# One small-but-structured geometry shared by every traced variant:
# m slots, n operation clusters, k pairs per shard, v-dim values,
# C pipeline chunks with per-chunk send caps.
M, N_CLUSTERS, K_PAIRS, V_DIM, CHUNKS = 4, 8, 32, 3, 4
CHUNK_CAPS: Tuple[int, ...] = (16, 16, 16, 16)
CAPACITY = 32


@dataclasses.dataclass
class TracedTarget:
    """One traced phase-B program + the flags the checkers dispatch on."""

    name: str
    graph: jg.EqnGraph
    timed: bool = False
    coded: bool = False
    pipelined: bool = False


def _shard_args():
    """ShapeDtypeStruct arguments of one per-shard phase-B call."""
    inter = (
        jax.ShapeDtypeStruct((K_PAIRS,), jnp.int32),
        jax.ShapeDtypeStruct((K_PAIRS, V_DIM), jnp.float32),
        jax.ShapeDtypeStruct((K_PAIRS,), jnp.bool_),
    )
    vec = jax.ShapeDtypeStruct((N_CLUSTERS,), jnp.int32)
    return inter, vec, vec, vec


def _static(pipelined: bool, use_kernel: bool = False, replication: int = 1,
            quantize: Optional[str] = None) -> Tuple:
    """The engine's ``cfg_static`` tuple for one variant."""
    chunks = CHUNKS if pipelined else 1
    caps = CHUNK_CAPS if pipelined else (CAPACITY,)
    return (M, N_CLUSTERS, CAPACITY, caps, "sum", pipelined, chunks,
            use_kernel, replication, quantize)


def _trace_phase_b(static, timed: bool) -> jg.EqnGraph:
    args = _shard_args()

    if timed:
        from repro.kernels.wave_timer import ops as wt_ops

        def body(inter, a, r, c):
            return mr._phase_b_shard_timed(inter, a, r, c, static)

        # Pin the CPU callback backend so the traced stamps are the
        # io_callback path the analyzer's stamp rules certify.
        with wt_ops.force_backend("callback"):
            closed = jg.trace_sharded(body, args, mr.AXIS, M)
    else:
        def body(inter, a, r, c):
            return mr._phase_b_shard(inter, a, r, c, static)

        closed = jg.trace_sharded(body, args, mr.AXIS, M)
    return jg.EqnGraph(closed)


def _trace_fenced_wave() -> List[TracedTarget]:
    """The checkpointed/measured-fallback per-wave copy + run programs."""
    total = M * sum(CHUNK_CAPS)
    fv = jax.ShapeDtypeStruct((total, V_DIM), jnp.float32)
    fc = jax.ShapeDtypeStruct((total,), jnp.int32)
    fm = jax.ShapeDtypeStruct((total,), jnp.bool_)
    cap = CHUNK_CAPS[1]
    off = M * CHUNK_CAPS[0]

    def copy_body(fv, fc, fm):
        return mr._fenced_wave_copy(fv, fc, fm, off, cap, M, V_DIM)

    rv = jax.ShapeDtypeStruct((M * cap, V_DIM), jnp.float32)
    rc = jax.ShapeDtypeStruct((M * cap,), jnp.int32)
    rm = jax.ShapeDtypeStruct((M * cap,), jnp.bool_)
    rank = jax.ShapeDtypeStruct((N_CLUSTERS,), jnp.int32)

    def run_body(rv, rc, rm, rank):
        return mr._fenced_wave_run(rv, rc, rm, rank, N_CLUSTERS, "sum", False)

    copy_g = jg.EqnGraph(jg.trace_sharded(copy_body, (fv, fc, fm), mr.AXIS, M))
    run_g = jg.EqnGraph(jg.trace_sharded(run_body, (rv, rc, rm, rank),
                                         mr.AXIS, M))
    return [
        TracedTarget("checkpointed-wave-copy", copy_g, pipelined=True),
        TracedTarget("checkpointed-wave-run", run_g, pipelined=True),
    ]


def _trace_shard_map() -> Optional[TracedTarget]:
    """Whole shard_map-wrapped phase B (needs >= M devices on the host)."""
    if len(jax.devices()) < M:
        return None
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:M]), (mr.AXIS,))
    static = _static(pipelined=True)

    def body(inter, a, r, c):
        return mr._phase_b_shard(inter, a, r, c, static)

    sharded = compat.shard_map(
        body, mesh=mesh,
        in_specs=((P(mr.AXIS), P(mr.AXIS), P(mr.AXIS)), P(), P(), P()),
        out_specs=(P(mr.AXIS), P(mr.AXIS), P(mr.AXIS), P(mr.AXIS)),
    )
    inter = (
        jax.ShapeDtypeStruct((M * K_PAIRS,), jnp.int32),
        jax.ShapeDtypeStruct((M * K_PAIRS, V_DIM), jnp.float32),
        jax.ShapeDtypeStruct((M * K_PAIRS,), jnp.bool_),
    )
    vec = jax.ShapeDtypeStruct((N_CLUSTERS,), jnp.int32)
    closed = jax.make_jaxpr(sharded)(inter, vec, vec, vec)
    return TracedTarget("shard_map-pipelined", jg.EqnGraph(closed),
                        pipelined=True)


def _trace_phase_a_sketch() -> TracedTarget:
    """Phase A with the sketch provider: map + count-min collection.

    The traced program is the real ``_phase_a_shard`` body under
    ``SketchStats.collect`` (jnp fallback — the kernel path is certified
    by its own ref-oracle test). No all_to_all, no callbacks, no wire
    sorts: the overlap and determinism checkers verify that emptiness.
    """
    from repro.core import stats_provider as sp

    provider = sp.SketchStats(N_CLUSTERS, width=64, depth=3)

    def body(shard_input):
        return mr._phase_a_shard(
            shard_input, map_fn=lambda s: s, num_clusters=N_CLUSTERS,
            stats_fn=provider.collect)

    args = ((
        jax.ShapeDtypeStruct((K_PAIRS,), jnp.int32),
        jax.ShapeDtypeStruct((K_PAIRS, V_DIM), jnp.float32),
        jax.ShapeDtypeStruct((K_PAIRS,), jnp.bool_),
    ),)
    closed = jg.trace_sharded(body, args, mr.AXIS, M)
    return TracedTarget("phase-a-sketch", jg.EqnGraph(closed))


def phase_b_targets() -> List[TracedTarget]:
    """Every real phase-B variant, traced and graphed."""
    targets = [
        TracedTarget("sequential",
                     _trace_phase_b(_static(False), timed=False)),
        TracedTarget("pipelined",
                     _trace_phase_b(_static(True), timed=False),
                     pipelined=True),
        TracedTarget("pipelined-kernels",
                     _trace_phase_b(_static(True, use_kernel=True),
                                    timed=False),
                     pipelined=True),
        TracedTarget("pipelined-int8",
                     _trace_phase_b(_static(True, quantize="int8"),
                                    timed=False),
                     pipelined=True),
        TracedTarget("coded-r2",
                     _trace_phase_b(_static(True, replication=2),
                                    timed=False),
                     coded=True, pipelined=True),
        TracedTarget("coded-r2-int8",
                     _trace_phase_b(_static(True, replication=2,
                                            quantize="int8"), timed=False),
                     coded=True, pipelined=True),
        TracedTarget("timed-sequential",
                     _trace_phase_b(_static(False), timed=True), timed=True),
        TracedTarget("timed-pipelined",
                     _trace_phase_b(_static(True), timed=True),
                     timed=True, pipelined=True),
    ]
    targets.extend(_trace_fenced_wave())
    targets.append(_trace_phase_a_sketch())
    sm = _trace_shard_map()
    if sm is not None:
        targets.append(sm)
    return targets


# ---------------------------------------------------------------------------
# Plan targets (host objects, produced by the job's real planner).
# ---------------------------------------------------------------------------


def _plan_for(cfg: mr.MapReduceConfig, seed: int) -> sc.CachedSchedule:
    job = mr.MapReduceJob(lambda s: s, cfg)
    rng = np.random.default_rng(seed)
    hist = rng.integers(1, 64, size=(cfg.num_slots, cfg.num_clusters))
    hist = hist.astype(np.float64)
    k_per_shard = int(np.ceil(hist.sum(axis=1).max()))
    if cfg.stats == "sketch":
        # The planner consumes provider state — sketch the synthetic
        # histogram (count-min is linear, so from_dense == collect).
        state = job._stats.from_dense(hist)
        if cfg.stream_prefix is not None:
            # Prefix state: a thinner sample of the same distribution,
            # as the first stream_prefix of pairs would produce.
            noise = rng.uniform(0.5, 1.5, size=hist.shape)
            prefix = np.floor(hist * cfg.stream_prefix * noise)
            return job._plan_prefixed(
                state, job._stats.from_dense(prefix), k_per_shard)
        return job._plan(state, None, k_per_shard)
    return job._plan(hist, hist.sum(axis=0), k_per_shard)


def plan_targets() -> List[Tuple[str, sc.CachedSchedule]]:
    """Real planner outputs across scheduler / speed / coding variants."""
    out: List[Tuple[str, sc.CachedSchedule]] = []
    out.append(("lpt-uniform", _plan_for(
        mr.MapReduceConfig(num_slots=4, num_clusters=16, scheduler="lpt"),
        seed=0)))
    out.append(("os4m-pipelined", _plan_for(
        mr.MapReduceConfig(num_slots=4, num_clusters=12, scheduler="os4m",
                           pipeline_chunks=3), seed=1)))
    out.append(("lpt-straggler", _plan_for(
        mr.MapReduceConfig(num_slots=4, num_clusters=16, scheduler="lpt",
                           speeds=(1.0, 0.5, 1.0, 2.0)), seed=2)))
    out.append(("lpt-dead-slot", _plan_for(
        mr.MapReduceConfig(num_slots=4, num_clusters=16, scheduler="lpt",
                           speeds=(1.0, 1.0, 0.0, 1.0)), seed=3)))
    out.append(("coded-r2", _plan_for(
        mr.MapReduceConfig(num_slots=4, num_clusters=16, scheduler="lpt",
                           shuffle_replication=2), seed=4)))
    out.append(("sketch-os4m", _plan_for(
        mr.MapReduceConfig(num_slots=4, num_clusters=16, scheduler="os4m",
                           stats="sketch", sketch_width=64, sketch_depth=3),
        seed=5)))
    out.append(("sketch-lpt", _plan_for(
        mr.MapReduceConfig(num_slots=4, num_clusters=16, scheduler="lpt",
                           stats="sketch", sketch_width=32, sketch_depth=4),
        seed=6)))
    out.append(("sketch-prefix", _plan_for(
        mr.MapReduceConfig(num_slots=4, num_clusters=16, scheduler="lpt",
                           stats="sketch", sketch_width=64, sketch_depth=3,
                           stream_prefix=0.25), seed=7)))
    return out
