"""Jaxpr tracing + a flattened equation-level dependency DAG.

The contract checkers reason about *traced programs*, not running ones:
:func:`trace_sharded` traces a per-shard phase-B body under the engine's
named axis (``jax.make_jaxpr`` inside ``extend_axis_env_nd`` — the
collectives ``all_to_all`` / ``psum`` / ``axis_index`` stay first-class
equations instead of being rewritten by a transform), and
:class:`EqnGraph` turns the result into one flat producer→consumer DAG.

Flattening matters: ``jnp.argsort`` and friends lower into ``pjit``
*sub-jaxprs*, so a top-level walk never sees a ``sort`` equation. The
graph builder therefore **inlines** call-like equations (``pjit``,
``closed_call``, ``custom_jvp_call``/``custom_vjp_call``, ``remat``,
``shard_map``), threading producers through the call boundary, and keeps
everything else (``pallas_call``, control flow) as one opaque node whose
outputs depend on all of its inputs — conservative in exactly the safe
direction for dependence questions.

Edges are recorded per *output slot* (``(producer id, out index)``), so a
checker can ask "who consumes output 0 of this equation" — the question
the wave-timer pass-through check needs — not just "who depends on it".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
from jax import core as jcore

# Call-like primitives whose sub-jaxpr is semantically inline code.
_INLINE_PRIMS = {
    "pjit", "closed_call", "core_call", "remat", "remat2", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_jvp_call_jaxpr",
    "custom_vjp_call_jaxpr", "shard_map",
}


def trace_sharded(fn, args, axis_name: str, axis_size: int):
    """``jax.make_jaxpr`` of a per-shard body that uses a named mesh axis.

    Binds ``axis_name`` with ``axis_size`` in the trace-time axis
    environment, so a body containing ``all_to_all`` / ``psum`` /
    ``axis_index`` over the engine mesh axis traces *as written* — the
    same program every shard runs under ``vmap(axis_name=...)`` or
    ``shard_map`` — without standing up devices or letting a transform's
    batching rule rewrite the collectives.
    """
    with jcore.extend_axis_env_nd([(axis_name, axis_size)]):
        return jax.make_jaxpr(fn)(*args)


def _sub_jaxpr(params) -> Optional[jcore.Jaxpr]:
    """The single inline sub-jaxpr of a call-like eqn (None when absent)."""
    for key in ("jaxpr", "call_jaxpr"):
        v = params.get(key)
        if isinstance(v, jcore.ClosedJaxpr):
            return v.jaxpr
        if isinstance(v, jcore.Jaxpr):
            return v
    return None


def iter_eqns_recursive(jaxpr: jcore.Jaxpr, path: Tuple[str, ...] = ()):
    """Yield ``(eqn, path)`` for every equation at any nesting depth.

    Unlike the graph (which inlines only call-like prims), this walks
    *every* sub-jaxpr it can find in the params — including control-flow
    branches and scan bodies — so scans for forbidden primitives
    (unstable sorts, rogue callbacks) cannot be hidden by nesting.
    """
    for eqn in jaxpr.eqns:
        yield eqn, path
        for v in eqn.params.values():
            for sub in _jaxprs_in(v):
                name = eqn.params.get("name", eqn.primitive.name)
                yield from iter_eqns_recursive(sub, path + (str(name),))


def _jaxprs_in(value):
    """All jaxprs contained in one params value (handles tuples/lists)."""
    if isinstance(value, jcore.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jcore.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _jaxprs_in(v)


@dataclasses.dataclass
class Node:
    """One opaque equation in the flattened DAG."""

    id: int
    prim: str
    eqn: jcore.JaxprEqn
    path: Tuple[str, ...]                       # enclosing inlined calls
    preds: Set[Tuple[int, int]] = dataclasses.field(default_factory=set)

    def describe(self) -> str:
        """One readable line: id, primitive, context, salient params."""
        bits = []
        p = self.eqn.params
        if self.prim == "all_to_all":
            bits.append(f"axis={p.get('axis_name')}")
        if self.prim == "sort":
            bits.append(f"is_stable={p.get('is_stable')}")
        if self.prim in ("io_callback", "pure_callback"):
            bits.append(f"callback={resolve_callback(p.get('callback'))}")
        where = "/".join(self.path) if self.path else "top"
        extra = f" {' '.join(bits)}" if bits else ""
        return f"#{self.id} {self.prim}{extra} (in {where})"


class EqnGraph:
    """Flattened producer→consumer DAG over one traced program."""

    def __init__(self, closed: jcore.ClosedJaxpr):
        self.nodes: List[Node] = []
        # succ[(producer id, out idx)] -> consumer node ids
        self._succ_by_out: Dict[Tuple[int, int], Set[int]] = {}
        self._succ: Dict[int, Set[int]] = {}
        jaxpr = closed.jaxpr
        env: Dict[jcore.Var, Optional[Tuple[int, int]]] = {}
        for v in list(jaxpr.invars) + list(jaxpr.constvars):
            env[v] = None                       # graph sources
        out_env = self._build(jaxpr, env, path=())
        # Producers of the program's outputs, one (node, out idx) or None
        # (a literal / passed-through input) per top-level outvar.
        self.outputs: List[Optional[Tuple[int, int]]] = [
            out_env.get(v) if isinstance(v, jcore.Var) else None
            for v in jaxpr.outvars
        ]

    # -- construction -------------------------------------------------------

    def _build(self, jaxpr, env, path):
        for eqn in jaxpr.eqns:
            in_prods = [
                env.get(v) if isinstance(v, jcore.Var) else None
                for v in eqn.invars
            ]
            sub = _sub_jaxpr(eqn.params) if eqn.primitive.name in _INLINE_PRIMS else None
            if sub is not None and len(sub.invars) == len(eqn.invars):
                sub_env: Dict[jcore.Var, Optional[Tuple[int, int]]] = {}
                for cv in sub.constvars:
                    sub_env[cv] = None
                for sv, prod in zip(sub.invars, in_prods):
                    sub_env[sv] = prod
                name = str(eqn.params.get("name", eqn.primitive.name))
                sub_out = self._build(sub, sub_env, path + (name,))
                for ov, sv in zip(eqn.outvars, sub.outvars):
                    prod = sub_out.get(sv) if isinstance(sv, jcore.Var) else None
                    env[ov] = prod
                continue
            node = Node(id=len(self.nodes), prim=eqn.primitive.name,
                        eqn=eqn, path=path)
            self.nodes.append(node)
            for prod in in_prods:
                if prod is not None:
                    node.preds.add(prod)
                    self._succ_by_out.setdefault(prod, set()).add(node.id)
                    self._succ.setdefault(prod[0], set()).add(node.id)
            for i, ov in enumerate(eqn.outvars):
                env[ov] = (node.id, i)
        return env

    # -- queries ------------------------------------------------------------

    def by_prim(self, name: str) -> List[Node]:
        """All nodes of one primitive, in program order."""
        return [n for n in self.nodes if n.prim == name]

    def successors(self, node_id: int) -> Set[int]:
        """Direct consumers of any output of ``node_id``."""
        return self._succ.get(node_id, set())

    def consumers_of_output(self, node_id: int, out_idx: int) -> Set[int]:
        """Direct consumers of one specific output slot."""
        return self._succ_by_out.get((node_id, out_idx), set())

    def reachable_from(self, starts: Sequence[int]) -> Set[int]:
        """Transitive consumers of the given nodes (the nodes excluded)."""
        seen: Set[int] = set()
        frontier = list(starts)
        while frontier:
            nid = frontier.pop()
            for s in self._succ.get(nid, ()):  # noqa: B905
                if s not in seen:
                    seen.add(s)
                    frontier.append(s)
        return seen

    def ancestors_of(self, node_id: int) -> Set[int]:
        """Transitive producers feeding ``node_id`` (itself excluded)."""
        seen: Set[int] = set()
        frontier = [node_id]
        while frontier:
            nid = frontier.pop()
            for (p, _idx) in self.nodes[nid].preds:
                if p not in seen:
                    seen.add(p)
                    frontier.append(p)
        return seen

    def find_path(self, src: int, dst: int) -> List[int]:
        """One shortest dependency chain src → … → dst (BFS), [] if none."""
        if src == dst:
            return [src]
        parent: Dict[int, int] = {}
        frontier = [src]
        while frontier:
            nxt: List[int] = []
            for nid in frontier:
                for s in self._succ.get(nid, ()):
                    if s in parent:
                        continue
                    parent[s] = nid
                    if s == dst:
                        chain = [dst]
                        while chain[-1] != src:
                            chain.append(parent[chain[-1]])
                        return list(reversed(chain))
                    nxt.append(s)
            frontier = nxt
        return []

    def describe_path(self, chain: Sequence[int]) -> List[str]:
        """Render a node chain as readable evidence lines."""
        out = []
        for i, nid in enumerate(chain):
            arrow = "    " if i == 0 else " -> "
            out.append(f"{arrow}{self.nodes[nid].describe()}")
        return out

    def output_producer_ids(self, out_indices: Sequence[int]) -> Set[int]:
        """Node ids producing the given top-level output slots."""
        ids = set()
        for i in out_indices:
            if i < len(self.outputs) and self.outputs[i] is not None:
                ids.add(self.outputs[i][0])
        return ids


def resolve_callback(cb) -> str:
    """Fully-qualified name of an io/pure_callback's host function.

    Unwraps ``functools.partial`` layers and jax's internal
    ``_FlatCallback`` wrapper (attribute ``callback_func``) down to the
    user function, returning ``module.qualname`` — the key the
    :mod:`repro.analysis.allowlist` registry stores.
    """
    import functools

    seen = 0
    while seen < 10:
        seen += 1
        if isinstance(cb, functools.partial):
            cb = cb.func
            continue
        inner = getattr(cb, "callback_func", None) or getattr(cb, "func", None)
        if inner is not None and inner is not cb:
            cb = inner
            continue
        break
    mod = getattr(cb, "__module__", "?")
    qual = getattr(cb, "__qualname__", repr(cb))
    return f"{mod}.{qual}"
