"""Plan validator: structural invariants of the host planner's outputs.

Pure-Python checks over ``Schedule`` / ``WavePlan`` / ``CachedSchedule``
— the objects every phase-B shape and wire format is derived from. The
rules mirror what the executors *assume* without re-checking:

* **cluster-not-placed-once** — ``chunk_of_cluster`` must put every
  operation cluster in exactly one wave with dense chunk ids, and
  ``rank_of_cluster`` must be a permutation (it is the sort key of the
  fused kernel's stream; a repeated rank merges two clusters' records).
* **dead-slot-loaded** — a slot with speed exactly ``0.0`` has vanished
  from the mesh (elastic-mesh semantics); any assignment or load on it
  is work sent to a machine that no longer exists.
* **invalid-pairing** — the coded shuffle's partner schedule
  ``π(s, j) = (s + 1 + (j mod (m-1))) mod m`` must cover every other
  slot exactly once per sender; otherwise some pair's XOR packet is
  never decodable.
* **chunk-cap-undersized** — send capacities were statistics-sized from
  the plan-time ``K^(i)``; a cap below the exact per-(shard, dest) worst
  case guarantees overflow on the very distribution the plan was built
  for (slack and quantization only ever round *up*). When the snapshot
  was planned from a count-min sketch (``stats_provider == "sketch"``)
  the floor is recomputed with the provider's own distinct-bin bound
  (``SketchStats.send_bound`` — the exact computation the caps were
  committed from) — valid only because the snapshot records an
  overestimate-only provider, so the bound floors the exact worst case
  from above. Snapshots with
  ``caps_estimated`` set committed a deliberately optimistic wave-1 cap
  (streaming prefix) and are exempt: the runtime escape hatch re-executes
  with safe caps on overflow.
* **sketch-caps-unguarded** — a sketch-planned snapshot that neither
  claims the overestimate-only guarantee nor arms the overflow escape
  hatch has no defence against undersized caps at all.
* **snapshot-not-roundtrip** — ``CachedSchedule.to_json`` →
  ``from_json`` → ``to_json`` must be a fixed point, or a persisted plan
  replays with different shapes than it was planned with.
"""

from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np

from repro.analysis.report import Finding


def _finding(rule: str, target: str, summary: str, evidence) -> Finding:
    return Finding(checker="plan", rule=rule, target=target,
                   summary=summary, evidence=list(evidence))


def coded_partner(s: int, j: int, m: int) -> int:
    """The engine's coded-shuffle pairing π (see ``core.mapreduce``)."""
    return (s + 1 + (j % (m - 1))) % m


def validate_wave_plan(plan, num_clusters: int, target: str) -> List[Finding]:
    """Wave-plan invariants: permutation rank, dense one-shot chunk ids."""
    findings: List[Finding] = []
    n = num_clusters
    rank = np.asarray(plan.rank_of_cluster)
    chunk = np.asarray(plan.chunk_of_cluster)
    if rank.shape != (n,) or sorted(rank.tolist()) != list(range(n)):
        findings.append(_finding(
            "rank-not-permutation", target,
            "rank_of_cluster is not a permutation of the clusters — the "
            "fused kernel's sort key would merge or drop clusters",
            [f"rank_of_cluster={rank.tolist()}", f"expected a permutation of 0..{n - 1}"],
        ))
    if chunk.shape != (n,) or chunk.size == 0 or \
            chunk.min() < 0 or chunk.max() >= plan.num_chunks:
        findings.append(_finding(
            "chunk-id-out-of-range", target,
            "chunk_of_cluster assigns a cluster outside [0, num_chunks) — "
            "that cluster's records travel in no wave",
            [f"chunk_of_cluster={chunk.tolist()}",
             f"num_chunks={plan.num_chunks}"],
        ))
    else:
        empty = [c for c in range(plan.num_chunks)
                 if not np.any(chunk == c)]
        if empty:
            findings.append(_finding(
                "chunk-id-not-dense", target,
                "some waves are empty — the executor scans num_chunks "
                "waves and an empty one is a silent no-op stage",
                [f"empty chunks: {empty} of num_chunks={plan.num_chunks}"],
            ))
    if plan.replication not in (1, 2):
        findings.append(_finding(
            "bad-replication", target,
            "wave-plan replication must be 1 (unicast) or 2 (XOR pairs)",
            [f"replication={plan.replication}"],
        ))
    return findings


def validate_membership(member_lists: Sequence[Sequence[int]],
                        num_clusters: int, target: str) -> List[Finding]:
    """Every cluster must appear in exactly one wave's member list."""
    counts = np.zeros(num_clusters, dtype=np.int64)
    stray: List[int] = []
    for members in member_lists:
        for j in members:
            if 0 <= int(j) < num_clusters:
                counts[int(j)] += 1
            else:
                stray.append(int(j))
    missing = np.nonzero(counts == 0)[0].tolist()
    dup = np.nonzero(counts > 1)[0].tolist()
    if not (stray or missing or dup):
        return []
    return [_finding(
        "cluster-not-placed-once", target,
        "wave membership does not place every cluster exactly once",
        [f"missing clusters: {missing}",
         f"multiply-placed clusters: {dup}",
         f"out-of-range members: {stray}"],
    )]


def validate_pairing(m: int, replication: int, target: str) -> List[Finding]:
    """r=2 only: π must give every sender all other slots as partners."""
    if replication != 2:
        return []
    if m < 2:
        return [_finding(
            "invalid-pairing", target,
            "coded r=2 needs at least 2 slots to form multicast pairs",
            [f"num_slots={m}"],
        )]
    findings: List[Finding] = []
    for s in range(m):
        partners = {coded_partner(s, j, m) for j in range(m - 1)}
        expect = set(range(m)) - {s}
        if partners != expect:
            findings.append(_finding(
                "invalid-pairing", target,
                f"sender {s}'s partner schedule misses some slots — their "
                "XOR packets are never decodable",
                [f"partners under π: {sorted(partners)}",
                 f"expected: {sorted(expect)}"],
            ))
    return findings


def validate_schedule(schedule, target: str) -> List[Finding]:
    """Assignment range + dead slots (speed 0.0) carry exactly nothing."""
    findings: List[Finding] = []
    m = int(schedule.num_slots)
    a = np.asarray(schedule.assignment)
    if a.size and (a.min() < 0 or a.max() >= m):
        findings.append(_finding(
            "assignment-out-of-range", target,
            "an operation is assigned to a slot id outside [0, num_slots)",
            [f"assignment={a.tolist()}", f"num_slots={m}"],
        ))
        return findings
    speeds = schedule.slot_speeds
    if speeds is not None:
        for s in np.nonzero(np.asarray(speeds) == 0.0)[0]:
            assigned = np.nonzero(a == s)[0].tolist()
            load = float(np.asarray(schedule.slot_loads)[s])
            if assigned or load != 0.0:
                findings.append(_finding(
                    "dead-slot-loaded", target,
                    f"slot {int(s)} has speed 0.0 (left the mesh) but "
                    "still carries work — it will never finish",
                    [f"assigned clusters: {assigned}",
                     f"slot_loads[{int(s)}]={load}"],
                ))
    return findings


def _chunk_floor(snap, members, per_shard) -> int:
    """Per-(shard, dest) worst-case sends for one wave, no slack.

    ``per_shard`` is the ``(m, n)`` plan-time count matrix: the exact
    histogram for exact providers, or the count-min *estimates* for
    sketch providers (an upper bound on the exact floor, see module doc).
    """
    members = np.asarray(members, dtype=np.int64)
    if members.size == 0:
        return 0
    m = int(snap.schedule.num_slots)
    dests = np.asarray(snap.schedule.assignment)[members]
    worst = 0.0
    for i in range(m):
        per_dest = np.bincount(dests, weights=per_shard[i, members],
                               minlength=m)
        worst = max(worst, float(per_dest.max()))
    return int(math.ceil(worst))


def _rebuild_sketch(snap, n: int):
    """Rebuild the snapshot's ``SketchStats`` provider from its params.

    The validator must size its floor with the *same* distinct-bin bound
    the planner committed caps from (``SketchStats.send_bound``) — a
    different overestimate could legitimately exceed a committed cap and
    manufacture a false finding. Returns ``None`` when the recorded
    params don't describe the stored cells.
    """
    from repro.core.stats_provider import SketchStats

    p = snap.stats_params
    try:
        prov = SketchStats(n, width=int(p["width"]), depth=int(p["depth"]),
                           seed=int(p.get("seed", 0)))
    except (KeyError, TypeError, ValueError):
        return None
    cells = np.asarray(snap.local_hist)
    if cells.ndim != 2 or cells.shape[1] != prov.state_size:
        return None
    return prov


def validate_snapshot(snap, target: str) -> List[Finding]:
    """All invariants of one ``CachedSchedule``, including caps + JSON."""
    provider = getattr(snap, "stats_provider", "exact")
    if provider == "exact":
        n = int(np.asarray(snap.local_hist).shape[1])
    else:
        # Sketch snapshots carry (m, depth*width) cells, not per-cluster
        # columns — the cluster count lives in the assignment vector.
        n = int(np.asarray(snap.schedule.assignment).shape[0])
    m = int(snap.schedule.num_slots)
    findings = []
    findings += validate_schedule(snap.schedule, target)
    findings += validate_wave_plan(snap.waves, n, target)
    findings += validate_membership(
        [snap.waves.chunk_members(c) for c in range(snap.waves.num_chunks)],
        n, target)
    findings += validate_pairing(m, snap.waves.replication, target)

    # Statistics-sized capacities: slack and octave quantization only
    # round up, so every cap must clear the worst case computed from the
    # very statistics the plan snapshot carries — the exact histograms,
    # or (overestimate-only providers) the count-min estimates the caps
    # were sized from. Only trusted while the f32-accumulated raw
    # counters are integer-exact.
    raw = np.asarray(snap.local_hist)
    hist_exact = (float(raw.max()) if raw.size else 0.0) < float(2 ** 24) - 1.0
    wave_floor = None
    if hist_exact:
        if provider == "exact":
            per_shard = np.asarray(snap.local_hist, np.float64)

            def wave_floor(members):
                return _chunk_floor(snap, members, per_shard)
        elif getattr(snap, "caps_estimated", False):
            # Streaming-prefix plans commit an optimistic wave-1 cap on
            # purpose; the runtime escape hatch covers the overflow case.
            wave_floor = None
        elif getattr(snap, "stats_overestimate", True):
            sketch = _rebuild_sketch(snap, n)
            if sketch is not None:
                cells = np.asarray(snap.local_hist, np.float64)
                assign = np.asarray(snap.schedule.assignment)

                def wave_floor(members):
                    members = np.asarray(members, np.int64)
                    if members.size == 0:
                        return 0
                    return int(math.ceil(sketch.send_bound(
                        cells, assign[members], members, m)))
        else:
            findings.append(_finding(
                "sketch-caps-unguarded", target,
                "sketch-planned snapshot neither claims the "
                "overestimate-only guarantee nor arms the overflow "
                "escape hatch — undersized caps would go undetected",
                [f"stats_provider={provider}",
                 "stats_overestimate=False", "caps_estimated=False"],
            ))
    if wave_floor is not None:
        for c in range(snap.waves.num_chunks):
            if c >= len(snap.chunk_caps):
                findings.append(_finding(
                    "chunk-cap-missing", target,
                    "fewer chunk_caps than waves — a wave has no capacity",
                    [f"num_chunks={snap.waves.num_chunks}",
                     f"chunk_caps={list(snap.chunk_caps)}"],
                ))
                break
            floor = min(int(snap.capacity),
                        wave_floor(snap.waves.chunk_members(c)))
            if int(snap.chunk_caps[c]) < floor:
                findings.append(_finding(
                    "chunk-cap-undersized", target,
                    f"wave {c}'s send cap is below the worst case of "
                    "its own plan-time statistics — guaranteed "
                    "overflow on the planned distribution",
                    [f"chunk_caps[{c}]={int(snap.chunk_caps[c])}",
                     f"plan-time per-(shard,dest) worst case: {floor}",
                     f"capacity={int(snap.capacity)}"],
                ))
            if int(snap.chunk_caps[c]) > int(snap.capacity):
                findings.append(_finding(
                    "chunk-cap-exceeds-capacity", target,
                    f"wave {c}'s cap exceeds the sequential capacity the "
                    "buffers are sized from",
                    [f"chunk_caps[{c}]={int(snap.chunk_caps[c])}",
                     f"capacity={int(snap.capacity)}"],
                ))

    findings += validate_roundtrip(snap, target)
    return findings


def validate_roundtrip(snap, target: str) -> List[Finding]:
    """to_json → from_json → to_json must be a fixed point."""
    from repro.core.schedule_cache import CachedSchedule

    d1 = snap.to_json()
    d2 = CachedSchedule.from_json(d1).to_json()
    if d1 == d2:
        return []
    diff = [k for k in sorted(set(d1) | set(d2))
            if d1.get(k) != d2.get(k)]
    return [_finding(
        "snapshot-not-roundtrip", target,
        "CachedSchedule does not survive JSON round-trip — a persisted "
        "plan would replay with different shapes than it was planned with",
        [f"fields that changed: {diff}"]
        + [f"  {k}: {d1.get(k)!r} -> {d2.get(k)!r}" for k in diff[:4]],
    )]


def check_plans(plans: Sequence[Tuple[str, object]]) -> List[Finding]:
    """Validate every (name, CachedSchedule) the real planner produced."""
    findings: List[Finding] = []
    for name, snap in plans:
        findings.extend(validate_snapshot(snap, name))
    return findings
