"""Mutation self-tests: seeded violations every checker must catch.

A static analyzer that has never seen a violation is indistinguishable
from one that checks nothing. Each case here *constructs* a known-bad
program / plan / source file — the exact bug class a checker claims to
certify against — runs only the analyzer (never the mutant), and demands
a finding from the intended checker, with the intended rule, carrying
non-empty evidence:

* a phase-B body whose second all-to-all consumes the first's output
  (the §4.4 overlap killer);
* a wave-timer stamp whose pass-through buffer is dropped, and one with
  no all-to-all anchor;
* an unstable sort ordering all-to-all output (wire contract);
* an unregistered host callback;
* a kernel builder whose block size derives from the slab length
  (PR 8 bug class);
* plans with a duplicated rank, an out-of-range chunk id, a
  double-placed cluster, a loaded dead slot, undersized chunk caps
  (exact *and* sketch-planned — the latter exercises the count-min
  estimate floor), a sketch snapshot stripped of both the
  overestimate-only claim and the escape hatch, and a lossy JSON
  snapshot;
* source files with a jitted ``time.time()``, a default-stability wire
  sort, and an unmarked callback call site.

``run_self_tests()`` is wired into ``--self-test`` and the CI gate: a
checker that goes blind fails the build, not just the review.
"""

from __future__ import annotations

import dataclasses
import pathlib
import tempfile
import textwrap
from typing import Callable, List, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import io_callback

from repro.analysis import conventions, determinism, overlap, plan_checks
from repro.analysis import jaxpr_graph as jg
from repro.analysis.report import Finding
from repro.core import mapreduce as mr

_M = 4  # mutant mesh size


@dataclasses.dataclass
class SelfTestResult:
    """One mutation case: did the intended checker catch it with evidence?"""

    name: str
    checker: str
    rule: str
    caught: bool
    findings: List[Finding]

    def render(self) -> str:
        mark = "caught" if self.caught else "MISSED"
        return f"{mark:7s} {self.name} -> [{self.checker}:{self.rule}]"


def _fake_target(name: str, body, args, timed=False, coded=False):
    """Trace a mutant per-shard body into a TracedTarget-shaped object."""
    from repro.analysis.targets import TracedTarget

    closed = jg.trace_sharded(body, args, mr.AXIS, _M)
    return TracedTarget(name, jg.EqnGraph(closed), timed=timed, coded=coded)


def _x44():
    return (jax.ShapeDtypeStruct((_M, 8), jnp.float32),)


# --------------------------------------------------------------------------
# Jaxpr mutants
# --------------------------------------------------------------------------


def _mutant_a2a_chain():
    """Second all-to-all data-depends on the first: overlap is impossible."""

    def body(x):
        a = lax.all_to_all(x, mr.AXIS, 0, 0)
        b = lax.all_to_all(x + jnp.sum(a) * 0, mr.AXIS, 0, 0)
        return a + b

    t = _fake_target("mutant-a2a-chain", body, _x44())
    return overlap.check_overlap([t])


def _mutant_stamp_dropped():
    """Stamp's pass-through buffer discarded: downstream reads the original."""
    from repro.kernels.wave_timer import ops as wt_ops

    def body(x):
        y = lax.all_to_all(x, mr.AXIS, 0, 0)
        passed, ticks = wt_ops.stamp_through(y)
        out = jnp.sum(y)          # BUG: consumes y, not passed
        return out, out * 0, ticks

    with wt_ops.force_backend("callback"):
        t = _fake_target("mutant-stamp-dropped", body, _x44(), timed=True)
    return overlap.check_overlap([t])


def _mutant_stamp_unanchored():
    """Stamp with no all-to-all ancestor: can fire before its wave exists."""
    from repro.kernels.wave_timer import ops as wt_ops

    def body(x):
        passed, ticks = wt_ops.stamp_through(x)   # BUG: pre-wave stamp
        y = lax.all_to_all(passed, mr.AXIS, 0, 0)
        out = jnp.sum(y)
        return y, out, ticks

    with wt_ops.force_backend("callback"):
        t = _fake_target("mutant-stamp-unanchored", body, _x44(), timed=True)
    return overlap.check_overlap([t])


def _mutant_unstable_sort():
    """stable=False on a sort ordering received (post-all-to-all) records."""

    def body(x):
        a = lax.all_to_all(x, mr.AXIS, 0, 0)
        order = jnp.argsort(a[:, 0], stable=False)   # BUG: ties reorder
        return a[order]

    t = _fake_target("mutant-unstable-sort", body, _x44(), coded=True)
    return determinism.check_determinism([t])


def _rogue_clock(x):
    """An UNREGISTERED host callback body (intentionally not allowlisted)."""
    return np.asarray(x)


def _mutant_rogue_callback():
    """io_callback to a body missing from the allowlist registry."""

    def body(x):
        shape = jax.ShapeDtypeStruct(x.shape, x.dtype)
        return io_callback(_rogue_clock, shape, x)

    t = _fake_target("mutant-rogue-callback", body, _x44())
    return determinism.check_determinism([t])


def _mutant_slab_blocking():
    """Kernel builder whose block size tracks the slab length (PR 8 bug)."""
    from repro.kernels.fused_shuffle_reduce.fused_shuffle_reduce import (
        fused_gather_segment_reduce_pallas,
    )

    def build(n: int):
        def body(values, gather_idx, seg_ids):
            return fused_gather_segment_reduce_pallas(
                values, gather_idx, seg_ids, num_segments=8,
                block_tokens=max(8, n),          # BUG: length-derived block
                interpret=True)

        return jax.make_jaxpr(body)(
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        )

    return determinism.check_slab_invariance(build)


# --------------------------------------------------------------------------
# Plan mutants
# --------------------------------------------------------------------------


def _mutant_rank_duplicate():
    from repro.core.pipeline import WavePlan

    plan = WavePlan(
        rank_of_cluster=np.array([0, 1, 1, 3], np.int32),   # BUG: rank 1 twice
        chunk_of_cluster=np.array([0, 0, 1, 1], np.int32),
        num_chunks=2)
    return plan_checks.validate_wave_plan(plan, 4, "mutant-rank-duplicate")


def _mutant_chunk_out_of_range():
    from repro.core.pipeline import WavePlan

    plan = WavePlan(
        rank_of_cluster=np.arange(4, dtype=np.int32),
        chunk_of_cluster=np.array([0, 1, 2, 1], np.int32),  # BUG: chunk 2 of 2
        num_chunks=2)
    return plan_checks.validate_wave_plan(plan, 4, "mutant-chunk-range")


def _mutant_double_placed():
    # BUG: cluster 2 rides in both waves, cluster 3 in none.
    return plan_checks.validate_membership(
        [[0, 2], [1, 2]], 4, "mutant-double-placed")


def _mutant_dead_slot_loaded():
    from repro.core.scheduler import Schedule

    sched = Schedule(                       # BUG: slot 2 is dead but loaded
        assignment=np.array([0, 1, 2, 3, 2], np.int32),
        num_slots=4, slot_speeds=(1.0, 1.0, 0.0, 1.0))
    return plan_checks.validate_schedule(sched, "mutant-dead-slot")


def _real_snapshot():
    from repro.analysis.targets import plan_targets

    return plan_targets()[0][1]


def _mutant_chunk_cap_undersized():
    snap = _real_snapshot()
    starved = dataclasses.replace(          # BUG: caps far below statistics
        snap, chunk_caps=tuple(1 for _ in snap.chunk_caps))
    return plan_checks.validate_snapshot(starved, "mutant-cap-undersized")


def _sketch_snapshot():
    from repro.analysis.targets import plan_targets

    for _name, snap in plan_targets():
        if snap.stats_provider == "sketch" and not snap.caps_estimated:
            return snap
    raise RuntimeError("no sketch plan target without estimated caps")


def _mutant_sketch_cap_undersized():
    snap = _sketch_snapshot()
    starved = dataclasses.replace(          # BUG: caps below the estimates
        snap, chunk_caps=tuple(1 for _ in snap.chunk_caps))
    return plan_checks.validate_snapshot(starved, "mutant-sketch-cap")


def _mutant_sketch_unguarded():
    snap = _sketch_snapshot()
    bare = dataclasses.replace(             # BUG: no guarantee, no hatch
        snap, stats_overestimate=False, caps_estimated=False)
    return plan_checks.validate_snapshot(bare, "mutant-sketch-unguarded")


def _mutant_lossy_snapshot():
    from repro.core.schedule_cache import CachedSchedule

    class _Lossy(CachedSchedule):
        def to_json(self):
            d = super().to_json()
            d.pop("slot_speeds")            # BUG: drops the Q||C_max speeds
            return d

    snap = _real_snapshot()
    lossy = _Lossy(**{f.name: getattr(snap, f.name)
                      for f in dataclasses.fields(snap)})
    return plan_checks.validate_roundtrip(lossy, "mutant-lossy-snapshot")


# --------------------------------------------------------------------------
# Source (AST) mutants
# --------------------------------------------------------------------------

_SRC_JIT_TIME = """
    import time
    import jax

    @jax.jit
    def scaled(x):
        return x * time.time()      # BUG: trace-time clock
"""

_SRC_WIRE_SORT = """
    import jax.numpy as jnp

    def encode(slab):
        return slab[jnp.argsort(slab[:, 0])]    # BUG: stability implicit
"""

_SRC_UNMARKED_CB = """
    import jax
    from jax.experimental import io_callback

    def _peek(x):
        return x

    def traced(x):
        shape = jax.ShapeDtypeStruct(x.shape, x.dtype)
        return io_callback(_peek, shape, x)     # BUG: no marker comment
"""


def _lint_snippet(relpath: str, source: str):
    with tempfile.TemporaryDirectory() as tmp:
        path = pathlib.Path(tmp) / relpath
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(source))
        return conventions.lint_paths([path])


def _mutant_src_jit_time():
    return _lint_snippet("engine.py", _SRC_JIT_TIME)


def _mutant_src_wire_sort():
    return _lint_snippet("kernels/coded_shuffle/encode.py", _SRC_WIRE_SORT)


def _mutant_src_unmarked_cb():
    return _lint_snippet("timers.py", _SRC_UNMARKED_CB)


# --------------------------------------------------------------------------
# Harness
# --------------------------------------------------------------------------

_CASES: Sequence = (
    ("a2a-dependency-chain", "overlap", "a2a-depends-on-a2a",
     _mutant_a2a_chain),
    ("stamp-pass-through-dropped", "overlap", "stamp-pass-through-dropped",
     _mutant_stamp_dropped),
    ("stamp-unanchored", "overlap", "stamp-unanchored",
     _mutant_stamp_unanchored),
    ("unstable-wire-sort", "determinism", "unstable-wire-sort",
     _mutant_unstable_sort),
    ("rogue-host-callback", "determinism", "undeclared-host-callback",
     _mutant_rogue_callback),
    ("slab-derived-blocking", "determinism", "slab-dependent-blocking",
     _mutant_slab_blocking),
    ("rank-duplicate", "plan", "rank-not-permutation",
     _mutant_rank_duplicate),
    ("chunk-out-of-range", "plan", "chunk-id-out-of-range",
     _mutant_chunk_out_of_range),
    ("cluster-double-placed", "plan", "cluster-not-placed-once",
     _mutant_double_placed),
    ("dead-slot-loaded", "plan", "dead-slot-loaded",
     _mutant_dead_slot_loaded),
    ("chunk-cap-undersized", "plan", "chunk-cap-undersized",
     _mutant_chunk_cap_undersized),
    ("sketch-cap-undersized", "plan", "chunk-cap-undersized",
     _mutant_sketch_cap_undersized),
    ("sketch-caps-unguarded", "plan", "sketch-caps-unguarded",
     _mutant_sketch_unguarded),
    ("lossy-snapshot", "plan", "snapshot-not-roundtrip",
     _mutant_lossy_snapshot),
    ("jitted-time-call", "conventions", "jit-rng-time",
     _mutant_src_jit_time),
    ("implicit-wire-sort", "conventions", "wire-sort-stability",
     _mutant_src_wire_sort),
    ("unmarked-callback", "conventions", "callback-marker",
     _mutant_src_unmarked_cb),
)


def run_self_tests(
        cases: Sequence = _CASES,
        progress: Callable[[str], None] = lambda _line: None,
) -> List[SelfTestResult]:
    """Run every mutation case; a case passes only with the intended
    checker + rule and non-empty evidence."""
    results: List[SelfTestResult] = []
    for name, checker, rule, fn in cases:
        findings = fn()
        caught = any(
            f.checker == checker and f.rule == rule and len(f.evidence) > 0
            for f in findings)
        r = SelfTestResult(name, checker, rule, caught, list(findings))
        progress(r.render())
        results.append(r)
    return results


def self_tests_ok(results: Sequence[SelfTestResult]) -> bool:
    """True when every mutation was caught by its intended checker."""
    return all(r.caught for r in results)
