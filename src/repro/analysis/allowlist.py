"""Host-callback allowlist, declared at the call site.

The determinism linter (:mod:`repro.analysis.determinism`) flags every
``io_callback`` / ``pure_callback`` equation it finds in a traced phase-B
program — host callbacks are the one escape hatch from jit purity, so
each one must be *declared*, not discovered. Modules that legitimately
cross the host boundary register their callback bodies here::

    from repro.analysis import allowlist

    @allowlist.allow_callback
    def _host_stamp_through(primary, *anchors): ...

and mark the ``io_callback(...)`` call line with ``# analysis:
allow-callback`` for the AST convention lint (:mod:`repro.analysis.
conventions`), so both layers of the check read the declaration from the
same place the callback lives.

This module is import-cycle free by construction: it imports nothing
from jax or the rest of :mod:`repro`, so kernel packages can register
their callbacks at import time without dragging the analyzer in.
"""

from __future__ import annotations

from typing import Callable, FrozenSet, Set

# Fully-qualified names ("module.qualname") of host functions that may
# appear as an io_callback/pure_callback target in a traced program.
_ALLOWED: Set[str] = set()


def qualname_of(fn: Callable) -> str:
    """The registry key for a callback body: ``module.qualname``."""
    return f"{getattr(fn, '__module__', '?')}.{getattr(fn, '__qualname__', repr(fn))}"


def allow_callback(fn: Callable) -> Callable:
    """Register ``fn`` as an allowed host-callback body (decorator-friendly)."""
    _ALLOWED.add(qualname_of(fn))
    return fn


def is_allowed(qualname: str) -> bool:
    """True when a callback's resolved qualname was registered."""
    return qualname in _ALLOWED


def allowed_names() -> FrozenSet[str]:
    """Snapshot of the registered callback qualnames (for reports/docs)."""
    return frozenset(_ALLOWED)
