"""Determinism linter: declared callbacks, stable wires, fixed blocking.

Three rules, each pinned to a bug class this repo has actually shipped
or explicitly designed against:

**undeclared-host-callback (D1)** — host callbacks are the one escape
hatch from jit purity (wall clocks, RNG, file IO all fit through it), so
every ``io_callback`` / ``pure_callback`` equation in a traced phase-B
program must resolve to a body registered in
:mod:`repro.analysis.allowlist`. Today that registry holds exactly the
two wave-timer stamp bodies.

**unstable-wire-sort (D2)** — the coded shuffle's decode works only
because sender and receiver run the *identical* sort over replicated
records (docs/SHUFFLE.md's identical-sort wire contract), and ties are
common (the spill key quantizes). Any ``sort`` equation with
``is_stable=False`` that is entangled with the wire — an ``all_to_all``
among its ancestors or its consumers — makes the wire
permutation-dependent and is flagged with the connecting path.

**slab-dependent-blocking (D3)** — the PR 8 bug class: a Pallas grid or
block shape derived from the data-dependent slab length recompiles per
length *and* changes the reduction tree shape, so the same records can
sum to different floats depending on how full the slab is.
:func:`check_slab_invariance` traces the fused gather+segment-reduce
kernel builder at two slab lengths and requires the 1-D operand shapes
of every ``pallas_call`` to be identical — with the fixed
``block_tokens`` both pad to the same block; a length-derived block
leaks the length into the operands.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

from repro.analysis import allowlist
from repro.analysis.jaxpr_graph import EqnGraph, iter_eqns_recursive, resolve_callback
from repro.analysis.report import Finding

_CALLBACK_PRIMS = ("io_callback", "pure_callback")

# Two probe lengths, both under the kernel's fixed 512-token block, so a
# correctly-padded kernel produces identical operand shapes for both.
_SLAB_LENGTHS = (96, 160)


def check_determinism(targets: Sequence,
                      extra_allowed: Sequence[str] = (),
                      slab_build: Optional[Callable] = None) -> List[Finding]:
    """Run D1 + D2 over every traced target, then D3 on the kernel builder."""
    findings: List[Finding] = []
    for t in targets:
        findings.extend(_check_callbacks(t.name, t.graph, extra_allowed))
        findings.extend(_check_wire_sorts(t.name, t.graph, coded=t.coded))
    findings.extend(check_slab_invariance(slab_build))
    return findings


def _check_callbacks(name: str, g: EqnGraph,
                     extra_allowed: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for n in g.nodes:
        if n.prim not in _CALLBACK_PRIMS:
            continue
        qual = resolve_callback(n.eqn.params.get("callback"))
        if allowlist.is_allowed(qual) or qual in extra_allowed:
            continue
        findings.append(Finding(
            checker="determinism",
            rule="undeclared-host-callback",
            target=name,
            summary=(
                f"host callback {qual!r} is not in the analyzer allowlist "
                "— undeclared host effects (clocks, RNG, IO) break "
                "replayability of a traced program"),
            evidence=[n.describe(),
                      f"allowed: {sorted(allowlist.allowed_names()) or 'none'}"],
        ))
    return findings


def _check_wire_sorts(name: str, g: EqnGraph, coded: bool) -> List[Finding]:
    findings: List[Finding] = []
    a2a_ids = {n.id for n in g.by_prim("all_to_all")}
    for n in g.by_prim("sort"):
        if n.eqn.params.get("is_stable", True):
            continue
        # Entangled with the wire = an all_to_all upstream (the sort
        # orders received records) or downstream (the sort shapes what
        # gets sent). In a coded trace every sort is wire-shaping.
        up = g.ancestors_of(n.id) & a2a_ids
        down = g.reachable_from([n.id]) & a2a_ids
        if not (coded or up or down):
            continue
        if up:
            other = min(up)
            chain = g.find_path(other, n.id)
        elif down:
            other = min(down)
            chain = g.find_path(n.id, other)
        else:
            chain = [n.id]
        findings.append(Finding(
            checker="determinism",
            rule="unstable-wire-sort",
            target=name,
            summary=(
                "an unstable sort is entangled with the shuffle wire — "
                "ties reorder freely, so sender and receiver can rebuild "
                "different slabs (identical-sort contract broken)"),
            evidence=g.describe_path(chain),
        ))
    return findings


def _default_slab_build(n: int):
    """Trace the fused gather+segment-reduce kernel at slab length ``n``."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.fused_shuffle_reduce.fused_shuffle_reduce import (
        fused_gather_segment_reduce_pallas,
    )

    def body(values, gather_idx, seg_ids):
        return fused_gather_segment_reduce_pallas(
            values, gather_idx, seg_ids, num_segments=8, interpret=True)

    return jax.make_jaxpr(body)(
        jax.ShapeDtypeStruct((n, 3), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )


def _pallas_operand_shapes_1d(closed) -> List[tuple]:
    """Sorted 1-D operand shapes of every pallas_call in a traced program.

    The 1-D operands are the token-indexed slabs (gather indices, segment
    ids, padded token columns); with a fixed ``block_tokens`` they are
    padded to the block and their shapes do not depend on the slab
    length. Higher-rank operands (the value table) legitimately scale
    with the input and are excluded.
    """
    shapes = []
    for eqn, _path in iter_eqns_recursive(closed.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape is not None and len(shape) == 1:
                shapes.append(tuple(shape))
    return sorted(shapes)


def check_slab_invariance(build: Optional[Callable] = None) -> List[Finding]:
    """D3: kernel blocking must not depend on the data-dependent slab length.

    ``build(n)`` must return the traced (ClosedJaxpr) kernel program for
    slab length ``n``; defaults to the repo's fused gather+segment-reduce
    builder. Traces at two lengths below one block and compares the 1-D
    operand shapes of every ``pallas_call``.
    """
    build = build or _default_slab_build
    n_a, n_b = _SLAB_LENGTHS
    shapes_a = _pallas_operand_shapes_1d(build(n_a))
    shapes_b = _pallas_operand_shapes_1d(build(n_b))
    if shapes_a == shapes_b:
        return []
    return [Finding(
        checker="determinism",
        rule="slab-dependent-blocking",
        target="fused_gather_segment_reduce",
        summary=(
            "pallas_call operand shapes change with the slab length — "
            "blocking derives from data-dependent length, so the "
            "reduction tree (and its float rounding) varies per slab "
            "(PR 8 bug class)"),
        evidence=[
            f"slab length {n_a}: 1-D operands {shapes_a}",
            f"slab length {n_b}: 1-D operands {shapes_b}",
            "a fixed block_tokens pads both lengths to identical blocks",
        ],
    )]
