"""AST convention lint over ``src/repro`` — the source-level contract layer.

The jaxpr checkers certify traced programs; this layer catches the same
bug classes at the source level, where they are cheaper to localise and
where untraced code paths (host planners, helpers) also live:

* **jit-rng-time (C1)** — no ``time.*`` / ``random.*`` / ``np.random.*``
  calls inside a function that gets jit-traced (passed to ``jax.jit`` /
  ``vmap`` / ``make_jaxpr`` / ``shard_map`` / ``pallas_call`` or
  decorated with one). A Python clock or RNG in a traced body runs
  *once, at trace time* — it bakes one arbitrary value into the compiled
  program, silently. Host callback bodies are exempt (they run on the
  host every call, which is the point).
* **wire-sort-stability (C2)** — in the wire-shaping modules
  (``core/mapreduce.py``, ``kernels/coded_shuffle``), every
  ``argsort`` / ``lax.sort`` / ``sort_key_val`` call must spell its
  stability (``stable=`` / ``is_stable=`` / numpy's ``kind=``). The
  identical-sort contract (docs/SHUFFLE.md) must be visible in the
  source, not inherited from a default that jax has changed before.
* **callback-marker (C3)** — every ``io_callback`` / ``pure_callback``
  call site carries an ``# analysis: allow-callback`` marker on the
  call (or the line above). The marker is the source-level half of the
  :mod:`repro.analysis.allowlist` declaration: greppable, reviewed in
  diffs, and checked here so it cannot rot.

The ``analysis`` package itself is excluded from tree scans: its
mutation fixtures intentionally embed violations.
"""

from __future__ import annotations

import ast
import pathlib
from typing import Iterable, List, Optional, Set

from repro.analysis.report import Finding

# Callee names whose first positional argument becomes traced code.
_TRACE_WRAPPERS = {
    "jit", "vmap", "pmap", "make_jaxpr", "shard_map", "pallas_call",
    "checkpoint", "remat", "grad", "value_and_grad",
}
_CALLBACK_NAMES = {"io_callback", "pure_callback"}
_SORT_ATTRS = {"argsort", "sort_key_val"}
_STABILITY_KWARGS = {"stable", "is_stable", "kind"}
_MARKER = "# analysis: allow-callback"

# Files whose sorts shape the shuffle wire (C2 scope).
_WIRE_PARTS = ("core/mapreduce.py", "kernels/coded_shuffle")


def _final_attr(func: ast.expr) -> Optional[str]:
    """The last dotted component of a callee (``jax.lax.sort`` → ``sort``)."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _dotted(func: ast.expr) -> str:
    """Best-effort dotted name of a callee expression."""
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


class _ModuleLint:
    """One parsed module + the alias/def maps the three rules need."""

    def __init__(self, path: pathlib.Path):
        self.path = path
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(path))
        # Aliases of the host-effect modules in this file.
        self.time_aliases: Set[str] = set()
        self.random_aliases: Set[str] = set()
        self.numpy_aliases: Set[str] = set()
        # Names imported *from* time/random (from time import perf_counter).
        self.host_fn_names: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    alias = a.asname or a.name.split(".")[0]
                    if a.name == "time":
                        self.time_aliases.add(alias)
                    elif a.name == "random":
                        self.random_aliases.add(alias)
                    elif a.name.split(".")[0] == "numpy":
                        self.numpy_aliases.add(alias)
            elif isinstance(node, ast.ImportFrom):
                if node.module in ("time", "random"):
                    for a in node.names:
                        self.host_fn_names.add(a.asname or a.name)
        # Module/class-level function defs by bare name.
        self.defs = {
            n.name: n for n in ast.walk(self.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }

    # -- traced-root discovery ---------------------------------------------

    def traced_roots(self) -> Set[str]:
        """Names of functions that end up inside a jax trace."""
        roots: Set[str] = set()
        host: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                attr = _final_attr(node.func)
                if attr in _TRACE_WRAPPERS and node.args:
                    first = node.args[0]
                    if isinstance(first, ast.Name):
                        roots.add(first.id)
                if attr in _CALLBACK_NAMES and node.args:
                    first = node.args[0]
                    if isinstance(first, ast.Name):
                        host.add(first.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    d = dec.func if isinstance(dec, ast.Call) else dec
                    if _final_attr(d) in _TRACE_WRAPPERS:
                        roots.add(node.name)
                    # functools.partial(jax.jit, ...) decorators
                    if isinstance(dec, ast.Call) and \
                            _final_attr(dec.func) == "partial" and dec.args and \
                            _final_attr(dec.args[0]) in _TRACE_WRAPPERS:
                        roots.add(node.name)
        roots -= host
        # Transitive closure over bare-name calls to module-local defs.
        frontier = [r for r in roots if r in self.defs]
        seen = set(frontier)
        while frontier:
            fn = self.defs[frontier.pop()]
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                    callee = node.func.id
                    if callee in self.defs and callee not in seen \
                            and callee not in host:
                        seen.add(callee)
                        frontier.append(callee)
        return seen

    def _is_host_effect_call(self, node: ast.Call) -> Optional[str]:
        """Dotted name when ``node`` calls a Python clock/RNG, else None."""
        dotted = _dotted(node.func)
        head = dotted.split(".")[0] if dotted else ""
        if head in self.time_aliases or head in self.random_aliases:
            return dotted
        if head in self.numpy_aliases and ".random." in f".{dotted}.":
            return dotted
        if isinstance(node.func, ast.Name) and \
                node.func.id in self.host_fn_names:
            return node.func.id
        return None

    def _excerpt(self, node: ast.AST) -> str:
        line = self.lines[node.lineno - 1].strip()
        return f"{self.path}:{node.lineno}: {line}"

    # -- the three rules ----------------------------------------------------

    def check_jit_host_effects(self) -> List[Finding]:
        findings: List[Finding] = []
        for name in sorted(self.traced_roots()):
            fn = self.defs.get(name)
            if fn is None:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    dotted = self._is_host_effect_call(node)
                    if dotted:
                        findings.append(Finding(
                            checker="conventions",
                            rule="jit-rng-time",
                            target=str(self.path),
                            summary=(
                                f"traced function {name!r} calls "
                                f"{dotted}() — it runs once at trace "
                                "time and bakes one value into the "
                                "compiled program"),
                            evidence=[self._excerpt(node)],
                        ))
        return findings

    def check_wire_sorts(self) -> List[Finding]:
        posix = self.path.as_posix()
        if not any(part in posix for part in _WIRE_PARTS):
            return []
        findings: List[Finding] = []
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            attr = _final_attr(node.func)
            dotted = _dotted(node.func)
            is_sort = attr in _SORT_ATTRS or (
                attr == "sort" and dotted.split(".")[0] in
                ("lax", "jax", "jnp", "np", "numpy"))
            if not is_sort:
                continue
            kwargs = {kw.arg for kw in node.keywords}
            if kwargs & _STABILITY_KWARGS:
                continue
            findings.append(Finding(
                checker="conventions",
                rule="wire-sort-stability",
                target=str(self.path),
                summary=(
                    f"{dotted or attr}() in a wire-shaping module "
                    "without an explicit stability argument — the "
                    "identical-sort contract must be spelled out, not "
                    "inherited from a default"),
                evidence=[self._excerpt(node)],
            ))
        return findings

    def check_callback_markers(self) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if _final_attr(node.func) not in _CALLBACK_NAMES:
                continue
            start = max(0, node.lineno - 2)          # line above the call
            end = getattr(node, "end_lineno", node.lineno)
            span = self.lines[start:end]
            if any(_MARKER in line for line in span):
                continue
            findings.append(Finding(
                checker="conventions",
                rule="callback-marker",
                target=str(self.path),
                summary=(
                    "host-callback call site without an '# analysis: "
                    "allow-callback' marker — callbacks must be declared "
                    "where they are called, not discovered by the linter"),
                evidence=[self._excerpt(node)],
            ))
        return findings


def lint_paths(paths: Iterable[pathlib.Path]) -> List[Finding]:
    """Run all three convention rules over the given Python files."""
    findings: List[Finding] = []
    for p in paths:
        lint = _ModuleLint(pathlib.Path(p))
        findings.extend(lint.check_jit_host_effects())
        findings.extend(lint.check_wire_sorts())
        findings.extend(lint.check_callback_markers())
    return findings


def lint_tree(root) -> List[Finding]:
    """Lint every ``.py`` under ``root``, excluding the analysis package."""
    root = pathlib.Path(root)
    paths = sorted(
        p for p in root.rglob("*.py")
        if "analysis" not in p.parts
    )
    return lint_paths(paths)


def default_root() -> pathlib.Path:
    """The installed ``repro`` package directory (what ``--check`` lints)."""
    import repro

    if getattr(repro, "__file__", None):
        return pathlib.Path(repro.__file__).parent
    return pathlib.Path(next(iter(repro.__path__)))   # namespace package
