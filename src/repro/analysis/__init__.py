"""Static contract analyzer for the OS4M engine (``python -m repro.analysis``).

Four checkers prove, on *traced* programs and *host* plan objects — before
anything executes — the contracts the runtime bench gates can only check
after the fact:

* ``overlap``      — §4.4 copy/run overlap: no all-to-all depends on
  another all-to-all's output, and every wave-timer stamp is pinned by
  true buffer dependencies (:mod:`repro.analysis.overlap`).
* ``determinism``  — host callbacks only from the declared allowlist,
  no unstable sorts feeding the wire, slab-length-invariant kernel
  blocking (:mod:`repro.analysis.determinism`).
* ``plan``         — structural invariants of ``WavePlan`` / ``Schedule``
  / ``CachedSchedule`` (:mod:`repro.analysis.plan_checks`).
* ``conventions``  — AST lint over ``src/repro``: no Python RNG/time in
  jitted bodies, explicit sort stability on the wire, declared callback
  call sites (:mod:`repro.analysis.conventions`).

Each checker is proven by mutation self-tests
(:mod:`repro.analysis.mutations`): seeded violations the analyzer must
catch with the right checker name and a non-empty evidence path.

This ``__init__`` stays import-light on purpose:
:mod:`repro.analysis.allowlist` is imported by kernel packages at import
time, and must not drag jax-heavy analyzer modules along.
"""

from __future__ import annotations

__all__ = ["allowlist", "main", "run"]


def __getattr__(name):
    """Lazy re-exports (keeps ``import repro.analysis.allowlist`` light)."""
    if name == "main":
        from repro.analysis.__main__ import main
        return main
    if name == "run":
        from repro.analysis.__main__ import run
        return run
    if name == "allowlist":
        import repro.analysis.allowlist as allowlist
        return allowlist
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
