# Data pipeline: synthetic corpus generation + OS4M-scheduled packing.
