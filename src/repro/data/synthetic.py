"""Synthetic corpus: zipf-distributed tokens in lognormal-length documents.

Deterministic per (seed, shard) — the same property the paper's §6 fault
tolerance relies on: a re-executed Map task reproduces its statistics, so
a restarted data shard reproduces its batches (checkpointed cursor =
(seed, step)).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

__all__ = ["CorpusConfig", "documents", "token_batches"]


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab: int = 512
    zipf_alpha: float = 1.2
    mean_doc_len: float = 180.0
    sigma_doc_len: float = 0.8
    min_doc_len: int = 8
    bos: int = 1
    eos: int = 2


def _doc_rng(cfg: CorpusConfig, seed: int, doc_id: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, doc_id]))


def documents(cfg: CorpusConfig, seed: int, start: int, count: int
              ) -> List[np.ndarray]:
    """``count`` documents (int32 token arrays), ids [start, start+count)."""
    out = []
    for d in range(start, start + count):
        rng = _doc_rng(cfg, seed, d)
        ln = int(np.clip(rng.lognormal(np.log(cfg.mean_doc_len),
                                       cfg.sigma_doc_len),
                         cfg.min_doc_len, 16 * cfg.mean_doc_len))
        # zipf over the vocab (reject ids >= vocab), reserve 0..2
        toks = rng.zipf(cfg.zipf_alpha, size=2 * ln)
        toks = toks[toks < cfg.vocab - 3][:ln].astype(np.int32) + 3
        if toks.shape[0] < ln:
            toks = np.concatenate(
                [toks, rng.integers(3, cfg.vocab, ln - toks.shape[0],
                                    dtype=np.int32)])
        toks[0] = cfg.bos
        toks[-1] = cfg.eos
        out.append(toks)
    return out


def token_batches(cfg: CorpusConfig, seed: int, batch: int, seq_len: int,
                  packer=None, start_doc: int = 0) -> Iterator[np.ndarray]:
    """Yields (batch, seq_len) int32 arrays forever.

    ``packer(docs, batch, seq_len) -> (tokens, stats)`` defaults to
    repro.data.packing.pack_documents with the OS4M scheduler.
    """
    from repro.data import packing

    pk = packer or (lambda docs, b, s: packing.pack_documents(
        docs, b, s, scheduler="os4m"))
    doc_id = start_doc
    while True:
        # Draw ~1.3x the tokens needed, pack, carry the doc cursor forward.
        need = batch * seq_len
        docs: List[np.ndarray] = []
        total = 0
        while total < 1.3 * need:
            block = documents(cfg, seed, doc_id, 64)
            docs.extend(block)
            total += sum(d.shape[0] for d in block)
            doc_id += 64
        tokens, _ = pk(docs, batch, seq_len)
        yield tokens
