"""OS4M sequence packing: documents → fixed-length rows by P||C_max.

The mapping: documents are operations (load = token length), the
``global_batch`` rows are slots, and max-load balance maximises real
tokens per row (minimises padding). The hash/round-robin baseline is the
paper's eq. 3-1 analogue. Documents longer than ``seq_len`` are split
(Map-side splitting is unconstrained — §3.1).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import scheduler as sched_lib

__all__ = ["PackingStats", "pack_documents"]


@dataclasses.dataclass
class PackingStats:
    real_tokens: int
    padded_tokens: int
    dropped_tokens: int
    balance_ratio: float

    @property
    def efficiency(self) -> float:
        total = self.real_tokens + self.padded_tokens
        return self.real_tokens / total if total else 0.0


def pack_documents(
    docs: Sequence[np.ndarray], batch: int, seq_len: int,
    scheduler: str = "os4m", pad_id: int = 0,
) -> Tuple[np.ndarray, PackingStats]:
    """Pack documents into a (batch, seq_len) array.

    Rows are filled in schedule order; per-row overflow beyond seq_len is
    dropped (drop-newest — counted). ``scheduler`` ∈ repro.core.scheduler
    names; "hash" is the round-robin-class baseline.
    """
    pieces: List[np.ndarray] = []
    for d in docs:
        for off in range(0, d.shape[0], seq_len):
            pieces.append(d[off:off + seq_len])
    loads = np.asarray([p.shape[0] for p in pieces], dtype=np.float64)

    if scheduler in ("bss", "os4m"):
        sched = sched_lib.schedule_bss(loads, batch)
    elif scheduler == "lpt":
        sched = sched_lib.schedule_lpt(loads, batch)
    else:
        sched = sched_lib.schedule_hash(loads, batch,
                                        keys=np.arange(loads.shape[0]))

    out = np.full((batch, seq_len), pad_id, dtype=np.int32)
    dropped = 0
    real = 0
    for row in range(batch):
        members = np.nonzero(sched.assignment == row)[0]
        cur = 0
        for mi, m in enumerate(members):
            p = pieces[m]
            take = min(p.shape[0], seq_len - cur)
            out[row, cur:cur + take] = p[:take]
            cur += take
            dropped += p.shape[0] - take
            real += take
            if cur >= seq_len:
                # remaining members of an overfull row are dropped whole
                dropped += sum(pieces[m2].shape[0]
                               for m2 in members[mi + 1:])
                break
    return out, PackingStats(
        real_tokens=real,
        padded_tokens=batch * seq_len - real,
        dropped_tokens=dropped,
        balance_ratio=sched.balance_ratio,
    )
