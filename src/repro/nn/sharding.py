"""Logical-axis sharding rules for the production mesh.

Every parameter/activation is annotated with a tuple of *logical* axis
names (e.g. ``("embed", "mlp")``). A rule table maps logical names to mesh
axes; :func:`logical_to_pspec` applies the table with a divisibility check
so an 8-kv-head tensor on a 16-way model axis degrades to replication
instead of a compile error (the fallback is recorded for DESIGN.md's
sharding notes).

Mesh conventions (launch/mesh.py):

* single-pod:  (16, 16)      axes ("data", "model")
* multi-pod:   (2, 16, 16)   axes ("pod", "data", "model")

Logical rules:

* ``batch``   → all data-parallel axes (("pod","data") when present)
* ``embed``   → the data axes too, i.e. ZeRO-3/FSDP-style parameter
  sharding: weights are stored sharded over DP and all-gathered
  just-in-time by XLA (the compiler sees P(("pod","data"), "model") on a
  (d_model, d_ff) weight).
* ``vocab, heads, kv_heads, mlp, experts`` → "model" (tensor/expert
  parallelism)
* ``seq`` → None (no sequence parallelism by default; a hillclimb lever)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "MeshAxes",
    "DEFAULT_RULES",
    "logical_to_pspec",
    "make_shardings",
    "fallback_log",
]

# Accumulates (tensor_path, logical_axis, reason) fallbacks for reporting.
fallback_log: List[Tuple[str, str, str]] = []


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: Tuple[str, ...]   # all data-parallel axes, e.g. ("pod", "data")
    model: str = "model"

    @staticmethod
    def from_mesh(mesh: Mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        model = "model" if "model" in names else names[-1]
        data = tuple(n for n in names if n != model)
        return MeshAxes(data=data, model=model)


_TRIVIAL_MESH: Optional[Mesh] = None


def trivial_mesh() -> Mesh:
    """A (1, 1) single-device mesh so mesh-requiring layers (shard_map MoE)
    run unchanged on one CPU device in tests/examples."""
    global _TRIVIAL_MESH
    if _TRIVIAL_MESH is None:
        import numpy as np

        _TRIVIAL_MESH = Mesh(
            np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model")
        )
    return _TRIVIAL_MESH


def default_rules(axes: MeshAxes, parallelism: str = "tp") -> Dict[str, Any]:
    if parallelism == "fsdp":
        all_axes = tuple(axes.data) + (axes.model,)
        return {
            "batch": all_axes,
            "embed": all_axes,   # ZeRO-3 over the whole mesh
            "vocab": None, "heads": None, "kv_heads": None, "mlp": None,
            "experts": None, "expert_mlp": None, "seq": None,
            "kv_lora": None, "conv": None, "state": None, None: None,
        }
    return {
        "batch": axes.data,
        "embed": axes.data,      # FSDP/ZeRO param sharding over DP
        "vocab": axes.model,
        "heads": axes.model,
        "kv_heads": axes.model,
        "mlp": axes.model,
        "experts": axes.model,
        "expert_mlp": axes.model,  # TP fallback inside an expert
        "seq": None,
        "kv_lora": None,
        "conv": None,
        "state": None,
        None: None,
    }


DEFAULT_RULES = default_rules  # alias


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def logical_to_pspec(
    logical: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Optional[Dict[str, Any]] = None,
    path: str = "",
) -> P:
    """Map logical axes to a PartitionSpec, replicating non-divisible dims."""
    axes = MeshAxes.from_mesh(mesh)
    rules = rules or default_rules(axes)
    if len(logical) != len(shape):
        raise ValueError(f"{path}: logical {logical} vs shape {shape}")
    out = []
    used: set = set()
    for dim, name in zip(shape, logical):
        target = rules.get(name)
        if target is None:
            out.append(None)
            continue
        flat = tuple(target) if isinstance(target, (tuple, list)) else (target,)
        # Drop axes already used by another dim of this tensor.
        flat = tuple(a for a in flat if a not in used)
        # Largest prefix of the axis tuple that divides the dim.
        while flat and dim % _axis_size(mesh, flat) != 0:
            flat = flat[:-1]
        if not flat:
            fallback_log.append(
                (path, str(name), f"dim {dim} not divisible; replicated")
            )
            out.append(None)
            continue
        used.update(flat)
        out.append(flat if len(flat) > 1 else flat[0])
    return P(*out)


def make_shardings(shapes, logical_tree, mesh: Mesh, rules=None):
    """shapes: pytree of ShapeDtypeStruct/arrays; logical_tree: same structure
    of logical-axis tuples. Returns a pytree of NamedSharding."""

    def leaf(path, shape_leaf, logical):
        shape = tuple(shape_leaf.shape)
        spec = logical_to_pspec(tuple(logical), shape, mesh, rules, path=path)
        return NamedSharding(mesh, spec)

    paths_shapes = jax.tree_util.tree_flatten_with_path(shapes)[0]
    logicals = jax.tree_util.tree_leaves(
        logical_tree, is_leaf=lambda x: isinstance(x, tuple)
    )
    if len(paths_shapes) != len(logicals):
        raise ValueError(
            f"shape tree has {len(paths_shapes)} leaves, logical tree {len(logicals)}"
        )
    flat = [
        leaf(jax.tree_util.keystr(kp), leaf_val, lg)
        for (kp, leaf_val), lg in zip(paths_shapes, logicals)
    ]
    treedef = jax.tree_util.tree_structure(shapes)
    return jax.tree_util.tree_unflatten(treedef, flat)


def pspec_tree(shapes, logical_tree, mesh: Mesh, rules=None):
    """Like make_shardings but returns PartitionSpecs (for in_shardings)."""
    shardings = make_shardings(shapes, logical_tree, mesh, rules)
    return jax.tree.map(lambda s: s.spec, shardings)
