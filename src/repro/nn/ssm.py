"""Mamba2 (SSD) layer — chunkwise-parallel scan + O(1) recurrent decode.

Used by zamba2-2.7b (hybrid: Mamba2 backbone + shared attention blocks).

The SSD (state-space dual) form splits the sequence into chunks: within a
chunk the token-token interaction is a small quadratic attention-like
matmul with exponential decay masks (MXU-friendly); across chunks a
recurrence over the (heads, head_dim, state) tensor carries the SSM state
(a ``lax.scan``). Decode is the pure recurrence — O(1) per token, which is
what makes ``long_500k`` runnable for the SSM/hybrid archs while the
full-attention archs skip it.

Conventions: x (B, L, H, P); dt (B, L, H); A (H,) negative; B/C (B, L, G, N)
with G groups broadcast over H (G | H).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import layers as L
from repro.nn.layers import Param

__all__ = ["SSMArgs", "init_mamba2", "mamba2", "mamba2_decode", "ssd_chunked", "ssd_recurrent_ref"]


import dataclasses


@dataclasses.dataclass(frozen=True)
class SSMArgs:
    d_model: int
    d_inner: int          # expand * d_model
    head_dim: int = 64
    d_state: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 128

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def init_mamba2(key, a: SSMArgs, *, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * a.d_inner + 2 * a.n_groups * a.d_state + a.n_heads
    p = {
        "in_proj": L.init_linear(ks[0], a.d_model, d_in_proj, ("embed", "mlp"),
                                 dtype=dtype),
        "conv_w": Param(
            jax.random.normal(ks[1], (a.conv_kernel, a.conv_dim), dtype) * 0.2,
            ("conv", "mlp")),
        "conv_b": Param(jnp.zeros((a.conv_dim,), dtype), ("mlp",)),
        "A_log": Param(jnp.log(jnp.linspace(1.0, 16.0, a.n_heads).astype(jnp.float32)),
                       ("heads",)),
        "D": Param(jnp.ones((a.n_heads,), jnp.float32), ("heads",)),
        "dt_bias": Param(jnp.zeros((a.n_heads,), jnp.float32), ("heads",)),
        "norm": L.init_rmsnorm(a.d_inner, dtype),
        "out_proj": L.init_linear(ks[2], a.d_inner, a.d_model, ("mlp", "embed"),
                                  dtype=dtype),
    }
    return p


def _causal_conv(x, w, b, *, state: Optional[jax.Array] = None):
    """Depthwise causal conv. x (B, L, C); w (K, C). Returns (y, new_state).

    ``state`` is the last K-1 inputs from the previous segment (decode)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, L+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):, :]
    return y, new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunkwise SSD. Returns (y (b,l,h,p), final_state (b,h,p,n)).

    x (b,l,h,p); dt (b,l,h) >= 0; A (h,) < 0; Bm/Cm (b,l,g,n)."""
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nc = lp // chunk

    # Chunk-major layout for a scan over chunks: the intra-chunk quadratic
    # work happens INSIDE the (remat'd) scan body so only the (b,h,p,n)
    # state carry is ever stacked for AD — the vectorised all-chunks form
    # materialises (b, nc, h, c, c) decay tensors (GBs per layer).
    xc = x.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(b, nc, chunk, g, n).transpose(1, 0, 2, 3, 4)
    Cc = Cm.reshape(b, nc, chunk, g, n).transpose(1, 0, 2, 3, 4)

    ii = jnp.arange(chunk)
    tri = (ii[:, None] >= ii[None, :])

    @jax.checkpoint
    def body(S_prev, inp):
        xz, dtz, Bz, Cz = inp                      # (b,c,h,p) (b,c,h) (b,c,g,n)
        Bz = jnp.repeat(Bz, rep, axis=2).astype(jnp.float32)
        Cz = jnp.repeat(Cz, rep, axis=2).astype(jnp.float32)
        dA = dtz * A                               # (b,c,h) negative
        cum = jnp.cumsum(dA, axis=1)
        total = cum[:, -1]                         # (b,h)
        # intra: att[i,j] = C_i·B_j e^{cum_i - cum_j} dt_j  (j <= i).
        # Mask the EXPONENT (upper triangle is exp(positive) -> inf -> NaN
        # grads through where).
        CB = jnp.einsum("bihn,bjhn->bhij", Cz, Bz)
        diff = cum.transpose(0, 2, 1)[:, :, :, None] \
            - cum.transpose(0, 2, 1)[:, :, None, :]
        decay = jnp.exp(jnp.where(tri[None, None], diff, 0.0))
        att = CB * decay * tri[None, None]
        att = att * dtz.transpose(0, 2, 1)[:, :, None, :]
        y = jnp.einsum("bhij,bjhp->bihp", att, xz.astype(jnp.float32))
        # inter: e^{cum_i} C_i · S_prev
        y = y + jnp.einsum("bihn,bhpn->bihp", Cz * jnp.exp(cum)[..., None],
                           S_prev)
        # state update
        w_state = jnp.exp(total[:, None, :] - cum) * dtz      # (b,c,h)
        S_new = S_prev * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bjh,bjhn,bjhp->bhpn", w_state, Bz, xz.astype(jnp.float32))
        return S_new, y

    init = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, ys = jax.lax.scan(body, init, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, lp, h, p)[:, :l]
    return y.astype(x.dtype), final_state


def ssd_recurrent_ref(x, dt, A, Bm, Cm, init_state=None):
    """Step-by-step oracle (also the decode semantics)."""
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bf = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Cf = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    s = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
         else init_state.astype(jnp.float32))
    ys = []
    for t in range(l):
        da = jnp.exp(dt[:, t].astype(jnp.float32) * A)  # (b,h)
        s = s * da[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t].astype(jnp.float32), Bf[:, t],
            x[:, t].astype(jnp.float32))
        ys.append(jnp.einsum("bhn,bhpn->bhp", Cf[:, t], s))
    return jnp.stack(ys, axis=1).astype(x.dtype), s


def _split_proj(a: SSMArgs, proj):
    z, xBC, dt = jnp.split(
        proj, [a.d_inner, a.d_inner + a.conv_dim], axis=-1)
    return z, xBC, dt


def mamba2(p, x, a: SSMArgs, *, init_state=None, conv_state=None,
           return_state: bool = False):
    """x (B, L, d_model) -> (B, L, d_model). Training/prefill path."""
    b, l, _ = x.shape
    proj = L.linear(p["in_proj"], x)
    z, xBC, dt_pre = _split_proj(a, proj)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), state=conv_state)
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(
        xBC, [a.d_inner, a.d_inner + a.n_groups * a.d_state], axis=-1)
    xs = xs.reshape(b, l, a.n_heads, a.head_dim)
    Bm = Bm.reshape(b, l, a.n_groups, a.d_state)
    Cm = Cm.reshape(b, l, a.n_groups, a.d_state)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xs, dt, A, Bm, Cm, a.chunk, init_state=init_state)
    y = y + xs.astype(y.dtype) * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b, l, a.d_inner)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = L.linear(p["out_proj"], y)
    if return_state:
        return out, {"ssm": state, "conv": new_conv}
    return out


def mamba2_decode(p, x, a: SSMArgs, state):
    """One-token step. x (B, 1, d_model); state {"ssm","conv"}."""
    b = x.shape[0]
    proj = L.linear(p["in_proj"], x)
    z, xBC, dt_pre = _split_proj(a, proj)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), state=state["conv"])
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(
        xBC, [a.d_inner, a.d_inner + a.n_groups * a.d_state], axis=-1)
    xs = xs.reshape(b, a.n_heads, a.head_dim)
    rep = a.n_heads // a.n_groups
    Bf = jnp.repeat(Bm.reshape(b, a.n_groups, a.d_state), rep, axis=1)
    Cf = jnp.repeat(Cm.reshape(b, a.n_groups, a.d_state), rep, axis=1)
    dt = jax.nn.softplus(dt_pre[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,h)
    A = -jnp.exp(p["A_log"])
    s = state["ssm"]
    s = s * jnp.exp(dt * A)[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bf.astype(jnp.float32), xs.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", Cf.astype(jnp.float32), s)
    y = y + xs.astype(y.dtype) * p["D"][None, :, None]
    y = y.reshape(b, 1, a.d_inner).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return L.linear(p["out_proj"], y), {"ssm": s, "conv": new_conv}
