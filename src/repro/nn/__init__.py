# Module-free neural-net layer library: parameters are plain pytrees of
# arrays, every init function also returns a parallel pytree of *logical
# axis names* which repro.nn.sharding maps onto the production mesh.
