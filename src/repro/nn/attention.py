"""Attention layers: GQA (+RoPE/M-RoPE, bias), MLA, cross-attention.

Three execution paths, selected by ``impl``:

* ``blocked`` — pure-XLA online-softmax over kv blocks (a ``lax.scan``),
  the flash-attention *access pattern* without Pallas: never materialises
  the (T, S) score matrix in HBM. This is the dry-run/default path — it
  compiles on any backend and its HLO shows the memory profile the TPU
  kernel delivers.
* ``pallas``  — the real TPU kernel (repro.kernels.flash_attention);
  interpret-mode on CPU. Additionally block-sparse-skips causal upper
  blocks, which the blocked path cannot (static scan), halving causal
  FLOPs on hardware.
* ``naive``   — materialised scores; small-shape test oracle only.

Decode (q_len = 1) always takes the einsum path — it is HBM-bound.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.nn import layers as L

__all__ = [
    "init_attention", "attention",
    "init_mla", "mla_attention",
    "blocked_attention",
]


def _shard_heads(x, mesh):
    """Constraint for (B, T, H, D) projections: batch → dp, heads → model
    when divisible (else replicated — the seq stays free so GSPMD can fall
    back to ring-style sequence sharding for non-divisible head counts)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.nn.sharding import MeshAxes

    axes = MeshAxes.from_mesh(mesh)
    dpsz = 1
    for a in axes.data:
        dpsz *= mesh.shape[a]
    b, t, h = x.shape[0], x.shape[1], x.shape[2]
    bspec = axes.data if (b % dpsz == 0 and b > 1) else None
    hspec = axes.model if h % mesh.shape[axes.model] == 0 else None
    if hspec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bspec, None, hspec, None)))


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _kv_blocks(k, v, block_k):
    b, hkv, s, d = k.shape
    pad = (-s) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (s + pad) // block_k
    kb = k.reshape(b, hkv, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    return kb, vb, nk


def _block_mask(k0, block_k, s, q_pos, causal):
    kv_idx = k0 + jnp.arange(block_k)
    mask = kv_idx[None, :] < s
    if causal:
        mask = mask & (kv_idx[None, :] <= q_pos[:, None])
    return mask  # (t, block_k)


def _blocked_fwd_impl(q, k, v, q_pos, causal, block_k, scale):
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    kb, vb, nk = _kv_blocks(k, v, block_k)
    qg = q.reshape(b, hkv, g, t, d)

    def body(carry, inputs):
        m, l, acc = carry
        kblk, vblk, k0 = inputs
        sc = jnp.einsum("bhgtd,bhsd->bhgts", qg, kblk,
                        preferred_element_type=jnp.float32) * scale
        mask = _block_mask(k0, block_k, s, q_pos, causal)
        sc = jnp.where(mask[None, None, None], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgts,bhsd->bhgtd", p.astype(v.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (
        jnp.full((b, hkv, g, t), -1e30, jnp.float32),
        jnp.zeros((b, hkv, g, t), jnp.float32),
        jnp.zeros((b, hkv, g, t, d), jnp.float32),
    )
    k0s = jnp.arange(nk) * block_k
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, k0s))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (b,hkv,g,t) f32
    return out.reshape(b, hq, t, d), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _blocked_attention(q, k, v, q_pos, causal, block_k, scale):
    out, _ = _blocked_fwd_impl(q, k, v, q_pos, causal, block_k, scale)
    return out


def _blocked_attention_fwd(q, k, v, q_pos, causal, block_k, scale):
    out, lse = _blocked_fwd_impl(q, k, v, q_pos, causal, block_k, scale)
    # Flash-style residuals: inputs + output + logsumexp only. The per-block
    # probability tensors are recomputed in the backward scan — this is what
    # keeps the HBM traffic O(T·S / block) instead of O(T·S) materialised.
    return out, (q, k, v, q_pos, out, lse)


def _blocked_attention_bwd(causal, block_k, scale, res, dout):
    q, k, v, q_pos, out, lse = res
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    kb, vb, nk = _kv_blocks(k, v, block_k)
    qg = q.reshape(b, hkv, g, t, d)
    og = out.reshape(b, hkv, g, t, d)
    dog = dout.reshape(b, hkv, g, t, d)
    # delta = rowsum(dout * out)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)

    def body(dq, inputs):
        kblk, vblk, k0 = inputs
        sc = jnp.einsum("bhgtd,bhsd->bhgts", qg, kblk,
                        preferred_element_type=jnp.float32) * scale
        mask = _block_mask(k0, block_k, s, q_pos, causal)
        p = jnp.exp(sc - lse[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)          # (b,h,g,t,bk)
        dv_blk = jnp.einsum("bhgts,bhgtd->bhsd", p.astype(dog.dtype), dog,
                            preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgtd,bhsd->bhgts", dog, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale               # f32
        dq = dq + jnp.einsum("bhgts,bhsd->bhgtd", ds.astype(kblk.dtype), kblk,
                             preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bhgts,bhgtd->bhsd", ds.astype(qg.dtype), qg,
                            preferred_element_type=jnp.float32)
        return dq, (dk_blk, dv_blk)

    k0s = jnp.arange(nk) * block_k
    dq, (dk_b, dv_b) = jax.lax.scan(
        body, jnp.zeros((b, hkv, g, t, d), jnp.float32), (kb, vb, k0s))
    dk = dk_b.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nk * block_k, d)[:, :, :s]
    dv = dv_b.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nk * block_k, d)[:, :, :s]
    import numpy as _np

    dpos = _np.zeros(q_pos.shape, jax.dtypes.float0)
    return (dq.reshape(b, hq, t, d).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype), dpos)


_blocked_attention.defvjp(_blocked_attention_fwd, _blocked_attention_bwd)


def blocked_attention(q, k, v, *, causal: bool, block_k: int = 1024,
                      sm_scale: Optional[float] = None, q_pos=None):
    """(B,Hq,T,D) x (B,Hkv,S,D)^2 -> (B,Hq,T,D).

    Online-softmax over kv blocks with a flash-style custom VJP (backward
    recomputes block probabilities instead of saving them). ``q_pos`` gives
    the absolute kv-axis position of each query row (defaults to suffix
    alignment); sequence-sharded callers pass their shard's offsets.
    """
    d = q.shape[-1]
    t, s = q.shape[2], k.shape[2]
    scale = sm_scale if sm_scale is not None else d ** -0.5
    block_k = min(block_k, s)
    if q_pos is None:
        q_pos = (s - t) + jnp.arange(t)
    return _blocked_attention(q, k, v, q_pos, causal, block_k, scale)


def _naive_attention(q, k, v, *, causal: bool, sm_scale=None):
    from repro.kernels.flash_attention.ref import attention_ref

    return attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)


def _run_attention(q, k, v, *, causal: bool, impl: str, block_q: int, block_k: int,
                   q_pos=None):
    if q.shape[2] == 1:  # decode: HBM-bound einsum path
        from repro.kernels.flash_attention.ops import decode_attention

        return decode_attention(q, k, v, k.shape[2])
    if impl == "pallas" and q_pos is None:
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k)
    if impl in ("blocked", "pallas"):
        return blocked_attention(q, k, v, causal=causal, block_k=block_k,
                                 q_pos=q_pos)
    return _naive_attention(q, k, v, causal=causal)


def _attention_core(q, k, v, *, causal: bool, impl: str, block_q: int,
                    block_k: int, mesh=None):
    """Train/prefill attention as a shard_map island.

    GSPMD struggles to partition the 5-D flash-VJP einsums (it falls back
    to "involuntary full rematerialization" — replicating (T, S)-sized
    tensors). Inside shard_map the math is purely local, and the only
    collectives are at the boundary:

    * heads divisible by the model axis → head-parallel: q sharded on
      heads; k/v sharded when their head count divides too, else
      replicated (one boundary all-gather; backward psums dk/dv once).
    * otherwise → sequence-parallel: q sharded on T (with per-shard
      absolute q positions for the causal mask), k/v replicated.
    """
    b, hq, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    if mesh is None or t == 1:
        return _run_attention(q, k, v, causal=causal, impl=impl,
                              block_q=block_q, block_k=block_k)
    from jax.sharding import PartitionSpec as P

    from repro.nn.sharding import MeshAxes

    axes = MeshAxes.from_mesh(mesh)
    msz = mesh.shape[axes.model]
    dpsz = 1
    for a in axes.data:
        dpsz *= mesh.shape[a]
    bspec = axes.data if (b % dpsz == 0 and b > 1) else None
    group = hq // hkv

    base_run = functools.partial(_run_attention, causal=causal, impl=impl,
                                 block_q=block_q, block_k=block_k)

    def run(ql, kl, vl, q_pos=None):
        # Tile q so the per-step (t, block_k) probability transient stays
        # bounded (the VMEM-tile analogue; a scan over q chunks).
        tq = ql.shape[2]
        if tq <= block_q or tq % block_q != 0:
            return base_run(ql, kl, vl, q_pos=q_pos)
        nq = tq // block_q
        if q_pos is None:
            q_pos = (kl.shape[2] - tq) + jnp.arange(tq)
        bq, hq_, dq_ = ql.shape[0], ql.shape[1], ql.shape[3]
        qs = ql.reshape(bq, hq_, nq, block_q, dq_).transpose(2, 0, 1, 3, 4)
        ps = q_pos.reshape(nq, block_q)
        outs = jax.lax.map(
            lambda a: base_run(a[0], kl, vl, q_pos=a[1]), (qs, ps))
        return outs.transpose(1, 2, 0, 3, 4).reshape(bq, hq_, tq, dq_)

    if hq % msz == 0:
        kv_sharded = hkv % msz == 0
        qspec = P(bspec, axes.model, None, None)
        kspec = P(bspec, axes.model if kv_sharded else None, None, None)
        if kv_sharded:
            body = lambda ql, kl, vl: run(ql, kl, vl)
        else:
            hq_loc = hq // msz

            def body(ql, kl, vl):
                j = jax.lax.axis_index(axes.model)
                heads = j * hq_loc + jnp.arange(hq_loc)
                kv_idx = heads // group
                return run(ql, jnp.take(kl, kv_idx, axis=1),
                           jnp.take(vl, kv_idx, axis=1))
    elif t % msz == 0 and s == t:
        t_loc = t // msz
        qspec = P(bspec, None, axes.model, None)
        kspec = P(bspec, None, None, None)

        def body(ql, kl, vl):
            j = jax.lax.axis_index(axes.model)
            q_pos = j * t_loc + jnp.arange(t_loc)
            return run(ql, kl, vl, q_pos=q_pos)
    else:
        return _run_attention(q, k, v, causal=causal, impl=impl,
                              block_q=block_q, block_k=block_k)

    return compat.shard_map(
        body, mesh=mesh, in_specs=(qspec, kspec, kspec), out_specs=qspec,
    )(q, k, v)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   *, bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "q": L.init_linear(ks[0], d_model, n_heads * head_dim,
                           ("embed", "heads"), bias=bias, dtype=dtype),
        "k": L.init_linear(ks[1], d_model, n_kv * head_dim,
                           ("embed", "kv_heads"), bias=bias, dtype=dtype),
        "v": L.init_linear(ks[2], d_model, n_kv * head_dim,
                           ("embed", "kv_heads"), bias=bias, dtype=dtype),
        "o": L.init_linear(ks[3], n_heads * head_dim, d_model,
                           ("heads", "embed"), dtype=dtype),
    }


def attention(
    p, x, *,
    n_heads: int, n_kv: int, head_dim: int,
    positions=None,                    # (B, T) or (B, T, 3) for mrope
    rope_kind: str = "rope",           # rope | mrope | none
    rope_theta: float = 10000.0,
    mrope_sections: Tuple[int, int, int] = (16, 24, 24),
    causal: bool = True,
    cache: Optional[dict] = None,      # {"k","v"} (B, S, n_kv, hd) + write pos
    cache_pos: Optional[jax.Array] = None,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    impl: str = "blocked",
    block_q: int = 512, block_k: int = 1024,
    mesh=None,
):
    """Returns (out (B,T,d), new_cache or None)."""
    b, t, _ = x.shape
    q = L.linear(p["q"], x).reshape(b, t, n_heads, head_dim)
    if t > 1:
        q = _shard_heads(q, mesh)

    if kv_override is not None:
        k, v = kv_override  # (B, S, n_kv, hd) — already projected (cross-attn)
        new_cache = None
    else:
        k = L.linear(p["k"], x).reshape(b, t, n_kv, head_dim)
        v = L.linear(p["v"], x).reshape(b, t, n_kv, head_dim)
        if t > 1:
            k = _shard_heads(k, mesh)
            v = _shard_heads(v, mesh)
        if positions is not None and rope_kind != "none":
            if rope_kind == "mrope":
                q = L.apply_mrope(q, positions, mrope_sections, rope_theta)
                k = L.apply_mrope(k, positions, mrope_sections, rope_theta)
            else:
                q = L.apply_rope(q, positions, rope_theta)
                k = L.apply_rope(k, positions, rope_theta)
        new_cache = None
        if cache is not None:
            if t == 1:  # decode: write one step at cache_pos
                if jnp.ndim(cache_pos) == 0:
                    k_cache = jax.lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype),
                        (0, cache_pos, 0, 0))
                    v_cache = jax.lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype),
                        (0, cache_pos, 0, 0))
                else:  # per-lane positions (continuous batching)
                    rows = jnp.arange(b)
                    k_cache = cache["k"].at[rows, cache_pos].set(
                        k[:, 0].astype(cache["k"].dtype))
                    v_cache = cache["v"].at[rows, cache_pos].set(
                        v[:, 0].astype(cache["v"].dtype))
                new_cache = {"k": k_cache, "v": v_cache}
                k, v = k_cache, v_cache
            else:       # prefill: write the whole block at 0
                k_cache = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
                new_cache = {"k": k_cache, "v": v_cache}

    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    if cache is not None and t == 1:
        # Decode against the cache with a validity length of cache_pos + 1.
        from repro.kernels.flash_attention.ops import decode_attention

        out = decode_attention(qh, kh, vh, cache_pos + 1)
    else:
        out = _attention_core(qh, kh, vh, causal=causal, impl=impl,
                              block_q=block_q, block_k=block_k, mesh=mesh)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, n_heads * head_dim)
    return L.linear(p["o"], out), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention, kv_lora compressed cache)
# ---------------------------------------------------------------------------


def init_mla(key, d_model: int, n_heads: int, *, kv_lora: int = 512,
             q_lora: int = 1536, qk_nope: int = 128, qk_rope: int = 64,
             v_dim: int = 128, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    return {
        # Query LoRA path
        "q_down": L.init_linear(ks[0], d_model, q_lora, ("embed", None), dtype=dtype),
        "q_norm": L.init_rmsnorm(q_lora, dtype),
        "q_up": L.init_linear(ks[1], q_lora, n_heads * (qk_nope + qk_rope),
                              (None, "heads"), dtype=dtype),
        # KV LoRA path: compressed cache c_kv (kv_lora) + shared rope key
        "kv_down": L.init_linear(ks[2], d_model, kv_lora, ("embed", "kv_lora"), dtype=dtype),
        "kv_norm": L.init_rmsnorm(kv_lora, dtype),
        "k_pe": L.init_linear(ks[3], d_model, qk_rope, ("embed", None), dtype=dtype),
        "k_up": L.init_linear(ks[4], kv_lora, n_heads * qk_nope,
                              ("kv_lora", "heads"), dtype=dtype),
        "v_up": L.init_linear(ks[5], kv_lora, n_heads * v_dim,
                              ("kv_lora", "heads"), dtype=dtype),
        "o": L.init_linear(ks[6], n_heads * v_dim, d_model, ("heads", "embed"),
                           dtype=dtype),
    }


def mla_attention(
    p, x, *, n_heads: int, kv_lora: int = 512, qk_nope: int = 128,
    qk_rope: int = 64, v_dim: int = 128,
    positions=None, rope_theta: float = 10000.0, causal: bool = True,
    cache: Optional[dict] = None,      # {"c_kv": (B,S,kv_lora), "k_pe": (B,S,qk_rope)}
    cache_pos: Optional[jax.Array] = None,
    impl: str = "blocked", block_q: int = 512, block_k: int = 1024,
    mesh=None,
):
    """Returns (out, new_cache). Cache stores the COMPRESSED kv (the MLA win)."""
    b, t, _ = x.shape
    scale = (qk_nope + qk_rope) ** -0.5

    q = L.linear(p["q_up"], L.rmsnorm(p["q_norm"], L.linear(p["q_down"], x)))
    q = q.reshape(b, t, n_heads, qk_nope + qk_rope)
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]

    c_kv = L.rmsnorm(p["kv_norm"], L.linear(p["kv_down"], x))  # (B,T,kv_lora)
    k_pe = L.linear(p["k_pe"], x)                              # (B,T,qk_rope)
    if positions is not None:
        q_pe = L.apply_rope(q_pe, positions, rope_theta)
        k_pe = L.apply_rope(k_pe, positions, rope_theta)

    new_cache = None
    if cache is not None:
        if t == 1 and jnp.ndim(cache_pos) > 0:  # per-lane positions
            rows = jnp.arange(b)
            c_full = cache["c_kv"].at[rows, cache_pos].set(
                c_kv[:, 0].astype(cache["c_kv"].dtype))
            pe_full = cache["k_pe"].at[rows, cache_pos].set(
                k_pe[:, 0].astype(cache["k_pe"].dtype))
        else:
            at = cache_pos if t == 1 else 0
            c_full = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, at, 0))
            pe_full = jax.lax.dynamic_update_slice(
                cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, at, 0))
        new_cache = {"c_kv": c_full, "k_pe": pe_full}

    if cache is not None and t == 1:
        # Decode: absorbed form — attend in the compressed space; never
        # materialise per-head k/v for the whole cache.
        c_all, pe_all = new_cache["c_kv"], new_cache["k_pe"]
        s = c_all.shape[1]
        wk = p["k_up"]["w"].reshape(kv_lora, n_heads, qk_nope)
        # q absorbed into latent space: (B,1,H,kv_lora)
        q_abs = jnp.einsum("bthn,lhn->bthl", q_nope.astype(jnp.float32),
                           wk.astype(jnp.float32))
        logits = (
            jnp.einsum("bthl,bsl->bhts", q_abs, c_all.astype(jnp.float32))
            + jnp.einsum("bthr,bsr->bhts", q_pe.astype(jnp.float32),
                         pe_all.astype(jnp.float32))
        ) * scale
        valid = jnp.arange(s)[None, :] < jnp.reshape(cache_pos + 1, (-1, 1))
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhts,bsl->bthl", probs, c_all.astype(jnp.float32))
        wv = p["v_up"]["w"].reshape(kv_lora, n_heads, v_dim)
        out = jnp.einsum("bthl,lhv->bthv", o_lat, wv.astype(jnp.float32))
        out = out.reshape(b, t, n_heads * v_dim).astype(x.dtype)
        return L.linear(p["o"], out), new_cache

    # Train/prefill: materialise per-head k/v (MHA) and run the fast path.
    # Head-shard the expansions: the cross-shard gather then happens on the
    # *compressed* c_kv (kv_lora wide), not on the 128-head k/v — the whole
    # point of MLA's low-rank cache, preserved under TP.
    k_nope = L.linear(p["k_up"], c_kv).reshape(b, t, n_heads, qk_nope)
    v = L.linear(p["v_up"], c_kv).reshape(b, t, n_heads, v_dim)
    k_nope = _shard_heads(k_nope, mesh)
    v = _shard_heads(v, mesh)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, t, n_heads, qk_rope))],
        axis=-1,
    )
    qf = _shard_heads(jnp.concatenate([q_nope, q_pe], axis=-1), mesh)
    # Pad v to qk dim so one attention call handles it; slice after.
    dv_pad = (qk_nope + qk_rope) - v_dim
    v_padded = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dv_pad))) if dv_pad else v
    out = _attention_core(
        qf.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v_padded.transpose(0, 2, 1, 3), causal=causal, impl=impl,
        block_q=block_q, block_k=block_k, mesh=mesh,
    ).transpose(0, 2, 1, 3)[..., :v_dim]
    out = out.reshape(b, t, n_heads * v_dim)
    return L.linear(p["o"], out), new_cache
