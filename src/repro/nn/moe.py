"""MoE layer with OS4M operation-level load balancing (the paper's technique).

The mapping (DESIGN.md §2.1): a routed expert's token group is a Reduce
*operation cluster* (all pairs of one key ↔ all tokens of one expert); EP
shards are Reduce *slots*; the router-count histogram ``psum``'d over the
data axes is the §4.1 communication mechanism; the host-side BSS scheduler
(repro.core.scheduler / repro.core.balancer) solves P||C_max to produce the
expert → shard *placement*; and the static per-shard dispatch **capacity is
the scheduled max-load** — balance becomes a compile-time compute saving.

Execution: one ``shard_map`` island per MoE layer.

* EP regime (num_experts % model_axis == 0): expert weights sharded over
  the model axis on the expert dim. Each shard gathers the tokens routed
  to *its* experts into a (capacity, d) bucket sorted by local expert id
  and runs two ``lax.ragged_dot``s (grouped matmul — per-shard FLOPs scale
  with *capacity*, i.e. with the scheduled max-load, not with E·C_e).
  The combine is a scatter-add + ``psum`` over the model axis (disjoint
  expert contributions sum; the psum tree is the shuffle's "copy").
* TP regime (num_experts < model_axis, e.g. grok-1's 8 experts on 16-way
  model): expert weights are f-sliced over the model axis; every shard
  processes all experts on its slice, dropless (capacity = all routed
  assignments). Per-shard load is inherently balanced; OS4M placement is
  degenerate here (recorded in DESIGN.md §Arch-applicability).

Both regimes share one per-shard body; the psum doubles as the TP
partial-sum reduction.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.nn import layers as L
from repro.nn.layers import Param
from repro.nn.sharding import MeshAxes

__all__ = ["MoEArgs", "init_moe", "moe", "default_placement",
           "balanced_placement", "capacity_for"]


@dataclasses.dataclass(frozen=True)
class MoEArgs:
    num_experts: int
    top_k: int
    d_model: int
    d_ff: int                      # per-expert hidden
    shared_experts: int = 0        # DeepSeek-style always-on experts
    act: str = "silu"
    gated: bool = True
    capacity_factor: float = 1.25  # slack over the *scheduled* max-load
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2
    # EP dispatch strategy:
    #  "a2a"       — tokens stay sequence-sharded; counting-sort into
    #                per-destination buckets + all_to_all (the paper's
    #                shuffle/"copy" phase), expert compute, a2a back.
    #  "broadcast" — x replicated over the model axis, every shard computes
    #                its experts on all dp-local tokens, psum combine.
    #                (baseline; 2×+ collective bytes and replicated
    #                activations — kept for §Perf comparison)
    strategy: str = "a2a"
    # Chunked-dispatch pipelining (§4.4 applied to MoE): split the a2a send
    # buckets into this many capacity slabs and double-buffer — slab i+1's
    # all-to-all is issued before slab i's expert FFN, overlapping ICI with
    # MXU exactly like the shuffle→reduce engine. 1 = single-shot a2a.
    # Per-expert capacity drops use global in-expert ranks (a carry across
    # slabs), so the kept/dropped COUNT per expert matches single-shot
    # dispatch exactly; when capacity binds, the kept *subset* may differ
    # (slab-major vs shard-major drop-newest order).
    pipeline_chunks: int = 1

    def ep_size(self, mesh: Mesh) -> int:
        return mesh.shape[MeshAxes.from_mesh(mesh).model]

    def is_ep(self, mesh: Mesh) -> bool:
        return self.num_experts % self.ep_size(mesh) == 0

    def experts_per_shard(self, mesh: Mesh) -> int:
        return self.num_experts // self.ep_size(mesh)


def default_placement(args: MoEArgs, mesh: Mesh):
    """The static hash-class baseline (paper eq. 3-1): expert e → shard by id.

    The physical expert-weight array is sharded in contiguous blocks over
    the model axis, so shard j's local slot s holds weight row
    ``j * per + s``. A placement table must stay consistent with that
    layout: ``placement[:, e] = (shard, slot)`` means expert e's weights
    live at physical row ``shard * per + slot``. Rebalancing (the OS4M
    balancer) therefore permutes the *weight rows* together with the
    table — the TPU analogue of moving a Reduce operation to another slot.
    """
    e = jnp.arange(args.num_experts, dtype=jnp.int32)
    if args.is_ep(mesh):
        per = args.experts_per_shard(mesh)
        return jnp.stack([e // per, e % per])
    # TP regime: every expert lives on every shard, slot = expert id.
    return jnp.stack([jnp.zeros_like(e), e])


def balanced_placement(args: MoEArgs, mesh: Mesh, counts,
                       speeds=None):
    """The OS4M placement for one layer's measured expert loads.

    ``counts`` is the (E,) per-expert token histogram (the §4.1 key
    distribution); ``speeds`` the optional per-EP-shard relative speed
    vector (Q||C_max — the measured ``slot_speeds`` of a heterogeneous
    fleet; ``None`` reproduces the P||C_max placement bit-for-bit).
    Returns ``(placement (2, E) jnp.int32, perm (E,) np.int64)`` — the
    table :func:`moe` consumes plus the weight-row permutation that must
    accompany it (:func:`repro.core.balancer.permute_expert_weights`).
    TP-regime meshes (experts not divisible over the model axis) fall
    back to :func:`default_placement` with the identity perm — placement
    is degenerate there.
    """
    import numpy as _np

    from repro.core.balancer import (placement_from_assignment,
                                     schedule_balanced_cardinality)

    if not args.is_ep(mesh):
        return default_placement(args, mesh), _np.arange(args.num_experts)
    m = args.ep_size(mesh)
    assignment = schedule_balanced_cardinality(
        _np.asarray(counts, _np.float64), m, args.experts_per_shard(mesh),
        speeds=speeds)
    placement, perm = placement_from_assignment(assignment, m)
    return jnp.asarray(placement, jnp.int32), perm


def capacity_for(args: MoEArgs, tokens_per_src_shard: int, mesh: Mesh,
                 max_load_ratio: float = 1.0) -> int:
    """Static bucket capacity from the scheduled max-load.

    ``max_load_ratio`` is the scheduler's max-load / ideal-load (≈1 for
    OS4M/BSS, ≈2–3 for the hash baseline — paper Fig 1b/6). Capacity is
    ideal · ratio · slack, rounded up to a multiple of 8 for layout.

    For the a2a strategy ``tokens_per_src_shard`` is the per-(dp, model)
    shard token count and the result is the per-(src, dst) send bucket;
    for broadcast it is the per-dp shard count and the result is the
    per-EP-shard bucket.
    """
    if not args.is_ep(mesh):
        return tokens_per_src_shard * args.top_k  # dropless TP regime
    m = args.ep_size(mesh)
    ideal = tokens_per_src_shard * args.top_k / m
    cap = int(ideal * max_load_ratio * args.capacity_factor) + 1
    return max(8, -(-cap // 8) * 8)


def init_moe(key, args: MoEArgs, mesh: Optional[Mesh] = None, *, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    E, d, f = args.num_experts, args.d_model, args.d_ff
    # Logical axes: EP shards experts; TP regime shards the hidden dim.
    exp_axis = ("experts", "embed", None)
    exp_axis_tp = (None, "embed", "expert_mlp")
    is_ep = mesh is None or args.is_ep(mesh)
    ax_up = exp_axis if is_ep else exp_axis_tp
    ax_dn = ("experts", None, "embed") if is_ep else (None, "expert_mlp", "embed")
    scale = d ** -0.5
    p = {
        "router": {"w": Param(
            jax.random.normal(ks[0], (d, E), jnp.float32) * scale, ("embed", None))},
        "up": {"w": Param(jax.random.normal(ks[1], (E, d, f), dtype) * scale, ax_up)},
        "down": {"w": Param(
            jax.random.normal(ks[2], (E, f, d), dtype) * (f ** -0.5), ax_dn)},
    }
    if args.gated:
        p["gate"] = {"w": Param(
            jax.random.normal(ks[3], (E, d, f), dtype) * scale, ax_up)}
    if args.shared_experts:
        fs = args.shared_experts * f
        p["shared"] = {
            "up": L.init_linear(ks[4], d, fs, ("embed", "mlp"), dtype=dtype),
            "gate": L.init_linear(
                jax.random.fold_in(ks[4], 1), d, fs, ("embed", "mlp"), dtype=dtype),
            "down": L.init_linear(
                jax.random.fold_in(ks[4], 2), fs, d, ("mlp", "embed"), dtype=dtype),
        }
    return p


def _moe_shard_body(
    x,            # (N_loc, d) — local tokens
    router_w,     # (d, E) replicated
    up_w,         # EP: (E_loc, d, f) | TP: (E, d, f_loc)
    gate_w,       # like up_w or None
    down_w,       # EP: (E_loc, f, d) | TP: (E, f_loc, d)
    placement,    # (2, E) int32 [shard; slot]
    *, args: MoEArgs, capacity: int, n_local_experts: int,
    model_axis: str, data_axes: Tuple[str, ...], is_ep: bool,
):
    N, d = x.shape
    k = args.top_k
    E = args.num_experts

    # --- Router (identical on every model shard: x and router_w replicated
    # over the model axis).
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                          # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- §4.1 communication mechanism: local histogram K^(i), psum over the
    # data axes = TaskTracker→JobTracker aggregation. (E,) replicated result.
    ones = jnp.ones_like(top_e, jnp.float32)
    local_counts = jax.ops.segment_sum(ones.reshape(-1), top_e.reshape(-1),
                                       num_segments=E)
    counts = jax.lax.psum(local_counts, data_axes) if data_axes else local_counts

    # --- Aux losses (Switch-style balance + router z-loss).
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    mean_probs = jax.lax.pmean(probs.mean(0), data_axes) if data_axes else probs.mean(0)
    aux = args.aux_coef * E * jnp.sum(frac_tokens * mean_probs)
    zloss = args.router_z_coef * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- Dispatch: which assignments belong to THIS model shard.
    j = jax.lax.axis_index(model_axis)
    shard_of = placement[0]   # (E,)
    slot_of = placement[1]    # (E,)
    flat_e = top_e.reshape(-1)                    # (N*k,)
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), k)
    if is_ep:
        mine = shard_of[flat_e] == j
    else:
        mine = jnp.ones_like(flat_e, dtype=bool)  # TP: every shard, all experts
    sort_key = jnp.where(mine, slot_of[flat_e], n_local_experts)
    order = jnp.argsort(sort_key, stable=True)    # mine first, grouped by slot
    sel = order[:capacity]                        # static-capacity bucket
    bucket_tok = flat_tok[sel]
    bucket_w = jnp.where(mine[sel], flat_w[sel], 0.0)
    bucket_slot = sort_key[sel]                   # n_local_experts = invalid

    # Group sizes per local expert, truncated by capacity (drop-newest).
    slot_counts = jax.ops.segment_sum(
        jnp.ones_like(sort_key, jnp.int32), sort_key,
        num_segments=n_local_experts + 1)[:-1]
    cum = jnp.cumsum(slot_counts)
    group_sizes = jnp.minimum(cum, capacity) - jnp.minimum(
        jnp.concatenate([jnp.zeros((1,), cum.dtype), cum[:-1]]), capacity)
    overflow = jnp.sum(jnp.where(mine, 1, 0)) - group_sizes.sum()

    gathered = x[bucket_tok] * (bucket_slot < n_local_experts)[:, None].astype(x.dtype)

    # --- Expert compute: dense per-expert buckets (see _expert_bucket_run).
    y, run_overflow = _expert_bucket_run(
        gathered, bucket_slot, n_local_experts, up_w, gate_w, down_w, args)
    overflow = overflow + run_overflow

    # --- Combine ("copy" back): weighted scatter-add, then psum over model
    # (EP: disjoint expert partials; TP: f-slice partials — same reduction).
    out = jnp.zeros((N, d), y.dtype).at[bucket_tok].add(
        y * bucket_w[:, None].astype(y.dtype))
    out = jax.lax.psum(out, model_axis)

    stats = {
        "counts": counts,
        "aux_loss": aux + zloss,
        "overflow": jax.lax.psum(
            overflow, (model_axis,) + tuple(data_axes)) if data_axes
            else jax.lax.psum(overflow, model_axis),
    }
    return out, stats


def _moe_a2a_shard_body(
    x,            # (B_loc, T_loc, d) — tokens sharded over (dp, model)
    router_w,     # (d, E) replicated
    up_w,         # (E_loc, d, f)
    gate_w,
    down_w,       # (E_loc, f, d)
    placement,    # (2, E) int32 [shard; slot]
    *, args: MoEArgs, send_cap: int, n_local_experts: int,
    model_axis: str, data_axes: Tuple[str, ...],
    chunk_slabs: Tuple[Tuple[int, int], ...] = ((0, -1),),
):
    """The paper's shuffle, per MoE layer: counting-sort of (token, k)
    assignments into per-destination-slot buckets ("bucket file per
    operation cluster", §4.4) + all_to_all (the "copy"), grouped
    matmul on the receiver (the "run"), and the reverse all_to_all for the
    combine. Tokens stay sequence-sharded throughout — no replication.

    ``chunk_slabs`` (static, from ``moe_dispatch.plan_capacity_slabs``)
    cuts the capacity axis into pipeline chunks: the walk below issues slab
    ``i+1``'s all-to-all before slab ``i``'s expert FFN, so on hardware the
    next slab's "copy" rides the ICI while the current slab's "run" holds
    the MXU — expert FFN overlapped with chunked all-to-all."""
    b_loc, t_loc, d = x.shape
    xf = x.reshape(b_loc * t_loc, d)
    N = xf.shape[0]
    k = args.top_k
    E = args.num_experts
    m = placement.shape[1] // n_local_experts  # EP shards

    logits = xf.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # §4.1 communication mechanism: local histogram, psum over dp AND model
    # (tokens are sharded over both) -> global key distribution.
    ones = jnp.ones_like(top_e, jnp.float32)
    local_counts = jax.ops.segment_sum(ones.reshape(-1), top_e.reshape(-1),
                                       num_segments=E)
    counts = jax.lax.psum(local_counts, (model_axis,) + tuple(data_axes))

    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    mean_probs = jax.lax.pmean(probs.mean(0), (model_axis,) + tuple(data_axes))
    aux = args.aux_coef * E * jnp.sum(frac_tokens * mean_probs)
    zloss = args.router_z_coef * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)

    shard_of, slot_of = placement[0], placement[1]
    flat_e = top_e.reshape(-1)                       # (N*k,)
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), k)
    dest = shard_of[flat_e].astype(jnp.int32)        # destination EP shard

    # Counting-sort into (m, send_cap) buckets — kernels/moe_dispatch ref
    # semantics (drop-newest beyond send_cap).
    order = jnp.argsort(dest * (n_local_experts + 1) + slot_of[flat_e],
                        stable=True)
    dest_s = dest[order]
    idx = jnp.arange(dest_s.shape[0])
    start = jnp.searchsorted(dest_s, dest_s, side="left")
    pos = idx - start
    ok = pos < send_cap
    overflow = jnp.sum(~ok)
    flat_slot = jnp.where(ok, dest_s * send_cap + pos, m * send_cap)

    def bucketize(vals, fill):
        shape = (m * send_cap + 1,) + vals.shape[1:]
        return (jnp.full(shape, fill, vals.dtype).at[flat_slot]
                .set(vals)[:-1].reshape((m, send_cap) + vals.shape[1:]))

    send_x = bucketize(xf[flat_tok[order]], 0)                   # (m,C,d)
    send_slot = bucketize(
        jnp.where(ok, slot_of[flat_e][order], n_local_experts), n_local_experts)
    send_w = bucketize(jnp.where(ok, flat_w[order], 0.0), 0.0)
    # Keep the local scatter index for the combine (same bucket order).
    local_tok = bucketize(jnp.where(ok, flat_tok[order], N), N)

    if chunk_slabs == ((0, -1),):
        chunk_slabs = ((0, send_cap),)

    def _copy_slab(s: int, z: int):
        """The "copy" of one capacity slab: all_to_all to its Reduce slot."""
        rx = jax.lax.all_to_all(
            send_x[:, s:s + z], model_axis, 0, 0, tiled=False)
        rs = jax.lax.all_to_all(
            send_slot[:, s:s + z], model_axis, 0, 0, tiled=False)
        return rx.reshape(m * z, d), rs.reshape(-1)

    def _run_slab(rx, rslot, carry):
        """The "sort" (order by local expert slot) + "run" (dense
        per-expert bucket matmuls) of one received slab. ``carry`` holds
        per-expert rows already seen in earlier slabs so capacity drops
        use global in-expert ranks (overflow parity with single-shot).
        (ragged_dot would be the ideal shape, but XLA's lowering densifies
        it to (groups, m, k) masks — E_loc× the memory and FLOPs; static
        per-expert buckets keep the compiled program tight. Expert
        replication for hot operations — OS4M with splittable ops, a la
        EPLB — is the §Perf follow-up.)"""
        rorder = jnp.argsort(rslot, stable=True)
        y_sorted, ovf = _expert_bucket_run(
            rx[rorder], rslot[rorder], n_local_experts, up_w, gate_w,
            down_w, args, cap_rows=m * send_cap, rank_offset=carry)
        slab_counts = jax.ops.segment_sum(
            (rslot < n_local_experts).astype(jnp.int32),
            jnp.clip(rslot, 0, n_local_experts),
            num_segments=n_local_experts + 1)[:-1]
        return y_sorted[jnp.argsort(rorder)], ovf, carry + slab_counts

    # Double-buffered slab walk: slab c+1's all_to_all is issued before
    # slab c's expert FFN; the reverse all_to_all (combine "copy") of slab
    # c likewise overlaps slab c+1's FFN in the XLA schedule.
    out = jnp.zeros((N + 1, d), xf.dtype)
    run_overflow = jnp.int32(0)
    carry = jnp.zeros((n_local_experts,), jnp.int32)
    recv = _copy_slab(*chunk_slabs[0])
    for ci, (s, z) in enumerate(chunk_slabs):
        cur = recv
        if ci + 1 < len(chunk_slabs):
            recv = _copy_slab(*chunk_slabs[ci + 1])
        y, ovf, carry = _run_slab(*cur, carry)
        run_overflow = run_overflow + ovf
        y_back = jax.lax.all_to_all(
            y.reshape(m, z, d), model_axis, 0, 0, tiled=False)
        yw = (y_back.reshape(m * z, d)
              * send_w[:, s:s + z].reshape(-1)[:, None].astype(y.dtype))
        out = out.at[local_tok[:, s:s + z].reshape(-1)].add(yw)
    out = out[:-1]

    stats = {
        "counts": counts,
        "aux_loss": aux + zloss,
        "overflow": jax.lax.psum(overflow + run_overflow,
                                 (model_axis,) + tuple(data_axes)),
    }
    return out.reshape(b_loc, t_loc, d).astype(x.dtype), stats


def _expert_bucket_run(rx_s, rslot_s, n_local: int, up_w, gate_w, down_w,
                       args: MoEArgs, cap_rows: Optional[int] = None,
                       rank_offset=None):
    """Dense grouped-matmul over sorted rows via static per-expert buckets.

    rx_s (M, d) sorted by ``rslot_s``; rows with slot >= n_local are
    padding. The drop *budget* per expert = capacity_factor × cap_rows /
    n_local (rounded to 8); rows beyond it are dropped (drop-newest) and
    counted. ``cap_rows`` defaults to M — chunked callers pass the *full*
    receive size so every slab shares the same per-expert budget as the
    unchunked path, and ``rank_offset`` ((n_local,) int32, rows each
    expert already received in earlier slabs) makes the drop decision use
    *global* in-expert ranks — total kept/dropped per expert is then
    identical to single-shot dispatch. The physical bucket (and the
    matmul) is sized min(budget, M): rows scatter at their slab-LOCAL
    rank (a kept row's local rank ≤ its global rank < budget, and
    < M trivially), so a slab's FFN cost scales with the slab, not with
    the full budget.
    Returns (y (M, d) aligned with the input order, overflow count)."""
    M, d = rx_s.shape
    base = M if cap_rows is None else cap_rows
    budget = int(base / max(n_local, 1) * args.capacity_factor) + 1
    budget = min(max(8, -(-budget // 8) * 8), base)
    c_e = min(budget, M)
    idx = jnp.arange(M)
    start = jnp.searchsorted(rslot_s, rslot_s, side="left")
    local_rank = idx - start
    rank = local_rank
    if rank_offset is not None:
        rank = rank + jnp.where(
            rslot_s < n_local,
            rank_offset[jnp.clip(rslot_s, 0, n_local - 1)], 0)
    ok = (rslot_s < n_local) & (rank < budget)
    pos = jnp.where(ok, rslot_s * c_e + local_rank, n_local * c_e)
    bucket = (
        jnp.zeros((n_local * c_e + 1, d), rx_s.dtype)
        .at[pos].set(jnp.where(ok[:, None], rx_s, 0))[:-1]
        .reshape(n_local, c_e, d))
    h = jnp.einsum("ecd,edf->ecf", bucket, up_w.astype(rx_s.dtype))
    if args.gated:
        g = jnp.einsum("ecd,edf->ecf", bucket, gate_w.astype(rx_s.dtype))
        h = L.ACTIVATIONS[args.act](g) * h
    else:
        h = L.ACTIVATIONS[args.act](h)
    yb = jnp.einsum("ecf,efd->ecd", h, down_w.astype(rx_s.dtype))
    yb = yb.reshape(n_local * c_e, d)
    y = jnp.where(ok[:, None],
                  yb[jnp.clip(pos, 0, n_local * c_e - 1)], 0)
    overflow = jnp.sum(rslot_s < n_local) - jnp.sum(ok)
    return y, overflow


def moe(p, x, *, args: MoEArgs, mesh: Mesh, placement=None,
        capacity: Optional[int] = None):
    """x: (B, T, d) sharded over the data axes. Returns (y, stats).

    ``placement`` is the (2, E) [shard; slot] table from the OS4M balancer
    (defaults to the hash baseline of eq. 3-1). ``capacity`` is the static
    per-shard bucket size — derived from the *scheduled* max-load via
    :func:`capacity_for`.
    """
    if mesh is None:
        from repro.nn.sharding import trivial_mesh

        mesh = trivial_mesh()
    axes = MeshAxes.from_mesh(mesh)
    is_ep = args.is_ep(mesh)
    msize = mesh.shape[axes.model]
    n_local = args.experts_per_shard(mesh) if is_ep else args.num_experts
    b, t, d = x.shape
    dp = 1
    for a in axes.data:
        dp *= mesh.shape[a]
    if placement is None:
        placement = default_placement(args, mesh)
    gate_w = p["gate"]["w"] if args.gated else jnp.zeros((), x.dtype)
    stats_spec = {"counts": P(), "aux_loss": P(), "overflow": P()}

    use_a2a = (is_ep and args.strategy == "a2a"
               and t % msize == 0 and t > 1 and b % dp == 0)
    if use_a2a:
        n_src = (b // dp) * (t // msize)
        send_cap = capacity if capacity is not None else \
            capacity_for(args, n_src, mesh)
        send_cap = min(send_cap, n_src * args.top_k)
        from repro.kernels.moe_dispatch import ops as dispatch_ops

        chunk_slabs = dispatch_ops.plan_capacity_slabs(
            send_cap, args.pipeline_chunks)
        body = functools.partial(
            _moe_a2a_shard_body, args=args, send_cap=send_cap,
            n_local_experts=n_local, model_axis=axes.model,
            data_axes=axes.data, chunk_slabs=chunk_slabs)
        xspec = P(axes.data, axes.model, None)
        y, stats = compat.shard_map(
            body, mesh=mesh,
            in_specs=(xspec, P(), P(axes.model, None, None),
                      P(axes.model, None, None) if args.gated else P(),
                      P(axes.model, None, None), P()),
            out_specs=(xspec, stats_spec),
        )(x, p["router"]["w"], p["up"]["w"], gate_w, p["down"]["w"], placement)
    else:
        n_loc_tokens = max(1, b // dp) * t
        cap = capacity if capacity is not None else \
            capacity_for(args, n_loc_tokens, mesh)
        cap = min(cap, n_loc_tokens * args.top_k)
        body = functools.partial(
            _moe_shard_body, args=args, capacity=cap,
            n_local_experts=n_local, model_axis=axes.model,
            data_axes=axes.data, is_ep=is_ep,
        )
        dpspec = P(axes.data) if axes.data else P()
        exp_spec = P(axes.model, None, None) if is_ep \
            else P(None, None, axes.model)
        dn_spec = P(axes.model, None, None) if is_ep \
            else P(None, axes.model, None)
        xf = x.reshape(b * t, d)
        yf, stats = compat.shard_map(
            body, mesh=mesh,
            in_specs=(dpspec, P(), exp_spec,
                      exp_spec if args.gated else P(), dn_spec, P()),
            out_specs=(dpspec, stats_spec),
        )(xf, p["router"]["w"], p["up"]["w"], gate_w, p["down"]["w"], placement)
        y = yf.reshape(b, t, d)
    y = y.astype(x.dtype)

    if args.shared_experts:
        sp = p["shared"]
        h = L.ACTIVATIONS[args.act](L.linear(sp["gate"], x)) * L.linear(sp["up"], x)
        y = y + L.linear(sp["down"], h)
    return y, stats
