"""Primitive layers: params are plain pytrees; logical axes ride along.

``init_*`` functions return a pytree whose leaves are :class:`Param`
(value + logical axis names). :func:`split` separates values from the
logical tree right before jit; the logical tree feeds
``repro.nn.sharding.make_shardings``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Param", "split", "is_param",
    "init_linear", "linear",
    "init_embedding", "embedding",
    "init_rmsnorm", "rmsnorm",
    "init_layernorm", "layernorm",
    "rope_freqs", "apply_rope", "apply_mrope", "sinusoidal_positions",
    "ACTIVATIONS",
]


@dataclasses.dataclass
class Param:
    value: object                 # jax.Array or ShapeDtypeStruct
    logical: Tuple[Optional[str], ...]


# Registered as a pytree (value = child, logical = aux data) so that
# ``jax.eval_shape(init_model, ...)`` works for the allocation-free dry-run.
jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), tuple(p.logical)),
    lambda aux, children: Param(children[0], aux),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def split(tree):
    """Param tree -> (value tree, logical tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    logical = jax.tree.map(lambda p: tuple(p.logical), tree, is_leaf=is_param)
    return values, logical


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------


def init_linear(
    key, d_in: int, d_out: int, logical: Tuple, *,
    bias: bool = False, dtype=jnp.float32, scale: Optional[float] = None,
):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), dtype) * scale
    out = {"w": Param(w, logical)}
    if bias:
        out["b"] = Param(jnp.zeros((d_out,), dtype), (logical[1],))
    return out


def linear(p, x):
    """Apply-time params are raw value trees (post-:func:`split`)."""
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_embedding(key, vocab: int, d: int, *, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, d), dtype) * (d ** -0.5)
    return {"w": Param(w, ("vocab", "embed"))}


def embedding(p, ids):
    return jnp.take(p["w"], ids, axis=0)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": Param(jnp.ones((d,), dtype), ("embed",))}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {
        "scale": Param(jnp.ones((d,), dtype), ("embed",)),
        "bias": Param(jnp.zeros((d,), dtype), ("embed",)),
    }


def layernorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE, M-RoPE) and sinusoidal positions
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0):
    """Inverse frequencies for half the head dim."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., T, H, D) or (..., T, D); positions: (..., T)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., T, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:  # head axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x, positions_3d, sections: Tuple[int, int, int], theta: float = 1e6):
    """Qwen2-VL multimodal RoPE.

    ``positions_3d``: (..., T, 3) — temporal/height/width position per token
    (for pure text all three equal the text position). ``sections`` gives
    how many of the D/2 frequency slots use each of the three position
    streams (e.g. (16, 24, 24) for head_dim 128).
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)  # (D/2,)
    # Which of the 3 position streams each frequency slot consumes.
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions_3d.shape[:-1] + (d // 2,)).astype(jnp.int32),
        axis=-1,
    )  # (..., T, D/2)
    ang = pos * inv
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions_3d.ndim + 1:  # (..., T, H, D) with pos (..., T, 3)
        cos, sin = cos[..., None, :], sin[..., None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def sinusoidal_positions(length: int, d: int):
    """Whisper-style fixed sinusoidal embeddings (length, d)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}
