"""xLSTM layers: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scan).

xlstm-1.3b stacks mLSTM blocks with an sLSTM block every 8th layer (7:1).
The mLSTM is attention-free with a per-head (dk × dv) matrix memory and
exponential input / sigmoid forget gates; its chunkwise form mirrors the
SSD decomposition (intra-chunk quadratic + inter-chunk state recurrence)
with running-max stabilisation carried across chunks. Decode is the O(1)
recurrent update — this is why xlstm runs ``long_500k``.

The sLSTM has genuine hidden-state recurrence (h_{t-1} feeds the gates),
so train/prefill is a ``lax.scan`` over time — cheap per step but
sequential; with 1/8 of layers sLSTM this bounds the non-parallel
fraction (noted in DESIGN.md).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import layers as L
from repro.nn.layers import Param

__all__ = [
    "XLSTMArgs", "init_mlstm", "mlstm", "mlstm_decode",
    "init_slstm", "slstm", "slstm_decode",
    "mlstm_cell_chunked", "mlstm_cell_recurrent_ref",
]

_M_INIT = -1e30


@dataclasses.dataclass(frozen=True)
class XLSTMArgs:
    d_model: int
    n_heads: int = 4
    expand: int = 2          # mLSTM up-projection factor
    conv_kernel: int = 4
    chunk: int = 64
    ffn_factor: float = 4 / 3  # sLSTM post-FFN

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def s_head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ffn(self) -> int:
        return int(self.ffn_factor * self.d_model / 64 + 1) * 64


# ---------------------------------------------------------------------------
# mLSTM cell: chunkwise-parallel + recurrent forms
# ---------------------------------------------------------------------------


def mlstm_cell_chunked(q, k, v, log_i, log_f, chunk: int, state=None):
    """q,k,v (b,l,h,d); log_i/log_f (b,l,h). Returns (h_out, state).

    state = (C (b,h,d,d) tilde-scaled, n (b,h,d), m (b,h))."""
    b, l, h, d = q.shape
    scale = d ** -0.5
    pad = (-l) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=_M_INIT)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // chunk

    def cshape(a):
        return a.reshape(b, nc, chunk, *a.shape[2:]).transpose(
            1, 0, *range(2, a.ndim + 1))

    qc, kc, vc = cshape(q), cshape(k), cshape(v)     # (nc,b,c,h,d)
    lic, lfc = cshape(log_i), cshape(log_f)          # (nc,b,c,h)

    if state is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), _M_INIT, jnp.float32)
    else:
        C0, n0, m0 = state

    ii = jnp.arange(chunk)
    tri = ii[:, None] >= ii[None, :]

    def body(carry, inp):
        C, n, m_prev = carry
        qz, kz, vz, li, lf = inp
        bcum = jnp.cumsum(lf.astype(jnp.float32), axis=1)      # (b,c,h) inclusive
        # D[i,j] = bcum_i - bcum_j + li_j   (j <= i)
        Dm = (bcum[:, :, None, :] - bcum[:, None, :, :]
              + li.astype(jnp.float32)[:, None, :, :])          # (b,i,j,h)
        Dm = jnp.where(tri[None, :, :, None], Dm, _M_INIT)
        inter_scale = bcum + m_prev[:, None, :]                 # (b,i,h)
        m_i = jnp.maximum(jnp.max(Dm, axis=2), inter_scale)     # (b,i,h)

        qs = qz.astype(jnp.float32) * scale
        sc = jnp.einsum("bihd,bjhd->bijh", qs, kz.astype(jnp.float32))
        w = jnp.exp(Dm - m_i[:, :, None, :]) * jnp.where(
            tri[None, :, :, None], 1.0, 0.0)
        num_intra = jnp.einsum("bijh,bjhd->bihd", sc * w, vz.astype(jnp.float32))
        den_intra = jnp.einsum("bijh,bijh->bih", sc, w)
        inter_w = jnp.exp(inter_scale - m_i)                    # (b,i,h)
        num_inter = jnp.einsum("bihd,bhde->bihe", qs, C) * inter_w[..., None]
        den_inter = jnp.einsum("bihd,bhd->bih", qs, n) * inter_w
        num = num_intra + num_inter
        den = den_intra + den_inter
        h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]

        # State to chunk end.
        bQ = bcum[:, -1, :]                                      # (b,h)
        m_new = jnp.maximum(
            m_prev + bQ,
            jnp.max(bQ[:, None, :] - bcum + li.astype(jnp.float32), axis=1),
        )
        kw = jnp.exp(bQ[:, None, :] - bcum + li.astype(jnp.float32)
                     - m_new[:, None, :])                        # (b,j,h)
        C_new = (C * jnp.exp(m_prev + bQ - m_new)[..., None, None]
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", kw,
                              kz.astype(jnp.float32), vz.astype(jnp.float32)))
        n_new = (n * jnp.exp(m_prev + bQ - m_new)[..., None]
                 + jnp.einsum("bjh,bjhd->bhd", kw, kz.astype(jnp.float32)))
        return (C_new, n_new, m_new), h_out

    (Cf, nf, mf), hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, lp, h, d)[:, :l]
    return hs.astype(q.dtype), (Cf, nf, mf)


def mlstm_cell_recurrent_ref(q, k, v, log_i, log_f, state=None):
    """Step-by-step oracle; also defines decode semantics."""
    b, l, h, d = q.shape
    scale = d ** -0.5
    if state is None:
        C = jnp.zeros((b, h, d, d), jnp.float32)
        n = jnp.zeros((b, h, d), jnp.float32)
        m = jnp.full((b, h), _M_INIT, jnp.float32)
    else:
        C, n, m = state
    outs = []
    for t in range(l):
        li = log_i[:, t].astype(jnp.float32)
        lf = log_f[:, t].astype(jnp.float32)
        m_new = jnp.maximum(lf + m, li)
        C = (C * jnp.exp(lf + m - m_new)[..., None, None]
             + jnp.exp(li - m_new)[..., None, None]
             * jnp.einsum("bhd,bhe->bhde", k[:, t].astype(jnp.float32),
                          v[:, t].astype(jnp.float32)))
        n = (n * jnp.exp(lf + m - m_new)[..., None]
             + jnp.exp(li - m_new)[..., None] * k[:, t].astype(jnp.float32))
        m = m_new
        qs = q[:, t].astype(jnp.float32) * scale
        num = jnp.einsum("bhd,bhde->bhe", qs, C)
        den = jnp.einsum("bhd,bhd->bh", qs, n)
        outs.append(num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None])
    return jnp.stack(outs, 1).astype(q.dtype), (C, n, m)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm(key, a: XLSTMArgs, *, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    di = a.d_inner
    return {
        "up_u": L.init_linear(ks[0], a.d_model, di, ("embed", "mlp"), dtype=dtype),
        "up_z": L.init_linear(ks[1], a.d_model, di, ("embed", "mlp"), dtype=dtype),
        "conv_w": Param(jax.random.normal(ks[2], (a.conv_kernel, di), dtype) * 0.2,
                        ("conv", "mlp")),
        "conv_b": Param(jnp.zeros((di,), dtype), ("mlp",)),
        # Block-diagonal (per-head) q/k/v, as in the official mLSTM block.
        "q": Param(jax.random.normal(ks[3], (a.n_heads, a.head_dim, a.head_dim),
                                     dtype) * a.head_dim ** -0.5,
                   ("heads", None, None)),
        "k": Param(jax.random.normal(ks[4], (a.n_heads, a.head_dim, a.head_dim),
                                     dtype) * a.head_dim ** -0.5,
                   ("heads", None, None)),
        "v": Param(jax.random.normal(ks[5], (a.n_heads, a.head_dim, a.head_dim),
                                     dtype) * a.head_dim ** -0.5,
                   ("heads", None, None)),
        "gate_i": L.init_linear(ks[6], di, a.n_heads, ("mlp", None), bias=True,
                                dtype=dtype),
        "gate_f": L.init_linear(ks[7], di, a.n_heads, ("mlp", None), bias=True,
                                dtype=dtype),
        "hnorm": L.init_rmsnorm(a.head_dim, dtype),
        "down": L.init_linear(jax.random.fold_in(key, 9), di, a.d_model,
                              ("mlp", "embed"), dtype=dtype),
    }


def _mlstm_qkv_gates(p, x, a: XLSTMArgs, conv_state=None):
    from repro.nn.ssm import _causal_conv

    b, l, _ = x.shape
    u = L.linear(p["up_u"], x)
    z = L.linear(p["up_z"], x)
    c, new_conv = _causal_conv(u, p["conv_w"].astype(x.dtype),
                               p["conv_b"].astype(x.dtype), state=conv_state)
    c = jax.nn.silu(c)
    hshape = (b, l, a.n_heads, a.head_dim)
    ch = c.reshape(hshape)
    uh = u.reshape(hshape)
    q = jnp.einsum("blhd,hde->blhe", ch, p["q"].astype(x.dtype))
    k = jnp.einsum("blhd,hde->blhe", ch, p["k"].astype(x.dtype))
    v = jnp.einsum("blhd,hde->blhe", uh, p["v"].astype(x.dtype))
    log_i = L.linear(p["gate_i"], u).astype(jnp.float32)            # (b,l,h)
    log_f = jax.nn.log_sigmoid(L.linear(p["gate_f"], u).astype(jnp.float32) + 2.0)
    return q, k, v, log_i, log_f, z, new_conv


def _mlstm_out(p, h, z, a: XLSTMArgs):
    b, l = h.shape[0], h.shape[1]
    h = L.rmsnorm(p["hnorm"], h)  # headwise norm over head_dim
    h = h.reshape(b, l, a.d_inner)
    return L.linear(p["down"], h * jax.nn.silu(z))


def mlstm(p, x, a: XLSTMArgs, *, state=None, conv_state=None,
          return_state: bool = False):
    q, k, v, log_i, log_f, z, new_conv = _mlstm_qkv_gates(p, x, a, conv_state)
    h, new_state = mlstm_cell_chunked(q, k, v, log_i, log_f, a.chunk, state=state)
    out = _mlstm_out(p, h, z, a)
    if return_state:
        return out, {"cell": new_state, "conv": new_conv}
    return out


def mlstm_decode(p, x, a: XLSTMArgs, state):
    q, k, v, log_i, log_f, z, new_conv = _mlstm_qkv_gates(
        p, x, a, conv_state=state["conv"])
    h, new_cell = mlstm_cell_recurrent_ref(q, k, v, log_i, log_f,
                                           state=state["cell"])
    out = _mlstm_out(p, h, z, a)
    return out, {"cell": new_cell, "conv": new_conv}


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, true recurrence -> lax.scan over time)
# ---------------------------------------------------------------------------


def init_slstm(key, a: XLSTMArgs, *, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d, hd, nh = a.d_model, a.s_head_dim, a.n_heads
    return {
        "w_gates": L.init_linear(ks[0], d, 4 * d, ("embed", "mlp"), bias=True,
                                 dtype=dtype),
        "r_gates": Param(
            jax.random.normal(ks[1], (nh, hd, 4 * hd), dtype) * (hd ** -0.5),
            ("heads", None, None)),
        "hnorm": L.init_rmsnorm(hd, dtype),
        "ffn_up": L.init_linear(ks[2], d, a.d_ffn, ("embed", "mlp"), dtype=dtype),
        "ffn_gate": L.init_linear(jax.random.fold_in(ks[2], 1), d, a.d_ffn,
                                  ("embed", "mlp"), dtype=dtype),
        "ffn_down": L.init_linear(ks[3], a.d_ffn, d, ("mlp", "embed"), dtype=dtype),
    }


def _slstm_step(params_r, carry, gx, nh, hd):
    """One time step. carry = (h, c, n, m) each (b, nh, hd)."""
    h, c, n, m = carry
    gr = jnp.einsum("bhd,hdk->bhk", h, params_r)       # (b,nh,4hd)
    g = gx + gr
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(gf + 1.0)
    m_new = jnp.maximum(log_f + m, gi)
    i_p = jnp.exp(gi - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * jnp.tanh(gz)
    n = f_p * n + i_p
    h_new = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
    return (h_new, c, n, m_new)


def slstm(p, x, a: XLSTMArgs, *, state=None, return_state: bool = False,
          time_chunk: int = 64):
    b, l, d = x.shape
    nh, hd = a.n_heads, a.s_head_dim
    gx = L.linear(p["w_gates"], x).reshape(b, l, nh, 4 * hd).astype(jnp.float32)
    if state is None:
        zero = jnp.zeros((b, nh, hd), jnp.float32)
        state = (zero, zero, zero, jnp.full((b, nh, hd), _M_INIT, jnp.float32))
    rw = p["r_gates"].astype(jnp.float32)

    def body(carry, gxt):
        new = _slstm_step(rw, carry, gxt, nh, hd)
        return new, new[0]

    # Two-level scan with remat on the outer chunk: AD then saves the
    # carry once per *chunk* instead of once per step (4096-step scans
    # otherwise stack ~GBs of (h, c, n, m) residuals per layer).
    tc = min(time_chunk, l)
    if l % tc == 0 and l > tc:
        gxc = gx.transpose(1, 0, 2, 3).reshape(l // tc, tc, b, nh, 4 * hd)

        @jax.checkpoint
        def chunk_body(carry, gchunk):
            return jax.lax.scan(body, carry, gchunk)

        state_f, hs = jax.lax.scan(chunk_body, state, gxc)
        hs = hs.reshape(l, b, nh, hd)
    else:
        state_f, hs = jax.lax.scan(body, state, gx.transpose(1, 0, 2, 3))
    hs = hs.transpose(1, 0, 2, 3)                       # (b,l,nh,hd)
    y = L.rmsnorm(p["hnorm"], hs.astype(x.dtype)).reshape(b, l, d)
    y = y + L.linear(
        p["ffn_down"],
        jax.nn.silu(L.linear(p["ffn_gate"], y)) * L.linear(p["ffn_up"], y))
    if return_state:
        return y, state_f
    return y


def slstm_decode(p, x, a: XLSTMArgs, state):
    y, state_f = slstm(p, x, a, state=state, return_state=True)
    return y, state_f
