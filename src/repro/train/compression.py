"""int8 gradient compression with error feedback.

For cross-pod (DCN) gradient reduction the wire format matters: int8 with
per-tensor scale cuts the "pod"-axis all-reduce bytes 4× vs f32 (2× vs
bf16). Error feedback (Seide et al. / EF-SGD) keeps the quantisation
noise from biasing the update: the residual of each step is added back
before the next quantisation, making the scheme unbiased in the long run.

Usage (training loop):
    comp, err = compress(g + err)           # before the DCN all-reduce
    g_hat = decompress(comp)                 # after
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Compressed", "compress_leaf", "decompress_leaf",
           "compress_tree", "decompress_tree", "init_error"]


class Compressed(NamedTuple):
    q: jax.Array      # int8
    scale: jax.Array  # f32 scalar


def compress_leaf(g: jax.Array) -> Tuple[Compressed, jax.Array]:
    """Returns (compressed, residual error)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    err = g32 - q.astype(jnp.float32) * scale
    return Compressed(q, scale), err


def decompress_leaf(c: Compressed, dtype=jnp.float32) -> jax.Array:
    return (c.q.astype(jnp.float32) * c.scale).astype(dtype)


def init_error(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads, error):
    """(grads + error) -> (compressed tree, new error tree)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    comp, errs = [], []
    for g, e in zip(flat_g, flat_e):
        c, r = compress_leaf(g.astype(jnp.float32) + e)
        comp.append(c)
        errs.append(r)
    return (jax.tree_util.tree_unflatten(treedef, comp),
            jax.tree_util.tree_unflatten(treedef, errs))


def decompress_tree(comp, dtype=jnp.float32):
    return jax.tree.map(lambda c: decompress_leaf(c, dtype), comp,
                        is_leaf=lambda x: isinstance(x, Compressed))
