"""AdamW with dtype-configurable moments, global-norm clip, LR schedules.

Self-contained (optax is not available offline). Moments inherit the
parameter sharding (same shapes), which gives ZeRO-style optimizer-state
sharding for free once params are FSDP-sharded over the data axes.
``moment_dtype="bfloat16"`` halves optimizer HBM for the 236B/314B MoE
dry-runs (recorded in DESIGN.md as a deviation knob).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt", "adamw_step", "lr_at", "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    # schedule
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def _mdt(cfg: OptConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]


def init_opt(params, cfg: OptConfig):
    dt = _mdt(cfg)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(step, cfg: OptConfig):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_step(params, grads, opt_state, cfg: OptConfig,
               lr: Optional[jax.Array] = None):
    """One AdamW update. Returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    lr = lr_at(step, cfg) if lr is None else lr
    dt = _mdt(cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
