"""Atomic, keep-k, mesh-elastic checkpointing.

Fault-tolerance contract (DESIGN.md §7, paper §6 analogue):

* **Atomic**: leaves are written to ``step_XXXX.tmp`` and ``os.replace``d
  into place, manifest last — a killed writer never corrupts the latest
  checkpoint (the task-attempt idempotency of the paper's JobTracker map).
* **Keep-k**: older checkpoints garbage-collected after a successful save.
* **Elastic**: tensors are stored unsharded (gathered) with their logical
  axes; ``load`` re-shards onto *any* mesh via make_shardings — restart on
  a different pod count reshapes the data layout, not the data.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Optional

import numpy as np

import jax

__all__ = ["save", "load", "latest_step"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, params, opt_state=None, extra: Optional[dict] = None,
         keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = ckpt_dir / (name + ".tmp")
    final = ckpt_dir / name
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    state = {"params": params}
    if opt_state is not None:
        state["opt"] = opt_state
    leaves, treedef = _flatten(state)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(x))
              for i, x in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # keep-k GC (after the successful replace).
    steps = sorted(d for d in ckpt_dir.iterdir()
                   if d.is_dir() and d.name.startswith("step_")
                   and not d.name.endswith(".tmp"))
    for old in steps[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
                   if d.is_dir() and d.name.startswith("step_")
                   and not d.name.endswith(".tmp"))
    return steps[-1] if steps else None


def load(ckpt_dir, step: int, like, mesh=None, shardings=None):
    """Restore the state saved at ``step``.

    ``like``: a pytree with the same structure (e.g. from jax.eval_shape)
    used to unflatten. ``shardings``: optional matching pytree of
    NamedShardings for the (possibly different) target mesh — elastic
    restart path.
    Returns (state dict, extra manifest dict).
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        leaves = [z[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    treedef = jax.tree_util.tree_structure(like)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    elif mesh is not None:
        state = jax.device_put(state)
    return state, manifest["extra"]
