# Training substrate: optimizer, LR schedules, checkpointing, gradient
# compression, and the training loop.
