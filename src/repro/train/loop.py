"""Training loop: step execution + OS4M balancer + checkpoint/restart.

The loop wires the paper's control plane into training:

* every step, the MoE layer emits per-expert counts (the §4.1
  communication mechanism, psum'd in-step);
* the :class:`~repro.core.balancer.ExpertBalancer` accumulates them and
  every ``replan_interval`` steps solves P||C_max (host-side, sub-second
  — paper Fig 10) producing new placements + weight permutations, which
  are applied WITHOUT recompilation (shapes unchanged);
* checkpoints are atomic/keep-k; on restart the loop resumes from the
  latest step (elastic: a different mesh reshards on load);
* failures raised by a step (device loss in a real fleet) are caught,
  the state restored from the last checkpoint and execution resumed —
  the whole-job analogue of the paper's task re-execution.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.balancer import ExpertBalancer
from repro.launch.steps import build_train_step
from repro.models.config import ModelConfig, Shape
from repro.models.model import default_placements, init_model
from repro.nn import layers as L
from repro.nn.sharding import make_shardings
from repro.train import checkpoint as ckpt_lib
from repro.train.optim import OptConfig, init_opt

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    keep: int = 3
    replan_interval: int = 25
    # Drift-gate the balancer (None = replan every interval): layers whose
    # routing distribution moved less than this L1 distance keep their
    # placement — the schedule-reuse policy applied to expert placement.
    balancer_max_drift: "float | None" = None
    # Q||C_max expert placement: per-EP-shard relative speeds (1.0 =
    # nominal) the balancer solves under — a known-heterogeneous fleet, or
    # the measured slot_speeds vector of the MapReduce engine. None ≡
    # identical shards (placements bit-identical to the P||C_max solver).
    expert_slot_speeds: "tuple | None" = None
    log_every: int = 10
    seed: int = 0
    microbatches: int = 1


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: Shape, mesh,
                 opt_cfg: OptConfig = OptConfig(),
                 tcfg: TrainerConfig = TrainerConfig()):
        self.cfg, self.shape, self.mesh = cfg, shape, mesh
        self.tcfg = tcfg
        self.step_fn, _ = build_train_step(
            cfg, mesh, shape, opt_cfg=opt_cfg,
            microbatches=tcfg.microbatches)
        key = jax.random.PRNGKey(tcfg.seed)
        ptree = init_model(key, cfg, mesh)
        self.params, self.logical = L.split(ptree)
        if mesh is not None and mesh.devices.size > 1:
            shardings = make_shardings(self.params, self.logical, mesh)
            self.params = jax.device_put(self.params, shardings)
        self.opt_state = init_opt(self.params, opt_cfg)
        self.placements = (default_placements(cfg, mesh)
                           if cfg.moe is not None else None)
        n_moe = cfg.n_layers - cfg.first_k_dense if cfg.moe else 0
        self.balancer = None
        if cfg.moe is not None and cfg.moe.is_ep(mesh):
            self.balancer = ExpertBalancer(
                cfg.moe.num_experts, cfg.moe.ep_size(mesh), n_moe,
                interval=tcfg.replan_interval,
                max_drift=tcfg.balancer_max_drift,
                speeds=tcfg.expert_slot_speeds)
        self.step = 0
        self.history: list = []

    # -- fault tolerance ----------------------------------------------------

    def save(self):
        ckpt_lib.save(self.tcfg.ckpt_dir, self.step, self.params,
                      self.opt_state, extra={"arch": self.cfg.name},
                      keep=self.tcfg.keep)

    def try_resume(self) -> bool:
        last = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        like = {"params": self.params, "opt": self.opt_state}
        state, _ = ckpt_lib.load(self.tcfg.ckpt_dir, last, like,
                                 mesh=self.mesh)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = last
        return True

    # -- main loop ----------------------------------------------------------

    def run(self, batches: Iterator[np.ndarray], num_steps: int,
            on_metrics: Optional[Callable[[int, Dict[str, Any]], None]] = None):
        for _ in range(num_steps):
            tokens = next(batches)
            batch = {"tokens": jnp.asarray(tokens)}
            try:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch, self.placements)
            except Exception:
                # Node-failure path: restore the last checkpoint and retry
                # once (the launcher re-schedules the shard in a real fleet).
                if not self.try_resume():
                    raise
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch, self.placements)
            self.step += 1

            # OS4M control plane: collect stats, replan, permute weights.
            if self.balancer is not None and "expert_counts" in metrics:
                self.balancer.observe(
                    np.asarray(jax.device_get(metrics["expert_counts"])))
                if self.balancer.should_replan():
                    placements, perms, reports = self.balancer.replan()
                    # Drift-gated steady state: when every layer kept its
                    # placement, skip the device-side weight gather too —
                    # the reuse saves the permutation, not just the solve.
                    if any(r.moved_experts > 0 for r in reports) or \
                            getattr(self, "_cur_perms", None) is None:
                        self._apply_placements(placements, perms)
                    metrics["balance_ratio"] = float(
                        np.mean([r.balance_ratio for r in reports]))
                    metrics["baseline_ratio"] = float(
                        np.mean([r.baseline_ratio for r in reports]))

            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
            scalars = {k: float(np.asarray(jax.device_get(v)))
                       for k, v in metrics.items()
                       if np.ndim(jax.device_get(v)) == 0}
            self.history.append((self.step, scalars))
            if on_metrics and self.step % self.tcfg.log_every == 0:
                on_metrics(self.step, scalars)
        return self.history

    def _apply_placements(self, placements, perms):
        """Apply a replan: new placement tables + physically moved weights."""
        self.placements = jnp.asarray(placements, jnp.int32)
        moe = self.params["layers"]["moe"]
        prev = getattr(self, "_cur_perms", None)

        def permute_layer(stacked, take):
            return jnp.stack([jnp.take(stacked[i], jnp.asarray(take[i]), axis=0)
                              for i in range(len(take))])

        takes = []
        for i, perm in enumerate(perms):
            if prev is not None:
                cur_pos = np.argsort(prev[i])
                takes.append(cur_pos[perm])
            else:
                takes.append(np.asarray(perm))
        for kname in ("up", "gate", "down"):
            if kname in moe:
                moe[kname]["w"] = permute_layer(moe[kname]["w"], takes)
        self._cur_perms = [np.asarray(p) for p in perms]
