"""Balanced Subset Sum (BSS) — the per-slot sub-problem of the paper's scheduler.

The paper (§4.2, and the companion manuscript [F+14] arXiv:1401.0355) reduces
``P||C_max`` to a sequence of *Balanced Subset Sum* problems via dynamic
programming decomposition: for each slot in turn, select a subset of the
remaining operations whose total load is as close as possible to the balanced
target ``T = remaining_total / remaining_slots``.

We provide:

* :func:`bss_exact` — exact DP over achievable sums (weakly NP-hard /
  pseudo-polynomial), for small integer instances and as the test oracle.
* :func:`bss_approx` — FPTAS-style grid DP with relative error ``<= eta``,
  implemented with Python big-int bitsets so a 480-operation, ``eta=0.002``
  instance solves in milliseconds (paper Fig 10: < 0.5 s end to end).

Both return the *indices* of the chosen subset.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

__all__ = ["bss_exact", "bss_approx", "subset_closest_to_target"]


def _reconstruct(units: Sequence[int], snapshots: List[int], g: int) -> List[int]:
    """Walk the per-item reachability snapshots backwards to recover a subset.

    ``snapshots[i]`` is the reachability bitset *after* considering items
    ``0..i-1`` (so ``snapshots[0] == 1``, only sum 0 reachable).
    """
    chosen: List[int] = []
    for i in range(len(units) - 1, -1, -1):
        before = snapshots[i]
        if (before >> g) & 1:
            # ``g`` was already reachable without item i — skip it.
            continue
        # Item i must be part of the subset.
        chosen.append(i)
        g -= units[i]
        assert g >= 0, "BSS reconstruction walked below zero"
    chosen.reverse()
    return chosen


def _bitset_dp(units: Sequence[int], bound: int) -> Tuple[int, List[int]]:
    """0/1 subset-sum reachability over ``[0, bound]`` with big-int bitsets.

    Returns ``(final_bitset, snapshots)`` where snapshots[i] is the bitset
    before item ``i`` was applied.
    """
    mask = (1 << (bound + 1)) - 1
    reach = 1  # only the empty sum
    snapshots: List[int] = []
    for u in units:
        snapshots.append(reach)
        if u <= bound:
            reach |= (reach << u) & mask
    return reach, snapshots


def _closest_bit(reach: int, target: int, bound: int) -> int:
    """Index of the set bit in ``reach`` closest to ``target`` (ties: lower)."""
    # One O(bits) conversion, then an outward scan over a flat string —
    # avoids O(bits) big-int shifts per probe.
    bits = bin(reach)[2:][::-1]  # bits[i] == '1'  <=>  sum i reachable
    n = len(bits)
    target = min(target, bound)
    for dist in range(0, bound + 1):
        lo = target - dist
        hi = target + dist
        if 0 <= lo < n and bits[lo] == "1":
            return lo
        if lo < 0 and hi >= n:
            break
        if hi < n and bits[hi] == "1":
            return hi
    # Sum 0 (empty subset) is always reachable.
    return 0


def subset_closest_to_target(
    units: Sequence[int], target: int, bound: int | None = None
) -> List[int]:
    """Exact: subset of ``units`` whose sum is closest to ``target``.

    ``bound`` caps the DP table (defaults to a small overshoot above target —
    any sum further above the target than the largest single item can never
    be closest).
    """
    if not units:
        return []
    if bound is None:
        bound = target + max(units)
    bound = max(bound, 1)
    reach, snaps = _bitset_dp(units, bound)
    g = _closest_bit(reach, min(target, bound), bound)
    return _reconstruct(units, snaps, g)


def bss_exact(loads: Sequence[float], target: float) -> List[int]:
    """Exact BSS for integer-ish loads (test oracle; pseudo-polynomial)."""
    units = [int(round(x)) for x in loads]
    if any(u < 0 for u in units):
        raise ValueError("loads must be non-negative")
    return subset_closest_to_target(units, int(round(target)))


def bss_approx(loads: Sequence[float], target: float, eta: float = 0.002) -> List[int]:
    """FPTAS-style BSS: subset with ``|sum - target| <= eta * target`` of optimal.

    Loads are rounded down onto a grid of ``delta = eta * target / k`` so the
    accumulated rounding error over at most ``k`` chosen items is bounded by
    ``eta * target``. The DP is a big-int bitset shift-or, O(k) shifts of a
    ``O(k/eta)``-bit integer.
    """
    k = len(loads)
    if k == 0:
        return []
    if target <= 0:
        return []
    if eta <= 0:
        return bss_exact(loads, target)
    delta = (eta * target) / k
    if delta <= 0:
        delta = 1.0
    units = [int(x / delta) for x in loads]
    tgt = int(target / delta)
    # Allow a modest overshoot window: a sum slightly above target can still
    # be the closest achievable one.
    bound = tgt + max(max(units), 1)
    reach, snaps = _bitset_dp(units, bound)
    g = _closest_bit(reach, tgt, bound)
    return _reconstruct(units, snaps, g)
