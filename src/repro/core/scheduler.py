"""``Q||C_max`` schedulers for operation-level load balance (paper §3.2, §4.2).

The scheduling problem: assign ``n`` Reduce operations (or operation
clusters) with loads ``k_1..k_n`` to ``m`` slots minimising the makespan.
The paper treats the identical-slots case ``P||C_max`` (strongly NP-hard
[Ho98]); real fleets have stragglers and mixed device generations, so every
strategy here generalises to *uniform machines* ``Q||C_max``: slot ``j``
processes load at relative speed ``s_j`` (1.0 = nominal) and an operation
of load ``w`` placed on it contributes ``w / s_j`` of *finish time*.
``speeds=None`` (or all-ones) recovers ``P||C_max`` exactly — assignments
are bit-identical to the speed-oblivious algorithms, which the golden
regression test pins.

Implemented strategies (all return a :class:`Schedule`):

* :func:`schedule_hash`      — the MapReduce default, eq. (3-1): ``Hash(k) mod m``.
                               Speed-*oblivious* by design: the baseline.
* :func:`schedule_lpt`       — Graham's Longest Processing Time, placing each
                               operation on the slot with the earliest finish
                               time (4/3-approx on P, 2-approx on Q).
* :func:`schedule_multifit`  — MULTIFIT (binary search on a finish-time
                               deadline; slot capacity = deadline × speed).
* :func:`schedule_bss`       — the paper's algorithm: dynamic programming
                               decomposition into per-slot Balanced Subset Sum
                               problems with speed-proportional targets,
                               solved with an ``eta``-FPTAS.
* :func:`schedule_brute`     — exact branch-and-bound over finish times for
                               tiny instances (test oracle).
* :func:`lpt_assign_jax`     — a JAX-traceable earliest-finish-time LPT usable
                               *inside* a jitted step (sort + scan-argmin).

Loads are "number of key-value pairs" in the paper; here any non-negative
measure (tokens routed to an expert, document lengths, request decode
budgets). Speeds come from :mod:`repro.core.slot_speeds` (online EWMA
estimation from phase-B wave timings) or are passed explicitly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core import bss as _bss

__all__ = [
    "Schedule",
    "normalize_speeds",
    "schedule_hash",
    "schedule_lpt",
    "schedule_multifit",
    "schedule_bss",
    "schedule_brute",
    "get_scheduler",
    "lpt_assign_jax",
    "SCHEDULERS",
    "AUTO_CANDIDATES",
]


def normalize_speeds(
    speeds: Optional[Sequence[float]], num_slots: int
) -> Optional[np.ndarray]:
    """Validate a ``speeds`` argument: None stays None (≡ all slots nominal).

    Returns a float64 ``(num_slots,)`` array of non-negative relative
    speeds, or ``None``. Strategies treat ``None`` and all-ones identically.
    An **exact 0.0 means the slot is dead** (vanished from the mesh): every
    strategy excludes it from assignment entirely — elastic-mesh semantics,
    not "infinitely slow". Negative / non-finite speeds and an all-zero
    vector (no slot can make progress) are rejected.
    """
    if speeds is None:
        return None
    speeds = np.asarray(speeds, dtype=np.float64)
    if speeds.shape != (num_slots,):
        raise ValueError(
            f"speeds must have shape ({num_slots},), got {speeds.shape}"
        )
    if np.any(~np.isfinite(speeds)) or np.any(speeds < 0):
        raise ValueError("slot speeds must be finite and >= 0 (0 = dead slot)")
    if speeds.size and not np.any(speeds > 0):
        raise ValueError("all slots dead: at least one speed must be > 0")
    return speeds


def _dead_slot_split(
    speeds: Optional[Sequence[float]], num_slots: int
):
    """``(alive_idx, compact_speeds)`` when dead (speed-0) slots exist, else None.

    The strategies use this to *compact* the instance onto the surviving
    slots, run the unchanged all-alive algorithm there, and remap the
    assignment back through ``alive_idx`` — so a dead slot never receives
    work and the all-alive code paths stay bit-identical.
    """
    s = normalize_speeds(speeds, num_slots)
    if s is None or not np.any(s == 0.0):
        return None
    alive = np.flatnonzero(s > 0.0)
    return alive, s[alive]


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Result of scheduling ``n`` operations onto ``m`` (possibly uneven) slots.

    Derived metrics come in two spaces:

    * load space (the paper's P||C_max view): ``slot_loads`` / ``max_load``
      / ``balance_ratio`` — what each slot *holds*;
    * finish-time space (Q||C_max): ``slot_finish = slot_loads /
      slot_speeds``, ``makespan`` (the job's completion time) and
      ``finish_ratio = makespan / ideal_finish`` — what each slot *takes*.

    With uniform speeds the two coincide (``makespan == max_load``).
    Direct construction ``Schedule(assignment, num_slots)`` is valid:
    ``__post_init__`` derives ``slot_loads`` from unit operation loads and
    defaults speeds to nominal, so no field is ever left ``None``.
    """

    assignment: np.ndarray  # (n,) int32 — slot id per operation
    num_slots: int

    # --- derived (computed in __post_init__ when not given) ---------------
    slot_loads: Optional[np.ndarray] = None   # (m,) load held per slot
    slot_speeds: Optional[np.ndarray] = None  # (m,) relative speed, 1 = nominal

    def __post_init__(self):
        """Normalise arrays and derive missing metrics (unit loads, nominal speeds)."""
        assignment = np.asarray(self.assignment, dtype=np.int32)
        object.__setattr__(self, "assignment", assignment)
        if self.slot_loads is None:
            loads = np.bincount(assignment, minlength=self.num_slots)
            object.__setattr__(
                self, "slot_loads", loads[: self.num_slots].astype(np.float64)
            )
        else:
            object.__setattr__(
                self, "slot_loads", np.asarray(self.slot_loads, np.float64)
            )
        if self.slot_speeds is None:
            object.__setattr__(self, "slot_speeds", np.ones(self.num_slots))
        else:
            object.__setattr__(
                self, "slot_speeds",
                normalize_speeds(self.slot_speeds, self.num_slots),
            )

    @staticmethod
    def from_assignment(
        assignment: np.ndarray,
        loads: np.ndarray,
        num_slots: int,
        speeds: Optional[Sequence[float]] = None,
    ) -> "Schedule":
        """Build a Schedule (with derived metrics) from an assignment."""
        assignment = np.asarray(assignment, dtype=np.int32)
        loads = np.asarray(loads, dtype=np.float64)
        slot_loads = np.bincount(assignment, weights=loads, minlength=num_slots)
        return Schedule(
            assignment=assignment,
            num_slots=num_slots,
            slot_loads=slot_loads,
            slot_speeds=normalize_speeds(speeds, num_slots),
        )

    # --- load space (P||C_max view) ---------------------------------------

    @property
    def max_load(self) -> float:
        """Largest load held by any slot (speed-blind)."""
        return float(self.slot_loads.max()) if self.num_slots else 0.0

    @property
    def ideal_load(self) -> float:
        """Perfectly even split of the total load."""
        if not self.num_slots:
            return 0.0
        return float(self.slot_loads.sum()) / self.num_slots

    @property
    def balance_ratio(self) -> float:
        """max-load / ideal-load (paper Fig 6; 1.0 is perfect)."""
        if self.ideal_load == 0:
            return 1.0
        return self.max_load / self.ideal_load

    # --- finish-time space (Q||C_max view) --------------------------------

    @property
    def slot_finish(self) -> np.ndarray:
        """Per-slot completion time: ``slot_loads / slot_speeds``.

        A dead slot (speed 0) finishes at 0 when it holds no load — the
        invariant every strategy maintains — and at ``inf`` when it does
        (work stranded on a vanished slot never completes).
        """
        with np.errstate(divide="ignore", invalid="ignore"):
            finish = self.slot_loads / self.slot_speeds
        dead = self.slot_speeds == 0.0
        if np.any(dead):
            finish = np.where(dead & (self.slot_loads == 0.0), 0.0, finish)
            finish = np.where(dead & (self.slot_loads > 0.0), np.inf, finish)
        return finish

    @property
    def makespan(self) -> float:
        """Job completion time: the slowest slot's finish time."""
        return float(self.slot_finish.max()) if self.num_slots else 0.0

    @property
    def ideal_finish(self) -> float:
        """Lower bound: total load spread over the aggregate speed."""
        total_speed = float(self.slot_speeds.sum()) if self.num_slots else 0.0
        if total_speed == 0:
            return 0.0
        return float(self.slot_loads.sum()) / total_speed

    @property
    def finish_ratio(self) -> float:
        """makespan / ideal-finish — the speed-normalised balance_ratio."""
        if self.ideal_finish == 0:
            return 1.0
        return self.makespan / self.ideal_finish

    @property
    def rel_std(self) -> float:
        """std(slot finish times) / mean — heterogeneity-aware error bar."""
        finish = self.slot_finish
        mean = finish.mean()
        if mean == 0:
            return 0.0
        return float(finish.std() / mean)


def _speeds_or_ones(speeds: Optional[Sequence[float]], num_slots: int) -> np.ndarray:
    """Concrete speed vector for the assignment loops (None → nominal)."""
    s = normalize_speeds(speeds, num_slots)
    return np.ones(num_slots) if s is None else s


# ---------------------------------------------------------------------------
# Baseline: the MapReduce default hash partitioner (paper eq. 3-1).
# ---------------------------------------------------------------------------


def _default_hash(keys: np.ndarray) -> np.ndarray:
    """A deterministic integer mix (64-bit splitmix-style) of the key ids.

    Using the identity here would make ``key mod m`` artificially uniform for
    dense key ids; a real partitioner hashes, so we hash.
    """
    k = np.asarray(keys, dtype=np.uint64)
    k = (k ^ (k >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    k = (k ^ (k >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    k = k ^ (k >> np.uint64(31))
    return k


def schedule_hash(
    loads: Sequence[float],
    num_slots: int,
    keys: Optional[np.ndarray] = None,
    hash_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
    speeds: Optional[Sequence[float]] = None,
) -> Schedule:
    """Default MapReduce partitioning: ``i = |Hash(k)| mod m`` (eq. 3-1).

    Oblivious to both load *and* speed — the assignment ignores ``speeds``
    entirely (that is the point of the baseline); they are only recorded on
    the returned :class:`Schedule` so its finish-time metrics are honest.
    """
    loads = np.asarray(loads, dtype=np.float64)
    n = loads.shape[0]
    if keys is None:
        keys = np.arange(n)
    hashed = (hash_fn or _default_hash)(np.asarray(keys))
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        # Elastic mesh: hash onto the surviving slots only (mod num_alive,
        # remapped to the alive slot ids) — still load- and speed-oblivious
        # among the living, but a vanished slot receives nothing.
        alive, _ = dead
        idx = (hashed % np.uint64(alive.size)).astype(np.int64)
        assignment = alive[idx].astype(np.int32)
    else:
        assignment = (hashed % np.uint64(num_slots)).astype(np.int32)
    return Schedule.from_assignment(assignment, loads, num_slots, speeds=speeds)


# ---------------------------------------------------------------------------
# Graham's LPT, earliest-finish-time variant (host-side).
# ---------------------------------------------------------------------------


def schedule_lpt(
    loads: Sequence[float],
    num_slots: int,
    speeds: Optional[Sequence[float]] = None,
) -> Schedule:
    """Longest Processing Time first, placed by earliest finish time.

    Each operation (descending load) goes to the slot where it would
    *complete* soonest: ``argmin_j (load_j + w) / s_j``. With uniform
    speeds this is exactly Graham's LPT (4/3-approximation [Gr69]); on
    uniform machines it is the standard 2-approximation for Q||C_max.
    """
    loads = np.asarray(loads, dtype=np.float64)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        alive, s_alive = dead
        inner = schedule_lpt(loads, alive.size, speeds=s_alive)
        return Schedule.from_assignment(
            alive[inner.assignment], loads, num_slots, speeds=speeds
        )
    s = _speeds_or_ones(speeds, num_slots)
    n = loads.shape[0]
    order = np.argsort(-loads, kind="stable")
    assignment = np.zeros(n, dtype=np.int32)
    slot_loads = np.zeros(num_slots)
    for j in order:
        slot = int(np.argmin((slot_loads + loads[j]) / s))
        assignment[j] = slot
        slot_loads[slot] += loads[j]
    return Schedule.from_assignment(assignment, loads, num_slots, speeds=speeds)


# ---------------------------------------------------------------------------
# MULTIFIT: binary search on a finish-time deadline with first-fit-decreasing.
# ---------------------------------------------------------------------------


def _ffd_fits(
    loads_desc: np.ndarray,
    num_slots: int,
    deadline: float,
    speeds: np.ndarray,
    slot_order: np.ndarray,
) -> Optional[np.ndarray]:
    """First-fit-decreasing against per-slot capacity ``deadline * speed``.

    Slots are probed fastest-first (``slot_order``); returns the assignment
    (in sorted-operation order) or None when some operation does not fit.
    """
    slot_loads = np.zeros(num_slots)
    caps = deadline * speeds
    assignment = np.empty(loads_desc.shape[0], dtype=np.int32)
    for j, w in enumerate(loads_desc):
        placed = False
        for s in slot_order:
            if slot_loads[s] + w <= caps[s]:
                slot_loads[s] += w
                assignment[j] = s
                placed = True
                break
        if not placed:
            return None
    return assignment


def schedule_multifit(
    loads: Sequence[float],
    num_slots: int,
    iters: int = 20,
    speeds: Optional[Sequence[float]] = None,
) -> Schedule:
    """MULTIFIT: binary search on a finish-time deadline with an FFD probe.

    The classic bin-capacity search, lifted to Q||C_max: a probe at
    deadline ``C`` gives slot ``j`` capacity ``C * s_j`` (the load it can
    finish by ``C``). Uniform speeds reduce to the original algorithm.
    """
    loads = np.asarray(loads, dtype=np.float64)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        alive, s_alive = dead
        inner = schedule_multifit(loads, alive.size, iters=iters, speeds=s_alive)
        return Schedule.from_assignment(
            alive[inner.assignment], loads, num_slots, speeds=speeds
        )
    s = _speeds_or_ones(speeds, num_slots)
    order = np.argsort(-loads, kind="stable")
    loads_desc = loads[order]
    # Fastest slots first — stable, so uniform speeds keep the 0..m-1 order.
    slot_order = np.argsort(-s, kind="stable")
    total = loads.sum()
    biggest = loads_desc[0] if loads.size else 0.0
    lo = max(total / s.sum(), biggest / s.max())
    hi = max(2 * total / s.sum(), biggest / s.max())
    best = None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        fit = _ffd_fits(loads_desc, num_slots, mid, s, slot_order)
        if fit is not None:
            best = fit
            hi = mid
        else:
            lo = mid
    if best is None:
        best = _ffd_fits(loads_desc, num_slots, hi, s, slot_order)
        if best is None:  # pragma: no cover - hi is always feasible eventually
            return schedule_lpt(loads, num_slots, speeds=speeds)
    assignment = np.empty_like(best)
    assignment[order] = best
    return Schedule.from_assignment(assignment, loads, num_slots, speeds=speeds)


# ---------------------------------------------------------------------------
# The paper's algorithm: DP decomposition into Balanced Subset Sum.
# ---------------------------------------------------------------------------


def schedule_bss(
    loads: Sequence[float],
    num_slots: int,
    eta: float = 0.002,
    refine: bool = True,
    speeds: Optional[Sequence[float]] = None,
) -> Schedule:
    """Dynamic-programming decomposition over per-slot BSS sub-problems.

    Slots are peeled fastest-first; each slot's balanced target is its
    speed-proportional share ``T_j = remaining_total * s_j / remaining_speed``
    (the finish-balanced split — uniform speeds give the paper's
    ``remaining_total / remaining_slots``, §4.2 / [F+14]) and the
    remaining-operation subset whose load sum is closest to ``T_j`` is
    picked with an ``eta``-FPTAS; the last slot takes the remainder.
    Operations larger than the target get a dedicated slot (they dominate
    the makespan on their own; packing more onto that slot can only hurt).

    ``refine=True`` runs a cheap post-pass: if the makespan slot can donate
    an operation to the earliest-finishing slot and improve, do so
    (repeat). This recovers a little of the FPTAS rounding slack.
    """
    loads = np.asarray(loads, dtype=np.float64)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        alive, s_alive = dead
        inner = schedule_bss(
            loads, alive.size, eta=eta, refine=refine, speeds=s_alive
        )
        return Schedule.from_assignment(
            alive[inner.assignment], loads, num_slots, speeds=speeds
        )
    s = _speeds_or_ones(speeds, num_slots)
    n = loads.shape[0]
    assignment = np.full(n, -1, dtype=np.int32)
    if n == 0:
        return Schedule.from_assignment(
            np.zeros(0, np.int32), loads, num_slots, speeds=speeds
        )

    # Fastest slots first (stable → uniform speeds keep slot order 0..m-1):
    # the big subsets should land on the slots that can absorb them.
    slot_order = np.argsort(-s, kind="stable")
    remaining = list(np.argsort(-loads, kind="stable"))  # indices, descending load
    for rank in range(num_slots - 1):
        if not remaining:
            break
        slot = int(slot_order[rank])
        rem_loads = loads[remaining]
        total_rem = float(rem_loads.sum())
        speed_rem = float(s[slot_order[rank:]].sum())
        target = total_rem * float(s[slot]) / speed_rem
        if loads[remaining[0]] >= target and len(remaining) > 1:
            # A single dominating operation: isolate it (paper's huge-key case —
            # e.g. the 1.97e6-pair operation of Fig 1a).
            assignment[remaining.pop(0)] = slot
            continue
        chosen = _bss.bss_approx([float(x) for x in rem_loads], target, eta=eta)
        if not chosen:
            chosen = [0]
        chosen_set = set(chosen)
        for local_idx in sorted(chosen_set, reverse=True):
            assignment[remaining[local_idx]] = slot
        remaining = [g for i, g in enumerate(remaining) if i not in chosen_set]
    last_slot = int(slot_order[num_slots - 1])
    for g in remaining:
        assignment[g] = last_slot

    sched = Schedule.from_assignment(assignment, loads, num_slots, speeds=speeds)
    if refine:
        sched = _refine_moves(sched, loads)
        # The DP decomposition is near-optimal on skewed instances but can
        # lose to plain LPT on tiny/uniform ones; both are cheap host-side,
        # so keep whichever schedule is better (never worse than LPT).
        lpt = schedule_lpt(loads, num_slots, speeds=speeds)
        if lpt.makespan < sched.makespan:
            sched = lpt
    return sched


def _refine_moves(sched: Schedule, loads: np.ndarray, max_moves: int = 256) -> Schedule:
    """Greedy post-pass: donate ops from the makespan slot while it improves.

    Works in finish-time space, so a slow slot sheds work to fast idle
    slots; with uniform speeds this is exactly the load-space pass.
    """
    assignment = sched.assignment.copy()
    slot_loads = sched.slot_loads.copy()
    speeds = sched.slot_speeds
    for _ in range(max_moves):
        finish = slot_loads / speeds
        src = int(finish.argmax())
        dst = int(finish.argmin())
        if src == dst:
            break
        ops = np.nonzero(assignment == src)[0]
        if ops.size <= 1:
            break
        # An op w helps only if the destination stays under the current
        # makespan: (load_dst + w) / s_dst < finish_src.
        headroom = finish[src] * speeds[dst] - slot_loads[dst]
        cand = ops[loads[ops] < headroom]
        if cand.size == 0:
            break
        # Move the largest op that still improves the makespan.
        j = cand[np.argmax(loads[cand])]
        new_src = slot_loads[src] - loads[j]
        new_dst = slot_loads[dst] + loads[j]
        if max(new_src / speeds[src], new_dst / speeds[dst]) >= finish[src]:
            break
        assignment[j] = dst
        slot_loads[src] = new_src
        slot_loads[dst] = new_dst
    return Schedule.from_assignment(
        assignment, loads, sched.num_slots, speeds=sched.slot_speeds
    )


# ---------------------------------------------------------------------------
# Exact solver for tiny instances (test oracle).
# ---------------------------------------------------------------------------


def schedule_brute(
    loads: Sequence[float],
    num_slots: int,
    speeds: Optional[Sequence[float]] = None,
) -> Schedule:
    """Exact optimum by symmetry-pruned branch-and-bound (n ≤ 14; test oracle).

    Minimises the *makespan* ``max_j load_j / s_j``; slots are symmetric
    (interchangeable) only when both load and speed match.
    """
    loads = np.asarray(loads, dtype=np.float64)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        alive, s_alive = dead
        inner = schedule_brute(loads, alive.size, speeds=s_alive)
        return Schedule.from_assignment(
            alive[inner.assignment], loads, num_slots, speeds=speeds
        )
    s = _speeds_or_ones(speeds, num_slots)
    n = loads.shape[0]
    if n > 14:
        raise ValueError("brute force is for tiny test instances only")
    best_assign = np.zeros(n, dtype=np.int32)
    best_max = float("inf")
    assign = np.zeros(n, dtype=np.int32)
    slot_loads = np.zeros(num_slots)
    order = np.argsort(-loads, kind="stable")

    def rec(i: int) -> None:
        """Place operation order[i] on every non-symmetric slot, pruned."""
        nonlocal best_max, best_assign
        if (slot_loads / s).max() >= best_max:
            return
        if i == n:
            best_max = float((slot_loads / s).max())
            best_assign = assign.copy()
            return
        j = order[i]
        seen: set = set()
        for k in range(num_slots):
            key = (round(slot_loads[k], 9), round(float(s[k]), 9))
            if key in seen:
                continue  # symmetry: equal (load, speed) slots are interchangeable
            seen.add(key)
            slot_loads[k] += loads[j]
            assign[j] = k
            rec(i + 1)
            slot_loads[k] -= loads[j]

    rec(0)
    return Schedule.from_assignment(best_assign, loads, num_slots, speeds=speeds)


SCHEDULERS: Dict[str, Callable[..., Schedule]] = {
    "hash": schedule_hash,
    "lpt": schedule_lpt,
    "multifit": schedule_multifit,
    "bss": schedule_bss,
    "os4m": schedule_bss,  # alias: the paper's method
}

# The candidate pool "auto" mode chooses from (simulator.pick_strategy):
# every concrete algorithm, cheapest-overhead first so cost ties resolve to
# the cheaper scheduler.
AUTO_CANDIDATES = ("hash", "lpt", "multifit", "bss")


def get_scheduler(name: str) -> Callable[..., Schedule]:
    """Look up a concrete scheduling function by name (see ``SCHEDULERS``)."""
    try:
        return SCHEDULERS[name]
    except KeyError as exc:
        raise ValueError(
            f"unknown scheduler {name!r}; options: {sorted(SCHEDULERS)} "
            "(or 'auto' at the MapReduceConfig level, resolved by "
            "simulator.pick_strategy)"
        ) from exc


# ---------------------------------------------------------------------------
# JAX-traceable LPT (usable inside a jitted step).
# ---------------------------------------------------------------------------


def lpt_assign_jax(loads, num_slots: int, speeds=None):
    """Earliest-finish-time LPT as pure JAX ops: ``(assignment, slot_loads)``.

    ``loads``: (n,) array; ``speeds``: optional (num_slots,) relative slot
    speeds (None ≡ all nominal). Differentiability is not needed — this is
    integer scheduling — but the function is trace-safe (static
    ``num_slots``) so a step can re-balance without leaving the device.
    O(n log n + n·m) work, fine for n up to a few thousand
    operations/experts.
    """
    import jax
    import jax.numpy as jnp

    loads = jnp.asarray(loads)
    n = loads.shape[0]
    if speeds is None:
        speeds_arr = jnp.ones((num_slots,), loads.dtype)
    else:
        # Fractional speeds must not truncate against integer loads: run
        # the placement arithmetic in a float dtype (integer token counts
        # below 2^24 stay exact in f32).
        compute_dtype = jnp.promote_types(loads.dtype, jnp.float32)
        loads = loads.astype(compute_dtype)
        speeds_arr = jnp.asarray(speeds, compute_dtype)
    order = jnp.argsort(-loads)
    sorted_loads = loads[order]

    def body(slot_loads, w):
        """One EFT placement step: put w where it would finish earliest.

        Dead slots (speed exactly 0) are masked to an infinite finish time
        so the argmin never selects them — the traced analogue of the host
        strategies' alive-compaction.
        """
        finish = jnp.where(
            speeds_arr > 0,
            (slot_loads + w) / jnp.where(speeds_arr > 0, speeds_arr, 1.0),
            jnp.inf,
        )
        slot = jnp.argmin(finish)
        slot_loads = slot_loads.at[slot].add(w)
        return slot_loads, slot

    slot_loads, slots_sorted = jax.lax.scan(
        body, jnp.zeros((num_slots,), loads.dtype), sorted_loads
    )
    assignment = jnp.zeros((n,), jnp.int32).at[order].set(slots_sorted.astype(jnp.int32))
    return assignment, slot_loads
