"""``P||C_max`` schedulers for operation-level load balance (paper §3.2, §4.2).

The scheduling problem: assign ``n`` Reduce operations (or operation
clusters) with loads ``k_1..k_n`` to ``m`` slots minimising the max slot
load (makespan). Strongly NP-hard [Ho98].

Implemented strategies (all return a :class:`Schedule`):

* :func:`schedule_hash`      — the MapReduce default, eq. (3-1): ``Hash(k) mod m``.
                               This is the paper's baseline.
* :func:`schedule_lpt`       — Graham's Longest Processing Time (4/3-approx).
* :func:`schedule_multifit`  — MULTIFIT (binary search on capacity + FFD).
* :func:`schedule_bss`       — the paper's algorithm: dynamic programming
                               decomposition into per-slot Balanced Subset Sum
                               problems, solved with an ``eta``-FPTAS
                               (near-optimal; Fig 6 shows max/ideal ≈ 1).
* :func:`schedule_brute`     — exact branch-and-bound for tiny instances
                               (test oracle).
* :func:`lpt_assign_jax`     — a JAX-traceable LPT usable *inside* a jitted
                               step (sort + scan-argmin), for in-step
                               re-balancing where a host round-trip is not
                               affordable.

Loads are "number of key-value pairs" in the paper; here any non-negative
measure (tokens routed to an expert, document lengths, request decode
budgets).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core import bss as _bss

__all__ = [
    "Schedule",
    "schedule_hash",
    "schedule_lpt",
    "schedule_multifit",
    "schedule_bss",
    "schedule_brute",
    "get_scheduler",
    "lpt_assign_jax",
    "SCHEDULERS",
    "AUTO_CANDIDATES",
]


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Result of scheduling ``n`` operations onto ``m`` slots."""

    assignment: np.ndarray  # (n,) int32 — slot id per operation
    num_slots: int

    # --- derived metrics -------------------------------------------------
    slot_loads: np.ndarray = dataclasses.field(default=None)  # type: ignore[assignment]
    max_load: float = 0.0
    ideal_load: float = 0.0

    @staticmethod
    def from_assignment(
        assignment: np.ndarray, loads: np.ndarray, num_slots: int
    ) -> "Schedule":
        """Build a Schedule (with derived load metrics) from an assignment."""
        assignment = np.asarray(assignment, dtype=np.int32)
        loads = np.asarray(loads, dtype=np.float64)
        slot_loads = np.bincount(assignment, weights=loads, minlength=num_slots)
        total = float(loads.sum())
        return Schedule(
            assignment=assignment,
            num_slots=num_slots,
            slot_loads=slot_loads,
            max_load=float(slot_loads.max()) if num_slots else 0.0,
            ideal_load=total / num_slots if num_slots else 0.0,
        )

    @property
    def balance_ratio(self) -> float:
        """max-load / ideal-load (paper Fig 6; 1.0 is perfect)."""
        if self.ideal_load == 0:
            return 1.0
        return self.max_load / self.ideal_load

    @property
    def rel_std(self) -> float:
        """std(slot loads) / mean(slot loads) (paper error bars)."""
        mean = self.slot_loads.mean()
        if mean == 0:
            return 0.0
        return float(self.slot_loads.std() / mean)


# ---------------------------------------------------------------------------
# Baseline: the MapReduce default hash partitioner (paper eq. 3-1).
# ---------------------------------------------------------------------------


def _default_hash(keys: np.ndarray) -> np.ndarray:
    """A deterministic integer mix (64-bit splitmix-style) of the key ids.

    Using the identity here would make ``key mod m`` artificially uniform for
    dense key ids; a real partitioner hashes, so we hash.
    """
    k = np.asarray(keys, dtype=np.uint64)
    k = (k ^ (k >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    k = (k ^ (k >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    k = k ^ (k >> np.uint64(31))
    return k


def schedule_hash(
    loads: Sequence[float],
    num_slots: int,
    keys: Optional[np.ndarray] = None,
    hash_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
) -> Schedule:
    """Default MapReduce partitioning: ``i = |Hash(k)| mod m`` (eq. 3-1)."""
    loads = np.asarray(loads, dtype=np.float64)
    n = loads.shape[0]
    if keys is None:
        keys = np.arange(n)
    hashed = (hash_fn or _default_hash)(np.asarray(keys))
    assignment = (hashed % np.uint64(num_slots)).astype(np.int32)
    return Schedule.from_assignment(assignment, loads, num_slots)


# ---------------------------------------------------------------------------
# Graham's LPT (host-side).
# ---------------------------------------------------------------------------


def schedule_lpt(loads: Sequence[float], num_slots: int) -> Schedule:
    """Longest Processing Time first — 4/3-approximation [Gr69]."""
    loads = np.asarray(loads, dtype=np.float64)
    n = loads.shape[0]
    order = np.argsort(-loads, kind="stable")
    assignment = np.zeros(n, dtype=np.int32)
    # heap of (slot_load, slot_id)
    heap = [(0.0, i) for i in range(num_slots)]
    heapq.heapify(heap)
    for j in order:
        load, slot = heapq.heappop(heap)
        assignment[j] = slot
        heapq.heappush(heap, (load + loads[j], slot))
    return Schedule.from_assignment(assignment, loads, num_slots)


# ---------------------------------------------------------------------------
# MULTIFIT: binary search on bin capacity with first-fit-decreasing.
# ---------------------------------------------------------------------------


def _ffd_fits(loads_desc: np.ndarray, num_slots: int, capacity: float) -> Optional[np.ndarray]:
    """First-fit-decreasing; returns assignment (in sorted order) or None."""
    slot_loads = np.zeros(num_slots)
    assignment = np.empty(loads_desc.shape[0], dtype=np.int32)
    for j, w in enumerate(loads_desc):
        placed = False
        for s in range(num_slots):
            if slot_loads[s] + w <= capacity:
                slot_loads[s] += w
                assignment[j] = s
                placed = True
                break
        if not placed:
            return None
    return assignment


def schedule_multifit(
    loads: Sequence[float], num_slots: int, iters: int = 20
) -> Schedule:
    """MULTIFIT: binary search on bin capacity with an FFD feasibility probe."""
    loads = np.asarray(loads, dtype=np.float64)
    order = np.argsort(-loads, kind="stable")
    loads_desc = loads[order]
    total = loads.sum()
    lo = max(total / num_slots, loads_desc[0] if loads.size else 0.0)
    hi = max(2 * total / num_slots, loads_desc[0] if loads.size else 0.0)
    best = None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        fit = _ffd_fits(loads_desc, num_slots, mid)
        if fit is not None:
            best = fit
            hi = mid
        else:
            lo = mid
    if best is None:
        best = _ffd_fits(loads_desc, num_slots, hi)
        if best is None:  # pragma: no cover - hi is always feasible eventually
            return schedule_lpt(loads, num_slots)
    assignment = np.empty_like(best)
    assignment[order] = best
    return Schedule.from_assignment(assignment, loads, num_slots)


# ---------------------------------------------------------------------------
# The paper's algorithm: DP decomposition into Balanced Subset Sum.
# ---------------------------------------------------------------------------


def schedule_bss(
    loads: Sequence[float],
    num_slots: int,
    eta: float = 0.002,
    refine: bool = True,
) -> Schedule:
    """Dynamic-programming decomposition over per-slot BSS sub-problems.

    For slots ``1..m-1``: set the balanced target ``T = remaining_total /
    remaining_slots`` and pick the remaining-operation subset whose load sum
    is closest to ``T`` (``eta``-approximate, §4.2 / [F+14]); the last slot
    takes the remainder. Operations larger than ``T`` are given a dedicated
    slot (they dominate the makespan on their own; packing more onto that
    slot can only hurt).

    ``refine=True`` runs a cheap post-pass: if the makespan slot can donate
    its smallest operation to the min-loaded slot and improve, do so
    (repeat). This recovers a little of the FPTAS rounding slack.
    """
    loads = np.asarray(loads, dtype=np.float64)
    n = loads.shape[0]
    assignment = np.full(n, -1, dtype=np.int32)
    if n == 0:
        return Schedule.from_assignment(np.zeros(0, np.int32), loads, num_slots)

    remaining = list(np.argsort(-loads, kind="stable"))  # indices, descending load
    for slot in range(num_slots - 1):
        if not remaining:
            break
        rem_loads = loads[remaining]
        total_rem = float(rem_loads.sum())
        slots_rem = num_slots - slot
        target = total_rem / slots_rem
        if loads[remaining[0]] >= target and len(remaining) > 1:
            # A single dominating operation: isolate it (paper's huge-key case —
            # e.g. the 1.97e6-pair operation of Fig 1a).
            assignment[remaining.pop(0)] = slot
            continue
        chosen = _bss.bss_approx([float(x) for x in rem_loads], target, eta=eta)
        if not chosen:
            chosen = [0]
        chosen_set = set(chosen)
        for local_idx in sorted(chosen_set, reverse=True):
            assignment[remaining[local_idx]] = slot
        remaining = [g for i, g in enumerate(remaining) if i not in chosen_set]
    for g in remaining:
        assignment[g] = num_slots - 1

    sched = Schedule.from_assignment(assignment, loads, num_slots)
    if refine:
        sched = _refine_moves(sched, loads)
        # The DP decomposition is near-optimal on skewed instances but can
        # lose to plain LPT on tiny/uniform ones; both are cheap host-side,
        # so keep whichever schedule is better (never worse than LPT).
        lpt = schedule_lpt(loads, num_slots)
        if lpt.max_load < sched.max_load:
            sched = lpt
    return sched


def _refine_moves(sched: Schedule, loads: np.ndarray, max_moves: int = 256) -> Schedule:
    assignment = sched.assignment.copy()
    slot_loads = sched.slot_loads.copy()
    for _ in range(max_moves):
        src = int(slot_loads.argmax())
        dst = int(slot_loads.argmin())
        if src == dst:
            break
        ops = np.nonzero(assignment == src)[0]
        if ops.size <= 1:
            break
        gap = slot_loads[src] - slot_loads[dst]
        cand = ops[loads[ops] < gap]
        if cand.size == 0:
            break
        # Move the largest op that still improves the makespan.
        j = cand[np.argmax(loads[cand])]
        new_src = slot_loads[src] - loads[j]
        new_dst = slot_loads[dst] + loads[j]
        if max(new_src, new_dst) >= slot_loads[src]:
            break
        assignment[j] = dst
        slot_loads[src] = new_src
        slot_loads[dst] = new_dst
    return Schedule.from_assignment(assignment, loads, sched.num_slots)


# ---------------------------------------------------------------------------
# Exact solver for tiny instances (test oracle).
# ---------------------------------------------------------------------------


def schedule_brute(loads: Sequence[float], num_slots: int) -> Schedule:
    """Exact optimum by symmetry-pruned branch-and-bound (n ≤ 14; test oracle)."""
    loads = np.asarray(loads, dtype=np.float64)
    n = loads.shape[0]
    if n > 14:
        raise ValueError("brute force is for tiny test instances only")
    best_assign = np.zeros(n, dtype=np.int32)
    best_max = float("inf")
    assign = np.zeros(n, dtype=np.int32)
    slot_loads = np.zeros(num_slots)
    order = np.argsort(-loads, kind="stable")

    def rec(i: int) -> None:
        """Place operation order[i] on every non-symmetric slot, pruned."""
        nonlocal best_max, best_assign
        if slot_loads.max() >= best_max:
            return
        if i == n:
            best_max = float(slot_loads.max())
            best_assign = assign.copy()
            return
        j = order[i]
        seen: set = set()
        for s in range(num_slots):
            key = round(slot_loads[s], 9)
            if key in seen:
                continue  # symmetry: identical slot loads are interchangeable
            seen.add(key)
            slot_loads[s] += loads[j]
            assign[j] = s
            rec(i + 1)
            slot_loads[s] -= loads[j]

    rec(0)
    return Schedule.from_assignment(best_assign, loads, num_slots)


SCHEDULERS: Dict[str, Callable[..., Schedule]] = {
    "hash": schedule_hash,
    "lpt": schedule_lpt,
    "multifit": schedule_multifit,
    "bss": schedule_bss,
    "os4m": schedule_bss,  # alias: the paper's method
}

# The candidate pool "auto" mode chooses from (simulator.pick_strategy):
# every concrete algorithm, cheapest-overhead first so cost ties resolve to
# the cheaper scheduler.
AUTO_CANDIDATES = ("hash", "lpt", "multifit", "bss")


def get_scheduler(name: str) -> Callable[..., Schedule]:
    """Look up a concrete scheduling function by name (see ``SCHEDULERS``)."""
    try:
        return SCHEDULERS[name]
    except KeyError as exc:
        raise ValueError(
            f"unknown scheduler {name!r}; options: {sorted(SCHEDULERS)} "
            "(or 'auto' at the MapReduceConfig level, resolved by "
            "simulator.pick_strategy)"
        ) from exc


# ---------------------------------------------------------------------------
# JAX-traceable LPT (usable inside a jitted step).
# ---------------------------------------------------------------------------


def lpt_assign_jax(loads, num_slots: int):
    """LPT as pure JAX ops: returns ``(assignment, slot_loads)``.

    ``loads``: (n,) array. Differentiability is not needed — this is integer
    scheduling — but the function is trace-safe (static ``num_slots``) so a
    step can re-balance without leaving the device. O(n log n + n·m) work,
    fine for n up to a few thousand operations/experts.
    """
    import jax
    import jax.numpy as jnp

    loads = jnp.asarray(loads)
    n = loads.shape[0]
    order = jnp.argsort(-loads)
    sorted_loads = loads[order]

    def body(slot_loads, w):
        """One LPT placement step: drop load w on the least-loaded slot."""
        slot = jnp.argmin(slot_loads)
        slot_loads = slot_loads.at[slot].add(w)
        return slot_loads, slot

    slot_loads, slots_sorted = jax.lax.scan(
        body, jnp.zeros((num_slots,), loads.dtype), sorted_loads
    )
    assignment = jnp.zeros((n,), jnp.int32).at[order].set(slots_sorted.astype(jnp.int32))
    return assignment, slot_loads
