"""``Q||C_max`` / ``R||C_max`` schedulers for operation-level load balance.

The scheduling problem (paper §3.2, §4.2): assign ``n`` Reduce operations
(or operation clusters) with loads ``k_1..k_n`` to ``m`` slots minimising
the makespan. The paper treats the identical-slots case ``P||C_max``
(strongly NP-hard [Ho98]); real fleets have stragglers and mixed device
generations, so every strategy here generalises to *uniform machines*
``Q||C_max``: slot ``j`` processes load at relative speed ``s_j`` (1.0 =
nominal) and an operation of load ``w`` placed on it contributes
``w / s_j`` of *finish time*. ``speeds=None`` (or all-ones) recovers
``P||C_max`` exactly — assignments are bit-identical to the
speed-oblivious algorithms, which the golden regression test pins.

Multi-job fleets generalise one step further, to *unrelated processors*
``R||C_max`` (Fotakis et al., arXiv 1312.4203): operation ``j`` on slot
``i`` takes an arbitrary processing time ``p[j, i]`` — different jobs see
different relative slot speeds (cache residency, NUMA placement, expert
affinity), so no single speed vector explains the matrix. ``lpt`` /
``multifit`` / ``brute`` accept ``proc_times=`` (an ``(n, m)`` matrix;
``+inf`` marks a slot that cannot run the operation — an all-``inf``
column is the PR 6 dead-slot mask in matrix form), and
:func:`schedule_unrelated` adds the R-native EFT-greedy + local-search
strategy. ``speeds=`` remains the rank-1 special case: a matrix that
factors **exactly** as ``loads ⊗ (1/speeds)`` is detected
(:func:`factor_rank1_proc_times`) and delegated to the unchanged
``Q||C_max`` code path, so rank-1 ``proc_times`` reproduce the pinned
``speeds=`` assignments bit-for-bit (exactly so when speed ratios are
powers of two, where binary floating point scaling is lossless).

Implemented strategies (all return a :class:`Schedule`):

* :func:`schedule_hash`      — the MapReduce default, eq. (3-1): ``Hash(k) mod m``.
                               Speed-*oblivious* by design: the baseline.
* :func:`schedule_lpt`       — Graham's Longest Processing Time, placing each
                               operation on the slot with the earliest finish
                               time (4/3-approx on P, 2-approx on Q).
* :func:`schedule_multifit`  — MULTIFIT (binary search on a finish-time
                               deadline; slot capacity = deadline × speed).
* :func:`schedule_bss`       — the paper's algorithm: dynamic programming
                               decomposition into per-slot Balanced Subset Sum
                               problems with speed-proportional targets,
                               solved with an ``eta``-FPTAS.
* :func:`schedule_brute`     — exact branch-and-bound over finish times for
                               tiny instances (test oracle).
* :func:`lpt_assign_jax`     — a JAX-traceable earliest-finish-time LPT usable
                               *inside* a jitted step (sort + scan-argmin).

Loads are "number of key-value pairs" in the paper; here any non-negative
measure (tokens routed to an expert, document lengths, request decode
budgets). Speeds come from :mod:`repro.core.slot_speeds` (online EWMA
estimation from phase-B wave timings) or are passed explicitly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core import bss as _bss

__all__ = [
    "Schedule",
    "normalize_speeds",
    "normalize_proc_times",
    "factor_rank1_proc_times",
    "rank1_proc_times",
    "proc_dead_slots",
    "schedule_hash",
    "schedule_lpt",
    "schedule_multifit",
    "schedule_bss",
    "schedule_brute",
    "schedule_unrelated",
    "get_scheduler",
    "lpt_assign_jax",
    "SCHEDULERS",
    "AUTO_CANDIDATES",
]


def normalize_speeds(
    speeds: Optional[Sequence[float]], num_slots: int
) -> Optional[np.ndarray]:
    """Validate a ``speeds`` argument: None stays None (≡ all slots nominal).

    Returns a float64 ``(num_slots,)`` array of non-negative relative
    speeds, or ``None``. Strategies treat ``None`` and all-ones identically.
    An **exact 0.0 means the slot is dead** (vanished from the mesh): every
    strategy excludes it from assignment entirely — elastic-mesh semantics,
    not "infinitely slow". Negative / non-finite speeds and an all-zero
    vector (no slot can make progress) are rejected.
    """
    if speeds is None:
        return None
    speeds = np.asarray(speeds, dtype=np.float64)
    if speeds.shape != (num_slots,):
        raise ValueError(
            f"speeds must have shape ({num_slots},), got {speeds.shape}"
        )
    if np.any(~np.isfinite(speeds)) or np.any(speeds < 0):
        raise ValueError("slot speeds must be finite and >= 0 (0 = dead slot)")
    if speeds.size and not np.any(speeds > 0):
        raise ValueError("all slots dead: at least one speed must be > 0")
    return speeds


def _dead_slot_split(
    speeds: Optional[Sequence[float]], num_slots: int
):
    """``(alive_idx, compact_speeds)`` when dead (speed-0) slots exist, else None.

    The strategies use this to *compact* the instance onto the surviving
    slots, run the unchanged all-alive algorithm there, and remap the
    assignment back through ``alive_idx`` — so a dead slot never receives
    work and the all-alive code paths stay bit-identical.
    """
    s = normalize_speeds(speeds, num_slots)
    if s is None or not np.any(s == 0.0):
        return None
    alive = np.flatnonzero(s > 0.0)
    return alive, s[alive]


# ---------------------------------------------------------------------------
# R||C_max: per-(operation, slot) processing-time matrices.
# ---------------------------------------------------------------------------


def normalize_proc_times(
    proc_times: Optional[Sequence[Sequence[float]]],
    num_ops: int,
    num_slots: int,
) -> Optional[np.ndarray]:
    """Validate a ``proc_times`` argument: None stays None (≡ speeds path).

    Returns a float64 ``(num_ops, num_slots)`` matrix ``p`` where
    ``p[j, i]`` is the time operation ``j`` takes on slot ``i``.
    ``+inf`` means "slot i cannot run operation j"; a column of all
    ``inf`` is a **dead slot** (the matrix form of the speed-0
    convention). NaN and negative entries are rejected, as is any
    operation with no finite slot (it could never complete anywhere).
    """
    if proc_times is None:
        return None
    p = np.asarray(proc_times, dtype=np.float64)
    if p.shape != (num_ops, num_slots):
        raise ValueError(
            f"proc_times must have shape ({num_ops}, {num_slots}), "
            f"got {p.shape}"
        )
    if np.any(np.isnan(p)) or np.any(p < 0):
        raise ValueError(
            "proc_times must be >= 0 or +inf (inf = slot cannot run op)")
    if num_ops and not np.all(np.isfinite(p).any(axis=1)):
        raise ValueError(
            "every operation needs at least one finite-time slot")
    return p


def proc_dead_slots(proc_times: np.ndarray) -> np.ndarray:
    """Boolean dead-slot mask of a proc-time matrix: all-``inf`` columns."""
    p = np.asarray(proc_times, dtype=np.float64)
    if p.shape[0] == 0:
        return np.zeros(p.shape[1], dtype=bool)
    return ~np.isfinite(p).any(axis=0)


def rank1_proc_times(
    loads: Sequence[float],
    speeds: Optional[Sequence[float]],
    num_slots: int,
) -> np.ndarray:
    """Build the rank-1 ``(n, m)`` matrix ``p[j, i] = loads[j] / speeds[i]``.

    The Q||C_max instance written in R||C_max form; a dead slot (speed
    exactly 0.0) becomes an all-``inf`` column. This is the canonical way
    to hand a uniform-machines instance to a ``proc_times=`` code path.
    """
    loads = np.asarray(loads, dtype=np.float64)
    s = _speeds_or_ones(speeds, num_slots)
    with np.errstate(divide="ignore"):
        p = loads[:, None] / s[None, :]
    if np.any(s == 0.0):
        p[:, s == 0.0] = np.inf
    return p


def factor_rank1_proc_times(proc_times: np.ndarray):
    """Exactly factor ``p`` as ``loads ⊗ (1/speeds)``; None if not rank-1.

    Returns ``(loads, speeds)`` with the first alive slot pinned to speed
    1.0 and dead (all-``inf``) columns mapped to speed 0.0, **iff** the
    reconstruction ``loads[:, None] / speeds`` reproduces ``p`` bit for
    bit. The check is exact float equality, not a tolerance: a true
    rank-1 matrix built by :func:`rank1_proc_times` with power-of-two
    speed ratios round-trips losslessly (binary scaling), so the Q||C_max
    delegation below is bit-identical to the ``speeds=`` path, while a
    genuinely unrelated matrix falls through to the R-native algorithms.
    """
    p = np.asarray(proc_times, dtype=np.float64)
    n, m = p.shape
    if n == 0 or m == 0:
        return None
    dead = proc_dead_slots(p)
    alive = np.flatnonzero(~dead)
    if alive.size == 0:
        return None
    i0 = int(alive[0])
    loads = p[:, i0]
    if not np.all(np.isfinite(loads)):
        return None  # partial-inf column: per-op incompatibility, not rank-1
    speeds = np.zeros(m, dtype=np.float64)
    speeds[i0] = 1.0
    # The reference row: the largest load pins each column's speed ratio.
    j0 = int(np.argmax(loads))
    if loads[j0] == 0.0:
        # All-zero loads: any assignment has makespan 0; treat as uniform.
        if np.all(p[:, alive] == 0.0):
            speeds[alive] = 1.0
            return loads, speeds
        return None
    for i in alive[1:]:
        col = p[:, i]
        if not np.all(np.isfinite(col)) or col[j0] == 0.0:
            return None
        speeds[i] = loads[j0] / col[j0]
        if not np.array_equal(col, loads / speeds[i]):
            return None
    return loads, speeds


def _proc_or_none(proc_times, loads, num_slots):
    """Validated proc-time matrix, or None when the speeds path applies."""
    return normalize_proc_times(
        proc_times, np.asarray(loads).shape[0], num_slots)


def _require_one_speed_source(speeds, proc_times) -> None:
    """``speeds=`` and ``proc_times=`` are mutually exclusive inputs."""
    if speeds is not None and proc_times is not None:
        raise ValueError(
            "pass speeds= (uniform machines) or proc_times= (unrelated "
            "processors), not both — rank1_proc_times(loads, speeds, m) "
            "embeds a speed vector into the matrix form")


def _eft_r(p: np.ndarray) -> np.ndarray:
    """Earliest-finish-time greedy on unrelated processors.

    Operations in descending order of their best-case (min over slots)
    processing time; each goes to ``argmin_i (T_i + p[j, i])`` where
    ``T_i`` is the slot's accumulated finish time. ``inf`` entries (dead
    or incompatible slots) can never win the argmin because every
    operation has a finite-time slot.
    """
    n, m = p.shape
    best_case = np.min(p, axis=1)
    order = np.argsort(-best_case, kind="stable")
    assignment = np.zeros(n, dtype=np.int32)
    finish = np.zeros(m, dtype=np.float64)
    for j in order:
        slot = int(np.argmin(finish + p[j]))
        assignment[j] = slot
        finish[slot] += p[j, slot]
    return assignment


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Result of scheduling ``n`` operations onto ``m`` (possibly uneven) slots.

    Derived metrics come in two spaces:

    * load space (the paper's P||C_max view): ``slot_loads`` / ``max_load``
      / ``balance_ratio`` — what each slot *holds*;
    * finish-time space (Q||C_max): ``slot_finish = slot_loads /
      slot_speeds``, ``makespan`` (the job's completion time) and
      ``finish_ratio = makespan / ideal_finish`` — what each slot *takes*.

    With uniform speeds the two coincide (``makespan == max_load``).
    An R||C_max schedule additionally carries the ``proc_times`` matrix it
    was built from; finish-time metrics then sum the per-operation
    processing times actually paid on each slot instead of dividing load
    by a speed. Direct construction ``Schedule(assignment, num_slots)``
    is valid: ``__post_init__`` derives ``slot_loads`` from unit
    operation loads and defaults speeds to nominal, so no field is ever
    left ``None``.
    """

    assignment: np.ndarray  # (n,) int32 — slot id per operation
    num_slots: int

    # --- derived (computed in __post_init__ when not given) ---------------
    slot_loads: Optional[np.ndarray] = None   # (m,) load held per slot
    slot_speeds: Optional[np.ndarray] = None  # (m,) relative speed, 1 = nominal
    proc_times: Optional[np.ndarray] = None   # (n, m) R||C_max time matrix

    def __post_init__(self):
        """Normalise arrays and derive missing metrics (unit loads, nominal speeds)."""
        assignment = np.asarray(self.assignment, dtype=np.int32)
        object.__setattr__(self, "assignment", assignment)
        if self.slot_loads is None:
            loads = np.bincount(assignment, minlength=self.num_slots)
            object.__setattr__(
                self, "slot_loads", loads[: self.num_slots].astype(np.float64)
            )
        else:
            object.__setattr__(
                self, "slot_loads", np.asarray(self.slot_loads, np.float64)
            )
        if self.slot_speeds is None:
            object.__setattr__(self, "slot_speeds", np.ones(self.num_slots))
        else:
            object.__setattr__(
                self, "slot_speeds",
                normalize_speeds(self.slot_speeds, self.num_slots),
            )
        if self.proc_times is not None:
            object.__setattr__(
                self, "proc_times",
                normalize_proc_times(
                    self.proc_times, assignment.shape[0], self.num_slots),
            )

    @staticmethod
    def from_assignment(
        assignment: np.ndarray,
        loads: np.ndarray,
        num_slots: int,
        speeds: Optional[Sequence[float]] = None,
    ) -> "Schedule":
        """Build a Schedule (with derived metrics) from an assignment."""
        assignment = np.asarray(assignment, dtype=np.int32)
        loads = np.asarray(loads, dtype=np.float64)
        slot_loads = np.bincount(assignment, weights=loads, minlength=num_slots)
        return Schedule(
            assignment=assignment,
            num_slots=num_slots,
            slot_loads=slot_loads,
            slot_speeds=normalize_speeds(speeds, num_slots),
        )

    @staticmethod
    def from_proc_assignment(
        assignment: np.ndarray,
        loads: np.ndarray,
        proc_times: np.ndarray,
        num_slots: int,
    ) -> "Schedule":
        """Build an R||C_max Schedule: finish metrics come from the matrix.

        ``slot_speeds`` records the dead-slot mask (alive = 1.0, dead =
        0.0) so speed-vector consumers see the structural facts, while the
        real finish times sum ``proc_times[j, assignment[j]]`` per slot.
        """
        assignment = np.asarray(assignment, dtype=np.int32)
        loads = np.asarray(loads, dtype=np.float64)
        p = normalize_proc_times(proc_times, loads.shape[0], num_slots)
        slot_loads = np.bincount(assignment, weights=loads, minlength=num_slots)
        speeds = np.where(proc_dead_slots(p), 0.0, 1.0) if p is not None \
            else None
        return Schedule(
            assignment=assignment,
            num_slots=num_slots,
            slot_loads=slot_loads,
            slot_speeds=speeds,
            proc_times=p,
        )

    # --- load space (P||C_max view) ---------------------------------------

    @property
    def max_load(self) -> float:
        """Largest load held by any slot (speed-blind)."""
        return float(self.slot_loads.max()) if self.num_slots else 0.0

    @property
    def ideal_load(self) -> float:
        """Perfectly even split of the total load."""
        if not self.num_slots:
            return 0.0
        return float(self.slot_loads.sum()) / self.num_slots

    @property
    def balance_ratio(self) -> float:
        """max-load / ideal-load (paper Fig 6; 1.0 is perfect)."""
        if self.ideal_load == 0:
            return 1.0
        return self.max_load / self.ideal_load

    # --- finish-time space (Q||C_max view) --------------------------------

    @property
    def slot_finish(self) -> np.ndarray:
        """Per-slot completion time: ``slot_loads / slot_speeds``.

        A dead slot (speed 0) finishes at 0 when it holds no load — the
        invariant every strategy maintains — and at ``inf`` when it does
        (work stranded on a vanished slot never completes). An R||C_max
        schedule instead sums the processing times each slot actually
        pays: ``Σ_j proc_times[j, i]`` over its assigned operations ``j``
        (an ``inf`` entry — op landed on a slot that cannot run it —
        correctly reads as never finishing).
        """
        if self.proc_times is not None:
            paid = self.proc_times[
                np.arange(self.assignment.shape[0]), self.assignment]
            return np.bincount(
                self.assignment, weights=paid, minlength=self.num_slots
            )[: self.num_slots]
        with np.errstate(divide="ignore", invalid="ignore"):
            finish = self.slot_loads / self.slot_speeds
        dead = self.slot_speeds == 0.0
        if np.any(dead):
            finish = np.where(dead & (self.slot_loads == 0.0), 0.0, finish)
            finish = np.where(dead & (self.slot_loads > 0.0), np.inf, finish)
        return finish

    @property
    def makespan(self) -> float:
        """Job completion time: the slowest slot's finish time."""
        return float(self.slot_finish.max()) if self.num_slots else 0.0

    @property
    def ideal_finish(self) -> float:
        """Lower bound on the makespan any schedule could reach.

        Uniform machines: total load spread over the aggregate speed.
        Unrelated processors: the classic pair of R||C_max bounds — the
        best-case times spread over the alive slots, and the single
        worst operation at its best slot.
        """
        if self.proc_times is not None:
            if self.assignment.shape[0] == 0:
                return 0.0
            best_case = np.min(self.proc_times, axis=1)
            alive = int((self.slot_speeds > 0.0).sum())
            if alive == 0:
                return 0.0
            return float(max(best_case.sum() / alive, best_case.max()))
        total_speed = float(self.slot_speeds.sum()) if self.num_slots else 0.0
        if total_speed == 0:
            return 0.0
        return float(self.slot_loads.sum()) / total_speed

    @property
    def finish_ratio(self) -> float:
        """makespan / ideal-finish — the speed-normalised balance_ratio."""
        if self.ideal_finish == 0:
            return 1.0
        return self.makespan / self.ideal_finish

    @property
    def rel_std(self) -> float:
        """std(slot finish times) / mean — heterogeneity-aware error bar."""
        finish = self.slot_finish
        mean = finish.mean()
        if mean == 0:
            return 0.0
        return float(finish.std() / mean)


def _speeds_or_ones(speeds: Optional[Sequence[float]], num_slots: int) -> np.ndarray:
    """Concrete speed vector for the assignment loops (None → nominal)."""
    s = normalize_speeds(speeds, num_slots)
    return np.ones(num_slots) if s is None else s


# ---------------------------------------------------------------------------
# Baseline: the MapReduce default hash partitioner (paper eq. 3-1).
# ---------------------------------------------------------------------------


def _default_hash(keys: np.ndarray) -> np.ndarray:
    """A deterministic integer mix (64-bit splitmix-style) of the key ids.

    Using the identity here would make ``key mod m`` artificially uniform for
    dense key ids; a real partitioner hashes, so we hash.
    """
    k = np.asarray(keys, dtype=np.uint64)
    k = (k ^ (k >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    k = (k ^ (k >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    k = k ^ (k >> np.uint64(31))
    return k


def schedule_hash(
    loads: Sequence[float],
    num_slots: int,
    keys: Optional[np.ndarray] = None,
    hash_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
    speeds: Optional[Sequence[float]] = None,
    proc_times: Optional[Sequence[Sequence[float]]] = None,
) -> Schedule:
    """Default MapReduce partitioning: ``i = |Hash(k)| mod m`` (eq. 3-1).

    Oblivious to both load *and* speed — the assignment ignores ``speeds``
    entirely (that is the point of the baseline); they are only recorded on
    the returned :class:`Schedule` so its finish-time metrics are honest.
    With ``proc_times=`` the baseline stays oblivious to the matrix values
    but still respects the structural dead-slot mask (an all-``inf``
    column receives nothing — hashing work onto a vanished slot is not a
    baseline, it is a bug).
    """
    loads = np.asarray(loads, dtype=np.float64)
    _require_one_speed_source(speeds, proc_times)
    n = loads.shape[0]
    if keys is None:
        keys = np.arange(n)
    hashed = (hash_fn or _default_hash)(np.asarray(keys))
    p = _proc_or_none(proc_times, loads, num_slots)
    if p is not None:
        dead_mask = proc_dead_slots(p)
        if np.any(dead_mask):
            alive = np.flatnonzero(~dead_mask)
            idx = (hashed % np.uint64(alive.size)).astype(np.int64)
            assignment = alive[idx].astype(np.int32)
        else:
            assignment = (hashed % np.uint64(num_slots)).astype(np.int32)
        return Schedule.from_proc_assignment(assignment, loads, p, num_slots)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        # Elastic mesh: hash onto the surviving slots only (mod num_alive,
        # remapped to the alive slot ids) — still load- and speed-oblivious
        # among the living, but a vanished slot receives nothing.
        alive, _ = dead
        idx = (hashed % np.uint64(alive.size)).astype(np.int64)
        assignment = alive[idx].astype(np.int32)
    else:
        assignment = (hashed % np.uint64(num_slots)).astype(np.int32)
    return Schedule.from_assignment(assignment, loads, num_slots, speeds=speeds)


# ---------------------------------------------------------------------------
# Graham's LPT, earliest-finish-time variant (host-side).
# ---------------------------------------------------------------------------


def schedule_lpt(
    loads: Sequence[float],
    num_slots: int,
    speeds: Optional[Sequence[float]] = None,
    proc_times: Optional[Sequence[Sequence[float]]] = None,
) -> Schedule:
    """Longest Processing Time first, placed by earliest finish time.

    Each operation (descending load) goes to the slot where it would
    *complete* soonest: ``argmin_j (load_j + w) / s_j``. With uniform
    speeds this is exactly Graham's LPT (4/3-approximation [Gr69]); on
    uniform machines it is the standard 2-approximation for Q||C_max.

    ``proc_times=`` lifts the same rule to unrelated processors:
    operations in descending best-case time, placed at
    ``argmin_i (T_i + p[j, i])``. An exactly rank-1 matrix delegates to
    the uniform-machines path above (bit-identical assignments).
    """
    loads = np.asarray(loads, dtype=np.float64)
    _require_one_speed_source(speeds, proc_times)
    p = _proc_or_none(proc_times, loads, num_slots)
    if p is not None:
        rank1 = factor_rank1_proc_times(p)
        if rank1 is not None:
            q_loads, q_speeds = rank1
            inner = schedule_lpt(q_loads, num_slots, speeds=q_speeds)
            return Schedule.from_proc_assignment(
                inner.assignment, loads, p, num_slots)
        return Schedule.from_proc_assignment(_eft_r(p), loads, p, num_slots)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        alive, s_alive = dead
        inner = schedule_lpt(loads, alive.size, speeds=s_alive)
        return Schedule.from_assignment(
            alive[inner.assignment], loads, num_slots, speeds=speeds
        )
    s = _speeds_or_ones(speeds, num_slots)
    n = loads.shape[0]
    order = np.argsort(-loads, kind="stable")
    assignment = np.zeros(n, dtype=np.int32)
    # Pure-Python placement: np.argmin on an m-vector pays microseconds of
    # dispatch per call, which dominates the plan path at n >= 1e5 clusters.
    # Python floats are IEEE doubles, so (load + w) / speed rounds exactly as
    # the vectorised expression did — assignments stay bit-identical, with
    # ties still broken toward the lowest slot index.
    slot_loads = [0.0] * num_slots
    sp = [float(v) for v in s]
    w_list = loads.tolist()
    for j in order.tolist():
        w = w_list[j]
        best = 0
        best_key = (slot_loads[0] + w) / sp[0]
        for i in range(1, num_slots):
            key = (slot_loads[i] + w) / sp[i]
            if key < best_key:
                best = i
                best_key = key
        assignment[j] = best
        slot_loads[best] += w
    return Schedule.from_assignment(assignment, loads, num_slots, speeds=speeds)


# ---------------------------------------------------------------------------
# MULTIFIT: binary search on a finish-time deadline with first-fit-decreasing.
# ---------------------------------------------------------------------------


def _ffd_fits(
    loads_desc: np.ndarray,
    num_slots: int,
    deadline: float,
    speeds: np.ndarray,
    slot_order: np.ndarray,
) -> Optional[np.ndarray]:
    """First-fit-decreasing against per-slot capacity ``deadline * speed``.

    Slots are probed fastest-first (``slot_order``); returns the assignment
    (in sorted-operation order) or None when some operation does not fit.
    """
    slot_loads = np.zeros(num_slots)
    caps = deadline * speeds
    assignment = np.empty(loads_desc.shape[0], dtype=np.int32)
    for j, w in enumerate(loads_desc):
        placed = False
        for s in slot_order:
            if slot_loads[s] + w <= caps[s]:
                slot_loads[s] += w
                assignment[j] = s
                placed = True
                break
        if not placed:
            return None
    return assignment


def _ffd_fits_r(
    p_desc: np.ndarray,
    deadline: float,
) -> Optional[np.ndarray]:
    """FFD probe on unrelated processors: fit each op by preferred slot.

    ``p_desc`` is the proc-time matrix with rows already in descending
    best-case order. Each operation probes its *own* slot preference
    (ascending ``p[j, i]``, stable) — there is no global fastest-first
    order when every operation ranks the slots differently — and fits
    where ``T_i + p[j, i] <= deadline``. Returns the assignment in
    sorted-operation order, or None when some operation does not fit.
    """
    n, m = p_desc.shape
    finish = np.zeros(m, dtype=np.float64)
    assignment = np.empty(n, dtype=np.int32)
    pref = np.argsort(p_desc, axis=1, kind="stable")
    for j in range(n):
        placed = False
        for s in pref[j]:
            pj = p_desc[j, s]
            if np.isfinite(pj) and finish[s] + pj <= deadline:
                finish[s] += pj
                assignment[j] = s
                placed = True
                break
        if not placed:
            return None
    return assignment


def schedule_multifit(
    loads: Sequence[float],
    num_slots: int,
    iters: int = 20,
    speeds: Optional[Sequence[float]] = None,
    proc_times: Optional[Sequence[Sequence[float]]] = None,
) -> Schedule:
    """MULTIFIT: binary search on a finish-time deadline with an FFD probe.

    The classic bin-capacity search, lifted to Q||C_max: a probe at
    deadline ``C`` gives slot ``j`` capacity ``C * s_j`` (the load it can
    finish by ``C``). Uniform speeds reduce to the original algorithm.

    ``proc_times=`` lifts it to R||C_max — the deadline becomes a direct
    finish-time budget per slot (``T_i + p[j, i] <= C``), bracketed
    between the classic lower bounds and the EFT-greedy makespan; this
    is the binary-search-over-a-feasibility-LP shape of Fotakis et al.
    (arXiv 1312.4203) with FFD standing in for the rounding step. An
    exactly rank-1 matrix delegates to the uniform-machines path.
    """
    loads = np.asarray(loads, dtype=np.float64)
    _require_one_speed_source(speeds, proc_times)
    p = _proc_or_none(proc_times, loads, num_slots)
    if p is not None:
        rank1 = factor_rank1_proc_times(p)
        if rank1 is not None:
            q_loads, q_speeds = rank1
            inner = schedule_multifit(
                q_loads, num_slots, iters=iters, speeds=q_speeds)
            return Schedule.from_proc_assignment(
                inner.assignment, loads, p, num_slots)
        if p.shape[0] == 0:
            return Schedule.from_proc_assignment(
                np.zeros(0, np.int32), loads, p, num_slots)
        best_case = np.min(p, axis=1)
        order = np.argsort(-best_case, kind="stable")
        p_desc = p[order]
        alive = int((~proc_dead_slots(p)).sum())
        eft = _eft_r(p)
        hi = float(Schedule.from_proc_assignment(
            eft, loads, p, num_slots).makespan)
        lo = float(max(best_case.sum() / max(alive, 1), best_case.max()))
        best = None
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            fit = _ffd_fits_r(p_desc, mid)
            if fit is not None:
                best = fit
                hi = mid
            else:
                lo = mid
        if best is None:
            # The EFT schedule is always feasible at its own makespan.
            assignment = eft
        else:
            assignment = np.empty_like(best)
            assignment[order] = best
        return Schedule.from_proc_assignment(assignment, loads, p, num_slots)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        alive, s_alive = dead
        inner = schedule_multifit(loads, alive.size, iters=iters, speeds=s_alive)
        return Schedule.from_assignment(
            alive[inner.assignment], loads, num_slots, speeds=speeds
        )
    s = _speeds_or_ones(speeds, num_slots)
    order = np.argsort(-loads, kind="stable")
    loads_desc = loads[order]
    # Fastest slots first — stable, so uniform speeds keep the 0..m-1 order.
    slot_order = np.argsort(-s, kind="stable")
    total = loads.sum()
    biggest = loads_desc[0] if loads.size else 0.0
    lo = max(total / s.sum(), biggest / s.max())
    hi = max(2 * total / s.sum(), biggest / s.max())
    best = None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        fit = _ffd_fits(loads_desc, num_slots, mid, s, slot_order)
        if fit is not None:
            best = fit
            hi = mid
        else:
            lo = mid
    if best is None:
        best = _ffd_fits(loads_desc, num_slots, hi, s, slot_order)
        if best is None:  # pragma: no cover - hi is always feasible eventually
            return schedule_lpt(loads, num_slots, speeds=speeds)
    assignment = np.empty_like(best)
    assignment[order] = best
    return Schedule.from_assignment(assignment, loads, num_slots, speeds=speeds)


# ---------------------------------------------------------------------------
# The paper's algorithm: DP decomposition into Balanced Subset Sum.
# ---------------------------------------------------------------------------


def schedule_bss(
    loads: Sequence[float],
    num_slots: int,
    eta: float = 0.002,
    refine: bool = True,
    speeds: Optional[Sequence[float]] = None,
) -> Schedule:
    """Dynamic-programming decomposition over per-slot BSS sub-problems.

    Slots are peeled fastest-first; each slot's balanced target is its
    speed-proportional share ``T_j = remaining_total * s_j / remaining_speed``
    (the finish-balanced split — uniform speeds give the paper's
    ``remaining_total / remaining_slots``, §4.2 / [F+14]) and the
    remaining-operation subset whose load sum is closest to ``T_j`` is
    picked with an ``eta``-FPTAS; the last slot takes the remainder.
    Operations larger than the target get a dedicated slot (they dominate
    the makespan on their own; packing more onto that slot can only hurt).

    ``refine=True`` runs a cheap post-pass: if the makespan slot can donate
    an operation to the earliest-finishing slot and improve, do so
    (repeat). This recovers a little of the FPTAS rounding slack.
    """
    loads = np.asarray(loads, dtype=np.float64)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        alive, s_alive = dead
        inner = schedule_bss(
            loads, alive.size, eta=eta, refine=refine, speeds=s_alive
        )
        return Schedule.from_assignment(
            alive[inner.assignment], loads, num_slots, speeds=speeds
        )
    s = _speeds_or_ones(speeds, num_slots)
    n = loads.shape[0]
    assignment = np.full(n, -1, dtype=np.int32)
    if n == 0:
        return Schedule.from_assignment(
            np.zeros(0, np.int32), loads, num_slots, speeds=speeds
        )

    # Fastest slots first (stable → uniform speeds keep slot order 0..m-1):
    # the big subsets should land on the slots that can absorb them.
    slot_order = np.argsort(-s, kind="stable")
    remaining = list(np.argsort(-loads, kind="stable"))  # indices, descending load
    for rank in range(num_slots - 1):
        if not remaining:
            break
        slot = int(slot_order[rank])
        rem_loads = loads[remaining]
        total_rem = float(rem_loads.sum())
        speed_rem = float(s[slot_order[rank:]].sum())
        target = total_rem * float(s[slot]) / speed_rem
        if loads[remaining[0]] >= target and len(remaining) > 1:
            # A single dominating operation: isolate it (paper's huge-key case —
            # e.g. the 1.97e6-pair operation of Fig 1a).
            assignment[remaining.pop(0)] = slot
            continue
        chosen = _bss.bss_approx([float(x) for x in rem_loads], target, eta=eta)
        if not chosen:
            chosen = [0]
        chosen_set = set(chosen)
        for local_idx in sorted(chosen_set, reverse=True):
            assignment[remaining[local_idx]] = slot
        remaining = [g for i, g in enumerate(remaining) if i not in chosen_set]
    last_slot = int(slot_order[num_slots - 1])
    for g in remaining:
        assignment[g] = last_slot

    sched = Schedule.from_assignment(assignment, loads, num_slots, speeds=speeds)
    if refine:
        sched = _refine_moves(sched, loads)
        # The DP decomposition is near-optimal on skewed instances but can
        # lose to plain LPT on tiny/uniform ones; both are cheap host-side,
        # so keep whichever schedule is better (never worse than LPT).
        lpt = schedule_lpt(loads, num_slots, speeds=speeds)
        if lpt.makespan < sched.makespan:
            sched = lpt
    return sched


def _refine_moves(sched: Schedule, loads: np.ndarray, max_moves: int = 256) -> Schedule:
    """Greedy post-pass: donate ops from the makespan slot while it improves.

    Works in finish-time space, so a slow slot sheds work to fast idle
    slots; with uniform speeds this is exactly the load-space pass.
    """
    assignment = sched.assignment.copy()
    slot_loads = sched.slot_loads.copy()
    speeds = sched.slot_speeds
    for _ in range(max_moves):
        finish = slot_loads / speeds
        src = int(finish.argmax())
        dst = int(finish.argmin())
        if src == dst:
            break
        ops = np.nonzero(assignment == src)[0]
        if ops.size <= 1:
            break
        # An op w helps only if the destination stays under the current
        # makespan: (load_dst + w) / s_dst < finish_src.
        headroom = finish[src] * speeds[dst] - slot_loads[dst]
        cand = ops[loads[ops] < headroom]
        if cand.size == 0:
            break
        # Move the largest op that still improves the makespan.
        j = cand[np.argmax(loads[cand])]
        new_src = slot_loads[src] - loads[j]
        new_dst = slot_loads[dst] + loads[j]
        if max(new_src / speeds[src], new_dst / speeds[dst]) >= finish[src]:
            break
        assignment[j] = dst
        slot_loads[src] = new_src
        slot_loads[dst] = new_dst
    return Schedule.from_assignment(
        assignment, loads, sched.num_slots, speeds=sched.slot_speeds
    )


# ---------------------------------------------------------------------------
# Exact solver for tiny instances (test oracle).
# ---------------------------------------------------------------------------


def _brute_r(p: np.ndarray, num_slots: int) -> np.ndarray:
    """Exact R||C_max branch-and-bound over a (n ≤ 14) proc-time matrix.

    Slots are interchangeable only when their entire remaining columns
    match (precomputed column groups) *and* their accumulated finish
    times match — the unrelated-processors analogue of the (load, speed)
    symmetry key. Each node is additionally bounded by the averaged
    best-case remaining work: even if every remaining op ran at its
    fastest slot's time, the final makespan is at least
    ``(Σ finish + Σ remaining best-case) / num_alive``.
    """
    n = p.shape[0]
    alive = max(int((~proc_dead_slots(p)).sum()), 1)
    best_case = np.min(p, axis=1)
    order = np.argsort(-best_case, kind="stable")
    # Suffix sums of best-case times: an admissible completion bound.
    suffix = np.concatenate([np.cumsum(best_case[order][::-1])[::-1], [0.0]])
    # Column symmetry groups: identical columns are interchangeable.
    col_group = np.zeros(num_slots, dtype=np.int64)
    seen_cols: dict = {}
    for k in range(num_slots):
        key = p[:, k].tobytes()
        col_group[k] = seen_cols.setdefault(key, len(seen_cols))
    best_assign = np.zeros(n, dtype=np.int32)
    best_max = float("inf")
    assign = np.zeros(n, dtype=np.int32)
    finish = np.zeros(num_slots, dtype=np.float64)

    def rec(i: int) -> None:
        """Place operation order[i] on every non-symmetric slot, pruned."""
        nonlocal best_max, best_assign
        cur = finish.max()
        if max(cur, (finish.sum() + suffix[i]) / alive) >= best_max:
            return
        if i == n:
            best_max = float(cur)
            best_assign = assign.copy()
            return
        j = order[i]
        seen: set = set()
        for k in range(num_slots):
            pj = p[j, k]
            if not np.isfinite(pj):
                continue  # dead or incompatible slot: never assignable
            key = (round(float(finish[k]), 9), int(col_group[k]))
            if key in seen:
                continue
            seen.add(key)
            finish[k] += pj
            assign[j] = k
            rec(i + 1)
            finish[k] -= pj
    rec(0)
    if not np.isfinite(best_max) and n:  # pragma: no cover - defensive
        return _eft_r(p)
    return best_assign


def schedule_brute(
    loads: Sequence[float],
    num_slots: int,
    speeds: Optional[Sequence[float]] = None,
    proc_times: Optional[Sequence[Sequence[float]]] = None,
) -> Schedule:
    """Exact optimum by symmetry-pruned branch-and-bound (n ≤ 14; test oracle).

    Minimises the *makespan* ``max_j load_j / s_j``; slots are symmetric
    (interchangeable) only when both load and speed match. With
    ``proc_times=`` it minimises ``max_i Σ_j p[j, i]`` exactly — the
    R||C_max oracle the multi-job property suite cross-checks against.
    """
    loads = np.asarray(loads, dtype=np.float64)
    _require_one_speed_source(speeds, proc_times)
    p = _proc_or_none(proc_times, loads, num_slots)
    if p is not None:
        if p.shape[0] > 14:
            raise ValueError("brute force is for tiny test instances only")
        rank1 = factor_rank1_proc_times(p)
        if rank1 is not None:
            q_loads, q_speeds = rank1
            inner = schedule_brute(q_loads, num_slots, speeds=q_speeds)
            return Schedule.from_proc_assignment(
                inner.assignment, loads, p, num_slots)
        return Schedule.from_proc_assignment(
            _brute_r(p, num_slots), loads, p, num_slots)
    dead = _dead_slot_split(speeds, num_slots)
    if dead is not None:
        alive, s_alive = dead
        inner = schedule_brute(loads, alive.size, speeds=s_alive)
        return Schedule.from_assignment(
            alive[inner.assignment], loads, num_slots, speeds=speeds
        )
    s = _speeds_or_ones(speeds, num_slots)
    n = loads.shape[0]
    if n > 14:
        raise ValueError("brute force is for tiny test instances only")
    best_assign = np.zeros(n, dtype=np.int32)
    best_max = float("inf")
    assign = np.zeros(n, dtype=np.int32)
    slot_loads = np.zeros(num_slots)
    order = np.argsort(-loads, kind="stable")

    def rec(i: int) -> None:
        """Place operation order[i] on every non-symmetric slot, pruned."""
        nonlocal best_max, best_assign
        if (slot_loads / s).max() >= best_max:
            return
        if i == n:
            best_max = float((slot_loads / s).max())
            best_assign = assign.copy()
            return
        j = order[i]
        seen: set = set()
        for k in range(num_slots):
            key = (round(slot_loads[k], 9), round(float(s[k]), 9))
            if key in seen:
                continue  # symmetry: equal (load, speed) slots are interchangeable
            seen.add(key)
            slot_loads[k] += loads[j]
            assign[j] = k
            rec(i + 1)
            slot_loads[k] -= loads[j]

    rec(0)
    return Schedule.from_assignment(best_assign, loads, num_slots, speeds=speeds)


# ---------------------------------------------------------------------------
# R||C_max native strategy: EFT-greedy + jump/swap local search.
# ---------------------------------------------------------------------------


def _refine_moves_r(
    assignment: np.ndarray, p: np.ndarray, max_moves: int = 256
) -> np.ndarray:
    """Local search on unrelated processors: jumps off the makespan slot.

    Repeatedly take the slot defining the makespan and try to *jump* one
    of its operations to whichever slot finishes it earliest without
    creating a new, equal-or-worse makespan — the single-exchange
    neighbourhood whose local optima are within 2·OPT + p_max on R
    (the combinatorial half of the Fotakis et al. analysis; the LP
    rounding supplies the other half). Stops at a local optimum.
    """
    assignment = assignment.copy()
    n, m = p.shape
    paid = p[np.arange(n), assignment]
    finish = np.bincount(assignment, weights=paid, minlength=m)[:m]
    for _ in range(max_moves):
        src = int(np.argmax(finish))
        span = finish[src]
        ops = np.flatnonzero(assignment == src)
        moved = False
        # Try the biggest contributors first: moving them buys the most.
        for j in ops[np.argsort(-p[ops, src], kind="stable")]:
            with np.errstate(invalid="ignore"):
                cand = finish + p[j]
            cand[src] = np.inf
            dst = int(np.argmin(cand))
            # The jump must strictly improve the slot pair's worst finish.
            if cand[dst] < span and np.isfinite(cand[dst]):
                finish[src] -= p[j, src]
                finish[dst] += p[j, dst]
                assignment[j] = dst
                moved = True
                break
        if not moved:
            return assignment
    return assignment


def schedule_unrelated(
    loads: Sequence[float],
    num_slots: int,
    speeds: Optional[Sequence[float]] = None,
    proc_times: Optional[Sequence[Sequence[float]]] = None,
) -> Schedule:
    """R||C_max strategy: earliest-finish-time greedy + local search.

    The practical half of Fotakis et al. (arXiv 1312.4203): operations
    in descending best-case time are placed greedily at their earliest
    finishing slot, then a jump local search drains the makespan slot
    until no single move improves. Called without ``proc_times`` it
    embeds the uniform instance (``rank1_proc_times``) first, so it
    degrades gracefully to a Q||C_max / P||C_max heuristic — but its
    reason to exist is the genuinely unrelated matrix, where no speed
    vector can express that different jobs rank the slots differently.
    """
    loads = np.asarray(loads, dtype=np.float64)
    _require_one_speed_source(speeds, proc_times)
    p = _proc_or_none(proc_times, loads, num_slots)
    if p is None:
        p = rank1_proc_times(loads, speeds, num_slots)
    if p.shape[0] == 0:
        return Schedule.from_proc_assignment(
            np.zeros(0, np.int32), loads, p, num_slots)
    assignment = _refine_moves_r(_eft_r(p), p)
    return Schedule.from_proc_assignment(assignment, loads, p, num_slots)


SCHEDULERS: Dict[str, Callable[..., Schedule]] = {
    "hash": schedule_hash,
    "lpt": schedule_lpt,
    "multifit": schedule_multifit,
    "bss": schedule_bss,
    "os4m": schedule_bss,  # alias: the paper's method
    "unrelated": schedule_unrelated,  # R||C_max native (multi-job R-matrix)
}

# The candidate pool "auto" mode chooses from (simulator.pick_strategy):
# every concrete algorithm, cheapest-overhead first so cost ties resolve to
# the cheaper scheduler.
AUTO_CANDIDATES = ("hash", "lpt", "multifit", "bss")


def get_scheduler(name: str) -> Callable[..., Schedule]:
    """Look up a concrete scheduling function by name (see ``SCHEDULERS``)."""
    try:
        return SCHEDULERS[name]
    except KeyError as exc:
        raise ValueError(
            f"unknown scheduler {name!r}; options: {sorted(SCHEDULERS)} "
            "(or 'auto' at the MapReduceConfig level, resolved by "
            "simulator.pick_strategy)"
        ) from exc


# ---------------------------------------------------------------------------
# JAX-traceable LPT (usable inside a jitted step).
# ---------------------------------------------------------------------------


def lpt_assign_jax(loads, num_slots: int, speeds=None):
    """Earliest-finish-time LPT as pure JAX ops: ``(assignment, slot_loads)``.

    ``loads``: (n,) array; ``speeds``: optional (num_slots,) relative slot
    speeds (None ≡ all nominal). Differentiability is not needed — this is
    integer scheduling — but the function is trace-safe (static
    ``num_slots``) so a step can re-balance without leaving the device.
    O(n log n + n·m) work, fine for n up to a few thousand
    operations/experts.
    """
    import jax
    import jax.numpy as jnp

    loads = jnp.asarray(loads)
    n = loads.shape[0]
    if speeds is None:
        speeds_arr = jnp.ones((num_slots,), loads.dtype)
    else:
        # Fractional speeds must not truncate against integer loads: run
        # the placement arithmetic in a float dtype (integer token counts
        # below 2^24 stay exact in f32).
        compute_dtype = jnp.promote_types(loads.dtype, jnp.float32)
        loads = loads.astype(compute_dtype)
        speeds_arr = jnp.asarray(speeds, compute_dtype)
    # Stability explicit: equal loads must tie-break identically to the
    # host LPT (np.argsort kind="stable") for bit-identical assignments.
    order = jnp.argsort(-loads, stable=True)
    sorted_loads = loads[order]

    def body(slot_loads, w):
        """One EFT placement step: put w where it would finish earliest.

        Dead slots (speed exactly 0) are masked to an infinite finish time
        so the argmin never selects them — the traced analogue of the host
        strategies' alive-compaction.
        """
        finish = jnp.where(
            speeds_arr > 0,
            (slot_loads + w) / jnp.where(speeds_arr > 0, speeds_arr, 1.0),
            jnp.inf,
        )
        slot = jnp.argmin(finish)
        slot_loads = slot_loads.at[slot].add(w)
        return slot_loads, slot

    slot_loads, slots_sorted = jax.lax.scan(
        body, jnp.zeros((num_slots,), loads.dtype), sorted_loads
    )
    assignment = jnp.zeros((n,), jnp.int32).at[order].set(slots_sorted.astype(jnp.int32))
    return assignment, slot_loads
