"""Reduce pipelining (paper §4.4).

A Reduce task's three phases consume three different resources
(copy = network, sort = disk/memory, run = CPU). Default MapReduce runs the
phases *sequentially over the whole task*; OS4M splits the task input at
operation(-cluster) granularity and streams the operations through a
3-stage pipeline, ordered by **increasing load** to minimise the sort/run
delays (the Map→Reduce barrier).

This module is the pure planner/timing model. It is used by:

* ``repro.core.simulator`` — the cluster-level discrete-event model that
  reproduces the paper's Figs 7/8/9/12/13/14/15/16;
* ``repro.core.mapreduce`` — to pick the on-device chunk order for the
  double-buffered shuffle→reduce scan (the TPU analogue: overlap the
  all-to-all "copy" of chunk *i+1* with the segment-reduce "run" of *i*);
* the MoE dispatch path — chunked all-to-all overlapped with expert FFN.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

__all__ = [
    "plan_order",
    "plan_chunks",
    "WavePlan",
    "WaveCheckpoint",
    "plan_waves",
    "coschedule_waves",
    "coschedule_overlap",
    "PhaseTimes",
    "PipelineResult",
    "run_pipelined",
    "run_sequential",
]


def plan_order(loads: Sequence[float], order: str = "increasing") -> np.ndarray:
    """Operation processing order on the pipeline.

    ``increasing`` (paper default, §4.4): the smallest operation primes the
    pipeline fastest, minimising sort/run delay. ``decreasing`` and
    ``arrival`` provided for ablation (benchmarks/fig12_13_delays.py).
    """
    loads = np.asarray(loads, dtype=np.float64)
    if order == "increasing":
        return np.argsort(loads, kind="stable")
    if order == "decreasing":
        return np.argsort(-loads, kind="stable")
    if order == "arrival":
        return np.arange(loads.shape[0])
    raise ValueError(f"unknown order {order!r}")


def plan_chunks(
    loads: Sequence[float], num_chunks: int, order: str = "increasing"
) -> List[np.ndarray]:
    """Group ordered operations into ``num_chunks`` contiguous chunks.

    Greedy: walk the ordered operations, cut when the running chunk load
    exceeds ``total / num_chunks``. Every chunk is non-empty as long as
    ``len(loads) >= num_chunks``. Used to bound the number of pipeline
    stages (= scan length) on device.
    """
    loads = np.asarray(loads, dtype=np.float64)
    idx = plan_order(loads, order)
    n = idx.shape[0]
    num_chunks = max(1, min(num_chunks, n))
    target = loads.sum() / num_chunks
    ordered = loads[idx]
    # Vectorized greedy walk: within one chunk the running load is the
    # left-to-right prefix sum of the remaining ordered loads (np.cumsum
    # adds in the same sequential order, so cut points land exactly
    # where the element-at-a-time loop put them), and the first position
    # reaching ``target`` is a searchsorted on that monotone prefix
    # (loads are non-negative counts). A cut is only taken while enough
    # operations remain to give every later chunk at least one; once the
    # first qualifying position violates that, no later position can
    # satisfy it either (ops only shrink), so the remainder is the final
    # chunk — again exactly the loop's behaviour.
    chunks: List[np.ndarray] = []
    start = 0
    while len(chunks) < num_chunks - 1 and start < n:
        prefix = np.cumsum(ordered[start:])
        cut = start + int(np.searchsorted(prefix, target, side="left"))
        remaining_slots = num_chunks - len(chunks) - 1
        if cut >= n or n - (cut + 1) < remaining_slots:
            break
        chunks.append(idx[start:cut + 1].astype(np.int64))
        start = cut + 1
    if start < n:
        chunks.append(idx[start:].astype(np.int64))
    return chunks


@dataclasses.dataclass(frozen=True)
class WavePlan:
    """The engine's serialized §4.4 wave plan for one schedule.

    ``rank_of_cluster[j]`` — position of cluster ``j`` in the global
    increasing-load processing order (the one key that is monotone along
    the fused kernel's sorted stream).
    ``chunk_of_cluster[j]`` — which of the ``num_chunks`` waves cluster
    ``j`` travels in; chunk ``c`` is the union of every Reduce slot's
    c-th wave, so every all-to-all stays balanced across destinations.

    Invariants: chunk ids are dense in ``[0, num_chunks)``; each cluster
    appears in exactly one chunk; within a slot, waves are non-decreasing
    in per-wave load. The plan is pure host data (int32 numpy), cheap to
    snapshot in a :class:`repro.core.schedule_cache.CachedSchedule` and
    replay across batches without re-running ``plan_chunks``. The
    structural invariants (permutation rank, dense one-shot chunk ids,
    valid replication pairing) are certified statically by
    ``repro.analysis --check plan`` (see docs/ANALYSIS.md) on every real
    planner output, so an executor never has to re-derive them.
    """

    rank_of_cluster: np.ndarray   # (n,) int32
    chunk_of_cluster: np.ndarray  # (n,) int32
    num_chunks: int
    # Coded-shuffle replication factor r (Coded MapReduce, arXiv
    # 1512.01625): r = 1 is the plain unicast shuffle; r = 2 means map
    # shards are pair-replicated and phase B ships XOR multicast packets
    # (``kernels/coded_shuffle``) instead of per-destination slabs. The
    # factor lives on the wave plan — not just the config — so a cached
    # snapshot replays with the wire format it was planned for.
    replication: int = 1

    def chunk_members(self, c: int) -> np.ndarray:
        """Cluster ids travelling in wave ``c``."""
        return np.nonzero(self.chunk_of_cluster == c)[0]

    def to_json(self) -> Dict[str, Any]:
        """Plain-type form for persistence alongside the cached schedule."""
        return {
            "rank_of_cluster": self.rank_of_cluster.tolist(),
            "chunk_of_cluster": self.chunk_of_cluster.tolist(),
            "num_chunks": int(self.num_chunks),
            "replication": int(self.replication),
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "WavePlan":
        """Rebuild a plan from :meth:`to_json` output (pre-coded snapshots
        default to replication=1)."""
        return WavePlan(
            rank_of_cluster=np.asarray(d["rank_of_cluster"], np.int32),
            chunk_of_cluster=np.asarray(d["chunk_of_cluster"], np.int32),
            num_chunks=int(d["num_chunks"]),
            replication=int(d.get("replication", 1)),
        )


@dataclasses.dataclass
class WaveCheckpoint:
    """Phase-B progress persisted at wave granularity (elastic mesh).

    Written by the checkpointing executor after each completed wave:
    waves ``[0, wave_cursor)`` of the plan's ``num_chunks`` are done and
    their per-cluster reduce outputs are final (every cluster travels in
    exactly one wave, so a completed wave's clusters never change again).
    On a mid-batch slot failure only the waves *at or after* the cursor
    are replanned onto the surviving mesh and re-executed — the replay
    bound the elastic CI gate asserts (``replayed ≤ num_chunks −
    wave_cursor``).

    ``completed_clusters`` is the boolean union of the finished waves'
    memberships; ``outputs`` maps cluster id → its final merged ``(v,)``
    reduce output (host numpy — a checkpoint must survive the device that
    produced it).
    """

    num_chunks: int
    wave_cursor: int = 0
    completed_clusters: Optional[np.ndarray] = None   # (n,) bool
    outputs: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)

    def mark_wave(self, members: np.ndarray, outputs: Dict[int, np.ndarray],
                  num_clusters: int) -> None:
        """Record one finished wave: advance the cursor, absorb its outputs."""
        if self.completed_clusters is None:
            self.completed_clusters = np.zeros(num_clusters, dtype=bool)
        self.completed_clusters[np.asarray(members, np.int64)] = True
        self.outputs.update(outputs)
        self.wave_cursor += 1

    @property
    def remaining_waves(self) -> int:
        """Waves that would need replay after a failure right now."""
        return max(0, self.num_chunks - self.wave_cursor)


# Warn-once flag for the chunks > clusters degenerate guard below.
_warned_excess_chunks = False


def plan_waves(
    loads: Sequence[float],
    assignment: np.ndarray,
    num_slots: int,
    num_chunks: int,
    order: str = "increasing",
    speeds: Optional[Sequence[float]] = None,
    replication: int = 1,
    pinned_first: Optional[Sequence[int]] = None,
) -> WavePlan:
    """Cut a schedule into per-slot §4.4 waves and merge them into chunks.

    The paper pipelines *within each Reduce task*: a slot streams its own
    operations in increasing-load order. Each slot's operations are cut
    into ``num_chunks`` load-balanced runs (:func:`plan_chunks`); wave
    ``c`` of the job is the union of every slot's c-th run, so per-wave
    loads are ≈ ``slot_load / num_chunks`` on every destination at once
    and the statistics-sized chunk buffers sum to ≈ the sequential buffer
    instead of C× it. Empty waves (tiny jobs) are dropped and chunk ids
    renumbered densely.

    ``speeds`` (Q||C_max): the *global* rank order balances waves by
    **finish time** — a cluster's pipeline priority is ``load /
    speed(assigned slot)``, so a modest cluster on a straggler slot is
    sequenced like the long-running operation it actually is. Within one
    slot the speed is constant, so the per-slot wave cutting (and hence
    the chunk membership invariants) are unchanged; uniform speeds
    reproduce the load-ordered plan bit-identically.

    ``replication`` is carried onto the plan as coded-shuffle metadata
    (:class:`WavePlan` ``replication``); it does not change wave cutting
    — coding changes the wire format of each wave's all-to-all, not
    which clusters travel together.

    Degenerate inputs with ``num_chunks > n`` (more pipeline stages than
    operation clusters) are clamped to ``n`` with a one-time warning:
    the extra stages could only ever be empty trailing waves, which
    would waste all-to-all dispatches on zero-row slabs.

    ``pinned_first`` (streaming-prefix planning): clusters listed here
    are forced into chunk 0 regardless of their load, and every
    remaining cluster is cut into the ``num_chunks - 1`` later waves.
    This is how a prefix-planned wave 1 keeps its committed membership
    when the plan is refined on the full statistics — wave 1 may already
    be in flight, so the refinement can only re-cut the tail. With a
    pin, the within-slot increasing-load invariant holds among waves
    ``1..C-1`` but not necessarily between chunk 0 and the rest.
    ``num_chunks == 1`` degenerates correctly (everything is chunk 0).
    """
    global _warned_excess_chunks
    loads = np.asarray(loads, dtype=np.float64)
    assignment = np.asarray(assignment)
    n = loads.shape[0]
    if num_chunks > n > 0:
        if not _warned_excess_chunks:
            _warned_excess_chunks = True
            warnings.warn(
                f"plan_waves: num_chunks={num_chunks} exceeds the "
                f"{n} operation cluster(s); clamping to {n} — the extra "
                "chunks would only produce empty trailing waves",
                stacklevel=2,
            )
        num_chunks = n
    if speeds is not None:
        speeds = np.asarray(speeds, np.float64)
        slot_speed = speeds[np.clip(assignment, 0, num_slots - 1)]
        # Dead slots (exact speed 0, elastic mesh) never receive
        # assignments from the schedulers; if an assignment does point at
        # one, rank it as nominal rather than emitting inf finish costs.
        finish_costs = loads / np.where(slot_speed > 0, slot_speed, 1.0)
        global_order = plan_order(finish_costs, order)
    else:
        global_order = plan_order(loads, order)
    rank_of_cluster = np.empty(n, np.int32)
    rank_of_cluster[global_order] = np.arange(n, dtype=np.int32)
    chunk_of_cluster = np.zeros(n, np.int32)
    n_waves = max(1, min(num_chunks, n))
    pinned = np.zeros(n, dtype=bool)
    if pinned_first is not None and n:
        pinned[np.asarray(list(pinned_first), np.int64)] = True
    for d in range(num_slots):
        members_d = np.nonzero((assignment == d) & ~pinned)[0]
        if members_d.size == 0:
            continue
        if pinned.any():
            # Pinned clusters already occupy chunk 0; the rest of this
            # slot fills the later waves (shifted by one). A 1-wave plan
            # leaves everything in chunk 0.
            rest_waves = plan_chunks(loads[members_d], max(1, n_waves - 1),
                                     order)
            for ci, wave in enumerate(rest_waves):
                shifted = min(ci + 1, n_waves - 1)
                chunk_of_cluster[members_d[wave]] = shifted
        else:
            waves = plan_chunks(loads[members_d], n_waves, order)
            for ci, wave in enumerate(waves):
                chunk_of_cluster[members_d[wave]] = min(ci, n_waves - 1)
    used = np.unique(chunk_of_cluster[:n] if n else [])
    if n:
        remap = {int(c): i for i, c in enumerate(sorted(used))}
        chunk_of_cluster = np.asarray(
            [remap[int(c)] for c in chunk_of_cluster], np.int32
        )
    return WavePlan(
        rank_of_cluster=rank_of_cluster,
        chunk_of_cluster=chunk_of_cluster,
        num_chunks=max(1, len(used)),
        replication=int(replication),
    )


@dataclasses.dataclass(frozen=True)
class PhaseTimes:
    """Per-operation durations of each phase, seconds."""

    copy: np.ndarray
    sort: np.ndarray
    run: np.ndarray

    def __post_init__(self):
        for f in (self.copy, self.sort, self.run):
            if np.any(np.asarray(f) < 0):
                raise ValueError("phase durations must be non-negative")


@dataclasses.dataclass(frozen=True)
class PipelineResult:
    """Timing summary of one Reduce task's copy/sort/run execution."""

    finish_time: float       # relative to pipeline start (all Maps done)
    sort_delay: float        # first op enters sort  (paper Fig 12)
    run_delay: float         # first op enters run   (paper Fig 13)
    copy_busy: float
    sort_busy: float
    run_busy: float

    @property
    def resource_utilisation(self) -> float:
        """Mean busy fraction of the three resources over the task's span."""
        if self.finish_time == 0:
            return 1.0
        return (self.copy_busy + self.sort_busy + self.run_busy) / (3 * self.finish_time)


def run_pipelined(
    phases: PhaseTimes, order: Sequence[int] | None = None, start: float = 0.0
) -> PipelineResult:
    """3-stage flow-shop timing: each resource handles one operation at a time.

    ``copy_i`` starts when the network is free; ``sort_i`` when both
    ``copy_i`` is done and the sorter is free; ``run_i`` likewise. This is
    the OS4M Reduce task of Fig 4(b).
    """
    copy, sort, run = (np.asarray(p, dtype=np.float64) for p in (phases.copy, phases.sort, phases.run))
    n = copy.shape[0]
    if order is None:
        order = np.arange(n)
    t_copy = t_sort = t_run = start
    first_sort = first_run = None
    for j in order:
        c_end = t_copy + copy[j]
        t_copy = c_end
        s_start = max(c_end, t_sort)
        if first_sort is None:
            first_sort = s_start
        s_end = s_start + sort[j]
        t_sort = s_end
        r_start = max(s_end, t_run)
        if first_run is None:
            first_run = r_start
        t_run = r_start + run[j]
    return PipelineResult(
        finish_time=t_run - start,
        sort_delay=(first_sort - start) if first_sort is not None else 0.0,
        run_delay=(first_run - start) if first_run is not None else 0.0,
        copy_busy=float(copy.sum()),
        sort_busy=float(sort.sum()),
        run_busy=float(run.sum()),
    )


def run_sequential(
    phases: PhaseTimes,
    start: float = 0.0,
    copy_head_start: float = 0.0,
    whole_task_sort: float | None = None,
) -> PipelineResult:
    """Default MapReduce Reduce task (Fig 4a): copy ALL, then sort ALL, then run ALL.

    ``copy_head_start``: how much copy work Hadoop already finished before
    the pipeline clock starts (it overlaps the copy phase with Map tasks).
    ``whole_task_sort``: Hadoop sorts the *entire* input in one (possibly
    multi-pass, disk-bound) sort; if given, it replaces ``sum(phases.sort)``.
    """
    copy, sort, run = (np.asarray(p, dtype=np.float64) for p in (phases.copy, phases.sort, phases.run))
    copy_total = max(0.0, float(copy.sum()) - copy_head_start)
    sort_total = float(sort.sum()) if whole_task_sort is None else whole_task_sort
    run_total = float(run.sum())
    sort_start = start + copy_total
    run_start = sort_start + sort_total
    return PipelineResult(
        finish_time=copy_total + sort_total + run_total,
        sort_delay=sort_start - start,
        run_delay=run_start - start,
        copy_busy=copy_total,
        sort_busy=sort_total,
        run_busy=run_total,
    )


# ---------------------------------------------------------------------------
# Multi-job co-scheduling: interleave several jobs' wave plans on one mesh.
# ---------------------------------------------------------------------------


def coschedule_waves(
    plans: Sequence["WavePlan"],
) -> List[tuple]:
    """Interleave N jobs' §4.4 wave sequences into one issue order.

    Returns ``[(job_index, wave_index), ...]`` — a round-robin merge that
    keeps each job's waves in order while alternating jobs whenever more
    than one still has waves left. Consecutive entries from *different*
    jobs are the co-scheduling win: wave ``w+1`` of one job is
    double-buffered (its all-to-all copy issued) while the *other* job's
    wave computes, so job B's a2a hides under job A's reduce exactly the
    way a single job's next wave hides under its current one
    (:func:`run_pipelined`) — but now the overlap survives each job's
    phase boundaries. Jobs with more waves than the rest finish with a
    consecutive (non-overlapped) tail, which
    :func:`coschedule_overlap` makes visible.
    """
    cursors = [0] * len(plans)
    totals = [int(p.num_chunks) for p in plans]
    out: List[tuple] = []
    live = [j for j, t in enumerate(totals) if t > 0]
    turn = 0
    while live:
        # Rotate through the live jobs so no job's waves starve.
        job = live[turn % len(live)]
        out.append((job, cursors[job]))
        cursors[job] += 1
        if cursors[job] >= totals[job]:
            drop = live.index(job)
            live.pop(drop)
            turn = drop  # next job after the one that just finished
        else:
            turn += 1
    return out


def coschedule_overlap(issue_order: Sequence[tuple]) -> float:
    """Fraction of wave transitions that cross jobs (overlap opportunities).

    Each adjacent pair from different jobs means the later wave's
    all-to-all was issued while another job's wave computed — the
    cross-job analogue of the double-buffer overlap inside one job. 0.0
    for FIFO one-job-at-a-time (all transitions stay within a job until
    it drains); approaches 1.0 for balanced round-robin co-scheduling.
    """
    if len(issue_order) < 2:
        return 0.0
    crossings = sum(
        1 for a, b in zip(issue_order, issue_order[1:]) if a[0] != b[0])
    return crossings / (len(issue_order) - 1)
