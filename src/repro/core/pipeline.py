"""Reduce pipelining (paper §4.4).

A Reduce task's three phases consume three different resources
(copy = network, sort = disk/memory, run = CPU). Default MapReduce runs the
phases *sequentially over the whole task*; OS4M splits the task input at
operation(-cluster) granularity and streams the operations through a
3-stage pipeline, ordered by **increasing load** to minimise the sort/run
delays (the Map→Reduce barrier).

This module is the pure planner/timing model. It is used by:

* ``repro.core.simulator`` — the cluster-level discrete-event model that
  reproduces the paper's Figs 7/8/9/12/13/14/15/16;
* ``repro.core.mapreduce`` — to pick the on-device chunk order for the
  double-buffered shuffle→reduce scan (the TPU analogue: overlap the
  all-to-all "copy" of chunk *i+1* with the segment-reduce "run" of *i*);
* the MoE dispatch path — chunked all-to-all overlapped with expert FFN.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

__all__ = [
    "plan_order",
    "plan_chunks",
    "PhaseTimes",
    "PipelineResult",
    "run_pipelined",
    "run_sequential",
]


def plan_order(loads: Sequence[float], order: str = "increasing") -> np.ndarray:
    """Operation processing order on the pipeline.

    ``increasing`` (paper default, §4.4): the smallest operation primes the
    pipeline fastest, minimising sort/run delay. ``decreasing`` and
    ``arrival`` provided for ablation (benchmarks/fig12_13_delays.py).
    """
    loads = np.asarray(loads, dtype=np.float64)
    if order == "increasing":
        return np.argsort(loads, kind="stable")
    if order == "decreasing":
        return np.argsort(-loads, kind="stable")
    if order == "arrival":
        return np.arange(loads.shape[0])
    raise ValueError(f"unknown order {order!r}")


def plan_chunks(
    loads: Sequence[float], num_chunks: int, order: str = "increasing"
) -> List[np.ndarray]:
    """Group ordered operations into ``num_chunks`` contiguous chunks.

    Greedy: walk the ordered operations, cut when the running chunk load
    exceeds ``total / num_chunks``. Every chunk is non-empty as long as
    ``len(loads) >= num_chunks``. Used to bound the number of pipeline
    stages (= scan length) on device.
    """
    loads = np.asarray(loads, dtype=np.float64)
    idx = plan_order(loads, order)
    n = idx.shape[0]
    num_chunks = max(1, min(num_chunks, n))
    target = loads.sum() / num_chunks
    chunks: List[np.ndarray] = []
    cur: List[int] = []
    cur_load = 0.0
    for j in idx:
        cur.append(int(j))
        cur_load += loads[j]
        remaining_slots = num_chunks - len(chunks) - 1
        remaining_ops = n - sum(len(c) for c in chunks) - len(cur)
        if cur_load >= target and remaining_slots > 0 and remaining_ops >= remaining_slots:
            chunks.append(np.asarray(cur, dtype=np.int64))
            cur, cur_load = [], 0.0
    if cur:
        chunks.append(np.asarray(cur, dtype=np.int64))
    return chunks


@dataclasses.dataclass(frozen=True)
class PhaseTimes:
    """Per-operation durations of each phase, seconds."""

    copy: np.ndarray
    sort: np.ndarray
    run: np.ndarray

    def __post_init__(self):
        for f in (self.copy, self.sort, self.run):
            if np.any(np.asarray(f) < 0):
                raise ValueError("phase durations must be non-negative")


@dataclasses.dataclass(frozen=True)
class PipelineResult:
    finish_time: float       # relative to pipeline start (all Maps done)
    sort_delay: float        # first op enters sort  (paper Fig 12)
    run_delay: float         # first op enters run   (paper Fig 13)
    copy_busy: float
    sort_busy: float
    run_busy: float

    @property
    def resource_utilisation(self) -> float:
        if self.finish_time == 0:
            return 1.0
        return (self.copy_busy + self.sort_busy + self.run_busy) / (3 * self.finish_time)


def run_pipelined(
    phases: PhaseTimes, order: Sequence[int] | None = None, start: float = 0.0
) -> PipelineResult:
    """3-stage flow-shop timing: each resource handles one operation at a time.

    ``copy_i`` starts when the network is free; ``sort_i`` when both
    ``copy_i`` is done and the sorter is free; ``run_i`` likewise. This is
    the OS4M Reduce task of Fig 4(b).
    """
    copy, sort, run = (np.asarray(p, dtype=np.float64) for p in (phases.copy, phases.sort, phases.run))
    n = copy.shape[0]
    if order is None:
        order = np.arange(n)
    t_copy = t_sort = t_run = start
    first_sort = first_run = None
    for j in order:
        c_end = t_copy + copy[j]
        t_copy = c_end
        s_start = max(c_end, t_sort)
        if first_sort is None:
            first_sort = s_start
        s_end = s_start + sort[j]
        t_sort = s_end
        r_start = max(s_end, t_run)
        if first_run is None:
            first_run = r_start
        t_run = r_start + run[j]
    return PipelineResult(
        finish_time=t_run - start,
        sort_delay=(first_sort - start) if first_sort is not None else 0.0,
        run_delay=(first_run - start) if first_run is not None else 0.0,
        copy_busy=float(copy.sum()),
        sort_busy=float(sort.sum()),
        run_busy=float(run.sum()),
    )


def run_sequential(
    phases: PhaseTimes,
    start: float = 0.0,
    copy_head_start: float = 0.0,
    whole_task_sort: float | None = None,
) -> PipelineResult:
    """Default MapReduce Reduce task (Fig 4a): copy ALL, then sort ALL, then run ALL.

    ``copy_head_start``: how much copy work Hadoop already finished before
    the pipeline clock starts (it overlaps the copy phase with Map tasks).
    ``whole_task_sort``: Hadoop sorts the *entire* input in one (possibly
    multi-pass, disk-bound) sort; if given, it replaces ``sum(phases.sort)``.
    """
    copy, sort, run = (np.asarray(p, dtype=np.float64) for p in (phases.copy, phases.sort, phases.run))
    copy_total = max(0.0, float(copy.sum()) - copy_head_start)
    sort_total = float(sort.sum()) if whole_task_sort is None else whole_task_sort
    run_total = float(run.sum())
    sort_start = start + copy_total
    run_start = sort_start + sort_total
    return PipelineResult(
        finish_time=copy_total + sort_total + run_total,
        sort_delay=sort_start - start,
        run_delay=run_start - start,
        copy_busy=copy_total,
        sort_busy=sort_total,
        run_busy=run_total,
    )
