"""Discrete-event model of a Hadoop-class cluster running a MapReduce job.

Reproduces the *duration* figures of the paper (Figs 2, 7, 8, 9, 12, 13, 14,
15, 16 and Table 4) that cannot be measured on this container: the paper's
numbers come from 8 worker VMs with measured bandwidths (network 37 MB/s,
disk read 203 MB/s, disk write 121 MB/s), 4 Map + 4 Reduce slots per node,
64 MB HDFS blocks, 500 MB task heap. We model exactly that cluster.

Model (assumptions documented in DESIGN.md / EXPERIMENTS.md):

* A Map task reads its block from disk, applies the Map function (CPU rate
  per benchmark), and writes ``block * shuffle_ratio`` of intermediate data.
* **Hadoop mode**: Reduce copy begins as soon as the first Map wave ends and
  shares each node's disk+network bandwidth with still-running Map tasks.
  The contention multiplies Map I/O time by ``1 + c * f`` where ``f`` is the
  fraction of Map output already produced (this reproduces the wave
  pattern of Fig 2: 45 s → 86 s → very slow). The Reduce task then runs the
  three phases sequentially (Fig 4a), with an external multi-pass sort when
  its input exceeds the task heap.
* **OS4M mode**: Maps run contention-free; Reduce starts after the last Map,
  fetches per-operation-cluster bucket files, and streams clusters through
  the copy→sort→run pipeline in increasing-load order (Fig 4b,
  ``repro.core.pipeline``). Small parts sort in memory.

The per-Reduce-slot loads come from an actual :mod:`repro.core.scheduler`
schedule over a synthetic key distribution (zipf-like skew calibrated per
benchmark to the skew the paper reports in Fig 1).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import pipeline as pipe
from repro.core import scheduler as sched_lib

__all__ = [
    "ClusterSpec",
    "BenchmarkSpec",
    "SimResult",
    "PAPER_CLUSTER",
    "PUMA_BENCHMARKS",
    "synth_key_distribution",
    "simulate_job",
    "estimate_reduce_time",
    "scheduling_overhead",
    "pick_strategy",
    "estimate_replan_benefit",
    "wspt_order",
    "weighted_completion_time",
]


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Paper §5: 8 worker VMs on IBM RC2; 9th VM runs JobTracker/NameNode."""

    num_nodes: int = 8
    map_slots_per_node: int = 4
    reduce_slots_per_node: int = 4
    net_bw: float = 37e6          # B/s per node (measured, paper §5)
    disk_read_bw: float = 203e6   # B/s per node
    disk_write_bw: float = 121e6  # B/s per node
    block_bytes: int = 64 * 2**20  # default HDFS block
    heap_bytes: int = 500 * 2**20  # task JVM heap (paper §5 point 4)
    # Hadoop map↔copy contention: wave slowdown = 1 + io_coeff * frac_output
    # * min(shuffle_bytes_per_node / pressure_ref, pressure_cap), capped at
    # factor_cap. io_coeff is per-benchmark (I/O intensity of the map task);
    # the per-NODE pressure makes both bigger shuffles and smaller clusters
    # contend harder (paper §5.5: "with fewer nodes, the data for each node
    # is larger ... contention is more intensive").
    pressure_ref: float = 0.75 * 2**30   # per node
    pressure_cap: float = 3.0
    factor_cap: float = 4.5

    @property
    def map_slots(self) -> int:
        """Cluster-wide Map slot count."""
        return self.num_nodes * self.map_slots_per_node

    @property
    def reduce_slots(self) -> int:
        """Cluster-wide Reduce slot count."""
        return self.num_nodes * self.reduce_slots_per_node


PAPER_CLUSTER = ClusterSpec()


@dataclasses.dataclass(frozen=True)
class BenchmarkSpec:
    """One PUMA benchmark (Table 2/3) with calibration knobs.

    ``zipf_alpha`` / ``num_keys`` shape the intermediate key distribution
    (Fig 1a showed 1 .. 1.97e6 pairs per operation for RII);
    ``shuffle_ratio`` = intermediate bytes / input bytes;
    ``map_cpu_bps`` / ``reduce_cpu_pps`` are the function costs.
    """

    name: str
    sizes_gb: Tuple[float, float, float]  # S, M, L (paper Table 3)
    zipf_alpha: float
    num_keys: int
    shuffle_ratio: float
    map_cpu_bps: float      # map function throughput, bytes/s
    reduce_cpu_pps: float   # reduce function throughput, pairs/s
    bytes_per_pair: int
    io_coeff: float         # map task I/O intensity (contention sensitivity)


# Calibrated (benchmarks/fig14_job_duration.py prints the fit): Hadoop
# durations match Table 4, skew matches Fig 1/5/6 qualitatively (II the
# hardest to balance, SJ nearly uniform), gains anchor Fig 14 (AL_L best
# ≈42 %, SJ_L worst ≈8 %).
PUMA_BENCHMARKS: Dict[str, BenchmarkSpec] = {
    "AL": BenchmarkSpec("AL", (5, 10, 15), 0.80, 60_000, 1.00, 3.9e5, 7.9e3, 96, 0.85),
    "II": BenchmarkSpec("II", (5, 10, 15), 0.97, 120_000, 0.55, 5.6e5, 1.1e4, 48, 0.70),
    "RII": BenchmarkSpec("RII", (10, 20, 30), 0.82, 90_000, 0.60, 8.4e5, 1.7e4, 64, 0.55),
    "SC": BenchmarkSpec("SC", (5, 10, 15), 0.75, 250_000, 1.20, 3.6e5, 7.3e3, 72, 0.60),
    "SJ": BenchmarkSpec("SJ", (10, 20, 30), 0.40, 150_000, 0.20, 1.2e6, 2.4e4, 56, 0.10),
    "TV": BenchmarkSpec("TV", (5, 10, 15), 0.82, 80_000, 0.45, 6.6e5, 1.3e4, 40, 0.60),
}


def synth_key_distribution(spec: BenchmarkSpec, input_bytes: float, seed: int = 0) -> np.ndarray:
    """Per-key pair counts with zipf skew, scaled to the job's shuffle volume."""
    rng = np.random.default_rng(seed + hash(spec.name) % 65536)
    ranks = np.arange(1, spec.num_keys + 1, dtype=np.float64)
    weights = ranks ** (-spec.zipf_alpha)
    # mild multiplicative noise so ties break realistically
    weights *= np.exp(rng.normal(0.0, 0.25, size=weights.shape))
    total_pairs = input_bytes * spec.shuffle_ratio / spec.bytes_per_pair
    counts = weights / weights.sum() * total_pairs
    return np.maximum(counts, 1.0)


@dataclasses.dataclass
class SimResult:
    """Per-job simulation outputs (the quantities the paper's figures plot)."""

    mode: str
    job_duration: float
    map_end: float
    avg_map_duration: float
    std_map_duration: float
    avg_reduce_duration: float
    std_reduce_duration: float
    avg_sort_delay: float
    avg_run_delay: float
    balance_ratio: float
    map_progress: List[Tuple[float, float]]     # (time, fraction complete)
    reduce_finish: List[float]
    phase_times: Dict[str, float]               # avg copy/sort/run busy per task


def _map_phase(
    cluster: ClusterSpec,
    spec: BenchmarkSpec,
    num_maps: int,
    mode: str,
    input_bytes: float,
) -> Tuple[float, np.ndarray, List[Tuple[float, float]]]:
    """Returns (map_end_time, per-task durations, progress trace).

    Hadoop contention model: once any Map output exists, Reduce copy flows
    share each node's disk and NIC with running Map tasks; a Map task stalls
    on I/O in proportion to (a) how much output is available to copy
    (``frac_output``, grows wave by wave — Fig 2's 45 s → 86 s → "extremely
    slow") and (b) the job's copy pressure (total shuffle volume relative to
    the cluster's drain capacity — Table 4's superlinear growth with size).
    OS4M removes the overlap entirely (§4.1 step 6), so its waves are flat
    (Fig 9's consistent progress rate).
    """
    base_io = (
        cluster.block_bytes / (cluster.disk_read_bw / cluster.map_slots_per_node)
        + (cluster.block_bytes * spec.shuffle_ratio)
        / (cluster.disk_write_bw / cluster.map_slots_per_node)
    )
    base_cpu = cluster.block_bytes / spec.map_cpu_bps
    base_wave = base_io + base_cpu
    shuffle_bytes = input_bytes * spec.shuffle_ratio
    pressure = min(shuffle_bytes / cluster.num_nodes / cluster.pressure_ref,
                   cluster.pressure_cap)
    waves = math.ceil(num_maps / cluster.map_slots)
    durations = np.zeros(num_maps)
    progress: List[Tuple[float, float]] = [(0.0, 0.0)]
    t = 0.0
    done = 0
    for _ in range(waves):
        tasks = min(cluster.map_slots, num_maps - done)
        if mode == "hadoop":
            frac_output = done / num_maps
            factor = min(
                1.0 + spec.io_coeff * frac_output * pressure, cluster.factor_cap
            )
        else:
            factor = 1.0
        wave_time = base_wave * factor
        durations[done : done + tasks] = wave_time
        t += wave_time
        done += tasks
        progress.append((t, done / num_maps))
    return t, durations, progress


def _reduce_loads(
    spec: BenchmarkSpec,
    input_bytes: float,
    num_reduce: int,
    num_clusters: int,
    mode: str,
    seed: int = 0,
) -> Tuple[np.ndarray, sched_lib.Schedule, np.ndarray]:
    """Key distribution → clusters → schedule → per-slot cluster load lists."""
    key_counts = synth_key_distribution(spec, input_bytes, seed)
    from repro.core import clustering

    key_ids = np.arange(key_counts.shape[0])
    cids = clustering.cluster_ids_for_keys(
        sched_lib._default_hash(key_ids).astype(np.int64), num_clusters
    )
    cl_loads = clustering.cluster_loads(key_counts, cids, num_clusters)
    if mode == "hadoop":
        schedule = sched_lib.schedule_hash(cl_loads, num_reduce, keys=np.arange(num_clusters))
    else:
        schedule = sched_lib.schedule_bss(cl_loads, num_reduce)
    return cl_loads, schedule, key_counts


# ---------------------------------------------------------------------------
# Schedule cost model — the "auto" strategy picker.
#
# ``MapReduceConfig(scheduler="auto")`` needs a per-job answer to "which
# P||C_max algorithm is worth its host-side cost for THIS key
# distribution?". The estimate reuses exactly the machinery behind the
# paper figures: each candidate schedule's Reduce phase is played through
# the 3-stage flow-shop model (``pipeline.run_pipelined``) on the paper's
# cluster rates, and a deterministic model of the scheduler's own host
# cost is added so near-identical makespans resolve to the cheaper
# algorithm (on near-uniform distributions hash ≈ BSS on makespan, and
# the FPTAS buys nothing).
# ---------------------------------------------------------------------------


def estimate_reduce_time(
    loads: np.ndarray,
    schedule: sched_lib.Schedule,
    *,
    cluster: ClusterSpec = PAPER_CLUSTER,
    bytes_per_pair: float = 64,
    reduce_cpu_pps: float = 1.7e4,
    pipelined: bool = True,
    pipeline_order: str = "increasing",
    speeds: Optional[np.ndarray] = None,
    local_hist: Optional[np.ndarray] = None,
) -> float:
    """Estimated Reduce-phase makespan (s) of one schedule.

    Per slot: per-cluster copy/sort/run durations from the cluster's
    bandwidth shares, composed with the flow-shop pipeline (or the
    sequential Fig 4(a) layout when ``pipelined=False``); the job finishes
    when the slowest slot does.

    ``speeds`` (Q||C_max): per-slot relative speed factors. A slot at
    speed ``s`` runs *every* phase ``1/s`` slower — a straggler node's
    NIC share, disk, and CPU are all degraded together (noisy neighbour /
    older generation), which is the model
    :mod:`repro.core.slot_speeds` estimates against. ``None`` falls back
    to the schedule's own recorded speeds (nominal when those are unset).

    ``local_hist`` — the per-shard ``(m, n)`` K^(i) histogram of §4.1.
    When given, the copy phase charges each slot only for the pairs that
    actually cross the wire to it (``loads[k] − local_hist[slot, k]`` for
    its clusters ``k`` — the slot's own shard of a cluster never leaves
    the node), instead of assuming every pair pays uniform network cost.
    ``bytes_per_pair`` may be a *measured* wire rate (e.g.
    ``JobResult.shuffle_bytes / shuffle_rows`` from the engine's
    accounting layer), which is how quantized/coded shuffle modes keep
    this cost model honest about the volume they actually ship.
    """
    loads = np.asarray(loads, dtype=np.float64)
    if speeds is None:
        speeds = schedule.slot_speeds
    speeds = sched_lib.normalize_speeds(speeds, schedule.num_slots)
    if local_hist is not None:
        local_hist = np.asarray(local_hist, dtype=np.float64)
        if local_hist.shape != (schedule.num_slots, loads.shape[0]):
            raise ValueError(
                f"local_hist shape {local_hist.shape} does not match "
                f"(num_slots={schedule.num_slots}, n={loads.shape[0]})"
            )
    reduce_per_node = cluster.reduce_slots_per_node
    net_share = cluster.net_bw / reduce_per_node
    disk_r = cluster.disk_read_bw / reduce_per_node
    finish = 0.0
    for slot in range(schedule.num_slots):
        members = np.nonzero(schedule.assignment == slot)[0]
        if members.size == 0:
            continue
        slot_loads = loads[members]
        if local_hist is None:
            wire_pairs = slot_loads
        else:
            # Pairs of this slot's clusters that live on OTHER shards —
            # the only ones the copy phase ships (K − K^(slot) per §4.1).
            wire_pairs = np.maximum(slot_loads - local_hist[slot, members], 0.0)
        slow = 1.0 if speeds is None else 1.0 / float(speeds[slot])
        phases = pipe.PhaseTimes(
            # Copy pays only for pairs crossing the network; sort touches
            # every received pair (local shards included) regardless.
            copy=wire_pairs * bytes_per_pair / net_share * slow,
            sort=slot_loads * bytes_per_pair / (disk_r * 4.0) * slow,
            run=slot_loads / reduce_cpu_pps * slow,
        )
        if pipelined:
            res = pipe.run_pipelined(
                phases, order=pipe.plan_order(slot_loads, pipeline_order)
            )
        else:
            res = pipe.run_sequential(phases)
        finish = max(finish, res.finish_time)
    return finish


# Host "ops"/second for the scheduling-overhead model below. The constants
# only need the right *ordering* and rough magnitude: hash O(n) ≪
# LPT O(n log n) ≪ MULTIFIT O(iters·n·m) ≪ BSS O(n²/√η̃).
_HOST_RATE = 5e7


def scheduling_overhead(name: str, n: int, m: int, eta: float = 0.002) -> float:
    """Deterministic estimate (s) of a scheduler's own host-side cost."""
    n = max(1, int(n))
    m = max(1, int(m))
    if name == "hash":
        ops = float(n)
    elif name == "lpt":
        ops = n * max(1.0, math.log2(n))
    elif name == "multifit":
        ops = 20.0 * n * m
    elif name in ("bss", "os4m"):
        ops = float(n) ** 2 / max(math.sqrt(eta), 1e-3)
    else:
        ops = float(n) ** 2
    return ops / _HOST_RATE


def pick_strategy(
    loads: np.ndarray,
    num_slots: int,
    *,
    eta: float = 0.002,
    candidates: Tuple[str, ...] = sched_lib.AUTO_CANDIDATES,
    cluster: ClusterSpec = PAPER_CLUSTER,
    bytes_per_pair: float = 64,
    reduce_cpu_pps: float = 1.7e4,
    pipelined: bool = True,
    speeds: Optional[np.ndarray] = None,
    local_hist: Optional[np.ndarray] = None,
) -> Tuple[str, sched_lib.Schedule, Dict[str, float]]:
    """Choose the scheduling algorithm with the lowest estimated job cost.

    Returns ``(name, schedule, costs)`` where ``costs[name]`` is estimated
    Reduce makespan + scheduling overhead in model seconds. Ties resolve
    to the earlier (cheaper) candidate. ``speeds`` makes every candidate
    plan — and every makespan estimate — speed-aware (Q||C_max); under a
    straggler the imbalance term grows, so the picker naturally shifts
    from hash toward the speed-aware algorithms. ``local_hist`` /
    ``bytes_per_pair`` feed :func:`estimate_reduce_time`'s per-slot wire
    accounting — pass the engine's K^(i) statistics and *measured* wire
    rate so the picker sees real shuffle volume, not a uniform model.
    """
    loads = np.asarray(loads, dtype=np.float64)
    speeds = sched_lib.normalize_speeds(speeds, num_slots)
    n = loads.shape[0]
    best_name, best_sched, costs = None, None, {}
    for name in candidates:
        fn = sched_lib.get_scheduler(name)
        if name == "hash":
            schedule = fn(loads, num_slots, keys=np.arange(n), speeds=speeds)
        elif name in ("bss", "os4m"):
            schedule = fn(loads, num_slots, eta=eta, speeds=speeds)
        else:
            schedule = fn(loads, num_slots, speeds=speeds)
        cost = estimate_reduce_time(
            loads, schedule, cluster=cluster, bytes_per_pair=bytes_per_pair,
            reduce_cpu_pps=reduce_cpu_pps, pipelined=pipelined, speeds=speeds,
            local_hist=local_hist,
        ) + scheduling_overhead(name, n, num_slots, eta)
        costs[name] = cost
        if best_name is None or cost < costs[best_name]:
            best_name, best_sched = name, schedule
    return best_name, best_sched, costs


def estimate_replan_benefit(
    loads: np.ndarray,
    cached_schedule: sched_lib.Schedule,
    *,
    eta: float = 0.002,
    candidates: Tuple[str, ...] = sched_lib.AUTO_CANDIDATES,
    cluster: ClusterSpec = PAPER_CLUSTER,
    bytes_per_pair: float = 64,
    reduce_cpu_pps: float = 1.7e4,
    pipelined: bool = True,
    speeds: Optional[np.ndarray] = None,
    local_hist: Optional[np.ndarray] = None,
) -> Dict[str, object]:
    """Is replanning worth it, or is the stale schedule still good enough?

    The schedule-reuse cost model behind ``ReusePolicy(cost_gate=True)``:
    play the **cached** assignment against the **fresh** key distribution
    through the same flow-shop model as :func:`pick_strategy` (expected
    imbalance of staying stale), and compare with the best fresh
    candidate's makespan *plus its host scheduling overhead* (cost of
    replanning). A drifted distribution whose stale makespan still beats
    replan-cost − e.g. mild drift, expensive FPTAS − should keep reusing.

    Returns ``{"stale_makespan", "fresh_cost", "fresh_strategy",
    "benefit"}`` where ``benefit = stale_makespan - fresh_cost`` in model
    seconds; replan only when it is positive. ``speeds`` evaluates *both*
    sides under the current measured slot speeds — a stale schedule that
    piled work on a now-slow slot shows its true (inflated) makespan.
    """
    loads = np.asarray(loads, dtype=np.float64)
    speeds = sched_lib.normalize_speeds(speeds, cached_schedule.num_slots)
    stale = estimate_reduce_time(
        loads, cached_schedule, cluster=cluster, bytes_per_pair=bytes_per_pair,
        reduce_cpu_pps=reduce_cpu_pps, pipelined=pipelined, speeds=speeds,
        local_hist=local_hist,
    )
    name, _, costs = pick_strategy(
        loads, cached_schedule.num_slots, eta=eta, candidates=candidates,
        cluster=cluster, bytes_per_pair=bytes_per_pair,
        reduce_cpu_pps=reduce_cpu_pps, pipelined=pipelined, speeds=speeds,
        local_hist=local_hist,
    )
    fresh = costs[name]
    return {
        "stale_makespan": float(stale),
        "fresh_cost": float(fresh),
        "fresh_strategy": name,
        "benefit": float(stale - fresh),
    }


def simulate_job(
    benchmark: str,
    size: str,
    mode: str,
    cluster: ClusterSpec = PAPER_CLUSTER,
    num_reduce: int = 30,           # paper §5: 0.95 * 8 * 4 ≈ 30
    num_clusters: int = 240,        # paper §5: clustering kicks in above 240
    pipeline_order: str = "increasing",
    seed: int = 0,
) -> SimResult:
    """Simulate one (benchmark, dataset, mode) job. mode ∈ {hadoop, os4m}."""
    spec = PUMA_BENCHMARKS[benchmark]
    size_idx = {"S": 0, "M": 1, "L": 2}[size]
    input_bytes = spec.sizes_gb[size_idx] * 2**30
    num_maps = math.ceil(input_bytes / cluster.block_bytes)

    map_end, map_durs, progress = _map_phase(
        cluster, spec, num_maps, mode, input_bytes
    )

    cl_loads, schedule, _ = _reduce_loads(
        spec, input_bytes, num_reduce, num_clusters, mode, seed
    )

    # Per-node bandwidth shares for Reduce-phase resources.
    reduce_per_node = cluster.reduce_slots_per_node
    net_share = cluster.net_bw / reduce_per_node
    disk_r = cluster.disk_read_bw / reduce_per_node
    disk_w = cluster.disk_write_bw / reduce_per_node

    reduce_finish: List[float] = []
    reduce_durations: List[float] = []
    sort_delays: List[float] = []
    run_delays: List[float] = []
    busy = {"copy": 0.0, "sort": 0.0, "run": 0.0}

    for slot in range(num_reduce):
        members = np.nonzero(schedule.assignment == slot)[0]
        loads = cl_loads[members]  # pairs per cluster on this slot
        if loads.size == 0:
            reduce_finish.append(map_end)
            reduce_durations.append(0.0)
            sort_delays.append(0.0)
            run_delays.append(0.0)
            continue
        byte_loads = loads * spec.bytes_per_pair
        copy_t = byte_loads / net_share
        run_t = loads / spec.reduce_cpu_pps
        if mode == "os4m":
            # §4.4: per-cluster parts; parts under the heap sort in memory.
            in_mem = byte_loads <= cluster.heap_bytes
            mem_sort = byte_loads / (disk_r * 4.0)          # memory-speed sort
            dsk_sort = byte_loads / disk_r + byte_loads / disk_w
            sort_t = np.where(in_mem, mem_sort, dsk_sort)
            res = pipe.run_pipelined(
                pipe.PhaseTimes(copy_t, sort_t, run_t),
                order=pipe.plan_order(loads, pipeline_order),
                start=map_end,
            )
        else:
            total_bytes = float(byte_loads.sum())
            passes = 1 if total_bytes <= cluster.heap_bytes else (
                2 if total_bytes <= 8 * cluster.heap_bytes else 3
            )
            whole_sort = passes * (total_bytes / disk_r + total_bytes / disk_w)
            # Hadoop overlapped its copy phase with Maps: it has been copying
            # since the first wave finished, at the contended rate.
            first_wave_end = map_end / max(
                1, math.ceil(num_maps / cluster.map_slots)
            )
            overlap_window = max(0.0, map_end - first_wave_end)
            head_start = min(float(copy_t.sum()), overlap_window * 0.6)
            res = pipe.run_sequential(
                pipe.PhaseTimes(copy_t, np.zeros_like(copy_t), run_t),
                start=map_end,
                copy_head_start=head_start,
                whole_task_sort=whole_sort,
            )
        reduce_finish.append(map_end + res.finish_time)
        reduce_durations.append(res.finish_time)
        sort_delays.append(res.sort_delay)
        run_delays.append(res.run_delay)
        busy["copy"] += res.copy_busy
        busy["sort"] += res.sort_busy
        busy["run"] += res.run_busy

    nr = max(1, num_reduce)
    return SimResult(
        mode=mode,
        job_duration=max(reduce_finish) if reduce_finish else map_end,
        map_end=map_end,
        avg_map_duration=float(map_durs.mean()),
        std_map_duration=float(map_durs.std()),
        avg_reduce_duration=float(np.mean(reduce_durations)),
        std_reduce_duration=float(np.std(reduce_durations)),
        avg_sort_delay=float(np.mean(sort_delays)),
        avg_run_delay=float(np.mean(run_delays)),
        balance_ratio=schedule.balance_ratio,
        map_progress=progress,
        reduce_finish=reduce_finish,
        phase_times={k: v / nr for k, v in busy.items()},
    )


# ---------------------------------------------------------------------------
# Multi-job admission: weighted completion time on one shared mesh.
# ---------------------------------------------------------------------------


def wspt_order(times, weights=None):
    """Admission order minimising ``Σ wᵢ Cᵢ`` for sequential jobs (WSPT).

    When N jobs share one mesh and each runs with the full mesh (the OS4M
    schedule already balances *within* a job), the coordinator's freedom
    is the *order*. Weighted Shortest Processing Time — descending
    ``w_j / t_j`` — is exactly optimal for ``1 || Σ w C`` (Smith's rule)
    and is the admission rule the multi-job coordinator plans by.
    ``times`` are per-job estimated makespans (seconds or any consistent
    unit, e.g. from each job's row of the R-matrix); ties break by
    submission index (stable), so equal jobs keep FIFO fairness.
    """
    t = np.asarray(times, dtype=np.float64)
    w = (np.ones_like(t) if weights is None
         else np.asarray(weights, dtype=np.float64))
    if t.shape != w.shape:
        raise ValueError(f"times {t.shape} vs weights {w.shape}")
    if np.any(t < 0) or np.any(w < 0):
        raise ValueError("times and weights must be >= 0")
    # Sort by t/w ascending == w/t descending, without dividing by zero:
    # a zero-time or infinite-weight job goes first via the ratio's sign.
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(w > 0, t / np.where(w > 0, w, 1.0), np.inf)
    return np.argsort(ratio, kind="stable")


def weighted_completion_time(times, weights=None, order=None):
    """``Σ wᵢ Cᵢ`` when jobs run back-to-back in ``order``.

    ``C_j`` is the cumulative time until job ``j`` finishes. ``order=None``
    means FIFO (submission order) — the baseline the multijob CI gate
    compares WSPT against.
    """
    t = np.asarray(times, dtype=np.float64)
    w = (np.ones_like(t) if weights is None
         else np.asarray(weights, dtype=np.float64))
    idx = np.arange(t.shape[0]) if order is None else np.asarray(order)
    completion = np.cumsum(t[idx])
    return float(np.sum(w[idx] * completion))
