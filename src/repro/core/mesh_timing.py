"""Per-device wall-clock measurement for shard_map phase-B waves.

On a real mesh every Reduce slot is a device with its own clock, and the
§4.2 "collect statistics" loop of OS4M should run on *measured* per-slot
timings, not on the synthetic work/slowdown model a single-device
container has to fall back to. This module is the measurement layer:

* :func:`shard_ready_seconds` — given the (async-dispatched) sharded
  output of one per-shard program and the dispatch timestamp, block on
  each device's shard in turn and record when its buffer became ready.
  For a program **without collectives** (the per-wave segment-reduce
  "run" of phase B), a device's ready time is its own compute wall-clock;
  a program that ends in a collective synchronises every device and is
  useless for per-slot attribution — which is exactly why the measured
  executor in :mod:`repro.core.mapreduce` fences each wave into a "copy"
  program (all-to-all, not attributed) and a "run" program (shard-local,
  timed).
* :class:`WaveTimings` — the accumulated ``(slots, waves)`` seconds
  buffer plus per-slot work, convertible into the ``(work, seconds)``
  observation :meth:`repro.core.slot_speeds.SlotSpeedEstimator.update`
  consumes.

Caveats (documented, not hidden): blocking shards serially means a shard
that finished while an earlier one was being awaited reads the earlier
shard's timestamp — measured times are per-device *completion* upper
bounds, which is the right signal for straggler detection (the straggler
dominates its own bound). On forced-host virtual devices all shards share
one CPU and the programs are capacity-shaped, so measured times are near
uniform — fault injection (``MapReduceJob.set_slot_slowdown``) then
stands in for real slow hardware by scaling the *measured* seconds,
keeping the estimator on the measured path end to end.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

__all__ = ["WaveTimings", "shard_ready_seconds"]


def shard_ready_seconds(outputs: Sequence, num_slots: int, t0: float) -> np.ndarray:
    """Seconds from ``t0`` until each slot's output shard was ready.

    ``outputs`` are one or more sharded arrays produced by a single
    dispatched per-shard program whose global leading axis is
    ``num_slots * rows_per_slot`` (the engine's ``out_specs=0``
    convention). Shards are attributed to slots by their leading-axis
    slice; slots are awaited in id order. Arrays without addressable
    shards (single-device / fully replicated) fall back to one
    block_until_ready with the same time charged to every slot.
    """
    ready = np.zeros(num_slots)
    per_slot = [[] for _ in range(num_slots)]
    fallback = []
    for arr in outputs:
        shards = getattr(arr, "addressable_shards", None)
        if not shards or len(shards) < num_slots:
            fallback.append(arr)
            continue
        rows = arr.shape[0] // num_slots
        for sh in shards:
            start = sh.index[0].start if sh.index and sh.index[0].start else 0
            per_slot[min(int(start) // max(rows, 1), num_slots - 1)].append(sh.data)
    for slot in range(num_slots):
        for buf in per_slot[slot]:
            buf.block_until_ready()
        ready[slot] = time.perf_counter() - t0
    if fallback:
        for arr in fallback:
            arr.block_until_ready()
        ready = np.maximum(ready, time.perf_counter() - t0)
    return ready


@dataclasses.dataclass
class WaveTimings:
    """Accumulated measured phase-B timings of one executed batch.

    ``seconds[j, c]`` — wall seconds slot ``j``'s wave-``c`` "run" program
    took (per-device ready time since dispatch). ``slot_work[j]`` — the
    work unit per slot fed to the estimator. Phase-B wave programs are
    **capacity-shaped** (every device reduces the same statically padded
    buffer), so the honest work measure is the shape work — identical
    across slots — and the implied rate ``work/seconds`` isolates pure
    per-device speed instead of confusing an unevenly *loaded* slot with
    a slow one. An idle slot (no clusters assigned) still executes its
    padded wave, so its measurement remains a valid device-speed sample.

    ``valid`` — False when any timed wave also traced/compiled this batch
    (the clock would bill XLA compilation to whichever device compiled
    first); invalid batches are measured but not fed to the estimator.
    """

    seconds: np.ndarray                    # (slots, waves)
    slot_work: Optional[np.ndarray] = None  # (slots,)
    valid: bool = True

    @staticmethod
    def empty(num_slots: int, num_waves: int) -> "WaveTimings":
        """A zeroed buffer to accumulate one batch's waves into."""
        return WaveTimings(np.zeros((num_slots, max(num_waves, 1))))

    def record(self, wave: int, wave_seconds: np.ndarray) -> None:
        """Store one wave's per-slot seconds."""
        self.seconds[:, wave] = np.asarray(wave_seconds)

    def slot_seconds(self) -> np.ndarray:
        """Total measured seconds per slot (sum over waves)."""
        return self.seconds.sum(axis=1)

    def observation(self, slot_slowdown: Optional[np.ndarray] = None):
        """The ``(work, seconds)`` pair for the speed estimator.

        ``slot_slowdown`` injects a fault into the *measurement*: slot
        ``j`` at factor ``f`` reports ``seconds / f`` — the wall-clock a
        ``f``× slow device would have measured — which keeps fault
        injection on the measured path instead of reviving the synthetic
        model.
        """
        secs = self.slot_seconds()
        if slot_slowdown is not None:
            secs = secs / np.asarray(slot_slowdown, np.float64)
        work = (self.slot_work if self.slot_work is not None
                else np.ones(self.seconds.shape[0]))
        return np.asarray(work, np.float64), secs
