"""Per-device timing of shard_map phase-B waves (ticks first, fences second).

On a real mesh every Reduce slot is a device with its own clock, and the
§4.2 "collect statistics" loop of OS4M should run on *measured* per-slot
timings, not on the synthetic work/slowdown model a single-device
container has to fall back to. This module is the measurement layer:

* :class:`WaveTimings` — the accumulated ``(slots, waves)`` seconds
  buffer plus per-slot work, convertible into the ``(work, seconds)``
  observation :meth:`repro.core.slot_speeds.SlotSpeedEstimator.update`
  consumes. The **primary ingestion path** is :meth:`WaveTimings.
  from_ticks`: per-device counter stamps read *inside* the overlapped
  phase-B program by the ``kernels/wave_timer`` op — no wave fencing, no
  host attribution, compile time never billed (stamps fire at execution).
* :func:`shard_ready_seconds` — the documented **host-timing fallback**
  for platforms without a tick source: given the (async-dispatched)
  sharded output of one per-shard program and the dispatch timestamp,
  record when each device's shard became ready. Only meaningful for a
  program without collectives (a collective synchronises every device),
  which is why the fallback executor fences each wave into a "copy"
  program (all-to-all, unattributed) and a "run" program (shard-local,
  timed) — trading the copy/run overlap for its clocks.

Fallback attribution: shards are awaited in *completion order* (readiness
polled via ``jax.Array.is_ready``), so a fast shard finishing while a
straggler is still running is stamped near its true completion instead of
inheriting the straggler's timestamp. Runtimes whose buffers cannot
report readiness degrade to the serial slot-order await, whose times are
per-device completion *upper bounds* (still the right signal for
straggler detection — the straggler dominates its own bound). On
forced-host virtual devices all shards share one CPU and programs are
capacity-shaped, so measured times are near uniform — fault injection
(``MapReduceJob.set_slot_slowdown``) then stands in for real slow
hardware by scaling the *measured* seconds, keeping the estimator on the
measured path end to end.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

__all__ = ["WaveTimings", "shard_ready_seconds"]

#: Completion-order polling cadence (seconds): fine enough to attribute
#: sub-millisecond waves, doubling up to a 1 ms cap while nothing lands.
_POLL_SECONDS = 5e-5
_POLL_CAP_SECONDS = 1e-3


def _slot_buffers(outputs: Sequence, num_slots: int):
    """Group each output's addressable shards by owning slot.

    Returns ``(per_slot, fallback)``: per-slot device buffers (leading-axis
    attribution, the engine's ``out_specs=0`` convention) and arrays
    without enough addressable shards (single-device / fully replicated),
    which can only be awaited collectively.
    """
    per_slot = [[] for _ in range(num_slots)]
    fallback = []
    for arr in outputs:
        shards = getattr(arr, "addressable_shards", None)
        if not shards or len(shards) < num_slots:
            fallback.append(arr)
            continue
        rows = arr.shape[0] // num_slots
        for sh in shards:
            start = sh.index[0].start if sh.index and sh.index[0].start else 0
            per_slot[min(int(start) // max(rows, 1), num_slots - 1)].append(sh.data)
    return per_slot, fallback


def shard_ready_seconds(outputs: Sequence, num_slots: int, t0: float) -> np.ndarray:
    """Seconds from ``t0`` until each slot's output shard was ready.

    ``outputs`` are one or more sharded arrays produced by a single
    dispatched per-shard program whose global leading axis is
    ``num_slots * rows_per_slot`` (the engine's ``out_specs=0``
    convention). Slots are stamped in **completion order**: readiness is
    polled (``is_ready``) and every slot whose buffers are all ready is
    stamped on the spot, so a fast shard is never billed a straggler's
    await (the ISSUE 5 serial-await bug). Buffers that cannot report
    readiness fall back to the serial slot-order await (upper-bound
    attribution); arrays without addressable shards are awaited
    collectively with the same time charged to every slot.
    """
    ready = np.zeros(num_slots)
    per_slot, fallback = _slot_buffers(outputs, num_slots)
    pollable = all(
        hasattr(buf, "is_ready") for bufs in per_slot for buf in bufs
    )
    if pollable:
        pending = set(range(num_slots))
        sleep_s = _POLL_SECONDS
        while pending:
            done = [s for s in pending
                    if all(buf.is_ready() for buf in per_slot[s])]
            if done:
                now = time.perf_counter() - t0
                for s in done:
                    ready[s] = now
                pending.difference_update(done)
                sleep_s = _POLL_SECONDS
                continue
            time.sleep(sleep_s)
            sleep_s = min(sleep_s * 2.0, _POLL_CAP_SECONDS)
    else:
        for slot in range(num_slots):
            for buf in per_slot[slot]:
                buf.block_until_ready()
            ready[slot] = time.perf_counter() - t0
    if fallback:
        for arr in fallback:
            arr.block_until_ready()
        ready = np.maximum(ready, time.perf_counter() - t0)
    return ready


@dataclasses.dataclass
class WaveTimings:
    """Accumulated measured phase-B timings of one executed batch.

    ``seconds[j, c]`` — wall seconds slot ``j``'s wave-``c`` reduce took
    (tick-stamped on device, or per-device ready time on the fenced
    fallback). ``slot_work[j]`` — the work unit per slot fed to the
    estimator. Phase-B wave programs are **capacity-shaped** (every device
    reduces the same statically padded buffer), so the honest work measure
    is the shape work — identical across slots — and the implied rate
    ``work/seconds`` isolates pure per-device speed instead of confusing
    an unevenly *loaded* slot with a slow one. An idle slot (no clusters
    assigned) still executes its padded wave, so its measurement remains a
    valid device-speed sample.

    ``valid`` — False when the measurement is untrustworthy: a fenced-
    fallback batch whose timed waves also traced/compiled (the clock
    would bill XLA compilation to whichever device compiled first), or a
    ticks batch with wrapped/non-finite stamps. Invalid batches are
    recorded but not fed to the estimator. On-device tick batches are
    compile-clean by construction — stamps execute with the program, after
    compilation — so even a job's first batch is a valid sample.
    """

    seconds: np.ndarray                    # (slots, waves)
    slot_work: Optional[np.ndarray] = None  # (slots,)
    valid: bool = True

    @staticmethod
    def empty(num_slots: int, num_waves: int) -> "WaveTimings":
        """A zeroed buffer to accumulate one batch's waves into."""
        return WaveTimings(np.zeros((num_slots, max(num_waves, 1))))

    @staticmethod
    def from_ticks(ticks, seconds_per_tick: float) -> "WaveTimings":
        """Build timings from an on-device ``(slots, waves, 2)`` ticks buffer.

        ``ticks[j, c] = (start, end)`` are combined int64 counter stamps
        (see :func:`repro.kernels.wave_timer.ref.combine_ticks`) bracketing
        slot ``j``'s wave-``c`` reduce; ``seconds_per_tick`` comes from the
        tick source's calibration. A stamp pair that wrapped or failed
        (``end < start``, non-finite) floors to zero and marks the batch
        invalid rather than feeding a negative duration downstream.
        """
        t = np.asarray(ticks, np.int64)
        if t.ndim != 3 or t.shape[-1] != 2:
            raise ValueError(f"expected (slots, waves, 2) ticks, got {t.shape}")
        dur = (t[..., 1] - t[..., 0]).astype(np.float64) * float(seconds_per_tick)
        ok = bool(np.isfinite(dur).all() and (dur >= 0).all())
        return WaveTimings(np.maximum(np.nan_to_num(dur, nan=0.0), 0.0),
                           valid=ok)

    def record(self, wave: int, wave_seconds: np.ndarray) -> None:
        """Store one wave's per-slot seconds."""
        self.seconds[:, wave] = np.asarray(wave_seconds)

    def slot_seconds(self) -> np.ndarray:
        """Total measured seconds per slot (sum over waves)."""
        return self.seconds.sum(axis=1)

    def observation(self, slot_slowdown: Optional[np.ndarray] = None):
        """The ``(work, seconds)`` pair for the speed estimator.

        ``slot_slowdown`` injects a fault into the *measurement*: slot
        ``j`` at factor ``f`` reports ``seconds * f`` — a slowdown factor
        is a **wall-clock multiplier** (2.0 ⇒ the slot reads twice as
        slow), matching ``MapReduceJob.set_slot_slowdown`` — which keeps
        fault injection on the measured path instead of reviving the
        synthetic model.
        """
        secs = self.slot_seconds()
        if slot_slowdown is not None:
            secs = secs * np.asarray(slot_slowdown, np.float64)
        work = (self.slot_work if self.slot_work is not None
                else np.ones(self.seconds.shape[0]))
        return np.asarray(work, np.float64), secs
