"""Multi-job coordination: N MapReduce workloads sharing one mesh.

OS4M (§3.2, §4.2) plans one job's Reduce operations globally; production
traffic is *many* concurrent jobs with different key distributions on the
same fleet. Two things change at that scale:

* **The machine model.** Each job observes its own per-slot wave timings
  (one :class:`~repro.core.slot_speeds.SlotSpeedEstimator` per job), and
  different jobs genuinely rank the slots differently — cache residency,
  kernel mix, expert affinity. Stacking the per-job speed rows yields a
  per-(job, slot) processing-time matrix: *unrelated processors*,
  ``R||C_max`` (Fotakis et al., arXiv 1312.4203), which
  :mod:`repro.core.scheduler` now solves via ``proc_times=``.
* **The objective.** A fleet serving N tenants does not minimise one
  job's makespan; it minimises the *weighted completion time*
  ``Σ wᵢ Cᵢ``. With each job internally balanced by its own OS4M
  schedule, the coordinator's lever is admission **order** — Smith's
  rule (WSPT, :func:`repro.core.simulator.wspt_order`) is exactly
  optimal for the sequential case and is what :meth:`plan_admission`
  applies to the live R-matrix estimates.

Execution keeps each job's arrays, jit cache and
:class:`~repro.core.schedule_cache.ScheduleCache` fully isolated (the
cache becomes a keyed multi-tenant resource —
:class:`~repro.core.schedule_cache.MultiTenantScheduleCache`), so
interleaving jobs on one mesh is bit-identical to running each alone:
scheduling only ever moves *where* work runs, never what it computes.
Cross-job pipelining reuses the §4.4 double-buffer hooks
(:func:`repro.core.pipeline.coschedule_waves`): one job's all-to-all
copy wave issues while another job's reduce wave computes, so the
overlap that already hides a single job's shuffle keeps working across
job boundaries.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import pipeline as pipe
from repro.core import schedule_cache as sc
from repro.core import simulator as sim

__all__ = ["ManagedJob", "MultiJobCoordinator"]


@dataclasses.dataclass
class ManagedJob:
    """One live tenant: the job, its priority weight, and its queue state.

    ``weight`` is the ΣwᵢCᵢ priority (bigger = finish sooner);
    ``pending`` holds submitted-but-unexecuted batches in arrival order;
    ``batch_seconds`` is an EWMA of the measured wall time per batch —
    the ``t_j`` that WSPT admission divides the weight by.
    """

    name: str
    job: Any                      # repro.core.mapreduce.MapReduceJob
    weight: float = 1.0
    index: int = 0                # submission order (FIFO tie-break)
    pending: List[Any] = dataclasses.field(default_factory=list)
    results: List[Any] = dataclasses.field(default_factory=list)
    batch_seconds: Optional[float] = None
    completed_at: Optional[float] = None

    def observe_batch_seconds(self, seconds: float, ewma: float = 0.5) -> None:
        """Fold one measured batch wall time into the EWMA estimate."""
        if self.batch_seconds is None:
            self.batch_seconds = float(seconds)
        else:
            self.batch_seconds += ewma * (float(seconds) - self.batch_seconds)

    @property
    def estimated_seconds(self) -> float:
        """Estimated time to drain this job's queue (1.0/batch when cold)."""
        per_batch = 1.0 if self.batch_seconds is None else self.batch_seconds
        return per_batch * max(len(self.pending), 1)


class MultiJobCoordinator:
    """Holds N live MapReduce jobs and plans their shared-mesh execution.

    The coordinator is deliberately thin: each
    :class:`~repro.core.mapreduce.MapReduceJob` keeps its own schedule,
    estimator, jit cache and (tenant-keyed) schedule cache; the
    coordinator owns only the cross-job facts — the R-matrix view of
    everyone's measured slot speeds, the ΣwᵢCᵢ admission order, and the
    co-scheduled wave interleave.
    """

    def __init__(
        self,
        num_slots: int,
        policy: Optional[sc.ReusePolicy] = None,
    ):
        self.num_slots = int(num_slots)
        self.tenants = sc.MultiTenantScheduleCache(policy)
        self._jobs: Dict[str, ManagedJob] = {}

    # -- tenancy ------------------------------------------------------------

    def add_job(self, name: str, job, weight: float = 1.0) -> ManagedJob:
        """Admit a job under a unique tenant key.

        The job's slot count must match the coordinator's mesh. Its
        ScheduleCache (if any) is adopted into the multi-tenant cache
        under ``name``; a job arriving without one but with a
        coordinator-level default policy gets a fresh tenant cache
        attached. Either way, after admission the job's snapshots live
        under its own key — never another tenant's.
        """
        if name in self._jobs:
            raise ValueError(f"job {name!r} already admitted")
        if weight <= 0:
            raise ValueError(f"weight must be > 0, got {weight}")
        if job.cfg.num_slots != self.num_slots:
            raise ValueError(
                f"job {name!r} wants {job.cfg.num_slots} slots, "
                f"coordinator mesh has {self.num_slots}")
        if job.schedule_cache is not None:
            self.tenants.adopt(name, job.schedule_cache)
        elif self.tenants.default_policy is not None:
            job.attach_schedule_cache(self.tenants.tenant(name))
        handle = ManagedJob(
            name=name, job=job, weight=float(weight), index=len(self._jobs))
        self._jobs[name] = handle
        return handle

    def __getitem__(self, name: str) -> ManagedJob:
        return self._jobs[name]

    def __len__(self) -> int:
        return len(self._jobs)

    def jobs(self) -> List[ManagedJob]:
        """Managed jobs in admission order."""
        return list(self._jobs.values())

    def submit(self, name: str, batch) -> None:
        """Queue one batch of inputs for the named job."""
        self._jobs[name].pending.append(batch)

    # -- the R-matrix view --------------------------------------------------

    def r_matrix(
        self, loads: Optional[Sequence[float]] = None
    ) -> np.ndarray:
        """Per-(job, slot) processing times: stack each job's speed row.

        Row ``j`` is ``load_j / speeds[j, slot]`` from that job's *own*
        estimator (``MapReduceJob.proc_times_row``); ``+inf`` marks the
        slots the job cannot use (dead in its view of the mesh). This is
        the matrix the ``proc_times=`` schedulers and the admission
        planner consume. ``loads`` defaults to each job's estimated
        queue-drain seconds, so rows are commensurable.
        """
        handles = self.jobs()
        if loads is None:
            loads = [h.estimated_seconds for h in handles]
        loads = np.asarray(loads, dtype=np.float64)
        if loads.shape != (len(handles),):
            raise ValueError(
                f"loads must have shape ({len(handles)},), got {loads.shape}")
        rows = [h.job.proc_times_row(total_load=loads[j])
                for j, h in enumerate(handles)]
        return np.stack(rows) if rows else np.zeros((0, self.num_slots))

    def estimated_times(self) -> np.ndarray:
        """Estimated queue-drain seconds per job, via its R-matrix row.

        A job's whole queue runs on the mesh slice alive *in its own
        view*: the estimate spreads its measured per-batch seconds over
        the aggregate relative speed of the slots its row marks usable.
        """
        handles = self.jobs()
        times = np.zeros(len(handles))
        for j, h in enumerate(handles):
            load = h.estimated_seconds
            if load <= 0:
                continue
            row = h.job.proc_times_row(total_load=load)
            finite = np.isfinite(row)
            # row = load/speed per slot; aggregate speed = Σ (load/row).
            agg_speed = float(np.sum(load / row[finite]))
            alive = int(finite.sum())
            times[j] = (load * alive / agg_speed if agg_speed > 0 else load)
        return times

    # -- admission (Σ wᵢ Cᵢ) -------------------------------------------------

    def plan_admission(self, order: str = "wspt") -> List[str]:
        """Names in execution order: WSPT (Smith's rule) or FIFO baseline."""
        handles = self.jobs()
        if order == "fifo":
            return [h.name for h in handles]
        if order != "wspt":
            raise ValueError(f"unknown admission order {order!r}")
        times = self.estimated_times()
        weights = np.asarray([h.weight for h in handles])
        idx = sim.wspt_order(times, weights)
        return [handles[i].name for i in idx]

    def planned_weighted_completion(self, order: str = "wspt") -> float:
        """Predicted ``Σ wᵢ Cᵢ`` for an admission order (planning units)."""
        handles = self.jobs()
        times = self.estimated_times()
        weights = np.asarray([h.weight for h in handles])
        names = self.plan_admission(order)
        idx = [self._jobs[n].index for n in names]
        return sim.weighted_completion_time(times, weights, order=idx)

    # -- co-scheduled execution ----------------------------------------------

    def coschedule_plan(self) -> List[Tuple[int, int]]:
        """Cross-job wave interleave from the live snapshots' wave plans.

        Jobs whose tenant cache holds a planned snapshot contribute their
        §4.4 wave sequence; :func:`repro.core.pipeline.coschedule_waves`
        merges them round-robin so consecutive waves alternate jobs — the
        issue order under which one job's a2a hides beneath another's
        reduce. Jobs still cold (no snapshot) contribute nothing yet.
        """
        plans = []
        for h in self.jobs():
            cache = h.job.schedule_cache
            snap = cache.snapshot if cache is not None else None
            if snap is not None and snap.waves is not None:
                plans.append(snap.waves)
        return pipe.coschedule_waves(plans)

    def run_queue(self, order: str = "wspt") -> Dict[str, Any]:
        """Drain every job's pending batches in the planned admission order.

        Jobs run back-to-back (each with its full OS4M-scheduled mesh);
        the *next* job's batches are dispatched before the previous
        job's device values are fetched, so with async dispatch the next
        phase A/all-to-all issues under the previous reduce — and a
        job's completion time ``C_j`` is measured at the moment its last
        batch's values are actually on the host. Returns telemetry:
        per-job completion seconds, the measured ``Σ wᵢ Cᵢ``, the
        admission order, and the cross-job overlap fraction of the
        co-scheduled wave plan.
        """
        names = self.plan_admission(order)
        t0 = time.perf_counter()
        in_flight: List[Tuple[ManagedJob, Any, float]] = []

        def drain() -> None:
            """Fetch queued results to the host, stamping completions."""
            for handle, res, t_batch0 in in_flight:
                np.asarray(res.values)  # blocks until the device is done
                handle.results.append(res)
                handle.observe_batch_seconds(
                    time.perf_counter() - t_batch0)
                handle.completed_at = time.perf_counter() - t0
            in_flight.clear()

        for name in names:
            handle = self._jobs[name]
            batches, handle.pending = handle.pending, []
            for batch in batches:
                t_batch0 = time.perf_counter()
                res = handle.job.run(batch)
                in_flight.append((handle, res, t_batch0))
            drain()
        completions = {n: self._jobs[n].completed_at for n in names}
        weighted = sum(
            self._jobs[n].weight * (completions[n] or 0.0) for n in names)
        return {
            "order": names,
            "completions": completions,
            "weighted_completion": float(weighted),
            "coschedule_overlap": pipe.coschedule_overlap(
                self.coschedule_plan()),
            "cache": self.tenants.stats(),
        }

    def run_interleaved(
        self, sequence: Optional[List[str]] = None
    ) -> List[Tuple[str, Any]]:
        """Execute one pending batch at a time, alternating jobs.

        ``sequence`` gives the explicit (name, name, ...) batch order;
        None round-robins over jobs with pending batches. This is the
        finest-grained sharing mode — and the bit-identity property the
        tests pin: because every job's state is isolated (arrays, jit
        cache, tenant schedule cache), the interleaved outputs equal the
        solo outputs bit for bit. Returns ``[(name, JobResult), ...]``.
        """
        if sequence is None:
            counts = {h.name: len(h.pending) for h in self.jobs()}
            sequence = []
            while any(c > 0 for c in counts.values()):
                for h in self.jobs():
                    if counts[h.name] > 0:
                        sequence.append(h.name)
                        counts[h.name] -= 1
        out: List[Tuple[str, Any]] = []
        for name in sequence:
            handle = self._jobs[name]
            if not handle.pending:
                raise ValueError(f"job {name!r} has no pending batch")
            batch = handle.pending.pop(0)
            res = handle.job.run(batch)
            handle.results.append(res)
            out.append((name, res))
        return out
