"""Operation clustering (paper §4.3).

When the number of distinct Reduce keys ``n`` is large, OS4M groups keys into
*operation clusters* — the schedulable unit — to bound the network/compute
cost of the communication mechanism. The default algorithm puts keys ``a``
and ``b`` in the same cluster iff ``Hash(a) ≡ Hash(b) (mod n_target)``.

The paper's cost model (§4.3) is implemented verbatim in
:func:`network_cost_bytes` and validated by ``benchmarks/fig11_network.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

__all__ = [
    "cluster_ids_for_keys",
    "cluster_loads",
    "NetworkCost",
    "network_cost_bytes",
    "recommended_num_clusters",
]


def cluster_ids_for_keys(
    key_hashes: np.ndarray,
    n_target: int,
    custom: Optional[Callable[[np.ndarray, int], np.ndarray]] = None,
) -> np.ndarray:
    """Map (hashed) keys to cluster ids in ``[0, n_target)``.

    ``custom`` is the user-clustering hook the paper leaves as API; it must
    be a pure function ``(key_hashes, n_target) -> cluster_ids``.
    """
    if custom is not None:
        out = np.asarray(custom(key_hashes, n_target))
        if out.min(initial=0) < 0 or (out.size and out.max() >= n_target):
            raise ValueError("custom clustering produced ids outside [0, n_target)")
        return out.astype(np.int64)
    kh = np.abs(np.asarray(key_hashes, dtype=np.int64))
    return kh % np.int64(n_target)


def cluster_loads(
    key_loads: np.ndarray, cluster_ids: np.ndarray, n_clusters: int
) -> np.ndarray:
    """Aggregate per-key loads into per-cluster loads (exact, not sampled).

    The paper stresses (vs. Gufler et al. [G+12]) that cluster loads are
    *exact* sums of their member keys, which is what lets the scheduler be
    near-optimal.
    """
    return np.bincount(
        np.asarray(cluster_ids), weights=np.asarray(key_loads, dtype=np.float64),
        minlength=n_clusters,
    )


def recommended_num_clusters(num_reduce_slots: int, factor_lo: int = 6, factor_hi: int = 16) -> int:
    """Paper §5.4: best range is 6–16 clusters per Reduce slot; pick midpoint."""
    return num_reduce_slots * (factor_lo + factor_hi) // 2


@dataclasses.dataclass(frozen=True)
class NetworkCost:
    """Bytes moved by the §4.3 statistics collect + schedule broadcast."""

    collect_map_to_tt: int     # 8·M·n  — map ops -> TaskTrackers
    collect_tt_to_jt: int      # ≤ 8·M·n — TaskTrackers -> JobTracker
    broadcast_jt_to_tt: int    # 4·t·n
    broadcast_tt_to_task: int  # 4·r·n

    @property
    def collect_total(self) -> int:
        """Statistics-collection bytes (Map side up to the JobTracker)."""
        return self.collect_map_to_tt + self.collect_tt_to_jt

    @property
    def broadcast_total(self) -> int:
        """Schedule-broadcast bytes (JobTracker down to Reduce tasks)."""
        return self.broadcast_jt_to_tt + self.broadcast_tt_to_task

    @property
    def total(self) -> int:
        """Total mechanism overhead in bytes (paper bound: 4n(4M+t+r))."""
        return self.collect_total + self.broadcast_total


def network_cost_bytes(
    num_map_ops: int, num_clusters: int, num_tasktrackers: int, num_reduce_tasks: int
) -> NetworkCost:
    """Exact §4.3 cost model: total ≤ 4n(4M + t + r) bytes.

    ``long`` (8-byte) per-cluster counters in the collecting step, ``int``
    (4-byte) schedule entries in the broadcasting step.
    """
    M, n, t, r = num_map_ops, num_clusters, num_tasktrackers, num_reduce_tasks
    return NetworkCost(
        collect_map_to_tt=8 * M * n,
        collect_tt_to_jt=8 * M * n,
        broadcast_jt_to_tt=4 * t * n,
        broadcast_tt_to_task=4 * r * n,
    )
