"""The communication mechanism (paper §4.1): collect operation statistics.

Two realisations:

1. **Host-side** :class:`StatsCollector` — the JobTracker's hash map of
   per-Map-task statistics vectors, including §6's fault-tolerance
   semantics: statistics are keyed by *task id*, so re-executed or
   speculative attempts overwrite idempotently and exactly one entry per
   task survives.

2. **On-device** :func:`local_key_histogram` / :func:`global_key_distribution`
   — the TPU-native form: a per-shard histogram of cluster ids (the
   ``K^(i)`` vector of eq. 4-1) followed by ``lax.psum`` over the mesh axis,
   whose reduction tree *is* the TaskTracker→JobTracker aggregation tree.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "StatsCollector",
    "local_key_histogram",
    "global_key_distribution",
]


class StatsCollector:
    """JobTracker-side aggregation with task-id idempotency (paper §6).

    >>> c = StatsCollector(num_clusters=4, num_map_tasks=2)
    >>> c.report(task_id=0, counts=[1, 0, 2, 0], attempt_id=0)
    >>> c.report(task_id=0, counts=[1, 0, 2, 0], attempt_id=1)  # speculative retry
    >>> c.report(task_id=1, counts=[0, 3, 0, 1])
    >>> c.complete
    True
    >>> c.aggregate().tolist()
    [1.0, 3.0, 2.0, 1.0]
    """

    def __init__(self, num_clusters: int, num_map_tasks: int):
        self.num_clusters = int(num_clusters)
        self.num_map_tasks = int(num_map_tasks)
        self._by_task: Dict[int, np.ndarray] = {}
        self.duplicate_reports = 0

    def report(
        self,
        task_id: int,
        counts,
        attempt_id: int = 0,
        success: bool = True,
    ) -> None:
        """Record one Map task attempt's statistics vector.

        Failed attempts are discarded by the TaskTracker (paper §6); multiple
        successful attempts of the same task keep exactly one entry.
        """
        if not success:
            return
        counts = np.asarray(counts, dtype=np.float64)
        if counts.shape != (self.num_clusters,):
            raise ValueError(
                f"stats vector must have shape ({self.num_clusters},), got {counts.shape}"
            )
        if task_id in self._by_task:
            self.duplicate_reports += 1
        self._by_task[task_id] = counts

    @property
    def complete(self) -> bool:
        """True once every Map task has reported (schedule may be computed)."""
        return len(self._by_task) >= self.num_map_tasks

    def aggregate(self) -> np.ndarray:
        """K = sum_i K^(i): the key (cluster) distribution of intermediate pairs."""
        if not self._by_task:
            return np.zeros(self.num_clusters)
        return np.sum(list(self._by_task.values()), axis=0)

    def reset(self) -> None:
        """Drop all collected statistics (new job on the same collector)."""
        self._by_task.clear()
        self.duplicate_reports = 0


# ---------------------------------------------------------------------------
# On-device statistics (TPU-native communication mechanism).
# ---------------------------------------------------------------------------


def local_key_histogram(
    cluster_ids: jnp.ndarray,
    num_clusters: int,
    weights: Optional[jnp.ndarray] = None,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Per-shard ``K^(i)`` (eq. 4-1): counts of pairs per cluster id.

    ``cluster_ids``: int array of any shape; invalid entries may be marked by
    ``weights == 0``. Returns float32 ``(num_clusters,)``.

    ``use_kernel=True`` routes through the Pallas histogram kernel (TPU
    target; interpret-mode on CPU) — the default is a ``segment_sum`` which
    XLA lowers to an efficient one-pass scatter-add.
    """
    flat = cluster_ids.reshape(-1)
    if weights is None:
        w = jnp.ones(flat.shape, jnp.float32)
    else:
        w = weights.reshape(-1).astype(jnp.float32)
    if use_kernel:
        from repro.kernels.histogram import ops as hist_ops

        return hist_ops.histogram(flat, w, num_clusters)
    return jax.ops.segment_sum(w, flat, num_segments=num_clusters)


def global_key_distribution(
    local_hist: jnp.ndarray, axis_name: str | tuple
) -> jnp.ndarray:
    """All-reduce the local histograms over the mesh: the JobTracker sum.

    Must be called inside ``shard_map`` (or any context where ``axis_name``
    is bound).
    """
    return jax.lax.psum(local_hist, axis_name)
