"""OS4M expert-placement balancer — the paper's scheduler driving MoE EP.

Mapping (DESIGN.md §2.1): routed experts are Reduce *operation clusters*
(all tokens of one expert ↔ all pairs of one key), EP shards are Reduce
*slots*, and the per-expert token histogram psum'd over the mesh is the
§4.1 communication mechanism. The JobTracker step is here: given the
collected key distribution, solve the placement and broadcast it.

TPU static shapes add one constraint the paper didn't have: every shard
must own exactly ``experts_per_shard`` experts (the expert-weight array is
sharded in equal blocks), so the problem is Q||C_max with a cardinality
constraint: EP shard ``j`` has a relative speed ``s_j`` (mixed device
generations, a throttling host) and the makespan is measured in *finish
time* ``load_j / s_j``. :func:`schedule_balanced_cardinality` solves it
with capacity-constrained earliest-finish-time LPT + pairwise-swap
refinement in finish space; ``speeds=None`` reproduces the P||C_max
placements bit-for-bit. Speeds come from the same measured
:mod:`repro.core.slot_speeds` vector the MapReduce engine estimates
(``TrainerConfig.expert_slot_speeds`` pins a known one).

``ExpertBalancer`` is the stateful driver used by the training loop:
accumulate counts (EMA), replan every ``interval`` steps, emit both the
placement table and the weight-row permutation (moving an operation to
another slot physically moves its weights — the TPU analogue of the
paper's schedule broadcast; placement changes never change compiled
shapes, so no recompilation).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "schedule_balanced_cardinality", "placement_from_assignment",
    "ExpertBalancer", "BalanceReport",
]


def schedule_balanced_cardinality(
    loads: np.ndarray, num_slots: int, per_slot: int,
    refine_iters: int = 512,
    speeds: Optional[Sequence[float]] = None,
) -> np.ndarray:
    """Assign n = num_slots*per_slot operations, exactly per_slot each.

    Greedy earliest-finish-time LPT respecting slot capacity, then
    best-swap refinement in *finish space* (swapping two operations
    between the latest-finishing slot and any other preserves cardinality
    while reducing the makespan ``max_j load_j / s_j``).

    ``speeds`` (Q||C_max): per-slot relative speeds, 1.0 = nominal.
    ``None`` keeps the speed-oblivious greedy key (``argmin`` of held
    load) so existing P||C_max placements are reproduced **bit-for-bit**;
    the finish-space refinement with nominal speeds divides by exactly
    1.0, which is the identity in IEEE arithmetic.

    **Dead slots** (speed exactly 0.0, elastic mesh): the cardinality
    constraint is physical — the expert-weight array is sharded in equal
    blocks, so even a dead shard must *hold* ``per_slot`` expert rows —
    but its experts should carry as little routed load as possible. A
    dead slot therefore participates with an effectively-infinitesimal
    speed: EFT defers it until capacity forces placements there, and the
    swap refinement then drains the heaviest loads off it, so it ends up
    holding the ``per_slot`` lightest experts.
    """
    loads = np.asarray(loads, dtype=np.float64)
    n = loads.shape[0]
    assert n == num_slots * per_slot, (n, num_slots, per_slot)
    sp = np.ones(num_slots) if speeds is None else np.asarray(speeds, np.float64)
    if sp.shape != (num_slots,) or np.any(~np.isfinite(sp)) or np.any(sp < 0):
        raise ValueError(
            f"speeds must be ({num_slots},) finite >= 0 (0 = dead), got {sp}")
    if np.any(sp == 0.0):
        if not np.any(sp > 0):
            raise ValueError("all slots dead: at least one speed must be > 0")
        # Tiny-but-positive effective speed keeps the finish-space math
        # finite while making dead slots maximally unattractive.
        sp = np.where(sp > 0, sp, sp[sp > 0].min() * 1e-9)
    order = np.argsort(-loads, kind="stable")
    assignment = np.empty(n, dtype=np.int32)
    slot_loads = np.zeros(num_slots)
    slot_counts = np.zeros(num_slots, dtype=np.int64)
    for j in order:
        open_slots = np.nonzero(slot_counts < per_slot)[0]
        if speeds is None:
            # P||C_max key, kept verbatim: argmin over held load (ties and
            # rounding identical to the pre-Q code, golden-pinned).
            s = open_slots[np.argmin(slot_loads[open_slots])]
        else:
            # Earliest finish time: where would this operation complete
            # soonest at the slots' relative speeds?
            s = open_slots[np.argmin(
                (slot_loads[open_slots] + loads[j]) / sp[open_slots])]
        assignment[j] = s
        slot_loads[s] += loads[j]
        slot_counts[s] += 1

    # Pairwise swap refinement in finish space: swap one operation of the
    # latest-finishing slot with one of another slot (cardinality
    # preserved); pick the swap that minimises the new pairwise max finish.
    # Repeat until no improving swap. With nominal speeds every division
    # is by 1.0, so this is exactly the load-space pass.
    for _ in range(refine_iters):
        finish = slot_loads / sp
        src = int(finish.argmax())
        cur_max = finish[src]
        src_ops = np.nonzero(assignment == src)[0]
        best = None  # (new_pair_max, a, b, dst)
        for dst in range(num_slots):
            if dst == src:
                continue
            dst_ops = np.nonzero(assignment == dst)[0]
            # delta[a, b] = loads[a] - loads[b]
            delta = loads[src_ops][:, None] - loads[dst_ops][None, :]
            new_src = (slot_loads[src] - delta) / sp[src]
            new_dst = (slot_loads[dst] + delta) / sp[dst]
            pair_max = np.maximum(new_src, new_dst)
            i, jx = np.unravel_index(np.argmin(pair_max), pair_max.shape)
            if pair_max[i, jx] < cur_max - 1e-12:
                if best is None or pair_max[i, jx] < best[0]:
                    best = (pair_max[i, jx], src_ops[i], dst_ops[jx], dst)
        if best is None:
            break
        _, a, b, dst = best
        assignment[a], assignment[b] = dst, src
        slot_loads[src] += loads[b] - loads[a]
        slot_loads[dst] += loads[a] - loads[b]
    return assignment


def placement_from_assignment(assignment: np.ndarray, num_slots: int):
    """assignment (E,) shard-per-expert -> (placement (2, E), perm (E,)).

    ``perm`` lists experts in physical weight order (shard-major, slot
    order within shard): new weight row g holds expert ``perm[g]``.
    """
    e = np.asarray(assignment)
    n = e.shape[0]
    placement = np.zeros((2, n), dtype=np.int32)
    perm = np.zeros(n, dtype=np.int64)
    g = 0
    for s in range(num_slots):
        members = np.nonzero(e == s)[0]
        for slot, ex in enumerate(members):
            placement[0, ex] = s
            placement[1, ex] = slot
            perm[g] = ex
            g += 1
    return placement, perm


@dataclasses.dataclass
class BalanceReport:
    """Per-layer outcome of one replan (loads vs the contiguous baseline).

    Load-space fields are the paper's P||C_max view; ``makespan`` /
    ``finish_ratio`` are the Q||C_max view under the balancer's speed
    vector (``max_j load_j / s_j``; with nominal speeds they equal
    ``max_load`` / ``balance_ratio`` exactly).
    """

    max_load: float
    ideal_load: float
    balance_ratio: float
    baseline_ratio: float           # contiguous/hash-class placement
    moved_experts: int
    makespan: float = 0.0           # finish time of the slowest shard
    finish_ratio: float = 1.0       # makespan / ideal finish (Σload / Σspeed)


class ExpertBalancer:
    """Stateful OS4M replanner for one MoE model (per-layer placements).

    ``max_drift`` (optional) drift-gates the replan the same way
    :class:`repro.core.schedule_cache.ReusePolicy` gates the MapReduce
    engine: at each interval, a layer whose expert-count distribution
    moved less than ``max_drift`` (L1/total-variation,
    :func:`repro.core.schedule_cache.drift_metric`) keeps its current
    placement — no Q||C_max solve, no weight permutation. Steady routing
    then amortizes one placement over many intervals; ``layers_reused``
    counts the skips.

    ``speeds`` (optional) is the per-EP-shard relative speed vector the
    placements are solved under — the same measured ``slot_speeds``
    vector the MapReduce engine estimates. ``None`` ≡ identical shards
    (P||C_max, bit-for-bit the pre-Q placements). Update it mid-training
    with :meth:`set_speeds`; changed speeds count as drift, so the next
    interval re-solves every layer instead of reusing stale placements.
    """

    def __init__(self, num_experts: int, num_slots: int, n_layers: int,
                 interval: int = 100, ema: float = 0.8,
                 max_drift: float | None = None,
                 speeds: Optional[Sequence[float]] = None):
        self.num_experts = num_experts
        self.num_slots = num_slots
        self.per_slot = num_experts // num_slots
        self.n_layers = n_layers
        self.interval = interval
        self.ema = ema
        self.max_drift = max_drift
        self.speeds: Optional[np.ndarray] = None
        self.set_speeds(speeds)
        self.counts = np.zeros((n_layers, num_experts))
        self.step = 0
        # physical order: perm[layer, g] = expert id stored at weight row g
        self.perms = np.tile(np.arange(num_experts), (n_layers, 1))
        self.placements = np.stack(
            [placement_from_assignment(
                np.arange(num_experts) // self.per_slot, num_slots)[0]
             for _ in range(n_layers)])
        # drift baseline: counts each layer's live placement was solved from
        self._planned_counts = np.zeros((n_layers, num_experts))
        self._assignments = np.tile(
            np.arange(num_experts) // self.per_slot, (n_layers, 1))
        self.layers_reused = 0
        self.layers_replanned = 0

    def set_speeds(self, speeds: Optional[Sequence[float]]) -> None:
        """Install a new per-shard speed vector (None ≡ all nominal).

        A *changed* vector invalidates the drift baselines, so the next
        :meth:`replan` re-solves every layer under the new speeds instead
        of drift-gating against placements built for the old ones.
        """
        new = None
        if speeds is not None:
            new = np.asarray(speeds, np.float64)
            if new.shape != (self.num_slots,) or np.any(~np.isfinite(new)) \
                    or np.any(new < 0):
                raise ValueError(
                    f"speeds must be ({self.num_slots},) finite >= 0 "
                    "(0 = dead shard)")
            if not np.any(new > 0):
                raise ValueError(
                    "all shards dead: at least one speed must be > 0")
        old = self.speeds
        changed = ((old is None) != (new is None)
                   or (old is not None and not np.array_equal(old, new)))
        self.speeds = new
        if changed and hasattr(self, "_planned_counts"):
            self._planned_counts[:] = 0.0   # force re-solve at next interval

    def observe(self, counts) -> None:
        """counts (L, E) from the step metrics (the §4.1 statistics)."""
        c = np.asarray(counts, dtype=np.float64)
        self.counts = self.ema * self.counts + (1 - self.ema) * c
        self.step += 1

    def should_replan(self) -> bool:
        """True on interval boundaries (drift gating happens per layer in replan)."""
        return self.step > 0 and self.step % self.interval == 0

    def replan(self) -> Tuple[np.ndarray, List[np.ndarray], List[BalanceReport]]:
        """Returns (placements (L, 2, E), per-layer weight perms, reports).

        With ``max_drift`` set, a layer whose routing distribution stayed
        within the threshold of its plan-time baseline reuses its current
        assignment (the report row is computed against fresh loads, so
        imbalance is still observable); only drifted layers re-solve.
        """
        placements = []
        perms = []
        reports = []
        for layer in range(self.n_layers):
            loads = self.counts[layer]
            reuse = False
            if self.max_drift is not None and self._planned_counts[layer].sum() > 0:
                from repro.core.schedule_cache import drift_metric

                drift = float(drift_metric(
                    self._planned_counts[layer], loads, "l1"))
                reuse = drift <= self.max_drift
            if reuse:
                self.layers_reused += 1
                assignment = self._assignments[layer]
                # Copies, not views: callers hold the returned perm as the
                # "previous physical order" across intervals, and a later
                # replan writes self.perms[layer] in place.
                placement = self.placements[layer].copy()
                perm = self.perms[layer].copy()
            else:
                self.layers_replanned += 1
                assignment = schedule_balanced_cardinality(
                    loads, self.num_slots, self.per_slot, speeds=self.speeds)
                placement, perm = placement_from_assignment(
                    assignment, self.num_slots)
                self._assignments[layer] = assignment
                self._planned_counts[layer] = loads
                self.placements[layer] = placement
            base = np.arange(self.num_experts) // self.per_slot
            base_loads = np.bincount(base, weights=loads,
                                     minlength=self.num_slots)
            new_loads = np.bincount(assignment, weights=loads,
                                    minlength=self.num_slots)
            ideal = loads.sum() / self.num_slots
            sp = np.ones(self.num_slots) if self.speeds is None else self.speeds
            # Dead shards (speed 0): report finish over surviving shards
            # only — a dead shard's held experts receive ~no routed load
            # by construction, and 0/0 would only produce warning noise.
            with np.errstate(divide="ignore", invalid="ignore"):
                finish = np.where(sp > 0, new_loads / np.where(sp > 0, sp, 1.0),
                                  0.0)
            makespan = float(finish.max())
            ideal_finish = float(loads.sum() / sp.sum())
            reports.append(BalanceReport(
                max_load=float(new_loads.max()),
                ideal_load=float(ideal),
                balance_ratio=float(new_loads.max() / max(ideal, 1e-9)),
                baseline_ratio=float(base_loads.max() / max(ideal, 1e-9)),
                moved_experts=int((perm != self.perms[layer]).sum()),
                makespan=makespan,
                finish_ratio=float(makespan / max(ideal_finish, 1e-9)),
            ))
            placements.append(placement)
            perms.append(perm)
            self.perms[layer] = perm
        return np.stack(placements), perms, reports


def permute_expert_weights(moe_params, perm, prev_perm=None):
    """Reorder stacked expert-weight rows to a new physical order.

    ``moe_params``: the per-layer MoE param dict with leaves shaped
    (E, ...) on up/gate/down. ``perm[g]`` = expert id that must live at
    physical row g. ``prev_perm`` is the current physical order (defaults
    to identity).
    """
    import jax.numpy as jnp

    perm = np.asarray(perm)
    if prev_perm is not None:
        # rows currently hold prev_perm[g]; build index mapping new->current
        cur_pos = np.argsort(prev_perm)      # expert -> current row
        take = cur_pos[perm]
    else:
        take = perm
    out = dict(moe_params)
    for k in ("up", "gate", "down"):
        if k in out:
            out[k] = {"w": jnp.take(out[k]["w"], jnp.asarray(take), axis=0)}
    return out
