"""Pluggable statistics layer: exact histograms or count-min sketches.

OS4M plans its global Reduce schedule from the per-shard key statistics
``K^(i)`` (paper §4.1). This module decouples *what those statistics
are* from the planner that consumes them: a **stats provider** owns

* the traced phase-A collection step (``collect`` — runs inside the
  per-shard program, returns one flat ``(state_size,)`` float32 vector
  per shard),
* the host-side estimators that turn pulled provider state back into
  the dense quantities the planner needs (``to_dense`` → per-shard
  ``(m, n)`` estimates for capacity sizing, ``key_dist`` → the global
  ``(n,)`` cluster loads the scheduler balances), and
* the linear re-encoder ``from_dense`` (tests / analyzer targets /
  synthetic statistics).

Two implementations:

:class:`ExactStats` — today's ``local_key_histogram`` path. State IS the
``(m, n)`` histogram; estimates are exact and plans are bit-identical to
the pre-refactor engine (golden-pinned by the repro tests).

:class:`SketchStats` — a count-min sketch (Cormode & Muthukrishnan;
the "estimated key distribution" planning of Fan et al., arXiv
1401.0355). State is a ``(depth * width,)`` counter grid per shard;
``width`` is a power of two, each row hashes cluster ids through an
independent multiply-shift hash ``h_r(x) = (a_r * x mod 2^32) >> (32 -
log2 width)`` with a fixed odd multiplier ``a_r`` (drawn host-side at
construction from a seeded RNG — nothing nondeterministic enters the
traced program). Reading back takes the **min over rows**, so every
estimate is ``true + (non-negative collision mass)``:

    overestimate-only:  est[j] >= true[j]          (always)
    error bound:        est[j] <= true[j] + e/width * N
                        with prob >= 1 - exp(-depth)   (N = total pairs)

The planner's send capacities are sized from these estimates, so
*overestimate-only* is the load-bearing property: a pure-sketch plan can
over-provision a buffer but never silently under-provision one. The one
caveat is float32 saturation — a counter cell at or beyond 2^24 may have
lost integer exactness on device, voiding the guarantee, which is why
the planner checks the RAW cell maximum (not the estimates) before
trusting any sketch-derived bound (``MapReduceJob._plan``).

See docs/STATISTICS.md for the provider contract and the error-vs-memory
table.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

import jax.numpy as jnp

from repro.core.stats import local_key_histogram

__all__ = [
    "CountMinParams",
    "ExactStats",
    "SketchStats",
    "make_provider",
    "F32_EXACT_MAX",
]

# Largest f32-representable integer count that is still exact (2^24 - 1);
# an on-device counter at/above this may have absorbed rounding error,
# so no overestimate guarantee survives past it.
F32_EXACT_MAX = float(2 ** 24) - 1.0


def _check_width(width: int) -> int:
    width = int(width)
    if width < 8 or width & (width - 1):
        raise ValueError(
            f"sketch width must be a power of two >= 8, got {width}")
    return width


class CountMinParams:
    """The host-side count-min hash family (multipliers + binning).

    Deterministic given ``(width, depth, seed)`` — two processes with the
    same parameters hash identically, which is what lets a persisted
    sketch snapshot (``CachedSchedule.to_json``) be re-estimated and
    re-validated anywhere (``analysis/plan_checks``). Also used directly
    by the serving engine's sketch-planned admission
    (:meth:`repro.serve.engine.Engine.plan`).
    """

    def __init__(self, width: int = 1024, depth: int = 4, seed: int = 0):
        self.width = _check_width(width)
        self.depth = int(depth)
        if self.depth < 1:
            raise ValueError(f"sketch depth must be >= 1, got {depth}")
        self.seed = int(seed)
        self.shift = 32 - (self.width.bit_length() - 1)
        rng = np.random.default_rng(self.seed)
        # Odd multipliers: multiply-shift needs a unit in Z/2^32.
        self.multipliers = (
            rng.integers(0, 2 ** 32, size=self.depth, dtype=np.uint64)
            .astype(np.uint32) | np.uint32(1)
        )

    def bin_ids(self, ids) -> np.ndarray:
        """Per-row bin of each id: ``(depth, len(ids))`` int64 in [0, width)."""
        ids_u = np.asarray(ids, np.int64).astype(np.uint32)
        bins = (self.multipliers[:, None] * ids_u[None, :]) >> np.uint32(
            self.shift)
        return bins.astype(np.int64)

    def add_dense(self, counters: np.ndarray, ids, weights) -> None:
        """Accumulate weighted ids into ``counters`` (depth, width), in place."""
        bins = self.bin_ids(ids)
        w = np.asarray(weights, np.float64)
        for r in range(self.depth):
            counters[r] += np.bincount(
                bins[r], weights=w, minlength=self.width)

    def estimate(self, counters: np.ndarray, ids) -> np.ndarray:
        """Count-min read: min over rows of each id's hashed cell (>= true)."""
        counters = np.asarray(counters, np.float64).reshape(
            self.depth, self.width)
        bins = self.bin_ids(ids)
        est = counters[0, bins[0]]
        for r in range(1, self.depth):
            est = np.minimum(est, counters[r, bins[r]])
        return est

    def to_json(self) -> Dict[str, int]:
        """The three integers that reproduce this hash family anywhere."""
        return {"width": self.width, "depth": self.depth, "seed": self.seed}

    @staticmethod
    def from_json(d: Dict[str, int]) -> "CountMinParams":
        """Rebuild the family from :meth:`to_json` output."""
        return CountMinParams(width=int(d["width"]), depth=int(d["depth"]),
                              seed=int(d.get("seed", 0)))


class ExactStats:
    """The exact ``(m, n)`` histogram provider — today's statistics path.

    ``collect`` is :func:`repro.core.stats.local_key_histogram` verbatim;
    every estimator is the identity, so plans and outputs are
    bit-identical to the pre-provider engine.
    """

    kind = "exact"
    # Exact counts trivially satisfy "estimates never under-provision".
    overestimate_only = True

    def __init__(self, num_clusters: int, use_kernel: bool = False):
        self.num_clusters = int(num_clusters)
        self.use_kernel = bool(use_kernel)

    @property
    def state_size(self) -> int:
        """Per-shard state width: the full cluster histogram."""
        return self.num_clusters

    def collect(self, cluster_ids, weights):
        """Traced phase-A step: the per-shard ``K^(i)`` vector (n,)."""
        return local_key_histogram(
            cluster_ids, self.num_clusters, weights=weights,
            use_kernel=self.use_kernel,
        )

    def to_dense(self, state) -> np.ndarray:
        """Per-shard dense counts: state already IS the histogram.

        No dtype cast — the exact path must feed the planner the same
        float32 values it always did (plans are golden-pinned).
        """
        return np.asarray(state)

    def key_dist(self, state) -> np.ndarray:
        """Global cluster loads ``K``: shard-sum of the histograms."""
        h = np.asarray(state)
        return h.sum(axis=0) if h.ndim == 2 else h

    def from_dense(self, hist) -> np.ndarray:
        """Provider state equivalent to having observed ``hist`` (identity)."""
        return np.asarray(hist)

    def params(self) -> Dict[str, int]:
        """Serializable provider parameters (none for exact)."""
        return {}


class SketchStats:
    """Count-min sketch provider: O(depth * width) state per shard.

    ``collect`` runs on device inside phase A — either the
    ``kernels/sketch_hist`` Pallas kernel (``use_kernel=True``) or the
    jnp segment-sum fallback — and returns the flattened ``(depth *
    width,)`` counter grid. All read-back estimation happens on the
    host from pulled counters (:class:`CountMinParams`).
    """

    kind = "sketch"
    # Count-min reads are min-over-rows of true + collision mass: they
    # can only overestimate (while the raw f32 cells stay exact — see
    # F32_EXACT_MAX and the planner's raw-counter guard).
    overestimate_only = True

    def __init__(self, num_clusters: int, width: int = 1024, depth: int = 4,
                 seed: int = 0, use_kernel: bool = False):
        self.num_clusters = int(num_clusters)
        self.params_ = CountMinParams(width=width, depth=depth, seed=seed)
        self.use_kernel = bool(use_kernel)
        self._bins: Optional[np.ndarray] = None  # cached (depth, n)

    @property
    def width(self) -> int:
        """Counter columns per hash row (power of two)."""
        return self.params_.width

    @property
    def depth(self) -> int:
        """Independent hash rows (estimate = min across them)."""
        return self.params_.depth

    @property
    def state_size(self) -> int:
        """Per-shard state width: the flattened counter grid."""
        return self.depth * self.width

    def bins(self) -> np.ndarray:
        """Cached per-row bin of every cluster id: (depth, n) int64."""
        if self._bins is None:
            self._bins = self.params_.bin_ids(np.arange(self.num_clusters))
        return self._bins

    def collect(self, cluster_ids, weights):
        """Traced phase-A step: flattened (depth * width,) f32 counters."""
        if self.use_kernel:
            from repro.kernels.sketch_hist import ops as sk_ops

            counters = sk_ops.sketch_hist(
                cluster_ids, weights, jnp.asarray(self.params_.multipliers),
                self.width,
            )
        else:
            import jax

            ids_u = cluster_ids.reshape(-1).astype(jnp.uint32)
            w = weights.reshape(-1).astype(jnp.float32)
            mult = jnp.asarray(self.params_.multipliers)  # host constant
            shift = self.params_.shift

            def one_row(a):
                """One hash row's counters via segment-sum."""
                bins = ((ids_u * a) >> shift).astype(jnp.int32)
                return jax.ops.segment_sum(w, bins, num_segments=self.width)

            counters = jax.vmap(one_row)(mult)
        return counters.reshape(-1)

    def to_dense(self, state) -> np.ndarray:
        """Per-shard count-min estimates: (m, state) -> (m, n), each >= true.

        Vectorized min-over-rows gather; accepts a single flat state
        vector too (returns (n,)).
        """
        cells = np.asarray(state, np.float64)
        squeeze = cells.ndim == 1
        cells = cells.reshape(-1, self.depth, self.width)
        bins = self.bins()
        est = cells[:, 0, bins[0]]
        for r in range(1, self.depth):
            est = np.minimum(est, cells[:, r, bins[r]])
        return est[0] if squeeze else est

    def key_dist(self, state) -> np.ndarray:
        """Global cluster-load estimate ``K``: estimate over summed counters.

        Counters are summed over shards *before* the min-over-rows read.
        That matches the steady-state reuse path, which reduces the
        sketch on device and pulls only the ``(depth * width,)`` global
        counters — so the global estimate is identical whether it came
        from full per-shard state or from the reduced pull. (Summing
        per-shard estimates instead would be a little tighter, but
        path-dependent.) Still overestimate-only: summed cells are
        summed ``true + collision`` masses.
        """
        cells = np.asarray(state, np.float64)
        if cells.ndim == 2:
            cells = cells.sum(axis=0)
        return self.to_dense(cells)

    def send_bound(self, state, dests, members, num_slots: int) -> float:
        """Worst per-(shard, dest) send overestimate for one wave.

        For hash row ``r``, the pairs shard ``i`` can send destination
        ``d`` are bounded by the sum of ``cells[i, r, b]`` over the
        *distinct* bins ``b`` that ``d``'s wave members hash into — every
        member's true count is contained in its bin's cell, and a bin
        shared by several members is counted once (its cell already
        holds all of their mass). The bound is ``max over (i, d)`` of
        ``min over rows``.

        This is how the planner sizes sketch-backed capacities without
        ever materializing the ``(m, n)`` estimates: the cost is
        O(depth · (|members| + m · num_slots · width)), independent of
        the cluster count. It is also *tighter* than summing per-member
        estimates once ``n >> width`` (colliding members stop being
        double-counted). ``analysis/plan_checks`` re-derives the exact
        same bound from a persisted snapshot, so committed caps and the
        validator floor can never disagree.
        """
        members = np.asarray(members, np.int64)
        if members.size == 0:
            return 0.0
        cells = np.asarray(state, np.float64).reshape(
            -1, self.depth, self.width)
        dests = np.asarray(dests, np.int64)
        bins = self.bins()[:, members]                # (depth, |M|)
        mask = np.zeros((self.depth, int(num_slots), self.width))
        for r in range(self.depth):
            mask[r, dests, bins[r]] = 1.0
        # S[r, i, d] = row-r mass shard i holds in d's distinct bins
        per_dest = np.einsum("irw,rdw->rid", cells, mask)
        return float(per_dest.min(axis=0).max())

    def from_dense(self, hist) -> np.ndarray:
        """Provider state equivalent to having observed ``hist`` exactly.

        Count-min is linear in its input stream, so sketching a dense
        histogram row is one bincount of the cluster bins weighted by
        the row — used by tests, analyzer plan targets, and the elastic
        re-projection path to synthesize consistent sketch state.
        """
        h = np.asarray(hist, np.float64)
        squeeze = h.ndim == 1
        h = h.reshape(-1, self.num_clusters)
        bins = self.bins()
        out = np.zeros((h.shape[0], self.depth, self.width))
        for i in range(h.shape[0]):
            for r in range(self.depth):
                out[i, r] = np.bincount(
                    bins[r], weights=h[i], minlength=self.width)
        out = out.reshape(h.shape[0], -1)
        return out[0] if squeeze else out

    def params(self) -> Dict[str, int]:
        """Serializable provider parameters (hash family reproduction)."""
        return self.params_.to_json()


def make_provider(kind: str, num_clusters: int, *, width: int = 1024,
                  depth: int = 4, seed: int = 0, use_kernel: bool = False):
    """Build the provider named by ``MapReduceConfig.stats``."""
    if kind == "exact":
        return ExactStats(num_clusters, use_kernel=use_kernel)
    if kind == "sketch":
        return SketchStats(num_clusters, width=width, depth=depth, seed=seed,
                           use_kernel=use_kernel)
    raise ValueError(f"unknown stats provider {kind!r}; use exact | sketch")
