"""The paper's primary contribution — the OS4M system itself.

Scheduling (``scheduler``/``bss``/``balancer``), statistics (``stats``),
operation clustering (``clustering``), the Reduce pipeline planner
(``pipeline``), the sharded MapReduce engine (``mapreduce``), schedule
reuse for serving (``schedule_cache``), and the cluster-level simulator
(``simulator``). Sibling subpackages hold substrates (kernels, nn, …).
"""
