"""A keyed Map/Shuffle/Reduce engine over a JAX mesh with OS4M scheduling.

This is the faithful reproduction substrate: the paper's whole workflow —

    map  →  collect per-key statistics  →  (host) Q||C_max schedule
         →  chunked shuffle ("copy")    →  pipelined segment reduce ("run")
         →  measure per-slot wave timings → update slot-speed estimate

expressed as two jitted phases. Phase boundaries match the paper exactly:
Reduce work begins only after *all* Map operations have finished and the
schedule is known (§4.1 step 6), eliminating Map↔Reduce contention.

The Reduce phase is a **chunked, double-buffered pipeline** (§4.4): the
host groups operation clusters into chunks of roughly equal load in
*increasing-load order* (``pipeline.plan_chunks``), and phase B walks the
chunks with a software-pipelined loop — the all-to-all "copy" of chunk
``i+1`` is issued *before* the segment-reduce "run" of chunk ``i``, so on
real hardware the ICI transfer of the next chunk overlaps the current
chunk's compute (the TPU analogue of Fig 4(b)'s copy/sort/run overlap).
The "sort" and "run" of a chunk are fused into a single pass by
``kernels/fused_shuffle_reduce`` when ``use_kernels=True``.

Schedule selection: ``scheduler`` may name one algorithm (``hash`` | ``lpt``
| ``multifit`` | ``bss`` | ``os4m``) or ``"auto"``, which runs every
candidate on the measured key distribution and keeps the one whose
*estimated* Reduce makespan (``simulator.pick_strategy`` — the same
flow-shop cost model behind the paper's Figs 7–16) is lowest.

Steady-state serving: planning is decoupled from execution. Each ``run()``
produces (or replays) a :class:`repro.core.schedule_cache.CachedSchedule` —
the schedule, the §4.4 wave plan, and the statistics-sized send capacities.
With ``MapReduceConfig(reuse=ReusePolicy(...))`` the job snapshots the plan
and replays it while the measured key distribution stays close (an
on-device drift metric over the per-shard ``K^(i)`` histograms); only a
drifted, aged-out, or overflowed batch pays the host scheduling cost
again. Because the snapshot pins phase B's static shapes, reused batches
always hit the jitted-executable cache — zero retraces after warmup.

Execution backends share one per-shard code path written against named-axis
collectives:

* ``backend="vmap"``      — slots are a leading array axis mapped with
  ``jax.vmap(..., axis_name=AXIS)``; runs on a single CPU device (tests,
  examples).
* ``backend="shard_map"`` — slots are shards of a mesh axis; the same code
  runs under ``jax.shard_map`` with real ``psum`` / ``all_to_all``
  collectives (dry-run, production).

Data model: a Map operation emits up to ``K`` intermediate pairs
``(key_hash:int32, value:(V,)float32, valid:bool)``. Keys are pre-hashed by
the user's map function (or by :func:`repro.data.text.hash_tokens`).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Callable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import clustering, pipeline as pipe
from repro.core import mesh_timing as mt
from repro.core import schedule_cache as sc
from repro.core import scheduler as sched_lib
from repro.core import slot_speeds as ss
from repro.core import stats_provider as sp

AXIS = "mr_slots"

# fp8 wire format needs a float8 dtype in this jax build; gated, not required.
_FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)

__all__ = ["MapReduceConfig", "JobResult", "MapReduceJob", "AXIS"]


@dataclasses.dataclass(frozen=True)
class MapReduceConfig:
    """Static configuration of one :class:`MapReduceJob`.

    ``reuse`` switches the job into steady-state mode: plans are cached
    in a :class:`repro.core.schedule_cache.ScheduleCache` and replayed
    until the policy (drift / age / speed drift / overflow) demands a
    replan.

    Heterogeneous slots (Q||C_max): ``speeds`` pins a known per-slot
    relative speed vector; ``estimate_speeds`` instead learns one online
    from phase-B wave timings (:class:`repro.core.slot_speeds.
    SlotSpeedEstimator`, EWMA weight ``speed_ewma``). Speeds only move
    *where* clusters are reduced — outputs are bit-identical under any
    speed vector.

    ``measure_timings`` picks the timing source for the estimator.
    ``None`` (default) resolves automatically: *measured* per-device
    wave timings on the shard_map backend (each slot is a device with
    its own clock), the synthetic work/slowdown model on vmap (one
    device, per-slot clocks don't exist). ``True`` forces the measured
    path (requires shard_map + ``estimate_speeds``); ``False`` disables
    it. Measured mode runs the SAME overlapped double-buffered pipeline
    as the unmeasured path, with per-wave on-device tick stamps
    (``kernels/wave_timer``) read from a tiny ticks buffer after the
    batch — outputs stay bit-identical and the copy/run overlap is
    kept. Platforms without a tick source fall back to wave-fenced
    host timing (see :meth:`MapReduceJob._execute_measured_fenced`).
    """

    num_slots: int                      # m — Reduce slots (= mesh shards)
    num_clusters: int                   # n — operation clusters (§4.3)
    scheduler: str = "os4m"             # hash | lpt | multifit | bss | os4m | auto
    eta: float = 0.002                  # FPTAS precision (paper §5: 0.2%)
    reduce_op: str = "sum"              # sum | max | count
    pipeline_chunks: int = 4            # Reduce pipeline granularity (§4.4)
    pipelined: bool = True              # False = Hadoop-style single-shot phase B
    capacity_send: Optional[int] = None  # per-(shard,dest) send buffer; None = safe bound
    use_kernels: bool = False           # route histogram/fused shuffle-reduce via Pallas
    reuse: Optional[sc.ReusePolicy] = None  # schedule-reuse policy; None = replan per run
    speeds: Optional[Tuple[float, ...]] = None  # static per-slot speeds (1.0 = nominal)
    estimate_speeds: bool = False       # learn speeds online from phase-B timings
    speed_ewma: float = 0.4             # estimator smoothing (newest-sample weight)
    measure_timings: Optional[bool] = None  # real per-device wave clocks (shard_map)
    # Elastic mesh: walk phase B wave-by-wave, persisting each completed
    # wave's outputs + the wave cursor to the host
    # (:class:`repro.core.pipeline.WaveCheckpoint`). A slot killed
    # mid-batch (``set_slot_failure(slot, at_wave=w)``) then replays only
    # the waves at/after the cursor onto the surviving mesh — outputs stay
    # bit-identical to an uninterrupted run. Costs the §4.4 copy/run
    # overlap (each wave is fenced to the host), so it is a
    # fault-tolerance mode, not the throughput path. Incompatible with
    # measured timings (which own the fenced program structure).
    checkpoint_waves: bool = False
    # Coded shuffle (Coded MapReduce, arXiv 1512.01625): replicate each
    # map shard r-way under a pair placement, then ship XOR multicast
    # packets that serve two Reduce slots at once — phase B's measured
    # bytes-on-the-wire drop by up to 2(m−1)/(m−2)× at r=2 while outputs
    # stay bit-identical to the uncoded path (XOR decode is exact; the
    # decoded stream is re-ordered to the uncoded (src, position) order
    # before the same per-chunk reduce). r=1 is the uncoded engine;
    # r=2 is the coded pair placement; the replica exchange's bytes are
    # accounted separately (``JobResult.replication_bytes`` — in a real
    # deployment they are redundant map *compute*, not shuffle traffic).
    # Requires the fused executor: incompatible with ``checkpoint_waves``
    # and with measured timings. See docs/SHUFFLE.md.
    shuffle_replication: int = 1
    # Optional lossy wire format for the shuffle payload: ``"int8"``
    # (symmetric, one global psum-shared scale per batch — the
    # train/compression.py error-feedback idiom, minus the feedback
    # because shuffle values are one-shot) or ``"fp8"``
    # (``float8_e4m3fn`` cast). Every delivered value — including a
    # slot's own local pairs — goes through encode→decode, so coded and
    # uncoded runs of the same quantized job remain bit-identical to
    # each other. ``JobResult.quantize_exact`` reports whether the
    # round-trip was lossless for this batch (integer-valued payloads
    # within the dtype's exact range). None = exact f32/bf16 wire.
    quantize_shuffle: Optional[str] = None
    # Pluggable statistics layer (docs/STATISTICS.md). "exact" plans from
    # the full (m, n) histogram K^(i) — bit-identical to the pre-provider
    # engine. "sketch" plans from a per-shard count-min sketch
    # (core/stats_provider.py): phase A emits (sketch_depth *
    # sketch_width) counters per shard instead of n, the host plans from
    # overestimate-only estimates, and outputs stay bit-identical to the
    # exact path — capacities only gate buffer sizing, and estimates can
    # only over-provision (the overflow escape hatch covers the one case
    # that can't hold, prefix-committed caps below). Incompatible with
    # checkpoint_waves (recovery rewrites per-cluster histogram columns,
    # which don't exist in a sketch).
    stats: str = "exact"
    sketch_width: int = 1024            # count-min columns (power of two >= 8)
    sketch_depth: int = 4               # count-min hash rows (min over rows)
    # Streaming-prefix planning (sketch only): plan wave 1 from a sketch
    # of the first ``stream_prefix`` fraction of each shard's pairs
    # (scaled up), then refine the remaining waves from the full-batch
    # sketch once the tail lands — the refined plan keeps wave 1's
    # committed membership and capacity (``pipeline.plan_waves``
    # ``pinned_first``), so a wave already in flight is never re-cut. The
    # committed wave-1 cap is an extrapolation and may under-provision;
    # overflow then triggers the exact escape hatch (caps escalate to the
    # safe bound and the batch re-executes — outputs stay exact).
    stream_prefix: Optional[float] = None


@dataclasses.dataclass
class JobResult:
    """Outputs + provenance of one ``run()`` (fresh plan or cached replay)."""

    values: np.ndarray          # (num_clusters, V) reduced outputs
    counts: np.ndarray          # (num_clusters,) pairs per cluster
    schedule: sched_lib.Schedule
    key_distribution: np.ndarray  # K = (k_1..k_n) (cluster loads, §4.1)
    overflow: int               # pairs dropped by capacity clamp (0 in normal runs)
    network_cost: clustering.NetworkCost
    strategy: str = ""          # scheduler actually used ("auto" resolves here)
    strategy_costs: Optional[dict] = None  # auto mode: estimated cost per candidate
    reused: bool = False        # True = phase B replayed a cached schedule
    plan_reason: str = ""       # ReuseDecision.reason ("" when reuse is off)
    drift: Optional[float] = None  # drift metric, when it was computed this run
    replan_benefit: Optional[dict] = None  # cost-gate verdict (auto + cost_gate)
    slot_speeds: Optional[np.ndarray] = None  # speeds the plan was built for
    speed_drift: Optional[float] = None  # slot-speed change vs the cached plan
    # Measured bytes-on-the-wire of phase B's shuffle (None on executors
    # that do not account — the checkpointed walk). Rows are counted on
    # device (psum'd with the outputs); the host converts rows → bytes
    # with the static wire row size, so the cost model and the replan
    # gate see *measured* shuffle volume, not the modeled one.
    shuffle_bytes: Optional[int] = None   # a2a payload bytes (packets once per multicast)
    shuffle_rows: Optional[int] = None    # wire rows behind those bytes
    shuffle_pairs: Optional[int] = None   # non-local pairs the wire carried
    replication_bytes: int = 0            # coded replica-exchange bytes (not shuffle)
    quantize_exact: Optional[bool] = None  # quantized round-trip lossless? (None = off)


# ---------------------------------------------------------------------------
# Per-shard phase bodies (named-axis collectives; backend-agnostic).
# ---------------------------------------------------------------------------


def _phase_a_shard(
    shard_input,
    map_fn: Callable,
    num_clusters: int,
    stats_fn: Callable,
    prefix_fraction: Optional[float] = None,
):
    """Map + local statistics (paper §4.1 steps 1–3).

    Each slot returns its *local* statistics state — the TaskTracker →
    JobTracker report of §4.1. ``stats_fn(cluster_ids, weights)`` is the
    provider's traced collection step (``core/stats_provider``): the
    exact K^(i) histogram, or a count-min counter grid whose size is
    independent of ``num_clusters``.

    ``prefix_fraction`` (streaming ingestion): additionally sketch only
    the first ``ceil(fraction * K)`` pair positions of the shard — the
    pairs that would have "landed first" in a streaming deployment — and
    return ``concat([full_state, prefix_state])``, so wave-1 planning
    can start from the prefix while the tail is conceptually in flight.
    """
    key_hashes, values, valid = map_fn(shard_input)
    key_hashes = key_hashes.astype(jnp.int32)
    cluster_ids = jnp.abs(key_hashes) % num_clusters
    weights = valid.astype(jnp.float32)
    state = stats_fn(cluster_ids, weights)
    if prefix_fraction is not None:
        k = int(cluster_ids.shape[0])
        cut = int(np.ceil(prefix_fraction * k))
        in_prefix = (jnp.arange(k) < cut).astype(jnp.float32)
        prefix_state = stats_fn(cluster_ids, weights * in_prefix)
        state = jnp.concatenate([state, prefix_state])
    return (key_hashes, values, valid), state


def _counting_sort_to_buckets(
    dest: jnp.ndarray,       # (K,) int32 in [0, m] (m = invalid)
    values: jnp.ndarray,     # (K, V)
    payload: jnp.ndarray,    # (K,) int32 cluster ids
    num_slots: int,
    capacity: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Bucket pairs by destination slot into fixed-capacity send buffers.

    Returns (bucket_values (m, cap, V), bucket_clusters (m, cap),
    bucket_valid (m, cap), overflow_count). This is the "bucket file per
    operation cluster" layout of §4.4, bounded by the schedule's capacity.
    Mirrors the moe_dispatch kernel's reference semantics. Uniform-capacity
    special case of the ragged sort below.
    """
    caps = np.full(num_slots, capacity, np.int64)
    bv, bc, bm, overflow = _ragged_counting_sort_to_buckets(
        dest, values, payload, caps, num_slots * capacity
    )
    return (
        bv.reshape(num_slots, capacity, values.shape[-1]),
        bc.reshape(num_slots, capacity),
        bm.reshape(num_slots, capacity),
        overflow,
    )


def _ragged_counting_sort_to_buckets(
    group: jnp.ndarray,      # (K,) int32 in [0, G] (G = invalid)
    values: jnp.ndarray,     # (K, V)
    payload: jnp.ndarray,    # (K,) int32 cluster ids
    group_caps: np.ndarray,  # (G,) static per-group capacities
    total: int,              # = group_caps.sum()
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-pass counting sort into *ragged* fixed-capacity group buffers.

    The pipelined engine's groups are (chunk, dest) pairs with
    statistics-derived (hence unequal) capacities; a single stable sort
    writes every chunk's bucket file in one spill, with chunk slabs
    contiguous in the flat output. Returns flat ``(total, V)`` /
    ``(total,)`` buffers + overflow count.
    """
    k = group.shape[0]
    num_groups = group_caps.shape[0]
    base = np.zeros(num_groups, np.int64)
    base[1:] = np.cumsum(group_caps)[:-1]
    order = jnp.argsort(group, stable=True)
    g_sorted = group[order]
    idx = jnp.arange(k)
    pos = idx - jnp.searchsorted(g_sorted, g_sorted, side="left")
    g_clip = jnp.clip(g_sorted, 0, num_groups - 1)
    cap_of = jnp.asarray(group_caps, jnp.int32)[g_clip]
    in_range = g_sorted < num_groups
    ok = in_range & (pos < cap_of)
    overflow = jnp.sum(in_range & (pos >= cap_of))
    flat = jnp.where(ok, jnp.asarray(base, jnp.int32)[g_clip] + pos, total)
    v = values[order]
    c = payload[order]
    bucket_values = (
        jnp.zeros((total + 1, values.shape[-1]), values.dtype)
        .at[flat].set(jnp.where(ok[:, None], v, 0))[:-1]
    )
    bucket_clusters = (
        jnp.full((total + 1,), -1, jnp.int32)
        .at[flat].set(jnp.where(ok, c, -1))[:-1]
    )
    bucket_valid = jnp.zeros((total + 1,), jnp.bool_).at[flat].set(ok)[:-1]
    return bucket_values, bucket_clusters, bucket_valid, overflow


def _segment_reduce(
    cluster_ids, values, valid, num_clusters: int, reduce_op: str, use_kernel: bool
):
    """Reduce the "run" phase: aggregate pairs per cluster."""
    w = valid.astype(values.dtype)[..., None]
    seg = jnp.where(valid, cluster_ids, num_clusters)
    counts = jax.ops.segment_sum(
        valid.astype(jnp.float32), seg, num_segments=num_clusters + 1
    )[:-1]
    if reduce_op == "sum":
        if use_kernel:
            from repro.kernels.segment_reduce import ops as segops

            # Identical-sort wire contract (docs/SHUFFLE.md): stability is
            # explicit, not an argsort default — every engine path must
            # order equal keys identically for bit-identical reduces.
            order = jnp.argsort(seg, stable=True)
            out = segops.segment_reduce_sorted(
                (values * w)[order], seg[order].astype(jnp.int32), num_clusters + 1
            )[:-1]
        else:
            out = jax.ops.segment_sum(values * w, seg, num_segments=num_clusters + 1)[:-1]
    elif reduce_op == "max":
        big_neg = jnp.finfo(values.dtype).min
        masked = jnp.where(valid[:, None], values, big_neg)
        out = jax.ops.segment_max(masked, seg, num_segments=num_clusters + 1)[:-1]
        out = jnp.where(counts[:, None] > 0, out, 0.0)
    elif reduce_op == "count":
        out = jax.ops.segment_sum(w, seg, num_segments=num_clusters + 1)[:-1]
    else:
        raise ValueError(f"unknown reduce_op {reduce_op!r}")
    return out, counts


def _copy_chunk(buckets, value_dim: int):
    """The "copy" phase of one chunk: all-to-all every bucket to its slot."""
    bv, bc, bm = buckets
    rv = jax.lax.all_to_all(bv, AXIS, split_axis=0, concat_axis=0, tiled=False)
    rc = jax.lax.all_to_all(bc, AXIS, split_axis=0, concat_axis=0, tiled=False)
    rm = jax.lax.all_to_all(bm, AXIS, split_axis=0, concat_axis=0, tiled=False)
    return rv.reshape(-1, value_dim), rc.reshape(-1), rm.reshape(-1)


def _reduce_chunk(
    rv, rc, rm,
    rank_of_cluster: jnp.ndarray,
    num_clusters: int,
    reduce_op: str,
    use_kernel: bool,
):
    """The "sort" + "run" of one received chunk.

    Kernel path: pairs are ordered by pipeline *rank* (increasing cluster
    load, §4.4) — rank is the one key that is monotone along the sorted
    stream — and the fused kernel gathers + segment-reduces in a single
    pass; the result is un-permuted back to cluster ids with one gather.

    jnp path: ``segment_sum`` needs no sorted stream, and each cluster's
    pairs arrive in the same (src shard, bucket position) relative order on
    every path — sequential and pipelined accumulate bit-identically — so
    the explicit sort is skipped entirely.
    """
    if reduce_op == "sum" and use_kernel:
        from repro.kernels.fused_shuffle_reduce import ops as fused_ops

        rank = jnp.where(
            rm, rank_of_cluster[jnp.clip(rc, 0, num_clusters - 1)], num_clusters
        )
        order = jnp.argsort(rank, stable=True)
        rank_sorted = rank[order].astype(jnp.int32)
        out_by_rank = fused_ops.fused_shuffle_reduce(
            rv, order.astype(jnp.int32), rank_sorted, num_clusters,
            use_kernel=True,
        )
        out = out_by_rank[rank_of_cluster]
        seg = jnp.where(rm, rc, num_clusters)
        counts = jax.ops.segment_sum(
            rm.astype(jnp.float32), seg, num_segments=num_clusters + 1
        )[:-1]
        return out, counts
    return _segment_reduce(rc, rv, rm, num_clusters, reduce_op, False)


def _sequential_reduce(
    rv, rc, rm,
    rank_of_cluster: jnp.ndarray,
    num_clusters: int,
    reduce_op: str,
    use_kernel: bool,
):
    """Whole-input "sort"+"run" — Hadoop's Fig 4(a) Reduce on one shard.

    The *entire* received input is merge-sorted before the run phase
    (rank order, stable — each cluster's pairs keep their arrival order,
    so this stays bit-identical to the pipelined path's per-chunk
    reduce). Shared by the sequential branch of :func:`_phase_b_shard`
    and the fenced executors' single-wave run program, and traced
    directly by the contract analyzer (``repro.analysis``).
    """
    if reduce_op == "sum" and use_kernel:
        return _reduce_chunk(
            rv, rc, rm, rank_of_cluster, num_clusters, reduce_op, True
        )
    rank = jnp.where(
        rm, rank_of_cluster[jnp.clip(rc, 0, num_clusters - 1)], num_clusters
    )
    # Identical-sort wire contract: stability explicit, never a default.
    order = jnp.argsort(rank, stable=True)
    return _segment_reduce(
        rc[order], rv[order], rm[order], num_clusters, reduce_op, False
    )


def _fenced_wave_copy(fv, fc, fm, off: int, cap: int, num_slots: int,
                      v_dim: int):
    """The "copy" program of one fenced wave: slice its slab, all-to-all it.

    Module-level (not an executor closure) so the contract analyzer
    traces the *same* per-wave program the measured-fenced and
    checkpointed executors dispatch — not a reconstruction of it.
    """
    size = num_slots * cap
    slab = (fv[off:off + size].reshape(num_slots, cap, v_dim),
            fc[off:off + size].reshape(num_slots, cap),
            fm[off:off + size].reshape(num_slots, cap))
    return _copy_chunk(slab, v_dim)


def _fenced_wave_run(rv, rc, rm, rank_of_cluster, num_clusters: int,
                     reduce_op: str, use_kernel: bool):
    """The "sort"+"run" program of one fenced wave — shard-local reduce."""
    return _reduce_chunk(rv, rc, rm, rank_of_cluster, num_clusters,
                         reduce_op, use_kernel)


def _wire_payload_dtype(quantize: Optional[str], value_dtype):
    """The dtype actually serialized onto the shuffle wire."""
    if quantize == "int8":
        return jnp.int8
    if quantize == "fp8":
        return _FP8_DTYPE
    return value_dtype


def _quantize_scale(values, valid, quantize: Optional[str]):
    """One global psum-shared int8 scale per batch (compression.py idiom).

    A single scale — not per-chunk — so the sequential and pipelined
    engines encode identically and stay bit-identical to each other.
    """
    if quantize != "int8":
        return None
    mag = jnp.max(
        jnp.abs(values.astype(jnp.float32)) * valid.astype(jnp.float32)[:, None]
    )
    mag = jax.lax.pmax(mag, AXIS)
    return jnp.maximum(mag, 1e-12) / 127.0


def _quantize_encode(values, scale, quantize: str):
    """values → wire payload (symmetric int8 or fp8 cast)."""
    if quantize == "int8":
        return jnp.clip(
            jnp.round(values.astype(jnp.float32) / scale), -127, 127
        ).astype(jnp.int8)
    return values.astype(_FP8_DTYPE)


def _quantize_decode(q, scale, value_dtype, quantize: str):
    """Wire payload → delivered values (deterministic: one scale, one cast)."""
    if quantize == "int8":
        return (q.astype(jnp.float32) * scale).astype(value_dtype)
    return q.astype(jnp.float32).astype(value_dtype)


def _phase_b_shard_coded(
    intermediate,
    assignment: jnp.ndarray,
    rank_of_cluster: jnp.ndarray,
    chunk_of_cluster: jnp.ndarray,
    cfg_static: Tuple,
):
    """Coded phase B: r=2 pair placement + XOR multicast (arXiv 1512.01625).

    The coded execution of the same §4.4 chunk walk. Record ``j`` of slot
    ``s`` is *pair-placed* on ``{s, π(s, j)}`` with partner
    ``π(s, j) = (s + 1 + (j mod (m−1))) mod m`` — every slot holds a
    replica of ``1/(m−1)`` of each other slot's shard, the coded analogue
    of running each map shard on r=2 nodes. (Here the replicas arrive by
    an intermediate all-to-all whose rows are accounted separately as
    ``replication_bytes`` — a documented stand-in for HDFS-style storage
    replication / redundant map compute, which is the scheme's premise.)

    Shuffle then sends one XOR **multicast packet** per slot pair
    ``{d, q}`` instead of two unicast slabs: the sender XORs its
    (partner=d → dst=q) slab with its (partner=q → dst=d) slab word-wise
    (``kernels/coded_shuffle``). Receiver ``d`` holds replicas of every
    sender's partner-``d`` records, rebuilds the first slab with the
    *identical* stable counting sort, and XORs it out — recovering the
    slab addressed to it, bit-exactly. Pairs whose partner is their
    destination ride the replica exchange for free, so wire rows shrink
    by ``2(m−1)/(m−2)`` ≈ 2.3× at m=8 on a balanced workload.

    Bit-identity with the uncoded engine: each slab row carries the
    sender-local record index ``j`` (and its cluster id) beside the
    packed value words; the receiver re-orders all delivered pairs by
    ``(src_slot, j)`` — exactly the uncoded stream's per-cluster arrival
    order — and feeds the SAME per-chunk ``_reduce_chunk``. Invalid rows
    are all-zero words (XOR-neutral) and masked out.
    """
    from repro.kernels.coded_shuffle import ops as cs_ops

    (num_slots, num_clusters, capacity, chunk_caps, reduce_op, pipelined,
     num_chunks, use_kernel, replication, quantize) = cfg_static
    del replication  # == 2, dispatched on
    m, n = num_slots, num_clusters
    key_hashes, values, valid = intermediate
    k = key_hashes.shape[0]
    v_dim = values.shape[-1]
    v_dtype = values.dtype
    cluster_ids = jnp.abs(key_hashes) % n
    me = jax.lax.axis_index(AXIS)
    dest = assignment[cluster_ids]

    if pipelined and num_chunks > 1:
        chunks = num_chunks
        caps = tuple(chunk_caps)
        chunk_of_pair = chunk_of_cluster[cluster_ids]
    else:
        chunks = 1
        caps = (capacity,)
        chunk_of_pair = jnp.zeros((k,), jnp.int32)
    # Replica rows per (partner): each partner offset is hit every m−1
    # records, so ⌈k/(m−1)⌉ bounds every (chunk, partner, dst) group —
    # the coded slabs are usually much smaller than the uncoded buckets.
    n_rep = -(-k // (m - 1))
    cap2 = tuple(int(min(n_rep, caps[c])) for c in range(chunks))

    # ---- Quantized wire payload (optional). One global scale (psum'd)
    # so every slot — sender, replica holder, receiver — encodes the same
    # record to the same bits; delivered values are the decoded ones for
    # local pairs too, keeping coded ≡ uncoded under quantization.
    if quantize:
        scale = _quantize_scale(values, valid, quantize)
        q_all = _quantize_encode(values, scale, quantize)
        deq_all = _quantize_decode(q_all, scale, v_dtype, quantize)
        inexact = jnp.sum(
            valid & jnp.any(deq_all != values, axis=-1)
        ).astype(jnp.float32)
        wire_vals, deliv_vals = q_all, deq_all
    else:
        scale = None
        inexact = jnp.zeros((), jnp.float32)
        wire_vals, deliv_vals = values, values

    wire_words = cs_ops.pack_payload_words(wire_vals)       # (k, W)
    w_pay = wire_words.shape[-1]
    w_row = w_pay + 2       # + cluster_id+1 word, + j+1 word (0 = invalid)
    jidx = jnp.arange(k, dtype=jnp.int32)
    aug = jnp.concatenate([
        wire_words,
        (cluster_ids.astype(jnp.int32) + 1)[:, None],
        (jidx + 1)[:, None],
    ], axis=1)

    def _a2a(x):
        return jax.lax.all_to_all(
            x, AXIS, split_axis=0, concat_axis=0, tiled=False
        ).reshape(x.shape)

    # ---- r=2 replica exchange: slot p receives my records with
    # π(me, j) == p, i.e. j ≡ (p − me − 1) (mod m−1) — a strided slice.
    partner = (me + 1 + (jidx % (m - 1))) % m
    ofs_send = (jnp.arange(m) - me - 1) % m        # partner p ← offset row
    tt = jnp.arange(n_rep)
    sidx = ofs_send[:, None] + tt[None, :] * (m - 1)       # (m, n_rep)
    smask = (sidx < k) & (ofs_send < m - 1)[:, None]       # row me: empty
    sidx_c = jnp.minimum(sidx, k - 1)
    r_kh = _a2a(jnp.where(smask, key_hashes[sidx_c], 0))
    r_v = _a2a(jnp.where(smask[..., None], values[sidx_c], 0))
    r_ok = _a2a(smask & valid[sidx_c])
    ofs_recv = (me - jnp.arange(m) - 1) % m        # src s ← my offset at s
    r_ok = r_ok & (ofs_recv < m - 1)[:, None]
    r_j = (ofs_recv[:, None] + tt[None, :] * (m - 1)).astype(jnp.int32)
    rows_rep = jnp.sum(r_ok.astype(jnp.float32))

    r_cluster = jnp.abs(r_kh) % n
    r_dest = assignment[r_cluster]
    r_chunk = (chunk_of_cluster[r_cluster] if chunks > 1
               else jnp.zeros_like(r_cluster))
    r_flat_v = r_v.reshape(m * n_rep, v_dim)
    r_wire = (_quantize_encode(r_flat_v, scale, quantize) if quantize
              else r_flat_v)
    r_aug = jnp.concatenate([
        cs_ops.pack_payload_words(r_wire),
        (r_cluster.reshape(-1).astype(jnp.int32) + 1)[:, None],
        (r_j.reshape(-1) + 1)[:, None],
    ], axis=1)

    # ---- Two ragged spills with the SAME chunk-major group layout.
    # Sender side: my own records by (chunk, partner, dst), dst ≠ me —
    # these slabs are the packet XOR terms. Replica side: received
    # replicas by (chunk, src, dst) — bit-equal reconstructions of each
    # src's (partner=me, dst) slabs (same stable sort, same caps, same
    # j order), used to XOR packets open; their dst=me column doubles as
    # the replica-delivered pairs.
    caps2_np = np.concatenate(
        [np.full(m * m, cap2[c], np.int64) for c in range(chunks)]
    )
    total2 = int(caps2_np.sum())
    gid = jnp.where(
        valid & (dest != me),
        (chunk_of_pair * m + partner) * m + dest,
        chunks * m * m,
    ).astype(jnp.int32)
    s_aug, _s_bc, s_bm, ovf_send = _ragged_counting_sort_to_buckets(
        gid, aug, cluster_ids.astype(jnp.int32), caps2_np, total2
    )
    src_of_row = jnp.repeat(jnp.arange(m), n_rep)
    r_gid = jnp.where(
        r_ok.reshape(-1),
        (r_chunk.reshape(-1) * m + src_of_row) * m + r_dest.reshape(-1),
        chunks * m * m,
    ).astype(jnp.int32)
    k_aug, _k_bc, _k_bm, ovf_rep = _ragged_counting_sort_to_buckets(
        r_gid, r_aug, r_cluster.reshape(-1).astype(jnp.int32), caps2_np, total2
    )

    # ---- Pairs I both hold and reduce (dst == me): delivered locally,
    # decoded-value payload, same j tag. (f32 carrier is exact for
    # f32/bf16 payloads and the j index.)
    caps_own = np.asarray(caps, np.int64)
    total_own = int(caps_own.sum())
    gid_own = jnp.where(valid & (dest == me), chunk_of_pair, chunks)
    own_carrier = jnp.concatenate([
        deliv_vals.astype(jnp.float32),
        jidx.astype(jnp.float32)[:, None],
    ], axis=1)
    o_vals, o_bc, o_bm, ovf_own = _ragged_counting_sort_to_buckets(
        gid_own.astype(jnp.int32), own_carrier,
        cluster_ids.astype(jnp.int32), caps_own, total_own,
    )

    # ---- Per-chunk packet buffers: X[d, q] = S[p=d→q] ⊕ S[p=q→d], one
    # multicast packet per unordered pair {d, q} (symmetric — both copies
    # of the a2a row carry the same packet; accounted once below).
    pay_dtype = _wire_payload_dtype(quantize, v_dtype)
    dd = jnp.arange(m)[:, None]
    qq = jnp.arange(m)[None, :]
    pair_ok = (dd != qq) & (dd != me) & (qq != me)
    send_pkts = []
    wire_rows = jnp.zeros((), jnp.float32)
    off = 0
    for c in range(chunks):
        size = m * m * cap2[c]
        slab = s_aug[off:off + size].reshape(m, m, cap2[c], w_row)
        slab_m = s_bm[off:off + size].reshape(m, m, cap2[c])
        x = cs_ops.xor_words(
            slab.reshape(-1, w_row),
            jnp.swapaxes(slab, 0, 1).reshape(-1, w_row),
            use_kernel=use_kernel,
        ).reshape(m, m, cap2[c], w_row)
        x = jnp.where(pair_ok[:, :, None, None], x, 0)
        send_pkts.append(x)
        # Packet {d,q} rows = max of its two slab counts; each unordered
        # pair appears twice in the ordered sum, hence the /2.
        cnt = jnp.sum(slab_m, axis=2).astype(jnp.float32)
        wire_rows = wire_rows + jnp.sum(
            jnp.where(pair_ok, jnp.maximum(cnt, cnt.T), 0.0)
        ) / 2.0
        off += size
    pairs_nonlocal = jnp.sum(
        (valid & (dest != me)).astype(jnp.float32)
    )

    # ---- Double-buffered decode→reduce walk (same §4.4 overlap shape:
    # chunk c+1's packet all-to-all is issued before chunk c's reduce).
    acc_dtype = jnp.float32 if (reduce_op == "sum" and use_kernel) else v_dtype
    acc = jnp.zeros((n, v_dim), acc_dtype)
    cnt_acc = jnp.zeros((n,), jnp.float32)
    big = jnp.iinfo(jnp.int32).max
    src_ids = jnp.broadcast_to(jnp.arange(m)[:, None, None], (m, m, 1))
    q_ids = jnp.broadcast_to(jnp.arange(m)[None, :, None], (m, m, 1))
    off = 0
    own_off = 0
    recv = _a2a(send_pkts[0])
    for c in range(chunks):
        rx = recv
        if c + 1 < chunks:
            recv = _a2a(send_pkts[c + 1])
        size = m * m * cap2[c]
        kc = k_aug[off:off + size].reshape(m, m, cap2[c], w_row)
        # One XOR opens everything: for q ≠ me the packet minus my known
        # slab leaves src's (partner=q → me) slab; the q == me column has
        # no packet (zeros), so the XOR passes my replica-delivered slab
        # (partner=me → me) straight through.
        dec = cs_ops.xor_words(
            rx.reshape(-1, w_row), kc.reshape(-1, w_row),
            use_kernel=use_kernel,
        ).reshape(m, m, cap2[c], w_row)
        meta = dec[..., w_pay]
        d_ok = (
            (meta > 0)
            & jnp.broadcast_to(src_ids != me, meta.shape)
            & jnp.broadcast_to((q_ids == me) | (q_ids != src_ids), meta.shape)
        )
        d_vals = cs_ops.unpack_payload_words(
            dec[..., :w_pay].reshape(-1, w_pay), pay_dtype, v_dim
        )
        if quantize:
            d_vals = _quantize_decode(d_vals, scale, v_dtype, quantize)
        else:
            d_vals = d_vals.astype(v_dtype)
        d_cl = (dec[..., w_pay] - 1).reshape(-1)
        d_j = (dec[..., w_pay + 1] - 1).reshape(-1)
        d_src = jnp.broadcast_to(
            jnp.arange(m)[:, None, None], (m, m, cap2[c])
        ).reshape(-1)

        own = o_vals[own_off:own_off + caps[c]]
        own_v = own[:, :v_dim].astype(v_dtype)
        own_j = own[:, v_dim].astype(jnp.int32)
        own_cl = o_bc[own_off:own_off + caps[c]]
        own_ok = o_bm[own_off:own_off + caps[c]]
        own_off += caps[c]

        sv = jnp.concatenate([own_v, d_vals], axis=0)
        scl = jnp.concatenate([own_cl, d_cl.astype(jnp.int32)])
        sok = jnp.concatenate([own_ok, d_ok.reshape(-1)])
        skey = jnp.concatenate([
            me * k + own_j,
            d_src.astype(jnp.int32) * k + d_j.astype(jnp.int32),
        ])
        # The uncoded stream orders each cluster's pairs by (src shard,
        # bucket position) = (src, j); restore exactly that order so the
        # SAME reduce accumulates the SAME sequence → bit-identity. The
        # identical-sort wire contract demands explicit stability: sender
        # and receiver must break equal keys the same way on every path.
        order = jnp.argsort(jnp.where(sok, skey, big), stable=True)
        out_c, cnt_c = _reduce_chunk(
            sv[order], scl[order], sok[order], rank_of_cluster, n,
            reduce_op, use_kernel,
        )
        if chunks == 1:
            # Match the uncoded sequential branch exactly: the reduce
            # output IS the result (shape included — count yields (n, 1)).
            acc, cnt_acc = out_c, cnt_c
        else:
            if reduce_op == "max":
                acc = jnp.where(
                    cnt_c[:, None] > 0, out_c.astype(acc_dtype), acc)
            else:
                acc = acc + out_c.astype(acc_dtype)
            cnt_acc = cnt_acc + cnt_c.astype(jnp.float32)
        off += size

    overflow = ovf_send + ovf_rep + ovf_own
    wire = jnp.stack([wire_rows, rows_rep, inexact, pairs_nonlocal])
    return (acc, cnt_acc, jax.lax.psum(overflow, AXIS)[None],
            jax.lax.psum(wire, AXIS)[None])


def _phase_b_shard(
    intermediate,
    assignment: jnp.ndarray,        # (n_clusters,) int32 — the broadcast schedule S
    rank_of_cluster: jnp.ndarray,   # (n_clusters,) pipeline order rank (§4.4)
    chunk_of_cluster: jnp.ndarray,  # (n_clusters,) chunk id per cluster
    cfg_static: Tuple,
    stamp_through=None,
):
    """Chunked shuffle ("copy") + pipelined reduce ("run") — §4.1 step 6 + §4.4.

    ``pipelined=False`` (or a single chunk) is the Hadoop-style barrier:
    one bulk all-to-all of every pair, then one segment reduce. The
    pipelined path buckets each *chunk* separately and walks them with a
    double-buffered loop — the all-to-all of chunk ``c+1`` is issued before
    the reduce of chunk ``c``, so the next chunk's "copy" is in flight
    (ICI) while the current chunk's "run" occupies the compute units. The
    loop is unrolled (``num_chunks`` is static and small), which hands XLA
    the exact dependence structure: copy(c+1) has no edge from run(c).

    ``stamp_through`` is the measured executor's tick hook
    (``kernels/wave_timer.ops.stamp_through``; see
    :func:`_phase_b_shard_timed`). When set, per-wave boundary stamps are
    threaded through THIS body — one source of truth, so the measured
    path's advertised bit-identity cannot drift out of sync with the
    unmeasured program — and an extra ``(waves, 2, 2)`` uint32 ticks
    output is appended. ``None`` (the default) compiles to the identical
    untimed program.
    """
    (num_slots, num_clusters, capacity, chunk_caps, reduce_op, pipelined,
     num_chunks, use_kernel, replication, quantize) = cfg_static
    if replication > 1:
        # Coded pair placement (validated against stamp_through upstream:
        # MapReduceJob.__init__ rejects coded × measured timings).
        return _phase_b_shard_coded(
            intermediate, assignment, rank_of_cluster, chunk_of_cluster,
            cfg_static,
        )
    key_hashes, values, valid = intermediate
    v_dim = values.shape[-1]
    cluster_ids = jnp.abs(key_hashes) % num_clusters
    timed = stamp_through is not None
    me = jax.lax.axis_index(AXIS)

    # Optional quantized wire: every pair — local ones included — is
    # delivered as decode(encode(value)), so the wire format (not the
    # routing) defines the outputs and coded runs can match bit-for-bit.
    if quantize:
        scale = _quantize_scale(values, valid, quantize)
        send_vals = _quantize_encode(values, scale, quantize)
        deq = _quantize_decode(send_vals, scale, values.dtype, quantize)
        inexact = jnp.sum(
            valid & jnp.any(deq != values, axis=-1)
        ).astype(jnp.float32)
    else:
        scale = None
        send_vals = values
        inexact = jnp.zeros((), jnp.float32)

    def _wire_vec(wire_rows):
        # [a2a rows crossing the network, replica rows (coded only),
        #  inexact quantized records, non-local pairs carried] — psum'd
        # so the host reads one (4,) vector regardless of backend.
        vec = jnp.stack([
            wire_rows, jnp.zeros((), jnp.float32), inexact, wire_rows,
        ])
        return jax.lax.psum(vec, AXIS)[None]

    if not pipelined or num_chunks <= 1:
        dest = jnp.where(valid, assignment[cluster_ids], num_slots).astype(jnp.int32)
        bv, bc, bm, overflow = _counting_sort_to_buckets(
            dest, send_vals, cluster_ids.astype(jnp.int32), num_slots, capacity
        )
        # Bytes-on-the-wire: every bucketed row except the slot's own
        # diagonal bucket (delivered locally) crosses the network.
        wire_rows = (jnp.sum(bm.astype(jnp.float32))
                     - jnp.sum(bm[me].astype(jnp.float32)))
        rv, rc, rm = _copy_chunk((bv, bc, bm), v_dim)
        if quantize:
            rv = _quantize_decode(rv, scale, values.dtype, quantize)
        if timed:
            # Start stamp: produces the ids the reduce consumes.
            rc, start = stamp_through(rc)
        out, counts = _sequential_reduce(
            rv, rc, rm, rank_of_cluster, num_clusters, reduce_op, use_kernel
        )
        if timed:
            # End stamp: consumes + re-emits the outputs (bit-identical),
            # so it cannot fire before the reduce nor be deferred past
            # its use.
            out, end = stamp_through(out, counts[0])
            return (out, counts, jax.lax.psum(overflow, AXIS)[None],
                    _wire_vec(wire_rows), jnp.stack([start, end])[None])
        return (out, counts, jax.lax.psum(overflow, AXIS)[None],
                _wire_vec(wire_rows))

    # ---- Write every chunk's bucket file in ONE counting-sort spill
    # ("bucket file per operation cluster", §4.4): groups are (chunk, dest)
    # pairs with statistics-derived capacities, laid out chunk-major so
    # each chunk's send buckets are a contiguous static slab.
    chunk_of_pair = chunk_of_cluster[cluster_ids]
    dest = assignment[cluster_ids]
    group = jnp.where(
        valid, chunk_of_pair * num_slots + dest, num_chunks * num_slots
    ).astype(jnp.int32)
    group_caps = np.repeat(np.asarray(chunk_caps, np.int64), num_slots)
    total = int(group_caps.sum())
    fv, fc, fm, overflow = _ragged_counting_sort_to_buckets(
        group, send_vals, cluster_ids.astype(jnp.int32), group_caps, total
    )
    send = []
    wire_rows = jnp.zeros((), jnp.float32)
    off = 0
    for c in range(num_chunks):
        size = num_slots * chunk_caps[c]
        slab_m = fm[off:off + size].reshape(num_slots, chunk_caps[c])
        send.append((
            fv[off:off + size].reshape(num_slots, chunk_caps[c], v_dim),
            fc[off:off + size].reshape(num_slots, chunk_caps[c]),
            slab_m,
        ))
        wire_rows = wire_rows + (jnp.sum(slab_m.astype(jnp.float32))
                                 - jnp.sum(slab_m[me].astype(jnp.float32)))
        off += size

    # ---- Double-buffered copy→run walk, in increasing-load chunk order.
    # Accumulator dtype mirrors what the sequential path returns (f32 from
    # the fused kernel, else the value dtype) so both paths agree exactly.
    acc_dtype = jnp.float32 if (reduce_op == "sum" and use_kernel) else values.dtype
    acc = jnp.zeros((num_clusters, v_dim), acc_dtype)
    cnt = jnp.zeros((num_clusters,), jnp.float32)
    # Timed mode: boundary stamps b_0..b_C, b_c pinned between reduce(c-1)
    # and reduce(c) by true deps — it consumes reduce(c-1)'s outputs
    # (scalar reads) and produces the ids reduce(c) reads. Wave c's stamp
    # pair is (b_c, b_{c+1}); the final boundary passes the last wave's
    # outputs through instead, so it lands after the last reduce.
    boundaries = []
    prev_out = None
    recv = _copy_chunk(send[0], v_dim)
    for c in range(num_chunks):
        rv, rc, rm = recv
        if c + 1 < num_chunks:
            # Issue chunk c+1's all-to-all BEFORE reducing chunk c (no
            # data edge from run(c) — nor, in timed mode, to any stamp).
            recv = _copy_chunk(send[c + 1], v_dim)
        if quantize:
            rv = _quantize_decode(rv, scale, values.dtype, quantize)
        if timed:
            anchors = () if prev_out is None else (prev_out[0][0, 0],
                                                   prev_out[1][0])
            rc, b = stamp_through(rc, *anchors)
            boundaries.append(b)
        out_c, cnt_c = _reduce_chunk(
            rv, rc, rm, rank_of_cluster, num_clusters,
            reduce_op, use_kernel,
        )
        if timed and c + 1 == num_chunks:
            # Final boundary: re-emit the last outputs (bit-identical) so
            # the stamp sits after the reduce and before the merge below.
            out_c, b_last = stamp_through(out_c, cnt_c[0])
            boundaries.append(b_last)
        prev_out = (out_c, cnt_c)
        # Every cluster lives in exactly one chunk, so merging is a
        # *replace* where this chunk saw data — correct for max (a
        # maximum() merge would clamp negative maxima at the zero init)
        # and equivalent to += for sum/count (out_c is 0 elsewhere).
        if reduce_op == "max":
            acc = jnp.where(cnt_c[:, None] > 0, out_c.astype(acc_dtype), acc)
        else:
            acc = acc + out_c.astype(acc_dtype)
        cnt = cnt + cnt_c.astype(jnp.float32)
    if timed:
        ticks = jnp.stack([
            jnp.stack([boundaries[c], boundaries[c + 1]])
            for c in range(num_chunks)
        ])
        return (acc, cnt, jax.lax.psum(overflow, AXIS)[None],
                _wire_vec(wire_rows), ticks)
    return (acc, cnt, jax.lax.psum(overflow, AXIS)[None],
            _wire_vec(wire_rows))


def _phase_b_shard_timed(
    intermediate,
    assignment: jnp.ndarray,
    rank_of_cluster: jnp.ndarray,
    chunk_of_cluster: jnp.ndarray,
    cfg_static: Tuple,
):
    """:func:`_phase_b_shard` with on-device tick stamps around each reduce.

    A thin binding of the ONE phase-B body to the ``kernels/wave_timer``
    stamp hook — same per-chunk programs, same accumulation order, so
    outputs are **bit-identical** to the untimed program by construction
    (there is no second copy to drift). Ordering is by **true buffer
    dependencies** (``wave_timer.ops.stamp_through``): each boundary
    stamp consumes the previous wave's reduce outputs and *produces* the
    buffer the next wave's reduce reads (its cluster ids — every reduce
    path consumes them — or, at the final boundary, the last wave's
    outputs themselves), so no scheduler can hoist a stamp before its
    wave's data or defer it past the compute it precedes. Consecutive
    waves *share* their boundary stamp (end(c) ≡ start(c+1)), tiling the
    shard's reduce timeline with one counter read per boundary. The next
    chunk's all-to-all keeps NO edge to any stamp — the §4.4 copy/run
    overlap survives measurement, which is the whole point of moving the
    clock onto the device.

    Returns ``(out, counts, overflow, wire, ticks)`` with ``ticks`` shaped
    ``(waves, 2, 2)`` uint32 — (start, end) × (lo, hi) counter words.
    """
    from repro.kernels.wave_timer import ops as wt_ops

    return _phase_b_shard(
        intermediate, assignment, rank_of_cluster, chunk_of_cluster,
        cfg_static, stamp_through=wt_ops.stamp_through,
    )


# ---------------------------------------------------------------------------
# The job orchestrator.
# ---------------------------------------------------------------------------


class MapReduceJob:
    """Two-phase OS4M job. See module docstring.

    ``map_fn(shard_input) -> (key_hashes (K,), values (K, V), valid (K,))``
    must be a pure JAX function with static output shapes.
    """

    def __init__(
        self,
        map_fn: Callable,
        config: MapReduceConfig,
        backend: str = "vmap",
        mesh: Optional[Mesh] = None,
    ):
        self.map_fn = map_fn
        self.cfg = config
        self.backend = backend
        if backend == "shard_map":
            if mesh is None:
                raise ValueError("shard_map backend requires a mesh")
            devices = np.asarray(mesh.devices).reshape(-1)
            if devices.size != config.num_slots:
                raise ValueError(
                    f"mesh has {devices.size} devices but config.num_slots="
                    f"{config.num_slots}"
                )
            # Re-axis the mesh so the engine's named axis is bound.
            self.mesh = Mesh(devices, (AXIS,))
        else:
            self.mesh = None

        cfg = self.cfg
        # Statistics provider (docs/STATISTICS.md): owns phase A's traced
        # collection step and the host-side estimators _plan reads.
        self._stats = sp.make_provider(
            cfg.stats, cfg.num_clusters,
            width=cfg.sketch_width, depth=cfg.sketch_depth,
            use_kernel=cfg.use_kernels,
        )
        if cfg.stream_prefix is not None:
            if cfg.stats != "sketch":
                raise ValueError(
                    "stream_prefix requires stats='sketch' — prefix planning"
                    " extrapolates a sketch, the exact path has no estimate"
                    " to extrapolate"
                )
            if not 0.0 < cfg.stream_prefix <= 1.0:
                raise ValueError(
                    f"stream_prefix must be in (0, 1], got {cfg.stream_prefix}"
                )
        if cfg.stats == "sketch" and cfg.checkpoint_waves:
            raise ValueError(
                "stats='sketch' is incompatible with checkpoint_waves — "
                "wave recovery zeroes completed per-cluster histogram "
                "columns, which a count-min counter grid does not have"
            )
        self._phase_a = functools.partial(
            _phase_a_shard,
            map_fn=self.map_fn,
            num_clusters=cfg.num_clusters,
            stats_fn=self._stats.collect,
            prefix_fraction=cfg.stream_prefix,
        )
        # Overflow escape hatches taken for estimate-committed capacities
        # (prefix-planned wave-1 caps; see _escalate_caps). Telemetry —
        # distinct from ScheduleCache.capacity_fallbacks, which counts
        # reused-plan overflows.
        self.capacity_fallbacks = 0
        # Jitted executables cached per phase static config: a job object
        # runs many batches (serving, training); re-tracing phase B's
        # unrolled pipeline every run would dwarf the work at small sizes.
        # Keys carry the (quantized) statistics-derived capacities, which
        # still vary batch-to-batch when the schedule shifts — the LRU
        # bound keeps hot keys resident and the dict finite. (Schedule
        # reuse across batches of one workload is the follow-up that makes
        # this hit ~always.)
        self._jit_cache: "collections.OrderedDict" = collections.OrderedDict()
        # Measured mode adds one timed executable per plan shape ("bt"),
        # and its fenced *fallback* splits phase B into per-wave programs
        # (spill + one copy/run pair per chunk) — the cache must hold a
        # whole fenced plan next to the fused executables without
        # thrashing.
        self._jit_cache_max = 48
        # Trace telemetry: +1 every time a new executable is built. Steady-
        # state serving asserts this stays flat after warmup.
        self.jit_misses = 0
        # Schedule-reuse state (the ROADMAP serving item): holds the live
        # CachedSchedule snapshot + decision counters when cfg.reuse is set.
        # On shard_map the drift check is device-resident: the baseline
        # K^(i) stays sharded on the mesh between batches and the metric
        # is a per-device reduction + pmax (only the scalar crosses).
        self.schedule_cache: Optional[sc.ScheduleCache] = (
            sc.ScheduleCache(cfg.reuse, drift_fn=self._make_sharded_drift())
            if cfg.reuse is not None else None
        )
        # Q||C_max state: static speeds are validated once; the online
        # estimator closes the measure → update → next-plan feedback loop.
        if cfg.speeds is not None:
            sched_lib.normalize_speeds(cfg.speeds, cfg.num_slots)
        self.speed_estimator: Optional[ss.SlotSpeedEstimator] = (
            ss.SlotSpeedEstimator(cfg.num_slots, ewma=cfg.speed_ewma)
            if cfg.estimate_speeds else None
        )
        # Timing source: measured per-device wave clocks on a real mesh,
        # the synthetic model otherwise (see MapReduceConfig docstring).
        measure = cfg.measure_timings
        if measure is None:
            measure = backend == "shard_map" and cfg.estimate_speeds
        elif measure:
            if backend != "shard_map":
                raise ValueError(
                    "measure_timings=True needs backend='shard_map' — per-slot"
                    " clocks do not exist on a single vmap device"
                )
            if not cfg.estimate_speeds:
                raise ValueError(
                    "measure_timings=True without estimate_speeds=True would "
                    "measure timings nothing consumes"
                )
        self._measure_timings = bool(measure)
        # Coded / quantized shuffle: validated once, executed by the fused
        # phase-B program only (the fenced and checkpointed executors have
        # their own copy programs and raise instead of silently shipping
        # an uncoded wire).
        if cfg.shuffle_replication not in (1, 2):
            raise ValueError(
                "shuffle_replication must be 1 (uncoded) or 2 (coded pair"
                f" placement), got {cfg.shuffle_replication}"
            )
        if cfg.quantize_shuffle not in (None, "int8", "fp8"):
            raise ValueError(
                f"quantize_shuffle must be None, 'int8' or 'fp8', got"
                f" {cfg.quantize_shuffle!r}"
            )
        if cfg.quantize_shuffle == "fp8" and _FP8_DTYPE is None:
            raise ValueError(
                "quantize_shuffle='fp8' needs jnp.float8_e4m3fn, which this"
                " jax build lacks — use 'int8' or None"
            )
        if cfg.shuffle_replication > 1:
            if cfg.num_slots < 2:
                raise ValueError(
                    "shuffle_replication=2 needs at least 2 slots (the pair"
                    " placement replicates across distinct slots)"
                )
            if cfg.checkpoint_waves:
                raise ValueError(
                    "shuffle_replication>1 is incompatible with"
                    " checkpoint_waves — the checkpointed walk has its own"
                    " per-wave copy programs; run coded jobs on the fused"
                    " executor"
                )
            if self._measure_timings:
                raise ValueError(
                    "shuffle_replication>1 is incompatible with measured"
                    " timings — the coded decode is not stamp-instrumented;"
                    " set measure_timings=False to combine coding with speed"
                    " estimation (synthetic model)"
                )
        if cfg.quantize_shuffle and cfg.checkpoint_waves:
            raise ValueError(
                "quantize_shuffle is incompatible with checkpoint_waves —"
                " the checkpointed copy programs ship the exact wire"
            )
        # Last measured (wire bytes, non-local pairs) — turns the cost
        # model's modeled bytes/pair into a measured rate on the next plan.
        self._last_wire: Optional[Tuple[int, int]] = None
        if cfg.checkpoint_waves and self._measure_timings:
            raise ValueError(
                "checkpoint_waves=True is incompatible with measured timings —"
                " both own the fenced phase-B program structure; set"
                " measure_timings=False (synthetic model) to combine fault"
                " tolerance with speed estimation"
            )
        # Last batch's measured (slots, waves) buffer (None on the
        # synthetic path) — telemetry for benches and tests.
        self.last_wave_timings: Optional[mt.WaveTimings] = None
        # Fault injection (tests, launch/serve --slot-slowdown): per-slot
        # wall-clock multipliers (2.0 = twice as slow). On the vmap
        # backend phase B runs every slot on one device, so per-slot wall
        # time cannot be clocked independently; the timing model below
        # synthesises wave timings as work × slowdown. On a shard_map
        # mesh the measured path clocks each device's wave programs for
        # real, and the injection scales the *measured* seconds instead
        # (a stand-in for genuinely slow hardware). Callers with their
        # own clocks feed ``observe_slot_times`` directly.
        self._slot_slowdown = np.ones(cfg.num_slots)
        # True once observe_slot_times delivered a real measurement; the
        # synthetic model then stays out of the estimator.
        self._external_timings = False
        # Elastic-mesh state: which slots have vanished (speed pinned to
        # exact 0.0 — the dead-slot convention of ``scheduler.
        # normalize_speeds``), and armed mid-batch kills (slot → wave
        # index; fired by the checkpointing executor just before that
        # wave runs). ``on_mesh_change(event_dict)`` is an optional
        # observer hook (serve/engine lane accounting); ``mesh_events``
        # keeps the full join/leave/death log for telemetry either way.
        self._dead_slots = np.zeros(cfg.num_slots, dtype=bool)
        self._kill_at_wave: dict = {}
        self.on_mesh_change: Optional[Callable[[dict], None]] = None
        self.mesh_events: list = []
        # Checkpoint telemetry of the last run() (None before the first
        # checkpointed batch): wave cursor at the last completed
        # checkpoint, how many waves the recovery replayed (0 = clean
        # uninterrupted batch), and the WaveCheckpoint itself.
        self.last_checkpoint_wave: Optional[int] = None
        self.last_replayed_waves: Optional[int] = None
        self.last_checkpoint: Optional[pipe.WaveCheckpoint] = None
        # The recovery plan of the last mid-batch failure (None if the
        # last batch ran clean) — benches assert its schedule assigns
        # zero load to the dead slots.
        self.last_replay_plan: Optional[sc.CachedSchedule] = None

    # -- Q||C_max speed plumbing --------------------------------------------

    def set_slot_slowdown(self, slot: int, factor: float) -> None:
        """Inject a fault: slot ``slot``'s wave wall-clock is multiplied by ``factor``.

        A slowdown factor is a **wall-clock multiplier** — ``2.0`` makes
        the slot read twice as *slow* (half the nominal speed); ``0.5``
        makes it read twice as fast. Affects only the wave timings the
        estimator sees (and hence future plans) — never the computed
        outputs.

        ``factor == 0`` is the elastic-mesh limit: the slot is **dead**
        (vanished, not infinitely slow) and the call routes to
        :meth:`set_slot_failure` — future plans assign it nothing at all.
        """
        if not 0 <= slot < self.cfg.num_slots:
            raise ValueError(f"slot {slot} out of range [0, {self.cfg.num_slots})")
        if factor < 0:
            raise ValueError("slowdown factor must be >= 0 (0 = dead slot)")
        if factor == 0:
            self.set_slot_failure(slot)
            return
        self._slot_slowdown[slot] = factor

    def set_slot_failure(self, slot: int, dead: bool = True,
                         at_wave: Optional[int] = None) -> None:
        """Declare slot ``slot`` dead (or revived) on the elastic mesh.

        ``dead=True`` with no ``at_wave`` takes effect immediately: the
        slot's speed is pinned to exact 0.0 in :meth:`current_speeds`, the
        online estimator masks it out (a dead slot never re-inherits
        work), and the next plan — forced by the schedule cache's
        ``"slot_dead"`` structural check — assigns it nothing.

        ``at_wave=w`` arms a **mid-batch kill** for fault injection
        (``launch/serve.py --kill-at-wave i:w``): the slot dies just
        before phase-B wave ``w`` executes, after waves ``0..w-1``
        checkpointed. Requires ``MapReduceConfig(checkpoint_waves=True)``
        — without wave checkpoints there is no consistent cut to recover
        from.

        ``dead=False`` revives a previously dead slot (a join): speed
        estimate resets to unknown and the next structural check replans.
        """
        if not 0 <= slot < self.cfg.num_slots:
            raise ValueError(f"slot {slot} out of range [0, {self.cfg.num_slots})")
        if at_wave is not None:
            if not dead:
                raise ValueError("at_wave only makes sense with dead=True")
            if not self.cfg.checkpoint_waves:
                raise ValueError(
                    "set_slot_failure(at_wave=...) requires "
                    "MapReduceConfig(checkpoint_waves=True)"
                )
            if at_wave < 0:
                raise ValueError("at_wave must be >= 0")
            self._kill_at_wave[int(slot)] = int(at_wave)
            return
        self._mark_slot_dead(slot, dead)

    def _mark_slot_dead(self, slot: int, dead: bool = True) -> None:
        """Flip one slot's dead bit + estimator mask; emit a mesh event."""
        if bool(self._dead_slots[slot]) == bool(dead):
            return
        self._dead_slots[slot] = dead
        self._kill_at_wave.pop(slot, None)
        if self.speed_estimator is not None:
            self.speed_estimator.set_slot_failure(slot, dead=dead)
        self._emit_mesh_event({
            "event": "slot_dead" if dead else "slot_join",
            "slot": int(slot),
            "num_slots": self.cfg.num_slots,
            "alive": int(self.cfg.num_slots - int(self._dead_slots.sum())),
        })

    def _emit_mesh_event(self, event: dict) -> None:
        """Log a join/leave/death/resize event; notify the observer hook."""
        self.mesh_events.append(event)
        if self.on_mesh_change is not None:
            self.on_mesh_change(event)

    def resize(self, num_slots: int, mesh: Optional[Mesh] = None) -> None:
        """Elastically resize the mesh to ``num_slots`` Reduce slots.

        The cheap path through a membership change: instead of discarding
        the job's warm state, every per-slot structure is re-shaped —

        * a cached plan snapshot is **re-projected** onto the new slot
          count (``CachedSchedule.reproject``: re-bin the per-shard
          ``K^(i)`` baseline + one host re-plan from those warm
          statistics — no cold statistics pass on the next batch);
        * the speed estimator keeps the surviving slots' learned rates
          (``SlotSpeedEstimator.resize``);
        * slowdown/dead-slot vectors are truncated or padded (new slots
          arrive alive and nominal);
        * the jit cache is flushed (phase shapes are keyed on ``m``) and
          the device-resident drift closure is rebuilt on the new mesh.

        ``mesh`` is required on the shard_map backend when growing or
        shrinking the device set (it must hold exactly ``num_slots``
        devices); the vmap backend needs none.
        """
        old_m = self.cfg.num_slots
        if num_slots == old_m:
            return
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if self.backend == "shard_map":
            if mesh is None:
                raise ValueError(
                    "resize on the shard_map backend needs a mesh with the"
                    " new device count"
                )
            devices = np.asarray(mesh.devices).reshape(-1)
            if devices.size != num_slots:
                raise ValueError(
                    f"mesh has {devices.size} devices but resize asked for"
                    f" {num_slots}"
                )
            self.mesh = Mesh(devices, (AXIS,))

        # Static speeds: keep survivors, pad joiners at nominal.
        new_speeds = None
        if self.cfg.speeds is not None:
            base = list(self.cfg.speeds)[:num_slots]
            base += [1.0] * (num_slots - len(base))
            new_speeds = tuple(base)
        self.cfg = dataclasses.replace(
            self.cfg, num_slots=num_slots, speeds=new_speeds
        )

        # Per-slot state: truncate or pad (new slots alive, nominal).
        keep = min(old_m, num_slots)
        slowdown = np.ones(num_slots)
        slowdown[:keep] = self._slot_slowdown[:keep]
        self._slot_slowdown = slowdown
        dead = np.zeros(num_slots, dtype=bool)
        dead[:keep] = self._dead_slots[:keep]
        self._dead_slots = dead
        self._kill_at_wave = {
            s: w for s, w in self._kill_at_wave.items() if s < num_slots
        }
        if self.speed_estimator is not None:
            self.speed_estimator.resize(num_slots)

        # Every cached executable is shaped on the old m — flush, and
        # rebuild the sharded drift closure against the new mesh.
        self._jit_cache.clear()
        if self.schedule_cache is not None:
            self.schedule_cache.drift_fn = self._make_sharded_drift()
            snap = self.schedule_cache.snapshot
            if snap is not None:
                # Warm resize: re-project the snapshot instead of going
                # cold — one re-plan from the re-binned K^(i) baseline.
                self.schedule_cache.snapshot = snap.reproject(
                    num_slots, self._plan
                )
                self.schedule_cache.reprojections += 1
        self._emit_mesh_event({
            "event": "resize",
            "from": int(old_m),
            "to": int(num_slots),
            "alive": int(num_slots - int(self._dead_slots.sum())),
        })

    @property
    def dead_slots(self) -> np.ndarray:
        """Boolean mask of vanished slots (copy)."""
        return self._dead_slots.copy()

    def current_speeds(self) -> Optional[np.ndarray]:
        """Speed vector the next plan will use (None ≡ all nominal).

        Static ``cfg.speeds`` wins; otherwise the online estimate (None
        until the estimator has seen at least one batch). Dead slots
        overlay an exact 0.0 on either source — with neither source set,
        a mesh with dead slots still returns a concrete vector (nominal
        alive, 0.0 dead) so every planner sees the failure.
        """
        if self.cfg.speeds is not None:
            base = np.asarray(self.cfg.speeds, np.float64)
        elif self.speed_estimator is not None:
            base = self.speed_estimator.speeds()
        else:
            base = None
        if np.any(self._dead_slots):
            if base is None:
                base = np.ones(self.cfg.num_slots, np.float64)
            return np.where(self._dead_slots, 0.0, base)
        return base

    def proc_times_row(self, total_load: float = 1.0) -> np.ndarray:
        """This job's row of the multi-job R-matrix: per-slot time for
        ``total_load`` units of its work.

        ``R[job, slot] = total_load / speed[job, slot]`` from the job's
        *own* :class:`~repro.core.slot_speeds.SlotSpeedEstimator` (each
        job observes its own wave timings — cache residency and kernel
        mix make relative slot speeds job-specific, which is exactly why
        the fleet view is unrelated processors, not uniform machines).
        Dead slots read ``+inf`` — the matrix form of the speed-0
        convention that :func:`repro.core.scheduler.normalize_proc_times`
        expects.
        """
        speeds = self.current_speeds()
        if speeds is None:
            speeds = np.ones(self.cfg.num_slots, np.float64)
        row = np.full(self.cfg.num_slots, np.inf, np.float64)
        alive = speeds > 0.0
        row[alive] = float(total_load) / speeds[alive]
        return row

    def attach_schedule_cache(self, cache: sc.ScheduleCache) -> None:
        """Adopt an externally owned cache (multi-tenant coordination).

        The multi-job coordinator hands each job the
        :class:`~repro.core.schedule_cache.ScheduleCache` it reserved
        under the job's tenant key. The job keeps its backend-resident
        drift reduction: if the tenant cache has no ``drift_fn`` yet it
        inherits this job's sharded one. Requires a reuse policy — a
        cache without one has nothing to decide.
        """
        if cache.drift_fn is None:
            cache.drift_fn = self._make_sharded_drift()
        self.cfg = dataclasses.replace(self.cfg, reuse=cache.policy)
        self.schedule_cache = cache

    def observe_slot_times(self, slot_work, slot_seconds) -> None:
        """Feed measured per-slot phase-B (work, wall seconds) to the estimator.

        The hook for real deployments where each slot is a device with its
        own clock. The first call permanently switches the job to
        external-measurement mode: ``run()`` stops folding in its
        synthetic timing model, so real samples are never diluted by
        all-nominal synthetic ones.
        """
        if self.speed_estimator is not None:
            self._external_timings = True
            self.speed_estimator.update(slot_work, slot_seconds)

    def _observe_wave_timings(self, planned: sc.CachedSchedule,
                              key_dist: np.ndarray) -> None:
        """Synthetic per-slot timing model: seconds = work × slowdown.

        One observation per executed batch — the phase-B wave timings of
        §4.4, with the injected ``_slot_slowdown`` (a wall-clock
        multiplier: 2.0 ⇒ twice as slow) standing in for real straggler
        hardware. The estimator normalises rates, so the nominal unit
        cancels; with no injected fault every slot measures 1.0 and plans
        stay bit-identical to the speed-oblivious ones. Disabled as soon
        as ``observe_slot_times`` has delivered a real measurement.
        """
        if self.speed_estimator is None or self._external_timings:
            return
        m = self.cfg.num_slots
        slot_work = np.bincount(
            planned.schedule.assignment, weights=np.asarray(key_dist),
            minlength=m,
        )[:m]
        slot_seconds = slot_work * self._slot_slowdown
        self.speed_estimator.update(slot_work, slot_seconds)

    def _observe_measured(self, timings: mt.WaveTimings,
                          planned: sc.CachedSchedule) -> None:
        """Feed one batch's *measured* per-device wave clocks to the estimator.

        Wave programs are capacity-shaped — every device reduces the same
        statically padded buffer — so the work unit is the shape work
        (rows processed, identical per slot) and ``work/seconds`` isolates
        per-device speed from per-slot load (see
        :class:`repro.core.mesh_timing.WaveTimings`). Injected slowdowns
        multiply the measured seconds by the factor — the wall-clock a
        genuinely slow device would have reported — so fault injection
        rides the measured path instead of reviving the synthetic model.
        Invalid batches are skipped (``timings.valid``: wrapped tick
        stamps, or fenced-fallback waves that traced/compiled). Routed
        through :meth:`observe_slot_times`, which permanently retires the
        synthetic fallback on first contact.
        """
        if self.speed_estimator is None or not timings.valid:
            return
        m = self.cfg.num_slots
        rows = float(m * planned.capacity if planned.waves.num_chunks <= 1
                     else m * sum(planned.chunk_caps))
        timings.slot_work = np.full(m, rows)
        work, secs = timings.observation(self._slot_slowdown)
        # Zero-second guard (ISSUE 5): an empty/degenerate buffer (e.g.
        # ``WaveTimings.empty(m, 0)``, or sub-tick waves on a coarse
        # counter) carries no speed signal — feeding it would flip the
        # job to external-measurement mode on a vacuous sample and risk
        # inf/NaN rates downstream. Skip it entirely.
        if not bool(np.any((secs > 0) & np.isfinite(secs))):
            return
        self.observe_slot_times(work, secs)

    # -- device-resident drift (shard_map backend) ---------------------------

    def _make_sharded_drift(self):
        """A drift_fn for :class:`~repro.core.schedule_cache.ScheduleCache`.

        shard_map backend only (``None`` elsewhere): the plan-time baseline
        ``K^(i)`` is uploaded ONCE, sharded row-per-device next to the
        fresh phase-A histograms, and the L1/χ² metric runs as a
        shard-local reduction + ``pmax`` — between batches the baseline
        stays resident on the mesh, and only the scalar verdict crosses to
        the host.
        """
        if self.backend != "shard_map" or self.cfg.reuse is None:
            return None
        from jax.sharding import NamedSharding

        mesh = self.mesh
        metric = self.cfg.reuse.metric

        def per_shard(ref, fresh):
            """One device's drift contribution over its own K^(i) row."""
            p = ref / jnp.maximum(ref.sum(-1, keepdims=True), 1e-9)
            q = fresh / jnp.maximum(fresh.sum(-1, keepdims=True), 1e-9)
            if metric == "l1":
                d = 0.5 * jnp.abs(p - q).sum()
            else:
                d = 0.5 * ((p - q) ** 2 / jnp.maximum(p + q, 1e-9)).sum()
            return jax.lax.pmax(d, AXIS)

        fn = jax.jit(compat.shard_map(
            per_shard, mesh=mesh,
            in_specs=(P(AXIS, None), P(AXIS, None)), out_specs=P(),
        ))
        sharding = NamedSharding(mesh, P(AXIS, None))

        def drift(snapshot: sc.CachedSchedule, fresh_hist):
            """Scalar drift of ``fresh_hist`` vs the device-resident baseline."""
            ref = snapshot.hist_device(
                lambda h: jax.device_put(jnp.asarray(h, jnp.float32), sharding)
            )
            return fn(ref, jnp.asarray(fresh_hist, jnp.float32))

        return drift

    def load_snapshot(self, snapshot) -> sc.CachedSchedule:
        """Install a persisted plan so a warm process skips the first replan.

        ``snapshot`` is a :class:`~repro.core.schedule_cache.CachedSchedule`
        or its ``to_json`` dict (e.g. read from ``launch/serve.py
        --schedule-snapshot path.json``). Requires ``cfg.reuse`` — the
        snapshot lands in the schedule cache and the first batch goes
        through the normal drift check instead of the cold replan.
        """
        if self.schedule_cache is None:
            raise ValueError("load_snapshot requires MapReduceConfig(reuse=...)")
        if isinstance(snapshot, dict):
            snapshot = sc.CachedSchedule.from_json(snapshot)
        m, n = self.cfg.num_slots, self.cfg.num_clusters
        if snapshot.schedule.num_slots != m:
            raise ValueError(
                f"snapshot has {snapshot.schedule.num_slots} slots, config {m}"
            )
        if snapshot.schedule.assignment.shape[0] != n:
            raise ValueError(
                f"snapshot covers {snapshot.schedule.assignment.shape[0]} "
                f"clusters, config {n}"
            )
        # Warm-start the estimator with the plan-time speeds: a snapshot
        # built from measured (non-nominal) speeds would otherwise face
        # its first drift check with fresh_speeds=None — conservative
        # ``inf`` — and replan immediately, defeating the warm start.
        if self.speed_estimator is not None \
                and self.speed_estimator.observations == 0:
            self.speed_estimator.seed(snapshot.schedule.slot_speeds)
        self.schedule_cache.store(snapshot)
        return snapshot

    # -- backend plumbing ---------------------------------------------------
    #
    # Array convention: per-shard code sees unbatched arrays. The caller
    # passes inputs with a leading (num_slots,) axis for ``vmap`` or a
    # global leading axis of size num_slots * per_shard for ``shard_map``.

    @staticmethod
    def _to_pspec(tree):
        return jax.tree.map(
            lambda a: P(AXIS) if a == 0 else P(),
            tree,
            is_leaf=lambda x: x is None or isinstance(x, int),
        )

    def _run_sharded(self, fn, in_specs, out_specs, *args, cache_key=None):
        # Callers use the vmap convention (leading (num_slots,) axis);
        # shard_map shards a flat global axis, so merge the first two dims
        # on sharded args (outputs come back in the matching flat layout).
        # This runs on every call — cached executables see the same layout
        # they were traced with.
        def _flatten(spec, a):
            if spec == 0 and hasattr(a, "ndim") and a.ndim >= 2:
                return a.reshape((-1,) + a.shape[2:])
            if isinstance(spec, tuple):
                return tuple(_flatten(s, x) for s, x in zip(spec, a))
            return a

        if self.backend != "vmap":
            args = tuple(_flatten(s, a) for s, a in zip(in_specs, args))

        jitted = self._jit_cache.get(cache_key) if cache_key is not None else None
        if jitted is not None:
            self._jit_cache.move_to_end(cache_key)
        else:
            self.jit_misses += 1
            if self.backend == "vmap":
                jitted = jax.jit(jax.vmap(
                    fn, in_axes=in_specs, out_axes=out_specs, axis_name=AXIS
                ))
            else:
                jitted = jax.jit(compat.shard_map(
                    fn,
                    mesh=self.mesh,
                    in_specs=self._to_pspec(in_specs),
                    out_specs=self._to_pspec(out_specs),
                ))
            if cache_key is not None:
                self._jit_cache[cache_key] = jitted
                while len(self._jit_cache) > self._jit_cache_max:
                    self._jit_cache.popitem(last=False)
        return jitted(*args)

    # -- measured shuffle-volume accounting ----------------------------------

    def _wire_rate(self) -> float:
        """Measured wire bytes per non-local pair (model default until measured).

        ``shuffle_bytes / shuffle_pairs`` of the last accounted batch: the
        *effective* per-pair cost of the wire after coding and/or
        quantization, which is what the flow-shop cost model's copy phase
        should charge. Falls back to the simulator's modeled 64 B/pair.
        """
        if self._last_wire is not None and self._last_wire[1] > 0:
            return max(1e-6, self._last_wire[0] / self._last_wire[1])
        return 64.0

    def _wire_accounting(self, wire_vec, values) -> dict:
        """Convert the device row counters into bytes (static row sizes).

        ``wire_vec`` is the psum'd ``[wire_rows, replica_rows, inexact,
        nonlocal_pairs]`` vector phase B returns. Rows are measured on
        device; the bytes per row are static properties of the wire
        format: uncoded rows carry the payload (quantized or native) plus
        a 4-byte cluster id; coded packet rows are XOR word slabs
        (payload words + cluster word + position word); replica rows ship
        the raw record (payload + 4-byte key hash).
        """
        rows, rep_rows, inexact, pairs = (float(x) for x in wire_vec)
        cfg = self.cfg
        v_dim = int(values.shape[-1])
        v_dtype = jnp.dtype(values.dtype)
        if cfg.shuffle_replication > 1:
            from repro.kernels.coded_shuffle import ops as cs_ops

            pay = _wire_payload_dtype(cfg.quantize_shuffle, v_dtype)
            row_bytes = (cs_ops.packed_width(v_dim, pay) + 2) * 4
        else:
            vb = 1 if cfg.quantize_shuffle else v_dtype.itemsize
            row_bytes = v_dim * vb + 4
        rep_row_bytes = v_dim * v_dtype.itemsize + 4
        return {
            "shuffle_bytes": int(round(rows * row_bytes)),
            "shuffle_rows": int(round(rows)),
            "shuffle_pairs": int(round(pairs)),
            "replication_bytes": int(round(rep_rows * rep_row_bytes)),
            "inexact": int(round(inexact)),
        }

    # -- planning (the host "JobTracker" step) -------------------------------

    def _plan(
        self,
        local_hist: np.ndarray,
        key_dist: Optional[np.ndarray],
        k_per_shard: int,
        prev: Optional[sc.CachedSchedule] = None,
        num_chunks: Optional[int] = None,
        assignment_override: Optional[np.ndarray] = None,
        strategy_override: Optional[str] = None,
        pinned_first: Optional[np.ndarray] = None,
        chunk0_cap: Optional[int] = None,
    ) -> sc.CachedSchedule:
        """One host planning step: schedule + §4.4 waves + send capacities.

        Pure host computation from the per-shard statistics; the returned
        :class:`~repro.core.schedule_cache.CachedSchedule` fully determines
        phase B (and its jit-cache key), so it can be replayed across
        batches. ``prev`` is the outgoing snapshot when replanning under a
        reuse policy — capacities take the elementwise max with it (shape
        hysteresis), so repeated replans of one workload converge on a
        single set of buffer shapes and the phase-B jit cache keeps
        hitting even across replans.

        ``local_hist`` is *provider state* (``core/stats_provider``): the
        exact ``(m, n)`` histogram, or ``(m, depth * width)`` count-min
        cells under ``cfg.stats == "sketch"`` — in which case every
        planning input here is O(sketch size), the dense per-shard and
        global estimates are derived on the host (overestimate-only, so
        capacities never silently under-provision), and the passed
        ``key_dist`` is ignored (a sketch's global distribution is an
        estimate, not a column sum — callers may pass ``None``).

        ``num_chunks`` overrides ``cfg.pipeline_chunks`` — the elastic
        recovery path plans only the *remaining* waves after a mid-batch
        failure, so the replayed pipeline is exactly as deep as the work
        left to do.

        The remaining keywords serve streaming-prefix refinement
        (:meth:`_plan_prefixed`): ``assignment_override`` /
        ``strategy_override`` replay a committed cluster → slot
        assignment instead of invoking the scheduler,  ``pinned_first``
        pins the committed wave-1 members to chunk 0, and ``chunk0_cap``
        clamps chunk 0 to the committed capacity — marking the plan
        ``caps_estimated`` (the commitment came from an extrapolated
        prefix and may under-provision; the runner's overflow escape
        hatch restores exactness).
        """
        cfg = self.cfg
        m, n = cfg.num_slots, cfg.num_clusters
        pipeline_chunks = (num_chunks if num_chunks is not None
                          else cfg.pipeline_chunks)
        speeds = self.current_speeds()
        provider = self._stats
        state = np.asarray(local_hist)
        # f32 integer-exactness guard on the RAW device counters — for
        # exact stats these are the histogram cells themselves; for a
        # sketch they are the count-min cells, whose estimates (mins over
        # rows) are only trustworthy while every cell is still exact. A
        # saturated counter voids the overestimate guarantee, so all
        # bounds fall back to the safe k_per_shard.
        raw_max = float(state.max()) if state.size else 0.0
        hist_exact = raw_max < sp.F32_EXACT_MAX
        if provider.kind == "sketch":
            # No (m, n) densify here: capacities come straight from the
            # cells (provider.send_bound) and only the (n,) global
            # estimate is materialized for the scheduler.
            dense_hist = None
            key_dist = provider.key_dist(state)
        else:
            dense_hist = state
            key_dist = (np.asarray(key_dist) if key_dist is not None
                        else provider.key_dist(state))

        # The JobTracker invokes the scheduling algorithm (§4.1 step 4).
        # "auto" tries every candidate and keeps the one with the lowest
        # estimated Reduce makespan under the flow-shop cost model. Every
        # strategy assigns by earliest finish time under the current
        # per-slot speed estimate (Q||C_max; None ≡ identical slots).
        strategy_costs = None
        if assignment_override is not None:
            # Prefix refinement: the assignment was committed by the
            # wave-1 plan; only waves and capacities are recomputed.
            strategy = strategy_override or cfg.scheduler
            schedule = sched_lib.Schedule.from_assignment(
                np.asarray(assignment_override, np.int32), key_dist, m,
                speeds=speeds,
            )
        elif cfg.scheduler == "auto":
            from repro.core import simulator as sim

            strategy, schedule, strategy_costs = sim.pick_strategy(
                key_dist, m, eta=cfg.eta,
                pipelined=cfg.pipelined and pipeline_chunks > 1,
                speeds=speeds,
                # Measured wire rate (last batch) + per-slot locality: the
                # model sees what the shuffle actually costs, so coding or
                # quantizing the wire shifts strategy choice honestly.
                bytes_per_pair=self._wire_rate(),
                # The locality-aware wire model wants per-shard (m, n)
                # counts; a sketch densifies its estimates only for this
                # one auto-strategy path.
                local_hist=(provider.to_dense(state) if dense_hist is None
                            else dense_hist),
            )
        else:
            strategy = cfg.scheduler
            scheduler = sched_lib.get_scheduler(cfg.scheduler)
            if cfg.scheduler == "hash":
                schedule = scheduler(key_dist, m, keys=np.arange(n),
                                     speeds=speeds)
            elif dense_hist is None:
                # Sketch plans schedule at *bin* granularity: the row-0
                # cell sums are the exact total mass landing in each bin,
                # so Q||C_max runs over ``width`` loads instead of ``n``
                # and the scheduling cost is O(sketch), independent of the
                # key count. The per-cluster assignment is a gather
                # through the row-0 hash — clusters sharing a bin travel
                # together, which is exactly the granularity the
                # distinct-bin send bound already charges capacities for.
                cells = state.reshape(m, provider.depth, provider.width)
                bin_loads = np.asarray(cells[:, 0, :].sum(axis=0),
                                       np.float64)
                if cfg.scheduler in ("bss", "os4m"):
                    bin_sched = scheduler(bin_loads, m, eta=cfg.eta,
                                          speeds=speeds)
                else:
                    bin_sched = scheduler(bin_loads, m, speeds=speeds)
                assignment = bin_sched.assignment[provider.bins()[0]]
                schedule = sched_lib.Schedule.from_assignment(
                    np.asarray(assignment, np.int32), key_dist, m,
                    speeds=speeds)
            elif cfg.scheduler in ("bss", "os4m"):
                schedule = scheduler(key_dist, m, eta=cfg.eta, speeds=speeds)
            else:
                schedule = scheduler(key_dist, m, speeds=speeds)

        # Static capacity for the all-to-all: the per-(shard,dest) worst
        # case from the per-shard statistics — shard i sends dest d exactly
        # the pairs of d's clusters that i holds, and the host has K^(i)
        # (or an overestimate of it) per shard, so every send buffer is
        # statistics-sized. Bounds are quantized (≤12.5% slack) so
        # repeated jobs with similar — not identical — distributions share
        # one jitted phase-B executable instead of retracing per batch.
        # Under a reuse policy the bound gains ``capacity_slack`` headroom
        # first, so sub-threshold drift between replans rarely overflows a
        # replayed plan's buffers.
        capacity = cfg.capacity_send or k_per_shard
        slack = 1.0 + (cfg.reuse.capacity_slack if cfg.reuse is not None else 0.0)

        def _quantize_cap(c: int) -> int:
            """Round up to ~1/8-octave steps: bounded cache-key alphabet."""
            c = max(1, int(c))
            if c <= 8:
                return c
            g = 1 << max(0, (c - 1).bit_length() - 3)
            return -(-c // g) * g

        def _send_bound(members) -> int:
            """max over (shard, dest) of pairs shard sends dest (+ slack)."""
            if not hist_exact:
                return k_per_shard      # saturated f32 counts: safe bound
            if len(members) == 0:
                return 1
            dests = schedule.assignment[members]
            if dense_hist is None:
                # Count-min distinct-bin bound: O(sketch), still >= the
                # true per-(shard, dest) worst case (overestimate-only).
                worst = provider.send_bound(state, dests, members, m)
            else:
                worst = 0.0
                for i in range(m):
                    per_dest = np.bincount(
                        dests, weights=dense_hist[i, members], minlength=m
                    )
                    worst = max(worst, float(per_dest.max()))
            return min(k_per_shard, _quantize_cap(int(np.ceil(worst * slack))))

        all_members = np.arange(n)
        capacity = max(1, int(min(capacity, k_per_shard, _send_bound(all_members))))

        # Pipeline plan (§4.4): per-slot increasing-load waves merged into
        # job-wide chunks, globally ordered by finish time under the slot
        # speeds — see ``pipeline.plan_waves``.
        waves = pipe.plan_waves(
            key_dist, schedule.assignment, m, pipeline_chunks,
            speeds=speeds, replication=cfg.shuffle_replication,
            pinned_first=pinned_first,
        )
        chunk_caps = [
            int(min(capacity, _send_bound(waves.chunk_members(ci))))
            for ci in range(waves.num_chunks)
        ]
        caps_estimated = False
        if chunk0_cap is not None:
            # Streaming commitment: wave 1's buffer was sized from the
            # prefix extrapolation before the tail landed, so the refined
            # plan must replay it — even if the full statistics now say
            # it is too small (that is what the overflow hatch is for).
            chunk_caps[0] = max(1, int(min(capacity, chunk0_cap)))
            caps_estimated = chunk_caps[0] < _send_bound(
                waves.chunk_members(0))

        # Shape hysteresis: buffer shapes may only grow across replans of
        # one workload (bounded by k_per_shard), so the phase-B jit cache
        # converges instead of ping-ponging between quantization buckets.
        if prev is not None and prev.waves.num_chunks == waves.num_chunks:
            capacity = max(capacity, prev.capacity)
            chunk_caps = [max(a, b) for a, b in zip(chunk_caps, prev.chunk_caps)]

        return sc.CachedSchedule(
            schedule=schedule,
            strategy=strategy,
            strategy_costs=strategy_costs,
            waves=waves,
            capacity=capacity,
            chunk_caps=tuple(int(c) for c in chunk_caps),
            local_hist=state,
            key_dist=np.asarray(key_dist),
            k_per_shard=int(k_per_shard),
            stats_provider=provider.kind,
            stats_params=provider.params(),
            stats_overestimate=not caps_estimated,
            caps_estimated=caps_estimated,
        )

    def _plan_prefixed(
        self,
        state: np.ndarray,
        prefix_state: np.ndarray,
        k_per_shard: int,
        prev: Optional[sc.CachedSchedule] = None,
    ) -> sc.CachedSchedule:
        """Streaming-prefix planning: commit wave 1 early, refine the rest.

        Emulates the streaming deployment where the JobTracker cannot
        wait for every Map to report before the Reduce pipeline starts:

        1. Plan from the *prefix* sketch scaled by ``1 / stream_prefix``
           (the prefix extrapolated to the full batch). This commits the
           cluster → slot assignment, wave 1's membership, and wave 1's
           send capacity — everything a real deployment would have
           dispatched before the tail landed.
        2. Re-plan from the full-batch sketch, replaying the committed
           assignment (``assignment_override``), pinning the committed
           wave-1 members to chunk 0 (``pinned_first``) and clamping
           chunk 0 to the committed capacity (``chunk0_cap``) — only the
           tail waves are re-cut and re-sized from the tighter
           statistics.

        The refined plan is what phase B executes, so prefix-planned and
        full-planned runs produce identical outputs whenever the
        committed wave-1 cap did not under-provision; when it did, the
        overflow hatch (:meth:`_escalate_caps`) restores exactness.
        """
        frac = self.cfg.stream_prefix
        plan1 = self._plan(prefix_state / frac, None, k_per_shard)
        pinned = plan1.waves.chunk_members(0)
        return self._plan(
            state, None, k_per_shard, prev=prev,
            assignment_override=plan1.schedule.assignment,
            strategy_override=plan1.strategy,
            pinned_first=pinned,
            chunk0_cap=plan1.chunk_caps[0],
        )

    def _escalate_caps(self, planned: sc.CachedSchedule) -> sc.CachedSchedule:
        """Exactness escape hatch for estimate-committed capacities.

        A plan whose chunk-0 cap was committed from a prefix estimate
        (``caps_estimated``) can overflow. Capacities only gate buffer
        sizing — assignment, wave membership and reduce order are
        untouched — so the recovery is NOT a replan: the same plan is
        re-issued with every capacity raised to the safe bound
        ``min(capacity_send, k_per_shard)`` (a shard holds at most
        ``k_per_shard`` pairs, so estimate-driven overflow becomes
        impossible and the re-executed batch is bit-identical to what an
        exact-stats plan of the same schedule would produce).
        """
        cfg = self.cfg
        k = int(planned.k_per_shard)
        safe = max(1, int(min(cfg.capacity_send or k, k)))
        return dataclasses.replace(
            planned,
            capacity=safe,
            chunk_caps=tuple(safe for _ in range(planned.waves.num_chunks)),
            stats_overestimate=True,
            caps_estimated=False,
        )

    # -- execution (phase B under one plan) ----------------------------------

    def _execute(self, intermediate, planned: sc.CachedSchedule):
        """Run phase B under one plan (fresh or replayed); device results.

        The jit-cache key is derived from the plan's static shapes alone,
        so replaying a snapshot is guaranteed to hit the cached executable.
        """
        cfg = self.cfg
        m, n = cfg.num_slots, cfg.num_clusters
        # Replication rides the WAVE PLAN, not the config: a replayed
        # snapshot executes with the wire format it was planned for (old
        # uncoded snapshots keep running uncoded after a config change).
        static = (
            m, n, planned.capacity, tuple(planned.chunk_caps), cfg.reduce_op,
            cfg.pipelined, planned.waves.num_chunks, cfg.use_kernels,
            planned.waves.replication, cfg.quantize_shuffle,
        )

        def phase_b(intermediate, assignment, rank_of_cluster, chunk_of_cluster):
            """Per-shard chunked shuffle + pipelined reduce under ``static``."""
            return _phase_b_shard(
                intermediate, assignment, rank_of_cluster, chunk_of_cluster, static
            )

        return self._run_sharded(
            phase_b,
            ((0, 0, 0), None, None, None),
            (0, 0, 0, 0),
            intermediate,
            jnp.asarray(planned.schedule.assignment, jnp.int32),
            jnp.asarray(planned.waves.rank_of_cluster),
            jnp.asarray(planned.waves.chunk_of_cluster),
            cache_key=("b", static),
        )

    def _execute_measured(self, intermediate, planned: sc.CachedSchedule):
        """Overlapped phase B with on-device wave tick stamps (no fencing).

        Runs the SAME double-buffered pipeline as :meth:`_execute` — the
        all-to-all of chunk i+1 issued under the reduce of chunk i — via
        :func:`_phase_b_shard_timed`, which brackets each wave's reduce
        with per-device (start, end) tick stamps from
        ``kernels/wave_timer``. Per-slot wall clocks are read from the
        tiny ``(slots, waves, 2)`` ticks buffer *after* the batch instead
        of host fences, so measured mode keeps the §4.4 copy/run overlap
        and its throughput penalty vs unmeasured drops to stamp overhead.
        Outputs are bit-identical to :meth:`_execute` (same per-chunk
        programs and accumulation order; the pass-through stamps are
        value identities), and — unlike the
        fenced fallback — the stamps execute with the program, after
        compilation, so even a freshly traced batch yields a valid
        measurement.

        Platforms without a tick source (``wave_timer.ops.available()``
        False — no device counter primitive and no CPU callback) fall
        back to :meth:`_execute_measured_fenced`, the documented
        host-timed path.

        Returns ``(out, counts, overflow, timings)`` where ``timings`` is
        the ``(slots, waves)`` :class:`repro.core.mesh_timing.WaveTimings`
        buffer.
        """
        from repro.kernels.wave_timer import ops as wt_ops

        if not wt_ops.available():
            return self._execute_measured_fenced(intermediate, planned)
        cfg = self.cfg
        m, n = cfg.num_slots, cfg.num_clusters
        num_chunks = planned.waves.num_chunks
        static = (
            m, n, planned.capacity, tuple(planned.chunk_caps), cfg.reduce_op,
            cfg.pipelined, num_chunks, cfg.use_kernels,
            planned.waves.replication, cfg.quantize_shuffle,
        )
        num_waves = num_chunks if cfg.pipelined and num_chunks > 1 else 1

        def phase_b_timed(intermediate, assignment, rank_of_cluster,
                          chunk_of_cluster):
            """Per-shard overlapped phase B + wave tick stamps."""
            return _phase_b_shard_timed(
                intermediate, assignment, rank_of_cluster, chunk_of_cluster,
                static,
            )

        out, counts, overflow, wire, words = self._run_sharded(
            phase_b_timed,
            ((0, 0, 0), None, None, None),
            (0, 0, 0, 0, 0),
            intermediate,
            jnp.asarray(planned.schedule.assignment, jnp.int32),
            jnp.asarray(planned.waves.rank_of_cluster),
            jnp.asarray(planned.waves.chunk_of_cluster),
            cache_key=("bt", static),
        )
        raw = np.asarray(jax.device_get(words)).reshape(m, num_waves, 2, 2)
        timings = mt.WaveTimings.from_ticks(
            wt_ops.combine_ticks(raw),
            wt_ops.tick_calibration().seconds_per_tick,
        )
        return out, counts, overflow, wire, timings

    def _execute_measured_fenced(self, intermediate, planned: sc.CachedSchedule):
        """Fenced fallback: per-wave dispatches + host-attributed clocks.

        The documented fallback for platforms where no tick source exists
        (``kernels/wave_timer`` probes a device counter primitive, then a
        CPU callback; see its ``ops.backend``). Same math as
        :meth:`_execute`, different program structure: the single unrolled
        phase-B program is split into a shard-local spill, and per §4.4
        wave one "copy" program (the all-to-all — a collective
        synchronises every device, so its time is not attributed per slot)
        and one "run" program (shard-local segment reduce, NO collectives
        — each device's output shard becomes ready when *that device*
        finishes, polled in completion order by
        :func:`repro.core.mesh_timing.shard_ready_seconds`). Accumulation
        walks the waves in the same order with the same per-chunk reduce,
        so outputs are bit-identical to the overlapped path; the price is
        the lost copy/run overlap — exactly what the tick path exists to
        avoid paying.

        Returns ``(out, counts, overflow, wire, timings)`` like
        :meth:`_execute_measured`.
        """
        cfg = self.cfg
        if planned.waves.replication > 1 or cfg.quantize_shuffle:
            raise ValueError(
                "the fenced measured fallback has its own copy programs and"
                " does not implement the coded/quantized wire — disable"
                " measure_timings (or provide a tick source) to run"
                " shuffle_replication>1 / quantize_shuffle jobs"
            )
        m, n = cfg.num_slots, cfg.num_clusters
        num_chunks = planned.waves.num_chunks
        static = (
            m, n, planned.capacity, tuple(planned.chunk_caps), cfg.reduce_op,
            cfg.pipelined, num_chunks, cfg.use_kernels,
            planned.waves.replication, cfg.quantize_shuffle,
        )
        assignment = jnp.asarray(planned.schedule.assignment, jnp.int32)
        rank_of_cluster = jnp.asarray(planned.waves.rank_of_cluster)
        chunk_of_cluster = jnp.asarray(planned.waves.chunk_of_cluster)
        capacity = planned.capacity
        chunk_caps = tuple(planned.chunk_caps)
        reduce_op, use_kernel = cfg.reduce_op, cfg.use_kernels
        pipelined = cfg.pipelined and num_chunks > 1

        def _block_all(arrs):
            for a in arrs:
                a.block_until_ready()

        if not pipelined:
            # Single wave, mirroring _phase_b_shard's sequential branch.
            def bucket_fn(inter, assignment):
                """Shard-local counting sort into per-dest send buckets."""
                key_hashes, values, valid = inter
                # Verbatim the fused path's expression (phase A already
                # emitted int32 hashes) so both executors bucket identically.
                cluster_ids = jnp.abs(key_hashes) % n
                dest = jnp.where(valid, assignment[cluster_ids], m).astype(jnp.int32)
                bv, bc, bm, overflow = _counting_sort_to_buckets(
                    dest, values, cluster_ids.astype(jnp.int32), m, capacity
                )
                me = jax.lax.axis_index(AXIS)
                rows = (jnp.sum(bm.astype(jnp.float32))
                        - jnp.sum(bm[me].astype(jnp.float32)))
                wire = jnp.stack(
                    [rows, jnp.zeros(()), jnp.zeros(()), rows])
                return (bv[None], bc[None], bm[None],
                        jax.lax.psum(overflow, AXIS)[None],
                        jax.lax.psum(wire, AXIS)[None])

            def copy_fn(bv, bc, bm):
                """The "copy": all-to-all every bucket to its Reduce slot."""
                rv, rc, rm = _copy_chunk((bv, bc, bm), bv.shape[-1])
                return rv[None], rc[None], rm[None]

            def run_fn(rv, rc, rm, rank_of_cluster):
                """Shard-local "sort"+"run" — the timed, collective-free part."""
                return _sequential_reduce(rv, rc, rm, rank_of_cluster, n,
                                          reduce_op, use_kernel)

            bv, bc, bm, overflow, wire = self._run_sharded(
                bucket_fn, ((0, 0, 0), None), (0, 0, 0, 0, 0),
                intermediate, assignment, cache_key=("m_bucket", static))
            recv = self._run_sharded(
                copy_fn, (0, 0, 0), (0, 0, 0), bv, bc, bm,
                cache_key=("m_copy", static))
            _block_all(recv)
            timings = mt.WaveTimings.empty(m, 1)
            miss0 = self.jit_misses
            t0 = time.perf_counter()
            out, counts = self._run_sharded(
                run_fn, (0, 0, 0, None), (0, 0),
                recv[0], recv[1], recv[2], rank_of_cluster,
                cache_key=("m_run", static))
            timings.record(0, mt.shard_ready_seconds([out, counts], m, t0))
            timings.valid = self.jit_misses == miss0
            return out, counts, overflow, wire, timings

        # Pipelined: one shard-local spill writes every wave's bucket file,
        # then a fenced copy→run walk per wave in the same chunk order.
        group_caps = np.repeat(np.asarray(chunk_caps, np.int64), m)
        total = int(group_caps.sum())

        def spill_fn(inter, assignment, chunk_of_cluster):
            """Shard-local ragged counting sort — all chunk slabs in one spill."""
            key_hashes, values, valid = inter
            cluster_ids = jnp.abs(key_hashes) % n   # fused-path expression
            chunk_of_pair = chunk_of_cluster[cluster_ids]
            dest = assignment[cluster_ids]
            group = jnp.where(
                valid, chunk_of_pair * m + dest, num_chunks * m
            ).astype(jnp.int32)
            fv, fc, fm, overflow = _ragged_counting_sort_to_buckets(
                group, values, cluster_ids.astype(jnp.int32), group_caps, total
            )
            me = jax.lax.axis_index(AXIS)
            rows = jnp.zeros((), jnp.float32)
            off = 0
            for cc in chunk_caps:
                slab_m = fm[off:off + m * cc].reshape(m, cc)
                rows = rows + (jnp.sum(slab_m.astype(jnp.float32))
                               - jnp.sum(slab_m[me].astype(jnp.float32)))
                off += m * cc
            wire = jnp.stack([rows, jnp.zeros(()), jnp.zeros(()), rows])
            return (fv[None], fc[None], fm[None],
                    jax.lax.psum(overflow, AXIS)[None],
                    jax.lax.psum(wire, AXIS)[None])

        fv, fc, fm, overflow, wire = self._run_sharded(
            spill_fn, ((0, 0, 0), None, None), (0, 0, 0, 0, 0),
            intermediate, assignment, chunk_of_cluster,
            cache_key=("m_spill", static))

        v_dim = int(fv.shape[-1])
        acc_dtype = (jnp.float32 if (reduce_op == "sum" and use_kernel)
                     else fv.dtype)
        acc = jnp.zeros((m * n, v_dim), acc_dtype)
        cnt = jnp.zeros((m * n,), jnp.float32)
        timings = mt.WaveTimings.empty(m, num_chunks)
        offsets = np.concatenate([[0], np.cumsum(
            [m * c for c in chunk_caps])]).astype(int)
        for c in range(num_chunks):
            off, cap = int(offsets[c]), chunk_caps[c]

            def copy_fn(fv, fc, fm, _off=off, _cap=cap):
                """The "copy" of wave c: slice its slab, all-to-all it."""
                rv, rc, rm = _fenced_wave_copy(fv, fc, fm, _off, _cap, m,
                                               v_dim)
                return rv[None], rc[None], rm[None]

            def run_fn(rv, rc, rm, rank_of_cluster):
                """The "sort"+"run" of wave c — shard-local, timed per device."""
                return _fenced_wave_run(rv, rc, rm, rank_of_cluster, n,
                                        reduce_op, use_kernel)

            recv = self._run_sharded(
                copy_fn, (0, 0, 0), (0, 0, 0), fv, fc, fm,
                cache_key=("m_wcopy", static, c))
            _block_all(recv)
            miss0 = self.jit_misses
            t0 = time.perf_counter()
            out_c, cnt_c = self._run_sharded(
                run_fn, (0, 0, 0, None), (0, 0),
                recv[0], recv[1], recv[2], rank_of_cluster,
                cache_key=("m_wrun", static, cap))
            timings.record(c, mt.shard_ready_seconds([out_c, cnt_c], m, t0))
            if self.jit_misses != miss0:
                timings.valid = False
            # Same merge as the fused program, elementwise on the global
            # (m·n, v) layout — replace-where-seen for max, += otherwise.
            if reduce_op == "max":
                acc = jnp.where((cnt_c > 0)[:, None], out_c.astype(acc_dtype),
                                acc)
            else:
                acc = acc + out_c.astype(acc_dtype)
            cnt = cnt + cnt_c.astype(jnp.float32)
        return acc, cnt, overflow, wire, timings

    def _mask_completed(self, intermediate, completed: np.ndarray):
        """Invalidate every pair whose cluster already checkpointed.

        Elementwise (no collectives), so one jitted function serves both
        backends and any intermediate layout. The replayed phase B then
        reduces exactly the pairs of the unfinished waves — completed
        clusters contribute nothing twice.
        """
        key_hashes, values, valid = intermediate
        fn = self._jit_cache.get(("mask",))
        if fn is None:
            self.jit_misses += 1
            n = self.cfg.num_clusters

            def mask(kh, valid, done):
                """valid &= cluster not yet checkpointed."""
                return valid & ~done[jnp.abs(kh) % n]

            fn = jax.jit(mask)
            self._jit_cache[("mask",)] = fn
        return (key_hashes, values, fn(key_hashes, valid,
                                       jnp.asarray(completed)))

    def _execute_checkpointed(self, intermediate, planned: sc.CachedSchedule,
                              local_k, k_per_shard: int):
        """Phase B with host checkpoints at wave granularity (elastic mesh).

        Walks the §4.4 waves one fenced copy→run pair at a time (same
        per-chunk programs and accumulation structure as :meth:`_execute`,
        so an uninterrupted walk is **bit-identical** to the fused
        pipeline: every cluster lives in exactly one wave and is reduced
        on exactly one slot, and merging its single non-zero contribution
        with exact zeros is order-insensitive). After each wave the merged
        outputs land in a host :class:`repro.core.pipeline.WaveCheckpoint`.

        An armed kill (``set_slot_failure(slot, at_wave=w)``) fires just
        before wave ``w``: the slot is marked dead, the *remaining* load
        (fresh ``K^(i)`` with completed clusters zeroed) is re-planned
        onto the surviving slots with exactly ``num_chunks − w`` chunks,
        completed clusters are masked out of the intermediate pairs, and
        the fused executor replays only that residue — so recovery costs
        ``remaining_waves`` of work, never the whole batch.

        Returns host ``(values (n, v), counts (n,), overflow_total)``.
        """
        cfg = self.cfg
        m, n = cfg.num_slots, cfg.num_clusters
        num_chunks = planned.waves.num_chunks
        pipelined = cfg.pipelined and num_chunks > 1
        waves_total = num_chunks if pipelined else 1
        ckpt = pipe.WaveCheckpoint(num_chunks=waves_total)
        vals = None
        cnts = None
        overflow_total = 0
        replayed = 0

        def _merge_host(out, counts):
            """Collapse device outputs over slots (each cluster: one slot)."""
            o = np.asarray(jax.device_get(out)).reshape(m, n, -1).sum(axis=0)
            ct = np.asarray(jax.device_get(counts)).reshape(m, n).sum(axis=0)
            return o, ct

        def _absorb(o, ct):
            """Merge one wave into the accumulators (replace for max)."""
            nonlocal vals, cnts
            if vals is None:
                vals = np.zeros_like(o)
                cnts = np.zeros_like(ct)
            if cfg.reduce_op == "max":
                vals = np.where(ct[:, None] > 0, o, vals)
            else:
                vals = vals + o
            cnts = cnts + ct

        def _fire(due):
            """Mark the due slots dead (pops their armed kills)."""
            for s in due:
                self._kill_at_wave.pop(s, None)
                self._mark_slot_dead(s)

        def _replay(cursor: int):
            """Re-plan + re-execute the unfinished waves on the survivors."""
            nonlocal overflow_total, replayed
            completed = (ckpt.completed_clusters
                         if ckpt.completed_clusters is not None
                         else np.zeros(n, dtype=bool))
            hist = np.asarray(jax.device_get(local_k), np.float64).copy()
            hist[:, completed] = 0.0
            key_dist = hist.sum(axis=0)
            remaining = max(1, waves_total - cursor)
            replan = self._plan(hist, key_dist, k_per_shard, prev=None,
                                num_chunks=remaining)
            masked = self._mask_completed(intermediate, completed)
            out, counts, overflow, _wire = self._execute(masked, replan)
            o, ct = _merge_host(out, counts)
            _absorb(o, ct)
            overflow_total += int(
                np.asarray(jax.device_get(overflow)).reshape(-1)[0]
            )
            replayed = (replan.waves.num_chunks
                        if cfg.pipelined and replan.waves.num_chunks > 1 else 1)
            self.last_replay_plan = replan

        def _due(c: int):
            return [s for s, w in self._kill_at_wave.items() if w <= c]

        killed = False
        if not pipelined:
            due = _due(0)
            if due:
                _fire(due)
                _replay(0)
                killed = True
            else:
                out, counts, overflow, _wire = self._execute(
                    intermediate, planned)
                o, ct = _merge_host(out, counts)
                _absorb(o, ct)
                overflow_total += int(
                    np.asarray(jax.device_get(overflow)).reshape(-1)[0]
                )
                ckpt.mark_wave(np.arange(n), {}, n)
        else:
            assignment = jnp.asarray(planned.schedule.assignment, jnp.int32)
            rank_of_cluster = jnp.asarray(planned.waves.rank_of_cluster)
            chunk_of_cluster = jnp.asarray(planned.waves.chunk_of_cluster)
            chunk_caps = tuple(planned.chunk_caps)
            static = (m, n, planned.capacity, chunk_caps, cfg.reduce_op,
                      cfg.pipelined, num_chunks, cfg.use_kernels,
                      planned.waves.replication, cfg.quantize_shuffle)
            reduce_op, use_kernel = cfg.reduce_op, cfg.use_kernels
            group_caps = np.repeat(np.asarray(chunk_caps, np.int64), m)
            total = int(group_caps.sum())
            # Keep every intermediate product in the caller-side vmap
            # convention (leading (m,) axis): vmap stacks per-shard
            # outputs itself; shard_map concatenates flat, so each shard
            # re-adds a leading 1 — then re-entry through ``_run_sharded``
            # flattens it back correctly on either backend.
            if self.backend == "vmap":
                lead = lambda a: a          # noqa: E731
            else:
                lead = lambda a: a[None]    # noqa: E731

            def spill_fn(inter, assignment, chunk_of_cluster):
                """Shard-local ragged spill — all wave slabs in one sort."""
                key_hashes, values, valid = inter
                cluster_ids = jnp.abs(key_hashes) % n
                chunk_of_pair = chunk_of_cluster[cluster_ids]
                dest = assignment[cluster_ids]
                group = jnp.where(
                    valid, chunk_of_pair * m + dest, num_chunks * m
                ).astype(jnp.int32)
                fv, fc, fm, overflow = _ragged_counting_sort_to_buckets(
                    group, values, cluster_ids.astype(jnp.int32), group_caps,
                    total,
                )
                return (lead(fv), lead(fc), lead(fm),
                        jax.lax.psum(overflow, AXIS)[None])

            fv, fc, fm, overflow = self._run_sharded(
                spill_fn, ((0, 0, 0), None, None), (0, 0, 0, 0),
                intermediate, assignment, chunk_of_cluster,
                cache_key=("c_spill", static))
            overflow_total += int(
                np.asarray(jax.device_get(overflow)).reshape(-1)[0]
            )
            v_dim = int(fv.shape[-1])
            offsets = np.concatenate([[0], np.cumsum(
                [m * cc for cc in chunk_caps])]).astype(int)
            for c in range(num_chunks):
                due = _due(c)
                if due:
                    _fire(due)
                    _replay(c)
                    killed = True
                    break
                off, cap = int(offsets[c]), chunk_caps[c]

                def copy_fn(fv, fc, fm, _off=off, _cap=cap):
                    """The "copy" of wave c: slice its slab, all-to-all it."""
                    rv, rc, rm = _fenced_wave_copy(fv, fc, fm, _off, _cap, m,
                                                   v_dim)
                    return lead(rv), lead(rc), lead(rm)

                def run_fn(rv, rc, rm, rank_of_cluster):
                    """The "sort"+"run" of wave c — shard-local reduce."""
                    return _fenced_wave_run(rv, rc, rm, rank_of_cluster, n,
                                            reduce_op, use_kernel)

                rv, rc, rm = self._run_sharded(
                    copy_fn, (0, 0, 0), (0, 0, 0), fv, fc, fm,
                    cache_key=("c_wcopy", static, c))
                out_c, cnt_c = self._run_sharded(
                    run_fn, (0, 0, 0, None), (0, 0),
                    rv, rc, rm, rank_of_cluster,
                    cache_key=("c_wrun", static, cap))
                o, ct = _merge_host(out_c, cnt_c)
                _absorb(o, ct)
                members = planned.waves.chunk_members(c)
                ckpt.mark_wave(
                    members, {int(j): o[j] for j in members}, n
                )

        # Kills armed past the last wave fire between batches: the slot is
        # dead for the NEXT plan, nothing of THIS batch needs replay.
        if self._kill_at_wave:
            _fire(list(self._kill_at_wave))

        self.last_checkpoint = ckpt
        self.last_checkpoint_wave = ckpt.wave_cursor
        self.last_replayed_waves = replayed
        return vals, cnts, overflow_total

    # -- public API ----------------------------------------------------------

    def run(self, inputs) -> JobResult:
        """Execute the full job: phase A → {replay cached | host plan} → phase B.

        Without a reuse policy this is the paper's per-job workflow (host
        schedule every run). With ``cfg.reuse`` set, the per-shard
        histograms feed an on-device drift check first; a reused batch
        skips the statistics pull and the scheduler entirely and replays
        the cached plan, which by construction hits the phase-B jit cache.
        """
        cfg = self.cfg
        m, n = cfg.num_slots, cfg.num_clusters

        # ---- Phase A: map + statistics (all Maps finish before any Reduce).
        def phase_a(shard_input):
            """Per-shard map + local K^(i) histogram (phase A body)."""
            return self._phase_a(shard_input)

        intermediate, local_k = self._run_sharded(
            phase_a, (0,), ((0, 0, 0), 0), inputs, cache_key=("a",)
        )
        # Per-shard provider state, still on device: (m, S) for vmap, a
        # flat global axis under shard_map — reshape covers both. S is the
        # provider's state size (n exact, depth*width sketch); streaming
        # prefix mode doubles it (columns [0:S) full batch, [S:2S) the
        # prefix sketch — see _phase_a_shard).
        provider = self._stats
        local_k = local_k.reshape(m, -1)
        prefix_k = None
        if cfg.stream_prefix is not None:
            s = provider.state_size
            prefix_k = local_k[:, s:]
            local_k = local_k[:, :s]
        k_per_shard = int(intermediate[0].shape[-1])
        cache = self.schedule_cache

        # ---- Reuse decision (on-device drift; only a scalar reaches host).
        decision = None
        benefit = None
        local_hist = None
        if cache is not None:
            decision = cache.decide(local_k, fresh_speeds=self.current_speeds())
            if (decision.action == "replan" and decision.reason == "drift"
                    and cache.policy.cost_gate and cfg.scheduler == "auto"):
                # The distribution drifted — but is a fresh plan actually
                # better than the stale schedule's expected imbalance, net
                # of the scheduler's own cost? (simulator cost model)
                from repro.core import simulator as sim

                local_hist = np.asarray(jax.device_get(local_k))
                # The cost model wants dense per-shard counts; under a
                # sketch these are the overestimate-only densifications.
                benefit = sim.estimate_replan_benefit(
                    provider.key_dist(local_hist), cache.snapshot.schedule,
                    eta=cfg.eta,
                    pipelined=cfg.pipelined and cfg.pipeline_chunks > 1,
                    speeds=self.current_speeds(),
                    # Gate on MEASURED shuffle cost: the wire rate of the
                    # last accounted batch and the per-slot locality both
                    # shrink the copy term the model weighs replanning by.
                    bytes_per_pair=self._wire_rate(),
                    local_hist=provider.to_dense(local_hist),
                )
                if benefit["benefit"] <= 0.0:
                    # Not worth it: keep the plan, re-anchor the drift
                    # baseline so the question isn't re-asked every batch.
                    cache.snapshot.refresh_baseline(
                        local_hist, key_dist=provider.key_dist(local_hist))
                    decision = sc.ReuseDecision(
                        "reuse", "cost_gate", decision.drift,
                        speed_drift=decision.speed_drift,
                    )

        # ---- Host plan (cold / drift / max_age) or cached replay.
        if decision is not None and decision.action == "reuse":
            planned = cache.snapshot
            # Fresh measured K for the result (an (S,) pull — the full
            # (m, S) statistics and the scheduler both stay off this path;
            # a cost-gated batch already pulled the statistics, reuse
            # them). Under a sketch the provider turns the pulled global
            # counters into the (n,) overestimate.
            key_dist = provider.key_dist(
                local_hist if local_hist is not None
                else np.asarray(jax.device_get(jnp.sum(local_k, axis=0))))
        else:
            local_hist = np.asarray(jax.device_get(local_k))
            key_dist = provider.key_dist(local_hist)
            prev = cache.snapshot if cache is not None else None
            if prefix_k is not None:
                planned = self._plan_prefixed(
                    local_hist, np.asarray(jax.device_get(prefix_k)),
                    k_per_shard, prev=prev,
                )
            else:
                planned = self._plan(local_hist, key_dist, k_per_shard,
                                     prev=prev)
            if cache is not None:
                cache.store(planned)

        # Measured mode (shard_map + estimation): the overlapped pipeline
        # with on-device wave tick stamps (host-fenced clocks only as the
        # no-tick-source fallback); otherwise the untimed fused program.
        # Checkpointing mode (elastic mesh) walks the waves fenced, with
        # host checkpoints, and returns host-merged results directly.
        measured = self._measure_timings and self.speed_estimator is not None
        checkpointing = cfg.checkpoint_waves and not measured
        timings: Optional[mt.WaveTimings] = None
        values = counts_np = None
        wire_vec = None
        if checkpointing:
            self.last_replay_plan = None
            values, counts_np, overflow_total = self._execute_checkpointed(
                intermediate, planned, local_k, k_per_shard)
        elif measured:
            out, counts, overflow, wire_vec, timings = self._execute_measured(
                intermediate, planned)
            overflow_total = int(
                np.asarray(jax.device_get(overflow)).reshape(-1)[0]
            )
        else:
            out, counts, overflow, wire_vec = self._execute(
                intermediate, planned)
            overflow_total = int(
                np.asarray(jax.device_get(overflow)).reshape(-1)[0]
            )

        # ---- Capacity fallback: a replayed plan's statistics-sized
        # buffers were too small for this batch (drift under the threshold
        # can still concentrate load). Overflow counting is exact, so
        # replan from the fresh statistics and re-execute — outputs are
        # always the no-drop ones. This doubles as the sketch path's
        # exactness escape hatch: a fresh pure-sketch plan's capacities
        # are overestimate-only, so the re-executed batch cannot
        # estimate-overflow again.
        if decision is not None and decision.action == "reuse" and overflow_total > 0:
            cache.capacity_fallbacks += 1
            local_hist = np.asarray(jax.device_get(local_k))
            key_dist = provider.key_dist(local_hist)
            planned = self._plan(local_hist, key_dist, k_per_shard,
                                 prev=cache.snapshot)
            cache.store(planned)
            decision = sc.ReuseDecision("replan", "overflow", decision.drift,
                                        speed_drift=decision.speed_drift)
            if checkpointing:
                # Mid-batch kills already fired during the first walk, so
                # this re-execution is a clean checkpointed pass.
                values, counts_np, overflow_total = self._execute_checkpointed(
                    intermediate, planned, local_k, k_per_shard)
            elif measured:
                out, counts, overflow, wire_vec, timings = (
                    self._execute_measured(intermediate, planned))
                overflow_total = int(
                    np.asarray(jax.device_get(overflow)).reshape(-1)[0]
                )
            else:
                out, counts, overflow, wire_vec = self._execute(
                    intermediate, planned)
                overflow_total = int(
                    np.asarray(jax.device_get(overflow)).reshape(-1)[0]
                )

        # ---- Estimate-commitment fallback (streaming prefix): wave 1's
        # committed cap under-provisioned this batch. Not a replan — the
        # schedule and wave membership are kept (capacities only gate
        # buffer sizing), every cap escalates to the safe bound, and the
        # batch re-executes drop-free (see _escalate_caps).
        if planned.caps_estimated and overflow_total > 0:
            self.capacity_fallbacks += 1
            planned = self._escalate_caps(planned)
            if cache is not None:
                cache.store(planned)
            if measured:
                out, counts, overflow, wire_vec, timings = (
                    self._execute_measured(intermediate, planned))
            else:
                out, counts, overflow, wire_vec = self._execute(
                    intermediate, planned)
            overflow_total = int(
                np.asarray(jax.device_get(overflow)).reshape(-1)[0]
            )

        if cache is not None:
            cache.record(decision)

        # ---- Close the Q||C_max feedback loop: this batch's phase-B wave
        # timings (measured per-device clocks on a shard_map mesh,
        # synthetic on the single-device vmap backend) update the speed
        # estimate the *next* plan will schedule under.
        self.last_wave_timings = timings
        if timings is not None:
            self._observe_measured(timings, planned)
        else:
            self._observe_wave_timings(planned, key_dist)

        # Each cluster is reduced on exactly one slot; merge = sum over
        # slots (the checkpointed executor already merged wave-by-wave).
        if not checkpointing:
            values = np.asarray(jax.device_get(out)).reshape(m, n, -1).sum(axis=0)
            counts_np = np.asarray(
                jax.device_get(counts)).reshape(m, n).sum(axis=0)

        # ---- Measured shuffle volume: device row counters → bytes with
        # static row sizes. Feeds the result AND the next plan's cost
        # model (``_wire_rate``), so the simulator charges the copy phase
        # what the wire actually cost, not the modeled 64 B/pair.
        shuffle_bytes = shuffle_rows = shuffle_pairs = None
        replication_bytes = 0
        quantize_exact = None
        if wire_vec is not None:
            wv = np.asarray(
                jax.device_get(wire_vec), np.float64).reshape(-1, 4)[0]
            acct = self._wire_accounting(wv, intermediate[1])
            shuffle_bytes = acct["shuffle_bytes"]
            shuffle_rows = acct["shuffle_rows"]
            shuffle_pairs = acct["shuffle_pairs"]
            replication_bytes = acct["replication_bytes"]
            if cfg.quantize_shuffle:
                quantize_exact = acct["inexact"] == 0
            self._last_wire = (shuffle_bytes, shuffle_pairs)

        # One Map operation per shard (paper footnote 1: Map task == operation).
        net = clustering.network_cost_bytes(
            num_map_ops=m, num_clusters=n, num_tasktrackers=m, num_reduce_tasks=m
        )
        return JobResult(
            values=values,
            counts=counts_np,
            schedule=planned.schedule,
            key_distribution=key_dist,
            overflow=overflow_total,
            network_cost=net,
            strategy=planned.strategy,
            strategy_costs=planned.strategy_costs,
            reused=bool(decision is not None and decision.action == "reuse"),
            plan_reason=decision.reason if decision is not None else "",
            drift=decision.drift if decision is not None else None,
            replan_benefit=benefit,
            slot_speeds=planned.schedule.slot_speeds,
            speed_drift=(decision.speed_drift if decision is not None else None),
            shuffle_bytes=shuffle_bytes,
            shuffle_rows=shuffle_rows,
            shuffle_pairs=shuffle_pairs,
            replication_bytes=replication_bytes,
            quantize_exact=quantize_exact,
        )
