"""A keyed Map/Shuffle/Reduce engine over a JAX mesh with OS4M scheduling.

This is the faithful reproduction substrate: the paper's whole workflow —

    map  →  collect per-key statistics  →  (host) P||C_max schedule
         →  shuffle by the schedule      →  pipelined segment reduce

expressed as two jitted phases. Phase boundaries match the paper exactly:
Reduce work begins only after *all* Map operations have finished and the
schedule is known (§4.1 step 6), eliminating Map↔Reduce contention.

Execution backends share one per-shard code path written against named-axis
collectives:

* ``backend="vmap"``      — slots are a leading array axis mapped with
  ``jax.vmap(..., axis_name=AXIS)``; runs on a single CPU device (tests,
  examples).
* ``backend="shard_map"`` — slots are shards of a mesh axis; the same code
  runs under ``jax.shard_map`` with real ``psum`` / ``all_to_all``
  collectives (dry-run, production).

Data model: a Map operation emits up to ``K`` intermediate pairs
``(key_hash:int32, value:(V,)float32, valid:bool)``. Keys are pre-hashed by
the user's map function (or by :func:`repro.data.text.hash_tokens`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import clustering, pipeline as pipe
from repro.core import scheduler as sched_lib
from repro.core.stats import local_key_histogram

AXIS = "mr_slots"

__all__ = ["MapReduceConfig", "JobResult", "MapReduceJob", "AXIS"]


@dataclasses.dataclass(frozen=True)
class MapReduceConfig:
    num_slots: int                      # m — Reduce slots (= mesh shards)
    num_clusters: int                   # n — operation clusters (§4.3)
    scheduler: str = "os4m"             # hash | lpt | multifit | bss | os4m
    eta: float = 0.002                  # FPTAS precision (paper §5: 0.2%)
    reduce_op: str = "sum"              # sum | max | count
    pipeline_chunks: int = 4            # Reduce pipeline granularity (§4.4)
    pipelined: bool = True              # False = Hadoop-style single-shot phase B
    capacity_send: Optional[int] = None  # per-(shard,dest) send buffer; None = safe bound
    use_kernels: bool = False           # route histogram/segment-reduce via Pallas


@dataclasses.dataclass
class JobResult:
    values: np.ndarray          # (num_clusters, V) reduced outputs
    counts: np.ndarray          # (num_clusters,) pairs per cluster
    schedule: sched_lib.Schedule
    key_distribution: np.ndarray  # K = (k_1..k_n) (cluster loads, §4.1)
    overflow: int               # pairs dropped by capacity clamp (0 in normal runs)
    network_cost: clustering.NetworkCost


# ---------------------------------------------------------------------------
# Per-shard phase bodies (named-axis collectives; backend-agnostic).
# ---------------------------------------------------------------------------


def _phase_a_shard(
    shard_input,
    map_fn: Callable,
    num_clusters: int,
    use_kernel: bool,
):
    """Map + local statistics + global aggregation (paper §4.1 steps 1–3)."""
    key_hashes, values, valid = map_fn(shard_input)
    key_hashes = key_hashes.astype(jnp.int32)
    cluster_ids = jnp.abs(key_hashes) % num_clusters
    local_k = local_key_histogram(
        cluster_ids, num_clusters, weights=valid.astype(jnp.float32),
        use_kernel=use_kernel,
    )
    global_k = jax.lax.psum(local_k, AXIS)
    return (key_hashes, values, valid), global_k


def _counting_sort_to_buckets(
    dest: jnp.ndarray,       # (K,) int32 in [0, m] (m = invalid)
    values: jnp.ndarray,     # (K, V)
    payload: jnp.ndarray,    # (K,) int32 cluster ids
    num_slots: int,
    capacity: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Bucket pairs by destination slot into fixed-capacity send buffers.

    Returns (bucket_values (m, cap, V), bucket_clusters (m, cap),
    bucket_valid (m, cap), overflow_count). This is the "bucket file per
    operation cluster" layout of §4.4, bounded by the schedule's capacity.
    Mirrors the moe_dispatch kernel's reference semantics.
    """
    k = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    dest_sorted = dest[order]
    # position within destination group
    idx = jnp.arange(k)
    group_start = jnp.searchsorted(dest_sorted, dest_sorted, side="left")
    pos = idx - group_start
    ok = (dest_sorted < num_slots) & (pos < capacity)
    overflow = jnp.sum((dest_sorted < num_slots) & (pos >= capacity))
    flat = jnp.where(ok, dest_sorted * capacity + pos, num_slots * capacity)
    v = values[order]
    c = payload[order]
    bucket_values = (
        jnp.zeros((num_slots * capacity + 1, values.shape[-1]), values.dtype)
        .at[flat].set(jnp.where(ok[:, None], v, 0))[:-1]
        .reshape(num_slots, capacity, values.shape[-1])
    )
    bucket_clusters = (
        jnp.full((num_slots * capacity + 1,), -1, jnp.int32)
        .at[flat].set(jnp.where(ok, c, -1))[:-1]
        .reshape(num_slots, capacity)
    )
    bucket_valid = (
        jnp.zeros((num_slots * capacity + 1,), jnp.bool_)
        .at[flat].set(ok)[:-1]
        .reshape(num_slots, capacity)
    )
    return bucket_values, bucket_clusters, bucket_valid, overflow


def _segment_reduce(
    cluster_ids, values, valid, num_clusters: int, reduce_op: str, use_kernel: bool
):
    """Reduce the "run" phase: aggregate pairs per cluster."""
    w = valid.astype(values.dtype)[..., None]
    seg = jnp.where(valid, cluster_ids, num_clusters)
    counts = jax.ops.segment_sum(
        valid.astype(jnp.float32), seg, num_segments=num_clusters + 1
    )[:-1]
    if reduce_op == "sum":
        if use_kernel:
            from repro.kernels.segment_reduce import ops as segops

            order = jnp.argsort(seg)
            out = segops.segment_reduce_sorted(
                (values * w)[order], seg[order].astype(jnp.int32), num_clusters + 1
            )[:-1]
        else:
            out = jax.ops.segment_sum(values * w, seg, num_segments=num_clusters + 1)[:-1]
    elif reduce_op == "max":
        big_neg = jnp.finfo(values.dtype).min
        masked = jnp.where(valid[:, None], values, big_neg)
        out = jax.ops.segment_max(masked, seg, num_segments=num_clusters + 1)[:-1]
        out = jnp.where(counts[:, None] > 0, out, 0.0)
    elif reduce_op == "count":
        out = jax.ops.segment_sum(w, seg, num_segments=num_clusters + 1)[:-1]
    else:
        raise ValueError(f"unknown reduce_op {reduce_op!r}")
    return out, counts


def _phase_b_shard(
    intermediate,
    assignment: jnp.ndarray,      # (n_clusters,) int32 — the broadcast schedule S
    rank_of_cluster: jnp.ndarray,  # (n_clusters,) pipeline order rank (§4.4)
    chunk_of_rank: jnp.ndarray,    # (n_clusters,) chunk id per rank
    cfg_static: Tuple,
):
    """Shuffle ("copy"), sort, pipelined reduce ("run") — §4.1 step 6 + §4.4."""
    (num_slots, num_clusters, capacity, reduce_op, pipelined, num_chunks, use_kernel) = cfg_static
    key_hashes, values, valid = intermediate
    cluster_ids = jnp.abs(key_hashes) % num_clusters
    dest = jnp.where(valid, assignment[cluster_ids], num_slots).astype(jnp.int32)

    bv, bc, bm, overflow = _counting_sort_to_buckets(
        dest, values, cluster_ids.astype(jnp.int32), num_slots, capacity
    )
    # The "copy" phase: one all-to-all moves every bucket to its Reduce slot.
    rv = jax.lax.all_to_all(bv, AXIS, split_axis=0, concat_axis=0, tiled=False)
    rc = jax.lax.all_to_all(bc, AXIS, split_axis=0, concat_axis=0, tiled=False)
    rm = jax.lax.all_to_all(bm, AXIS, split_axis=0, concat_axis=0, tiled=False)
    rv = rv.reshape(-1, values.shape[-1])
    rc = rc.reshape(-1)
    rm = rm.reshape(-1)

    # The "sort" phase: order received pairs by pipeline rank so each chunk
    # is a contiguous slab processed in increasing-load order.
    rank = jnp.where(rm, rank_of_cluster[jnp.clip(rc, 0, num_clusters - 1)], num_clusters)
    order = jnp.argsort(rank, stable=True)
    rv, rc, rm, rank = rv[order], rc[order], rm[order], rank[order]

    if not pipelined or num_chunks <= 1:
        out, counts = _segment_reduce(rc, rv, rm, num_clusters, reduce_op, use_kernel)
        return out, counts, jax.lax.psum(overflow, AXIS)[None]

    # The pipelined "run" phase: a scan over chunks. Chunk c reduces only its
    # own slab (mask), accumulating into the output. On TPU the per-chunk
    # slab load (HBM read) of chunk c+1 overlaps chunk c's reduction; the
    # double-buffer carry makes the dependence structure explicit to XLA.
    chunk_ids = jnp.where(rm, chunk_of_rank[jnp.clip(rc, 0, num_clusters - 1)], num_chunks)

    def body(carry, c):
        acc, cnt = carry
        in_chunk = chunk_ids == c
        out_c, cnt_c = _segment_reduce(
            rc, rv, rm & in_chunk, num_clusters, reduce_op, use_kernel
        )
        if reduce_op == "max":
            acc = jnp.where(cnt_c[:, None] > 0, jnp.maximum(acc, out_c), acc)
        else:
            acc = acc + out_c
        return (acc, cnt + cnt_c), None

    init = (
        jnp.zeros((num_clusters, values.shape[-1]), values.dtype),
        jnp.zeros((num_clusters,), jnp.float32),
    )
    # Under shard_map the carry becomes device-varying after the first chunk;
    # mark the init accordingly (no-op under vmap/single-device).
    init = jax.tree.map(lambda x: jax.lax.pvary(x, AXIS), init)
    (out, counts), _ = jax.lax.scan(body, init, jnp.arange(num_chunks))
    return out, counts, jax.lax.psum(overflow, AXIS)[None]


# ---------------------------------------------------------------------------
# The job orchestrator.
# ---------------------------------------------------------------------------


class MapReduceJob:
    """Two-phase OS4M job. See module docstring.

    ``map_fn(shard_input) -> (key_hashes (K,), values (K, V), valid (K,))``
    must be a pure JAX function with static output shapes.
    """

    def __init__(
        self,
        map_fn: Callable,
        config: MapReduceConfig,
        backend: str = "vmap",
        mesh: Optional[Mesh] = None,
    ):
        self.map_fn = map_fn
        self.cfg = config
        self.backend = backend
        if backend == "shard_map":
            if mesh is None:
                raise ValueError("shard_map backend requires a mesh")
            devices = np.asarray(mesh.devices).reshape(-1)
            if devices.size != config.num_slots:
                raise ValueError(
                    f"mesh has {devices.size} devices but config.num_slots="
                    f"{config.num_slots}"
                )
            # Re-axis the mesh so the engine's named axis is bound.
            self.mesh = Mesh(devices, (AXIS,))
        else:
            self.mesh = None

        cfg = self.cfg
        self._phase_a = functools.partial(
            _phase_a_shard,
            map_fn=self.map_fn,
            num_clusters=cfg.num_clusters,
            use_kernel=cfg.use_kernels,
        )

    # -- backend plumbing ---------------------------------------------------
    #
    # Array convention: per-shard code sees unbatched arrays. The caller
    # passes inputs with a leading (num_slots,) axis for ``vmap`` or a
    # global leading axis of size num_slots * per_shard for ``shard_map``.

    @staticmethod
    def _to_pspec(tree):
        return jax.tree.map(
            lambda a: P(AXIS) if a == 0 else P(),
            tree,
            is_leaf=lambda x: x is None or isinstance(x, int),
        )

    def _run_sharded(self, fn, in_specs, out_specs, *args):
        if self.backend == "vmap":
            mapped = jax.vmap(
                fn, in_axes=in_specs, out_axes=out_specs, axis_name=AXIS
            )
            return jax.jit(mapped)(*args)

        # Callers use the vmap convention (leading (num_slots,) axis);
        # shard_map shards a flat global axis, so merge the first two dims
        # on sharded args (outputs come back in the matching flat layout).
        def _flatten(spec, a):
            if spec == 0 and hasattr(a, "ndim") and a.ndim >= 2:
                return a.reshape((-1,) + a.shape[2:])
            if isinstance(spec, tuple):
                return tuple(_flatten(s, x) for s, x in zip(spec, a))
            return a

        args = tuple(_flatten(s, a) for s, a in zip(in_specs, args))
        smapped = jax.shard_map(
            fn,
            mesh=self.mesh,
            in_specs=self._to_pspec(in_specs),
            out_specs=self._to_pspec(out_specs),
        )
        return jax.jit(smapped)(*args)

    # -- public API ----------------------------------------------------------

    def run(self, inputs) -> JobResult:
        """Execute the full job: phase A → host schedule → phase B."""
        cfg = self.cfg
        m, n = cfg.num_slots, cfg.num_clusters

        # ---- Phase A: map + statistics (all Maps finish before any Reduce).
        def phase_a(shard_input):
            return self._phase_a(shard_input)

        intermediate, global_k = self._run_sharded(
            phase_a, (0,), ((0, 0, 0), 0), inputs
        )
        # ``global_k`` is psum'd, hence identical on every slot — take slot 0.
        key_dist = np.asarray(jax.device_get(global_k)).reshape(-1, n)[0]

        # ---- Host: the JobTracker invokes the scheduling algorithm (§4.1 step 4).
        scheduler = sched_lib.get_scheduler(cfg.scheduler)
        if cfg.scheduler == "hash":
            schedule = scheduler(key_dist, m, keys=np.arange(n))
        elif cfg.scheduler in ("bss", "os4m"):
            schedule = scheduler(key_dist, m, eta=cfg.eta)
        else:
            schedule = scheduler(key_dist, m)

        # Static capacity for the all-to-all: the per-(shard,dest) worst case.
        k_per_shard = int(intermediate[0].shape[-1])
        capacity = cfg.capacity_send or k_per_shard
        capacity = int(min(capacity, k_per_shard))

        # ---- Pipeline plan (§4.4): increasing-load order, chunked.
        order = pipe.plan_order(key_dist, "increasing")
        rank_of_cluster = np.empty(n, np.int32)
        rank_of_cluster[order] = np.arange(n, dtype=np.int32)
        chunks = pipe.plan_chunks(key_dist, cfg.pipeline_chunks, "increasing")
        chunk_of_cluster = np.zeros(n, np.int32)
        for ci, members in enumerate(chunks):
            chunk_of_cluster[members] = ci
        num_chunks = len(chunks)

        static = (
            m, n, capacity, cfg.reduce_op, cfg.pipelined, num_chunks, cfg.use_kernels
        )

        def phase_b(intermediate, assignment, rank_of_cluster, chunk_of_rank):
            return _phase_b_shard(
                intermediate, assignment, rank_of_cluster, chunk_of_rank, static
            )

        out, counts, overflow = self._run_sharded(
            phase_b,
            ((0, 0, 0), None, None, None),
            (0, 0, 0),
            intermediate,
            jnp.asarray(schedule.assignment, jnp.int32),
            jnp.asarray(rank_of_cluster),
            jnp.asarray(chunk_of_cluster),
        )

        # Each cluster is reduced on exactly one slot; merge = sum over slots.
        values = np.asarray(jax.device_get(out)).reshape(m, n, -1).sum(axis=0)
        counts_np = np.asarray(jax.device_get(counts)).reshape(m, n).sum(axis=0)
        overflow_total = int(np.asarray(jax.device_get(overflow)).reshape(-1)[0])

        # One Map operation per shard (paper footnote 1: Map task == operation).
        net = clustering.network_cost_bytes(
            num_map_ops=m, num_clusters=n, num_tasktrackers=m, num_reduce_tasks=m
        )
        return JobResult(
            values=values,
            counts=counts_np,
            schedule=schedule,
            key_distribution=key_dist,
            overflow=overflow_total,
            network_cost=net,
        )
