"""Online per-slot speed estimation from phase-B wave timings (Q||C_max).

The schedulers in :mod:`repro.core.scheduler` accept a ``speeds`` vector —
relative processing rates per Reduce slot (1.0 = nominal). This module
produces that vector *online*: every executed batch yields one observation
``(work_j, seconds_j)`` per slot (pairs reduced and wall time of the slot's
phase-B waves), the estimator folds the implied rate ``work_j / seconds_j``
into a per-slot EWMA, and :meth:`SlotSpeedEstimator.speeds` returns the
rates normalised to mean 1 — a straggler running at half rate shows up as
``0.5`` regardless of the absolute unit the timings were measured in.

The feedback loop (``MapReduceJob``): measure phase B → ``update`` → the
next ``_plan`` assigns by earliest finish time under the new speeds →
measure again. :func:`speed_drift` is the replan trigger for cached
schedules: a slot slowing (or recovering) by more than
``ReusePolicy.max_speed_drift`` invalidates the snapshot the same way key
drift does.

Everything here is plain host numpy — speeds only move *where* clusters
go, never what they compute, so the estimator never touches device code.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import numpy as np

__all__ = ["SlotSpeedEstimator", "speed_drift"]


def speed_drift(
    ref_speeds: Optional[Sequence[float]],
    new_speeds: Optional[Sequence[float]],
) -> float:
    """Largest fractional speed change of any slot between two estimates.

    ``max_j max(ref_j/new_j, new_j/ref_j) - 1`` — symmetric, so both a slot
    *slowing* (stale schedule now underestimates its finish time) and a
    slot *recovering* (capacity the schedule is not using) count. Returns
    0.0 for identical estimates; a slot dropping to half speed returns 1.0.

    ``None`` semantics: ``None`` means "no measurement". Two ``None`` sides
    (or ``None`` against an all-nominal vector) are zero drift — nothing
    was ever assumed, nothing can have changed. But a *one-sided* ``None``
    against a **non-nominal** vector is conservative ``inf``: the other
    side embodies a measured heterogeneity claim that can no longer be
    verified (an estimator ``reset()``, or a snapshot saved before any
    measurement), so a cached schedule built on it must be revalidated
    rather than silently trusted.

    **Dead slots (exact 0.0)** are structural, not drift: the ratio is
    taken only over slots *alive on both sides* — a slot dead on both
    sides contributes nothing (no rate to compare, and no 0/0 warning
    noise). If the *set* of dead slots differs between the two vectors
    (a slot died or rejoined), the function returns ``inf`` — a mesh-shape
    change always invalidates a plan — but callers that want to name the
    event precisely (``ReuseDecision`` reason ``"slot_dead"``) should
    compare dead masks *before* calling this.
    """
    if ref_speeds is None and new_speeds is None:
        return 0.0
    if ref_speeds is None or new_speeds is None:
        known = np.asarray(
            ref_speeds if ref_speeds is not None else new_speeds, np.float64
        )
        if known.size == 0 or np.allclose(known, 1.0, rtol=0.0, atol=1e-12):
            return 0.0          # None ≡ nominal: no evidence of change
        return float("inf")     # measured heterogeneity vs no measurement
    ref = np.asarray(ref_speeds, np.float64)
    new = np.asarray(new_speeds, np.float64)
    if ref.shape != new.shape:
        raise ValueError(f"speed shapes differ: {ref.shape} vs {new.shape}")
    if ref.size == 0:
        return 0.0
    ref_dead = ref == 0.0
    new_dead = new == 0.0
    if np.any(ref_dead != new_dead):
        return float("inf")     # structural: a slot died or rejoined
    both = ~ref_dead
    if not np.any(both):
        return 0.0              # degenerate: nothing alive to compare
    r, v = ref[both], new[both]
    ratio = np.maximum(r / v, v / r)
    return float(ratio.max() - 1.0)


@dataclasses.dataclass
class SlotSpeedEstimator:
    """EWMA estimate of per-slot relative processing speed.

    ``ewma``  — weight of the newest observation (1.0 = no smoothing; the
                default 0.4 converges on a step change in ~4 batches while
                riding out single-batch timing noise).
    ``floor`` — lower clamp on the *relative* speed, so one pathological
                timing sample cannot convince the scheduler a slot is
                10⁻⁶× and starve every other slot of its work.

    Slots with no observation yet report speed 1.0 (nominal). With zero
    observations :meth:`speeds` returns ``None`` — the schedulers' "assume
    P||C_max" signal — so a job without timing data behaves bit-identically
    to the speed-oblivious code.
    """

    num_slots: int
    ewma: float = 0.4
    floor: float = 0.05

    def __post_init__(self):
        """Validate knobs and reset the per-slot rate state."""
        if not 0.0 < self.ewma <= 1.0:
            raise ValueError("ewma must be in (0, 1]")
        if not 0.0 < self.floor < 1.0:
            raise ValueError("floor must be in (0, 1)")
        self._rate = np.full(self.num_slots, np.nan)  # EWMA of work/second
        self._dead = np.zeros(self.num_slots, dtype=bool)
        self.observations = 0

    # -- elastic mesh --------------------------------------------------------

    def set_slot_failure(self, slot: int, dead: bool = True) -> None:
        """Mark ``slot`` dead (speed pinned to exact 0.0) or revived.

        Dead slots are masked out of every estimate: their measurements are
        dropped, :meth:`speeds` reports exactly ``0.0`` for them (the
        schedulers' "never assign here" signal), and the normalisation
        mean runs over the surviving slots only. Revival clears the slot's
        rate history — a rejoining device re-learns its speed from scratch
        (filling in at the observed-fleet mean meanwhile) instead of
        trusting a stale pre-failure estimate.
        """
        if not 0 <= slot < self.num_slots:
            raise ValueError(
                f"slot {slot} out of range for {self.num_slots} slots")
        if dead:
            self._dead[slot] = True
        elif self._dead[slot]:
            self._dead[slot] = False
            self._rate[slot] = np.nan

    @property
    def dead_mask(self) -> np.ndarray:
        """Boolean (num_slots,) — True where the slot is marked dead."""
        return self._dead.copy()

    def resize(self, num_slots: int) -> None:
        """Re-shape the estimator for an elastic mesh resize.

        Growth: new (highest-numbered) slots start unobserved and alive.
        Shrink: the highest-numbered slots' state is dropped. Slot identity
        below ``min(old, new)`` is preserved — rates and dead flags ride
        along, so a resize does not throw away warm measurements.
        """
        if num_slots <= 0:
            raise ValueError("num_slots must be positive")
        old = self.num_slots
        if num_slots == old:
            return
        rate = np.full(num_slots, np.nan)
        dead = np.zeros(num_slots, dtype=bool)
        keep = min(old, num_slots)
        rate[:keep] = self._rate[:keep]
        dead[:keep] = self._dead[:keep]
        self.num_slots = num_slots
        self._rate = rate
        self._dead = dead
        if self.observations and not np.any(~np.isnan(self._rate)):
            self.observations = 0  # every observed slot was dropped

    def update(
        self,
        slot_work: Sequence[float],
        slot_seconds: Sequence[float],
    ) -> np.ndarray:
        """Fold one batch's per-slot (work, wall seconds) into the estimate.

        Slots with no work or no measured time this batch keep their prior
        estimate (an idle slot tells us nothing about its speed). Zero,
        negative, or non-finite seconds/work are likewise skipped per slot
        — a ``seconds == 0`` sample (empty ``WaveTimings``, sub-tick wave
        on a coarse counter) would otherwise imply an infinite rate and
        poison the EWMA; a batch with no usable slot at all does not count
        as an observation. Returns the updated relative speed vector (see
        :meth:`speeds`).
        """
        work = np.asarray(slot_work, np.float64)
        secs = np.asarray(slot_seconds, np.float64)
        if work.shape != (self.num_slots,) or secs.shape != (self.num_slots,):
            raise ValueError(
                f"expected ({self.num_slots},) work/seconds, got "
                f"{work.shape}/{secs.shape}"
            )
        observed = (work > 0) & np.isfinite(work) & (secs > 0) & np.isfinite(secs)
        observed &= ~self._dead  # a dead slot's residual timings are noise
        rate = np.where(observed, work / np.maximum(secs, 1e-12), np.nan)
        first = observed & np.isnan(self._rate)
        cont = observed & ~np.isnan(self._rate)
        self._rate = np.where(first, rate, self._rate)
        self._rate = np.where(
            cont, self.ewma * rate + (1.0 - self.ewma) * self._rate, self._rate
        )
        if observed.any():
            self.observations += 1
        return self.speeds(default_ones=True)

    def speeds(self, default_ones: bool = False) -> Optional[np.ndarray]:
        """Relative speed per slot, normalised to mean 1 over the FULL vector.

        ``None`` before the first observation (unless ``default_ones``),
        which downstream code treats as "all slots nominal" — the exact
        P||C_max behaviour.

        Partially-observed fleets (pinned semantics): a slot with no
        observation yet is *assumed to run at the observed-fleet mean
        rate* — it fills in at exactly the mean before normalisation, so
        the returned mixed vector is mean-1 over **all** slots, not just
        the observed ones, and earliest-finish assignment is not biased
        toward (or away from) unobserved slots. The ``floor`` clamp is
        applied last and may perturb the mean by design — bounding the
        damage of one pathological timing sample outranks exact
        normalisation.

        Dead slots (:meth:`set_slot_failure`) report **exact 0.0** — below
        the floor by design, since the floor guards against bad timing
        samples while death is a structural fact — and are excluded from
        the mean, so the returned vector is mean-1 over the *surviving*
        slots. With dead slots present the result is never ``None``: even
        with zero timing observations the mesh shape itself is information
        the schedulers must see.
        """
        dead_any = bool(self._dead.any())
        if self.observations == 0:
            if dead_any:
                return np.where(self._dead, 0.0, 1.0)
            return np.ones(self.num_slots) if default_ones else None
        seen = ~np.isnan(self._rate) & ~self._dead
        if not np.any(seen):
            fallback = np.where(self._dead, 0.0, 1.0)
            return fallback if (dead_any or default_ones) else None
        mean = float(self._rate[seen].mean())
        if mean <= 0:
            fallback = np.where(self._dead, 0.0, 1.0)
            return fallback if (dead_any or default_ones) else None
        # Unobserved (alive) slots fill in at the observed mean, then the
        # alive portion is normalised by its own mean; dead slots pin at 0.
        rate_full = np.where(seen, self._rate, mean)
        alive = ~self._dead
        alive_mean = float(rate_full[alive].mean())
        rel = rate_full / alive_mean
        rel = np.clip(rel, self.floor, 1.0 / self.floor)
        return np.where(self._dead, 0.0, rel)

    def seed(self, speeds: Sequence[float]) -> None:
        """Adopt a known relative-speed vector as the initial estimate.

        The warm-start hook: a process restoring a persisted
        :class:`~repro.core.schedule_cache.CachedSchedule` seeds the
        estimator with the snapshot's ``slot_speeds`` so the first drift
        check compares like with like instead of treating "no measurement
        yet" as unverifiable (:func:`speed_drift`'s conservative ``inf``).
        Counts as one observation; later measurements EWMA over it.
        """
        speeds = np.asarray(speeds, np.float64)
        if speeds.shape != (self.num_slots,):
            raise ValueError(
                f"expected ({self.num_slots},) speeds, got {speeds.shape}")
        if np.any(~np.isfinite(speeds)) or np.any(speeds < 0):
            raise ValueError(
                "seed speeds must be finite and >= 0 (0 = dead slot)")
        if not np.any(speeds > 0):
            raise ValueError("all slots dead: at least one speed must be > 0")
        # Exact zeros are dead-slot markers, not rates: they set the dead
        # mask (no rate history), matching normalize_speeds semantics.
        self._dead = speeds == 0.0
        self._rate = np.where(self._dead, np.nan, speeds)
        self.observations = 1

    def reset(self) -> None:
        """Forget every observation (speeds return to nominal).

        The dead mask survives — ``reset`` forgets *measurements*, not the
        mesh shape; use :meth:`set_slot_failure` to revive a slot.
        """
        self._rate = np.full(self.num_slots, np.nan)
        self.observations = 0

    # -- persistence (rides along CachedSchedule.to_json) -------------------

    def to_json(self) -> Dict[str, Any]:
        """Plain-type snapshot of the estimator state."""
        return {
            "num_slots": int(self.num_slots),
            "ewma": float(self.ewma),
            "floor": float(self.floor),
            "rate": [None if np.isnan(r) else float(r) for r in self._rate],
            "dead": [bool(d) for d in self._dead],
            "observations": int(self.observations),
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "SlotSpeedEstimator":
        """Rebuild an estimator from :meth:`to_json` output."""
        est = SlotSpeedEstimator(
            num_slots=int(d["num_slots"]),
            ewma=float(d["ewma"]),
            floor=float(d["floor"]),
        )
        est._rate = np.asarray(
            [np.nan if r is None else float(r) for r in d["rate"]], np.float64
        )
        dead = d.get("dead")  # absent in pre-elastic snapshots: all alive
        if dead is not None:
            est._dead = np.asarray([bool(x) for x in dead])
        est.observations = int(d["observations"])
        return est
