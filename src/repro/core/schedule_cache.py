"""Steady-state schedule reuse with drift detection (the ROADMAP serving item).

OS4M's schedule is a function of the measured key distribution, and key
distributions are stable across batches of one workload (Fan et al.,
arXiv:1401.0355; Rivas-Gomez et al., arXiv:1810.04146 decouple strategy
from execution on the same observation). This module decouples *planning*
from *execution*: a :class:`CachedSchedule` snapshots everything the host
produced for one plan — the Q||C_max assignment (with the per-slot speeds
it was built for), the §4.4 wave plan, the statistics-sized send
capacities, and the per-shard ``K^(i)`` histograms the plan was derived
from — and a :class:`ReusePolicy` decides per batch whether to replay
that snapshot or replan from fresh statistics. Replans trigger on *key*
drift (the distribution moved) or *speed* drift (a slot slowed past
``max_speed_drift`` — see :mod:`repro.core.slot_speeds`).

The decision is cheap by construction: the drift metric is computed
**on-device** from the phase-A histograms (one jnp reduction; only the
scalar crosses to the host), so a reused batch never pulls the full
``(m, n)`` statistics, never runs a scheduler, and — because the snapshot
pins the phase-B static shapes — always hits the job's jitted-executable
cache. The host scheduler leaves the hot path entirely.

Correctness backstop: a reused schedule's send capacities were sized from
*plan-time* statistics, so a sub-threshold drift could still overflow a
buffer. Phase B counts overflowed pairs exactly; the job treats a nonzero
count on a reused run as a forced replan + re-execution
(``capacity_fallbacks`` in :meth:`ScheduleCache.stats`), so outputs are
always exact. :class:`ReusePolicy.capacity_slack` sizes the headroom that
makes this rare.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import pipeline as pipe
from repro.core import scheduler as sched_lib
from repro.core import slot_speeds as ss

__all__ = [
    "DRIFT_METRICS",
    "drift_metric",
    "rebin_hist",
    "ReusePolicy",
    "ReuseDecision",
    "CachedSchedule",
    "ScheduleCache",
    "MultiTenantScheduleCache",
]

DRIFT_METRICS = ("l1", "chi2")


def drift_metric(ref_hist, new_hist, kind: str = "l1"):
    """Distance in ``[0, 1]`` between two key histograms.

    Both inputs are ``(n,)`` or ``(m, n)`` count arrays (``K`` or the
    per-shard ``K^(i)``); 2-D inputs score each shard's distribution
    separately and return the **max over shards** — the per-shard view is
    what the statistics-sized send capacities depend on, so it is the
    right conservative signal for reuse. Accepts jnp arrays and runs as a
    device reduction (only the scalar result crosses to the host) as well
    as plain numpy.

    ``kind="l1"``   — total variation: ``0.5 * sum |p - q|``.
    ``kind="chi2"`` — symmetric chi-square: ``0.5 * sum (p-q)^2 / (p+q)``.

    Rows are normalised to distributions first, so the metric sees shape
    change only — batch-size change alone is zero drift.
    """
    if kind not in DRIFT_METRICS:
        raise ValueError(f"unknown drift metric {kind!r}; use one of {DRIFT_METRICS}")
    p = jnp.asarray(ref_hist, jnp.float32)
    q = jnp.asarray(new_hist, jnp.float32)
    if p.ndim == 1:
        p = p[None, :]
    if q.ndim == 1:
        q = q[None, :]
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-9)
    q = q / jnp.maximum(q.sum(axis=-1, keepdims=True), 1e-9)
    if kind == "l1":
        per_shard = 0.5 * jnp.abs(p - q).sum(axis=-1)
    else:
        per_shard = 0.5 * ((p - q) ** 2 / jnp.maximum(p + q, 1e-9)).sum(axis=-1)
    return per_shard.max()


def rebin_hist(local_hist, new_m: int) -> np.ndarray:
    """Re-bin per-shard histograms ``(m, n) → (new_m, n)``, conserving mass.

    The elastic-mesh statistics re-projection: shard axes are treated as
    equal-width intervals of the same unit range (old shard ``i`` covers
    ``[i/m, (i+1)/m)``, new shard ``j`` covers ``[j/new_m, (j+1)/new_m)``)
    and each old row's counts are split across the new rows by fractional
    interval overlap. Per-cluster totals (the column sums — the global
    ``K`` the schedule is actually planned from) are preserved exactly up
    to float rounding, so a resized mesh replans from *warm* statistics
    instead of paying a cold measurement pass.

    Overlaps are computed on the common integer scale ``m * new_m`` so the
    weights are exact rationals (``overlap / new_m``), not accumulated
    float boundaries.
    """
    h = np.asarray(local_hist, np.float64)
    if h.ndim != 2:
        raise ValueError(f"local_hist must be (m, n), got {h.shape}")
    m = h.shape[0]
    if new_m < 1:
        raise ValueError("new_m must be >= 1")
    if new_m == m:
        return h.copy()
    out = np.zeros((new_m, h.shape[1]))
    for i in range(m):
        a, b = i * new_m, (i + 1) * new_m   # old row i on the common scale
        for j in range(a // m, -(-b // m)):
            c, d = j * m, (j + 1) * m       # new row j on the common scale
            ov = min(b, d) - max(a, c)
            if ov > 0:
                out[j] += h[i] * (ov / new_m)
    return out


@dataclasses.dataclass(frozen=True)
class ReusePolicy:
    """When may a cached schedule be replayed instead of replanned?

    ``max_drift``        — replan when the measured drift (``metric``)
                           between the plan-time and fresh ``K^(i)``
                           exceeds this threshold.
    ``max_age``          — replan after this many batches regardless of
                           drift (``None`` = never force; age counts
                           batches *executed with* the cached plan).
    ``revalidate_every`` — compute the drift metric only every k-th batch;
                           in between, reuse unconditionally. 1 = check
                           every batch.
    ``metric``           — ``"l1"`` (total variation) or ``"chi2"``.
    ``capacity_slack``   — fractional headroom added to the plan's send
                           capacities so sub-threshold drift rarely
                           overflows (overflow forces a replan + re-run).
    ``max_speed_drift``  — replan when any slot's measured relative speed
                           moved more than this fraction from the speeds
                           the plan was built for (a slot slowing 25%
                           re-creates the straggler tail the schedule was
                           supposed to kill; see
                           :func:`repro.core.slot_speeds.speed_drift`).
    ``cost_gate``        — with ``scheduler="auto"``: when drift trips,
                           first ask :func:`repro.core.simulator.
                           estimate_replan_benefit` whether a fresh plan
                           actually beats the stale schedule's expected
                           imbalance; if not, keep reusing (the drift
                           baseline is refreshed so the question is not
                           re-asked every batch).
    """

    max_drift: float = 0.15
    max_age: Optional[int] = None
    revalidate_every: int = 1
    metric: str = "l1"
    capacity_slack: float = 0.25
    max_speed_drift: float = 0.25
    cost_gate: bool = False

    def __post_init__(self):
        """Validate thresholds at construction (fail loud, not per batch)."""
        if self.max_drift < 0:
            raise ValueError("max_drift must be >= 0")
        if self.max_age is not None and self.max_age < 1:
            raise ValueError("max_age must be >= 1 (or None)")
        if self.revalidate_every < 1:
            raise ValueError("revalidate_every must be >= 1")
        if self.metric not in DRIFT_METRICS:
            raise ValueError(f"metric must be one of {DRIFT_METRICS}")
        if self.capacity_slack < 0:
            raise ValueError("capacity_slack must be >= 0")
        if self.max_speed_drift < 0:
            raise ValueError("max_speed_drift must be >= 0")


@dataclasses.dataclass(frozen=True)
class ReuseDecision:
    """One per-batch reuse-or-replan verdict (``JobResult.plan_reason`` echoes it).

    ``action`` is ``"reuse"`` or ``"replan"``; ``reason`` one of ``cold``
    (no snapshot yet), ``ok`` (drift under threshold), ``unchecked``
    (between revalidations), ``drift``, ``speed_drift`` (a slot's measured
    speed moved past ``max_speed_drift`` — the straggler trigger),
    ``slot_dead`` (the *set* of dead slots — exact-0.0 speeds — changed
    between plan time and now: a slot died or rejoined; structural, so it
    forces a replan regardless of how small the surviving slots' drift
    is, and is reported as itself rather than as ``inf`` speed drift),
    ``max_age``, ``cost_gate`` (drift tripped but the simulator found
    replanning not worth it), ``overflow`` (a reused run overflowed its
    capacities and was re-run). ``drift`` is the measured key-distribution
    metric and ``speed_drift`` the measured slot-speed change, when they
    were computed this batch.
    """

    action: str
    reason: str
    drift: Optional[float] = None
    speed_drift: Optional[float] = None


@dataclasses.dataclass
class CachedSchedule:
    """Everything phase B needs to replay one plan, plus its provenance.

    The snapshot is self-contained: ``schedule`` + ``waves`` + the
    capacities fully determine phase B's static shapes (the jit-cache
    key), and ``local_hist`` is the per-shard statistics the plan was
    derived from — the drift reference. ``key_dist`` is its shard-sum.

    Contract (checked by ``repro.analysis --check plan``): the
    :meth:`to_json` / :meth:`from_json` pair is a lossless fixed point,
    and every ``chunk_caps`` entry clears the per-(shard, dest) worst
    case recomputed from the snapshot's own statistics — exact
    histograms for ``stats_provider == "exact"``, count-min estimates
    (rebuilt from ``stats_params``) for ``"sketch"`` — so a persisted
    plan must replay with the shapes it was planned with. Sketch
    snapshots store the raw counter cells in ``local_hist`` (shape
    ``(m, depth * width)``), which keeps the device-resident drift
    metric working unchanged, and carry ``key_dist`` explicitly in JSON
    (it is an estimate, not a column sum of the cells).
    """

    schedule: sched_lib.Schedule
    strategy: str
    strategy_costs: Optional[Dict[str, float]]
    waves: pipe.WavePlan
    capacity: int                    # sequential-path per-(shard,dest) cap
    chunk_caps: Tuple[int, ...]      # per-wave caps (pipelined path)
    local_hist: np.ndarray           # (m, n) plan-time K^(i) (or sketch cells)
    key_dist: np.ndarray             # (n,)  plan-time K (exact or estimated)
    age: int = 0                     # batches executed with this plan
    batches_since_check: int = 0
    k_per_shard: Optional[int] = None  # plan-time pairs per shard (resize scaling)
    stats_provider: str = "exact"    # which provider produced local_hist
    stats_params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # True when every capacity in this plan came from overestimate-only
    # statistics (exact counts or a pure count-min read with intact f32
    # guard) — such caps can never under-provision. False only for
    # estimate-committed caps (prefix-planned wave 1), which instead arm
    # the overflow escape hatch below.
    stats_overestimate: bool = True
    # True when a chunk capacity was committed from a prefix estimate and
    # may under-provision; the runner's overflow escape hatch
    # (``MapReduceJob._escalate_caps``) watches this flag.
    caps_estimated: bool = False
    _hist_dev: Any = dataclasses.field(default=None, repr=False)

    @property
    def slot_speeds(self) -> np.ndarray:
        """The per-slot relative speeds this plan was built for (Q||C_max)."""
        return self.schedule.slot_speeds

    def hist_device(self, put=None):
        """The plan-time histograms as a device array (lazily uploaded once).

        ``put`` optionally controls the placement of the one upload (e.g.
        ``jax.device_put`` with a mesh sharding so the baseline lives
        shard-per-device next to the fresh phase-A histograms); the
        resulting buffer stays resident between batches — reused drift
        checks never re-upload the baseline.
        """
        if self._hist_dev is None:
            h = np.asarray(self.local_hist, np.float32)
            self._hist_dev = put(h) if put is not None else jnp.asarray(h)
        return self._hist_dev

    def refresh_baseline(self, local_hist: np.ndarray,
                         key_dist: Optional[np.ndarray] = None) -> None:
        """Re-anchor the drift reference without replanning (cost-gated reuse).

        ``key_dist`` must be supplied when ``local_hist`` is provider
        state whose global distribution is not its column sum (sketch
        cells); exact callers can omit it.
        """
        self.local_hist = np.asarray(local_hist)
        self.key_dist = (self.local_hist.sum(axis=0) if key_dist is None
                         else np.asarray(key_dist))
        self._hist_dev = None

    def reproject(self, new_num_slots: int, planner) -> "CachedSchedule":
        """Re-project this snapshot onto a different slot count (elastic mesh).

        Instead of discarding warm state on a resize, the per-shard
        ``K^(i)`` baseline is re-binned onto the new shard count
        (:func:`rebin_hist` — per-cluster mass preserved) and ``planner``
        — the job's ``_plan``-shaped callable
        ``planner(local_hist, key_dist, k_per_shard, prev)`` — is invoked
        once on the re-binned statistics to rebuild assignment, wave plan
        and capacities for the new mesh. The result is a fully executable
        snapshot whose drift baseline is the re-binned history, so the
        next batch's decide() compares against warm statistics (and
        reuses, when the workload is stationary) rather than starting
        cold. ``k_per_shard`` is re-scaled so total plan-time pairs are
        conserved (``ceil(k · m / new_m)``).
        """
        if new_num_slots < 1:
            raise ValueError("new_num_slots must be >= 1")
        old_m = int(self.local_hist.shape[0])
        if new_num_slots == old_m:
            return self
        new_hist = rebin_hist(self.local_hist, new_num_slots)
        k = self.k_per_shard
        if k is None:  # pre-elastic snapshot: bound from the statistics
            k = int(np.ceil(self.local_hist.sum(axis=1).max()))
        new_k = int(np.ceil(k * old_m / new_num_slots))
        snap = planner(new_hist, new_hist.sum(axis=0), new_k, None)
        snap.k_per_shard = new_k
        return snap

    def to_json(self) -> Dict[str, Any]:
        """Serialize plan + provenance (not the device mirror) to plain types.

        Sketch snapshots additionally serialize ``key_dist`` — for exact
        snapshots it is recomputed from ``local_hist`` on load, but a
        sketch's global distribution is an estimate, not a column sum of
        its counter cells.
        """
        out = {
            "assignment": self.schedule.assignment.tolist(),
            "num_slots": int(self.schedule.num_slots),
            "slot_speeds": [float(s) for s in self.schedule.slot_speeds],
            "strategy": self.strategy,
            "waves": self.waves.to_json(),
            "capacity": int(self.capacity),
            "chunk_caps": [int(c) for c in self.chunk_caps],
            "local_hist": self.local_hist.tolist(),
            "age": int(self.age),
            "k_per_shard": None if self.k_per_shard is None
            else int(self.k_per_shard),
            "stats": {
                "provider": self.stats_provider,
                "params": dict(self.stats_params),
                "overestimate": bool(self.stats_overestimate),
                "caps_estimated": bool(self.caps_estimated),
            },
        }
        if self.stats_provider != "exact":
            out["key_dist"] = [float(v) for v in np.asarray(self.key_dist)]
        return out

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "CachedSchedule":
        """Rebuild a snapshot from :meth:`to_json` output."""
        local_hist = np.asarray(d["local_hist"], np.float64)
        stats = d.get("stats", {})
        provider = stats.get("provider", "exact")
        if "key_dist" in d:
            key_dist = np.asarray(d["key_dist"], np.float64)
        else:
            key_dist = local_hist.sum(axis=0)
        schedule = sched_lib.Schedule.from_assignment(
            np.asarray(d["assignment"], np.int32), key_dist, int(d["num_slots"]),
            speeds=d.get("slot_speeds"),
        )
        return CachedSchedule(
            schedule=schedule,
            strategy=d["strategy"],
            strategy_costs=None,
            waves=pipe.WavePlan.from_json(d["waves"]),
            capacity=int(d["capacity"]),
            chunk_caps=tuple(int(c) for c in d["chunk_caps"]),
            local_hist=local_hist,
            key_dist=key_dist,
            age=int(d.get("age", 0)),
            k_per_shard=(None if d.get("k_per_shard") is None
                         else int(d["k_per_shard"])),
            stats_provider=provider,
            stats_params=dict(stats.get("params", {})),
            stats_overestimate=bool(stats.get("overestimate", True)),
            caps_estimated=bool(stats.get("caps_estimated", False)),
        )


class ScheduleCache:
    """Per-job reuse state: the live snapshot, the policy, and telemetry.

    ``drift_fn`` (optional) overrides the default drift computation with a
    backend-resident one: called as ``drift_fn(snapshot, fresh_hist)`` and
    expected to return the scalar metric. The shard_map backend installs a
    jitted per-device reduction here (baseline histogram kept sharded on
    the mesh between batches, only the scalar crosses to the host —
    :meth:`repro.core.mapreduce.MapReduceJob`); the default path uploads
    the baseline once and runs a plain jnp reduction.
    """

    def __init__(self, policy: ReusePolicy, drift_fn=None):
        self.policy = policy
        self.drift_fn = drift_fn
        self.snapshot: Optional[CachedSchedule] = None
        self.replans = 0
        self.reuses = 0
        self.drift_checks = 0
        self.capacity_fallbacks = 0
        self.speed_replans = 0
        self.dead_replans = 0
        self.reprojections = 0
        self.last_drift: Optional[float] = None
        self.last_speed_drift: Optional[float] = None
        self.last_decision: Optional[ReuseDecision] = None

    def decide(self, fresh_local_hist, fresh_speeds=None) -> ReuseDecision:
        """Reuse-or-replan for one batch, given phase A's fresh ``K^(i)``.

        ``fresh_local_hist`` may be a device array — the drift reduction
        then runs on-device and only the scalar is pulled. ``fresh_speeds``
        is the current per-slot speed estimate; a slot whose measured
        speed moved more than ``max_speed_drift`` from the plan-time
        speeds forces a replan even when the key distribution is perfectly
        stationary — the straggler trigger. ``fresh_speeds=None`` means
        *no measurement*: against a plan built for nominal speeds that is
        no evidence of change (drift 0), but against a plan built for
        **measured, non-nominal** speeds it is conservative — the plan's
        heterogeneity assumption can no longer be verified (an estimator
        ``reset()``), so :func:`repro.core.slot_speeds.speed_drift`
        returns ``inf`` and the plan is revalidated by a replan. Check
        order: cold → max_age → revalidation cadence → dead-slot mask →
        speed drift → key drift.

        Dead slots are checked *structurally* before any ratio math: when
        the set of exact-0.0 speeds differs between the plan and
        ``fresh_speeds`` (a slot died or rejoined), the verdict is a
        forced replan with reason ``"slot_dead"`` — never an ``inf``
        "speed drift" that would be indistinguishable from measurement
        noise in telemetry.
        """
        p, s = self.policy, self.snapshot
        if s is None:
            return ReuseDecision("replan", "cold")
        if p.max_age is not None and s.age >= p.max_age:
            return ReuseDecision("replan", "max_age")
        if p.revalidate_every > 1 and s.batches_since_check + 1 < p.revalidate_every:
            s.batches_since_check += 1
            return ReuseDecision("reuse", "unchecked")
        s.batches_since_check = 0
        if fresh_speeds is not None:
            fresh_arr = np.asarray(fresh_speeds, np.float64)
            ref_dead = np.asarray(s.slot_speeds, np.float64) == 0.0
            if (fresh_arr.shape == ref_dead.shape
                    and np.any((fresh_arr == 0.0) != ref_dead)):
                self.dead_replans += 1
                return ReuseDecision("replan", "slot_dead")
        sd = ss.speed_drift(s.slot_speeds, fresh_speeds)
        self.last_speed_drift = sd
        if sd > p.max_speed_drift:
            self.speed_replans += 1
            return ReuseDecision("replan", "speed_drift", speed_drift=sd)
        if self.drift_fn is not None:
            d = float(self.drift_fn(s, fresh_local_hist))
        else:
            d = float(drift_metric(s.hist_device(), fresh_local_hist, p.metric))
        self.drift_checks += 1
        self.last_drift = d
        if d > p.max_drift:
            return ReuseDecision("replan", "drift", d, speed_drift=sd)
        return ReuseDecision("reuse", "ok", d, speed_drift=sd)

    def record(self, decision: ReuseDecision) -> None:
        """Count the decision and age the snapshot on reuse."""
        self.last_decision = decision
        if decision.action == "reuse":
            self.reuses += 1
            if self.snapshot is not None:
                self.snapshot.age += 1
        else:
            self.replans += 1

    def store(self, snapshot: CachedSchedule) -> None:
        """Install a freshly planned snapshot (age and cadence reset)."""
        snapshot.age = 0
        snapshot.batches_since_check = 0
        self.snapshot = snapshot

    def stats(self) -> Dict[str, Any]:
        """Telemetry counters (replan rate is ``replans / batches``)."""
        batches = self.replans + self.reuses
        return {
            "batches": batches,
            "replans": self.replans,
            "reuses": self.reuses,
            "drift_checks": self.drift_checks,
            "capacity_fallbacks": self.capacity_fallbacks,
            "speed_replans": self.speed_replans,
            "dead_replans": self.dead_replans,
            "reprojections": self.reprojections,
            "replan_rate": self.replans / batches if batches else 0.0,
            "last_drift": self.last_drift,
            "last_speed_drift": self.last_speed_drift,
        }


class MultiTenantScheduleCache:
    """Per-job keyed :class:`ScheduleCache` snapshots — one cache, N tenants.

    The multi-job coordinator gives each live job its own isolated
    :class:`ScheduleCache` under a string key; snapshots, drift baselines
    and telemetry never cross tenants (job A's plan is useless for job B's
    key distribution, and silently replaying it would be a correctness
    bug, not an optimisation). Isolation is by construction — every
    tenant holds distinct objects — and :meth:`collisions` *measures* it,
    so the multijob CI gate can assert zero rather than trust the
    construction.
    """

    def __init__(self, policy: Optional[ReusePolicy] = None):
        self.default_policy = policy
        self._tenants: Dict[str, ScheduleCache] = {}

    def tenant(
        self,
        key: str,
        policy: Optional[ReusePolicy] = None,
        drift_fn=None,
    ) -> ScheduleCache:
        """The tenant's cache, created on first use (then args must agree).

        A second caller reaching for an existing key with a *different*
        policy object is almost certainly two jobs colliding on one key;
        that raises instead of silently sharing state.
        """
        cache = self._tenants.get(key)
        if cache is None:
            pol = policy if policy is not None else self.default_policy
            if pol is None:
                raise ValueError(
                    f"tenant {key!r}: no policy given and no default_policy")
            cache = ScheduleCache(pol, drift_fn=drift_fn)
            self._tenants[key] = cache
            return cache
        if policy is not None and cache.policy is not policy:
            raise ValueError(
                f"tenant key collision: {key!r} already exists with a "
                "different ReusePolicy — two jobs must not share one key")
        if drift_fn is not None:
            cache.drift_fn = drift_fn
        return cache

    def adopt(self, key: str, cache: ScheduleCache) -> ScheduleCache:
        """Register an existing per-job cache under a tenant key.

        Used when a job arrives already owning its ScheduleCache (built
        from ``MapReduceConfig.reuse``): the coordinator keys it rather
        than replacing it, so warm snapshots survive admission. Adopting
        a *different* cache under a live key is a collision and raises.
        """
        existing = self._tenants.get(key)
        if existing is not None and existing is not cache:
            raise ValueError(
                f"tenant key collision: {key!r} already holds another cache")
        self._tenants[key] = cache
        return cache

    def keys(self):
        """Tenant keys currently live (insertion order)."""
        return list(self._tenants)

    def collisions(self) -> int:
        """Snapshot objects shared between two tenants (must be 0).

        Counts pairs of distinct tenants whose live ``snapshot`` (or the
        snapshot's device-resident baseline histogram) is the *same
        object* — the observable form of a cross-job cache collision.
        """
        shared = 0
        items = list(self._tenants.values())
        for a in range(len(items)):
            for b in range(a + 1, len(items)):
                sa, sb = items[a].snapshot, items[b].snapshot
                if sa is None or sb is None:
                    continue
                if sa is sb or (sa._hist_dev is not None
                                and sa._hist_dev is sb._hist_dev):
                    shared += 1
        return shared

    def stats(self) -> Dict[str, Any]:
        """Aggregate + per-tenant telemetry (collision count included)."""
        per = {k: c.stats() for k, c in self._tenants.items()}
        agg = {
            "tenants": len(per),
            "collisions": self.collisions(),
            "batches": sum(s["batches"] for s in per.values()),
            "replans": sum(s["replans"] for s in per.values()),
            "reuses": sum(s["reuses"] for s in per.values()),
        }
        agg["per_tenant"] = per
        return agg
