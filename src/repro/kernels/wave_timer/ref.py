"""Pure host oracle for the wave-timer tick format.

A *tick stamp* is a pair of ``uint32`` words ``(lo, hi)`` holding one
64-bit monotone counter sample — the widest integer a jitted program can
return without ``jax_enable_x64`` (device-side callbacks and most TPU
cycle counters cannot emit i64 directly). The reference tick source is
the host's ``time.perf_counter_ns`` (monotone, ns resolution); the device
kernel substitutes its own cycle counter but keeps the word format, so
every consumer goes through :func:`combine_ticks` and never cares which
clock produced the words.
"""

from __future__ import annotations

import time

import numpy as np

__all__ = ["read_ticks_ref", "split_ticks", "combine_ticks"]

_WORD = np.uint64(0xFFFFFFFF)
_SHIFT = np.uint64(32)


def read_ticks_ref() -> np.ndarray:
    """One host tick stamp: ``perf_counter_ns`` split into (lo, hi) words."""
    return split_ticks(time.perf_counter_ns())


def split_ticks(ticks) -> np.ndarray:
    """Split 64-bit counter value(s) into trailing ``(..., 2)`` uint32 words."""
    t = np.asarray(ticks, np.uint64)
    return np.stack([t & _WORD, t >> _SHIFT], axis=-1).astype(np.uint32)


def combine_ticks(words) -> np.ndarray:
    """Recombine ``(..., 2)`` uint32 (lo, hi) words into int64 counter values.

    Inverse of :func:`split_ticks`. int64 (not uint64) so downstream
    arithmetic — tick *differences* — is ordinary signed math;
    ``perf_counter_ns`` and realistic cycle counts fit comfortably.
    """
    w = np.asarray(words, np.uint64)
    if w.shape[-1] != 2:
        raise ValueError(f"expected trailing (lo, hi) word axis, got {w.shape}")
    return ((w[..., 0] | (w[..., 1] << _SHIFT))).astype(np.int64)
