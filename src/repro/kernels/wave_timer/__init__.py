# On-device wave timers for the measured phase-B executor.
#
#   wave_timer.py  — the Pallas tick kernel (device cycle counter when the
#                    toolchain exposes one; host-clock callback body in
#                    interpret mode) + tick word format helpers
#   ops.py         — backend resolution + the jit-safe read_ticks() op the
#                    measured executor stamps waves with
#   ref.py         — pure host oracle (perf_counter ticks, word packing)
#   calibration.py — ticks -> seconds conversion + host-bracketed calibrate()
