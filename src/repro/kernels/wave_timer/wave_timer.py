"""Pallas tick kernel — stamp a per-device clock inside a jitted program.

The measured phase-B executor (``repro.core.mapreduce``) wants one
monotone counter sample *per device, at a chosen point of the program's
data flow* — immediately before and after each §4.4 wave's shard-local
reduce — without fencing the program into per-wave dispatches. That is a
kernel-level concern: the stamp must execute on the device, ordered by
data dependencies only.

Tick source resolution (compile-time, per process):

* **Device cycle counter** — when the installed Pallas/Mosaic toolchain
  exposes one (probed by name in :func:`device_tick_primitive`; jax
  generations disagree on where it lives, and the 0.4.x line this
  container ships has none). The kernel writes the counter's (lo, hi)
  uint32 words — see :mod:`repro.kernels.wave_timer.ref` for the format —
  and :mod:`.calibration` measures its seconds-per-tick once.
* **Interpret / CPU fallback** — the kernel body degrades to a host
  ``perf_counter_ns`` callback (per *virtual* device: under
  ``shard_map`` each shard's program invokes its own callback, so forced
  host devices still get per-slot stamps). Seconds-per-tick is exactly
  1e-9, no calibration needed.

Two kernels (the "kernel pair"):

* :func:`read_ticks_pallas` — a (1,) anchor in, a (2,) word pair out.
  The anchor is the ordering handle: its *value* is ignored, but the
  stamp cannot execute before whatever computed it.
* :func:`stamp_through_pallas` — copy a primary buffer verbatim AND
  stamp the clock in the same kernel execution. The copy is what pins
  the stamp *before* downstream compute: the consumer reads the
  kernel's output buffer, so no scheduler can defer the stamp past it
  (an anchor alone only orders the stamp *after* its inputs — see
  ``ops.stamp_through`` for the full ordering story).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis import allowlist as _allowlist
from repro.kernels.wave_timer import ref as wt_ref

__all__ = ["device_tick_primitive", "read_ticks_pallas",
           "stamp_through_pallas"]


# Interpret-mode kernels stamp the host clock through this one body —
# registered with the contract analyzer's allowlist (the jaxpr-level
# declaration) and marked at each call site (the source-level one).
@_allowlist.allow_callback
def _host_ticks(_anchor):
    """Callback body: one host perf_counter_ns stamp as (lo, hi) words."""
    return wt_ref.read_ticks_ref()

# Names a device cycle counter has gone by across Pallas-TPU generations.
# Probed, never imported directly: absence means "no device counter" and
# the caller falls back (CPU callback ticks, or host-fenced timing).
_DEVICE_TICK_CANDIDATES = ("cycle_count", "read_cycle_count", "clock")


def device_tick_primitive():
    """The device cycle-counter primitive, or ``None`` on this toolchain."""
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:                     # pragma: no cover - no pallas tpu
        return None
    for name in _DEVICE_TICK_CANDIDATES:
        fn = getattr(pltpu, name, None)
        if fn is not None:
            return fn
    return None


def _split_counter_words(t) -> jnp.ndarray:
    """Split a counter sample into ``(2,)`` (lo, hi) uint32 words.

    Deliberately avoids 64-bit jnp lanes: without ``jax_enable_x64``,
    ``jnp.uint64`` silently canonicalizes to uint32, which would zero the
    hi word and wrap the counter every 2^32 ticks. The split stays in the
    counter's native dtype — a 64-bit counter masks/shifts losslessly, a
    32-bit counter gets an explicit zero hi word (its wrap period is then
    the genuine hardware limit; ``WaveTimings.from_ticks`` flags wrapped
    intervals as invalid).
    """
    t = jnp.asarray(t).reshape(())
    if t.dtype.itemsize == 8:
        mask = t.dtype.type(0xFFFFFFFF)
        shift = t.dtype.type(32)
        lo = (t & mask).astype(jnp.uint32)
        hi = (t >> shift).astype(jnp.uint32)
    else:
        lo = t.astype(jnp.uint32)
        hi = jnp.zeros((), jnp.uint32)
    return jnp.stack([lo, hi])


def _tick_kernel_device(anchor_ref, out_ref, *, counter):
    """Compiled body: split the device cycle counter into (lo, hi) words."""
    del anchor_ref                          # ordering handled by pallas_call dep
    out_ref[...] = _split_counter_words(counter())


def _tick_kernel_host(anchor_ref, out_ref):
    """Interpret body: stamp the host clock via a pure callback.

    Interpret mode evaluates the kernel body as ordinary traced jax, so a
    host callback is legal here; a compiled TPU kernel could never take
    this path (``read_ticks_pallas`` refuses the combination).
    """
    out_ref[...] = jax.pure_callback(  # analysis: allow-callback
        _host_ticks,
        jax.ShapeDtypeStruct((2,), jnp.uint32),
        anchor_ref[0],
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def read_ticks_pallas(anchor, *, interpret: bool = True) -> jax.Array:
    """One tick stamp as ``(2,)`` uint32 (lo, hi) words.

    ``anchor`` is any scalar/array whose *computation* must precede the
    stamp — the kernel consumes it as input so the stamp cannot be hoisted
    above it. With ``interpret=False`` a device cycle counter is required
    (``RuntimeError`` when the toolchain has none).
    """
    counter = device_tick_primitive()
    if not interpret and counter is None:
        raise RuntimeError(
            "no device cycle-counter primitive in this Pallas toolchain; "
            "wave_timer ticks are interpret/CPU-only here"
        )
    kernel = (_tick_kernel_host if counter is None
              else functools.partial(_tick_kernel_device, counter=counter))
    a = jnp.asarray(anchor, jnp.float32).reshape(-1)[:1]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((2,), jnp.uint32),
        interpret=interpret,
    )(a)


def _stamp_through_kernel_device(primary_ref, *rest, counter):
    """Compiled body: verbatim copy of the primary + one counter stamp."""
    *_anchors, out_ref, tick_ref = rest
    out_ref[...] = primary_ref[...]
    tick_ref[...] = _split_counter_words(counter())


def _stamp_through_kernel_host(primary_ref, *rest):
    """Interpret body: verbatim copy + a host-clock callback stamp."""
    anchors = rest[:-2]
    out_ref, tick_ref = rest[-2:]
    out_ref[...] = primary_ref[...]
    a = anchors[0][0] if anchors else primary_ref[0]
    tick_ref[...] = jax.pure_callback(  # analysis: allow-callback
        _host_ticks,
        jax.ShapeDtypeStruct((2,), jnp.uint32), a,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def stamp_through_pallas(primary, *anchors, interpret: bool = True):
    """Copy ``primary`` bit-identically and stamp the clock in one kernel.

    Returns ``(primary_copy, ticks)``. ``anchors`` are additional inputs
    the stamp must wait for (their values are ignored). With
    ``interpret=False`` a device cycle counter is required.
    """
    counter = device_tick_primitive()
    if not interpret and counter is None:
        raise RuntimeError(
            "no device cycle-counter primitive in this Pallas toolchain; "
            "wave_timer ticks are interpret/CPU-only here"
        )
    kernel = (_stamp_through_kernel_host if counter is None
              else functools.partial(_stamp_through_kernel_device,
                                     counter=counter))
    flat_anchors = tuple(
        jnp.asarray(a, jnp.float32).reshape(-1)[:1] for a in anchors
    )
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct(primary.shape, primary.dtype),
                   jax.ShapeDtypeStruct((2,), jnp.uint32)),
        interpret=interpret,
    )(primary, *flat_anchors)
