"""Tick → seconds conversion for the wave-timer counters.

Tick *differences* are only useful to the slot-speed estimator once they
are wall-clock seconds, and the seconds-per-tick scale depends on the
tick source: host ``perf_counter_ns`` ticks are exactly 1e-9 s by
definition, while a device cycle counter runs at an opaque (and
per-part) frequency that must be *measured* once. :func:`calibrate`
brackets the device counter with host sleeps — read ticks, sleep a known
interval, read again, take the median implied scale — which is accurate
to the dispatch overhead over the sleep length (≲2% at the defaults) and
needs no hardware documentation.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

__all__ = ["TickCalibration", "HOST_NS", "calibrate"]


@dataclasses.dataclass(frozen=True)
class TickCalibration:
    """A tick unit: ``seconds_per_tick`` plus the two conversions."""

    seconds_per_tick: float
    source: str = "unknown"     # "host-ns" | "device" | test labels

    def __post_init__(self):
        """Reject non-positive or non-finite scales at construction."""
        spt = self.seconds_per_tick
        if not (np.isfinite(spt) and spt > 0):
            raise ValueError(f"seconds_per_tick must be finite > 0, got {spt}")

    def ticks_to_seconds(self, ticks) -> np.ndarray:
        """Tick counts/differences → float64 seconds."""
        return np.asarray(ticks, np.float64) * self.seconds_per_tick

    def seconds_to_ticks(self, seconds) -> np.ndarray:
        """Seconds → nearest whole tick counts (int64)."""
        return np.rint(
            np.asarray(seconds, np.float64) / self.seconds_per_tick
        ).astype(np.int64)


#: The CPU/interpret fallback unit — ``perf_counter_ns`` ticks.
HOST_NS = TickCalibration(1e-9, source="host-ns")


def calibrate(read_ticks_fn, *, sleep_seconds: float = 0.02,
              repeats: int = 5) -> TickCalibration:
    """Measure seconds-per-tick of an opaque counter by host bracketing.

    ``read_ticks_fn()`` must return one *combined* int64 tick value (see
    :func:`repro.kernels.wave_timer.ref.combine_ticks`) and block until
    the stamp is real (device reads must sync). Each repeat times a host
    ``sleep`` between two stamps; the median ratio rejects outlier
    repeats that hit a scheduler hiccup.
    """
    scales = []
    for _ in range(max(int(repeats), 1)):
        t0 = time.perf_counter()
        a = int(read_ticks_fn())
        time.sleep(sleep_seconds)
        b = int(read_ticks_fn())
        t1 = time.perf_counter()
        if b > a:
            scales.append((t1 - t0) / (b - a))
    if not scales:
        raise RuntimeError("tick counter never advanced during calibration")
    return TickCalibration(float(np.median(scales)), source="device")
