"""Public wave-timer ops: jit-safe per-device tick stamps + their unit.

Two ops, one per ordering constraint the measured executor needs:

* ``stamp_through(primary, *anchors)`` → ``(primary, ticks)`` — the op
  the executor brackets waves with. The stamp is pinned by **true
  buffer dependencies on both sides**: it *consumes* every anchor (it
  cannot fire before the previous wave's outputs exist) and *produces*
  the very buffer the next wave's reduce reads (the scheduler cannot
  defer it past the compute it precedes). This matters: XLA:CPU's
  scheduler places instructions as late as their consumers allow, and
  neither ``optimization_barrier`` nor a value-anchored "pure" callback
  constrains it (a pure callback may even be *duplicated*, stamping a
  second time at some arbitrary later point) — both failure modes were
  observed, which is why the pass-through design exists. The primary is
  returned bit-identically.
* ``read_ticks(*anchors)`` → ``(2,)`` uint32 (lo, hi) stamp — the
  anchor-only flavour for calibration and telemetry, where ordering
  only needs to follow completed host-visible steps.

Both are exactly-once (``io_callback`` on the CPU path — effectful, so
never duplicated or dropped), safe anywhere in a jitted /
``shard_map``-ed program; under ``shard_map`` every shard stamps its
*own* device clock.

Backend resolution (process-wide, probed once per call site — cheap):

* ``"device"``  — compiled Pallas kernels (copy + cycle-counter stamp).
  Requires a toolchain primitive
  (:func:`repro.kernels.wave_timer.wave_timer.device_tick_primitive`)
  and compiled (non-interpret) kernels; calibrated on first use.
* ``"callback"`` — the interpret/CPU fallback: a per-shard
  ``perf_counter_ns`` host callback (unit exactly 1e-9 s/tick). Correct
  on CPU, where every "device" is a host thread; on a real accelerator a
  host callback would fence the stream, so it is *not* offered there.
* ``"none"``    — no usable tick source (e.g. a TPU whose toolchain has
  no counter primitive). ``available()`` is False and the measured
  executor falls back to host-fenced timing
  (:func:`repro.core.mesh_timing.shard_ready_seconds`).
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro import kernels as _k
from repro.analysis import allowlist as _allowlist
from repro.kernels.wave_timer import calibration as _cal
from repro.kernels.wave_timer import ref as wt_ref
from repro.kernels.wave_timer import wave_timer as _wt

__all__ = ["backend", "available", "read_ticks", "stamp_through",
           "combine_ticks", "tick_calibration", "force_backend"]

# Test/bench override: force_backend("none") drills the host-fenced
# fallback without uninstalling the tick source.
_FORCED: Optional[str] = None

_TICK_SHAPE = jax.ShapeDtypeStruct((2,), jnp.uint32)

combine_ticks = wt_ref.combine_ticks


def backend() -> str:
    """Resolve the tick backend: ``"device"`` | ``"callback"`` | ``"none"``."""
    if _FORCED is not None:
        return _FORCED
    if not _k.INTERPRET and _wt.device_tick_primitive() is not None:
        return "device"
    if jax.default_backend() == "cpu":
        return "callback"
    return "none"


def available() -> bool:
    """True when on-device (or CPU-fallback) tick stamps can be read."""
    return backend() != "none"


# The wave-timer stamps are the engine's ONE sanctioned host callback:
# registered with the contract analyzer's allowlist at the definition,
# so `repro.analysis --check determinism` certifies that nothing else in
# a traced phase-B program crosses the host boundary.
@_allowlist.allow_callback
def _host_stamp(*_anchors) -> np.ndarray:
    """The callback body: one host perf_counter_ns stamp as (lo, hi) words."""
    return wt_ref.read_ticks_ref()


@_allowlist.allow_callback
def _host_stamp_through(primary, *_anchors):
    """Callback body: pass ``primary`` through untouched + one stamp."""
    return np.asarray(primary), wt_ref.read_ticks_ref()


def read_ticks(*anchors) -> jax.Array:
    """One per-device tick stamp ``(2,)`` uint32, ordered after ``anchors``.

    Exactly-once and ordered *after* its anchors (it consumes them), but
    a scheduler may still defer it until its ticks output is needed — use
    :func:`stamp_through` to pin a stamp *before* a computation. Raises
    ``RuntimeError`` when no backend is available — callers gate on
    :func:`available` and fall back to host-fenced timing instead.
    """
    b = backend()
    if b == "device":
        a = anchors[0] if anchors else jnp.float32(0)
        for extra in anchors[1:]:           # fold every anchor into the dep
            a = a + jnp.asarray(extra, jnp.float32).reshape(-1)[0] * 0
        return _wt.read_ticks_pallas(a, interpret=False)
    if b == "callback":
        if not anchors:
            anchors = (jnp.float32(0),)
        return io_callback(_host_stamp, _TICK_SHAPE, *anchors,
                           ordered=False)  # analysis: allow-callback
    raise RuntimeError("no wave-timer tick backend on this platform")


def stamp_through(primary, *anchors):
    """Stamp the device clock *between* two computations, exactly once.

    Returns ``(primary, ticks)`` where ``primary`` comes back
    bit-identical. The stamp consumes every ``anchor`` (true reads — it
    cannot execute before they exist) and produces the returned
    ``primary`` buffer — feed that to the downstream computation and the
    stamp cannot be deferred past it either. This double-sided pinning is
    what makes in-program wave timing honest; see the module docstring
    for why weaker orderings (``optimization_barrier``, pure callbacks)
    are not enough.
    """
    b = backend()
    if b == "device":
        return _wt.stamp_through_pallas(primary, *anchors, interpret=False)
    if b == "callback":
        # Only the leading row crosses the host (bytes, not buffers): the
        # callback passes ``primary[:1]`` through verbatim and the result
        # is stitched back with a device-side concatenate. Every consumer
        # of the stitched array now depends on the callback's output, so
        # the ordering is as strong as passing the whole buffer — without
        # round-tripping it through host memory.
        head = jax.lax.slice_in_dim(primary, 0, 1, axis=0)
        shapes = (jax.ShapeDtypeStruct(head.shape, head.dtype), _TICK_SHAPE)
        passed, ticks = io_callback(  # analysis: allow-callback
            _host_stamp_through, shapes, head, *anchors, ordered=False)
        if primary.shape[0] <= 1:
            return passed, ticks
        rest = jax.lax.slice_in_dim(primary, 1, primary.shape[0], axis=0)
        return jax.lax.concatenate([passed, rest], 0), ticks
    raise RuntimeError("no wave-timer tick backend on this platform")


class force_backend:
    """Context manager pinning :func:`backend` (tests / fallback drills)."""

    def __init__(self, name: Optional[str]):
        if name not in (None, "device", "callback", "none"):
            raise ValueError(f"unknown wave-timer backend {name!r}")
        self._name = name
        self._prev: Optional[str] = None

    def __enter__(self):
        global _FORCED
        self._prev, _FORCED = _FORCED, self._name
        return self

    def __exit__(self, *exc):
        global _FORCED
        _FORCED = self._prev
        return False


_CALIBRATION_CACHE: dict = {}


def tick_calibration() -> _cal.TickCalibration:
    """The current backend's tick unit (calibrated once for ``"device"``)."""
    b = backend()
    if b == "callback":
        return _cal.HOST_NS
    if b == "device":
        cached = _CALIBRATION_CACHE.get(b)
        if cached is None:
            def _read() -> int:
                words = jax.device_get(read_ticks(jnp.float32(time.monotonic())))
                return int(wt_ref.combine_ticks(np.asarray(words)))
            cached = _CALIBRATION_CACHE[b] = _cal.calibrate(_read)
        return cached
    raise RuntimeError("no wave-timer tick backend to calibrate")
