"""Pure-jnp oracle for the dispatch kernel."""

import jax.numpy as jnp


def dispatch_ranks_ref(dest, num_dests: int):
    dest = dest.astype(jnp.int32)
    valid = (dest >= 0) & (dest < num_dests)
    d = jnp.where(valid, dest, num_dests)
    onehot = (d[:, None] == jnp.arange(num_dests)[None, :]).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    rank = jnp.where(valid, jnp.sum(excl * onehot, axis=1), -1)
    counts = jnp.sum(onehot, axis=0)
    return rank.astype(jnp.int32), counts.astype(jnp.int32)


def dispatch_to_buckets_ref(values, dest, num_dests: int, capacity: int):
    """(T, V) values scattered to (num_dests, capacity, V); drop-newest."""
    rank, counts = dispatch_ranks_ref(dest, num_dests)
    ok = (rank >= 0) & (rank < capacity)
    flat = jnp.where(ok, dest * capacity + rank, num_dests * capacity)
    out = (
        jnp.zeros((num_dests * capacity + 1, values.shape[-1]), values.dtype)
        .at[flat]
        .set(jnp.where(ok[:, None], values, 0))[:-1]
        .reshape(num_dests, capacity, values.shape[-1])
    )
    overflow = jnp.sum((rank >= capacity).astype(jnp.int32))
    return out, jnp.minimum(counts, capacity), overflow
