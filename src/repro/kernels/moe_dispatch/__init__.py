from repro.kernels.moe_dispatch.ops import dispatch_ranks, dispatch_to_buckets  # noqa: F401
