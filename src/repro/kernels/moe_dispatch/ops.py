"""Public wrappers for the dispatch kernel (MoE / shuffle "copy" phase)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import kernels as _k
from repro.kernels.moe_dispatch.moe_dispatch import dispatch_ranks_pallas


def dispatch_ranks(dest: jax.Array, num_dests: int):
    """Stable in-bucket rank per token + per-destination counts."""
    return dispatch_ranks_pallas(dest, num_dests, interpret=_k.INTERPRET)


def dispatch_to_buckets(values: jax.Array, dest: jax.Array, num_dests: int,
                        capacity: int):
    """Scatter (T, V) values into (num_dests, capacity, V) buckets.

    Tokens beyond a bucket's capacity are dropped (drop-newest — the
    deterministic policy the capacity bound of the OS4M schedule implies).
    Returns (buckets, clamped_counts, overflow).
    """
    rank, counts = dispatch_ranks(dest, num_dests)
    ok = (rank >= 0) & (rank < capacity)
    flat = jnp.where(ok, dest * capacity + rank, num_dests * capacity)
    out = (
        jnp.zeros((num_dests * capacity + 1, values.shape[-1]), values.dtype)
        .at[flat]
        .set(jnp.where(ok[:, None], values, 0))[:-1]
        .reshape(num_dests, capacity, values.shape[-1])
    )
    overflow = jnp.sum((rank >= capacity).astype(jnp.int32))
    return out, jnp.minimum(counts, capacity), overflow


def plan_capacity_slabs(capacity: int, num_chunks: int) -> Tuple[Tuple[int, int], ...]:
    """Static (start, size) slabs cutting a bucket's capacity axis into
    pipeline chunks.

    This is the §4.4 chunk planner (``pipeline.plan_chunks``) applied to
    the dispatch bucket layout: before routing runs, every capacity row is
    equally likely to be filled, so the planner sees uniform loads and
    yields contiguous near-equal slabs. Callers all-to-all the slabs one
    at a time, overlapping slab ``i+1``'s "copy" with slab ``i``'s expert
    compute (the MoE analogue of the shuffle→reduce pipeline).
    """
    from repro.core import pipeline as pipe

    if num_chunks <= 1 or capacity <= 1:
        return ((0, capacity),)
    chunks = pipe.plan_chunks([1.0] * capacity, num_chunks, "arrival")
    return tuple((int(c[0]), len(c)) for c in chunks)


def dispatch_to_buckets_chunked(
    values: jax.Array, dest: jax.Array, num_dests: int, capacity: int,
    num_chunks: int,
):
    """Like :func:`dispatch_to_buckets`, pre-split into pipeline slabs.

    Returns ``(slabs, clamped_counts, overflow)`` where ``slabs`` is a
    tuple of ``(num_dests, size_c, V)`` views of the bucket tensor, one per
    chunk of :func:`plan_capacity_slabs` — ready for a chunked all-to-all.
    """
    buckets, counts, overflow = dispatch_to_buckets(
        values, dest, num_dests, capacity
    )
    slabs = tuple(
        buckets[:, s : s + z] for s, z in plan_capacity_slabs(capacity, num_chunks)
    )
    return slabs, counts, overflow
