"""Public wrappers for the dispatch kernel (MoE / shuffle "copy" phase)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import kernels as _k
from repro.kernels.moe_dispatch.moe_dispatch import dispatch_ranks_pallas


def dispatch_ranks(dest: jax.Array, num_dests: int):
    """Stable in-bucket rank per token + per-destination counts."""
    return dispatch_ranks_pallas(dest, num_dests, interpret=_k.INTERPRET)


def dispatch_to_buckets(values: jax.Array, dest: jax.Array, num_dests: int,
                        capacity: int):
    """Scatter (T, V) values into (num_dests, capacity, V) buckets.

    Tokens beyond a bucket's capacity are dropped (drop-newest — the
    deterministic policy the capacity bound of the OS4M schedule implies).
    Returns (buckets, clamped_counts, overflow).
    """
    rank, counts = dispatch_ranks(dest, num_dests)
    ok = (rank >= 0) & (rank < capacity)
    flat = jnp.where(ok, dest * capacity + rank, num_dests * capacity)
    out = (
        jnp.zeros((num_dests * capacity + 1, values.shape[-1]), values.dtype)
        .at[flat]
        .set(jnp.where(ok[:, None], values, 0))[:-1]
        .reshape(num_dests, capacity, values.shape[-1])
    )
    overflow = jnp.sum((rank >= capacity).astype(jnp.int32))
    return out, jnp.minimum(counts, capacity), overflow
