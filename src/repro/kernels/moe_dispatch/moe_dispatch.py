"""MoE/shuffle dispatch kernel: stable counting-sort ranks + counts.

This is the "copy"-phase address computation shared by the MapReduce
shuffle and the MoE token dispatch: given each token's destination
(Reduce slot, or expert after OS4M placement), compute

  rank[t]   = #{t' < t : dest[t'] == dest[t]}   (stable position in bucket)
  counts[e] = #{t : dest[t] == e}               (the K^(i) statistics)

``rank`` is what makes a fixed-capacity bucket scatter deterministic and
drop-newest under overflow; ``counts`` feeds the OS4M scheduler.

TPU design
----------
The loop-carried dependence (running per-destination offsets) is the part
a GPU handles with atomics; TPU-natively it becomes a *sequential grid
walk with VMEM-resident carry*:

* grid = (token_blocks,) — one sequential axis; scratch ``carry (E,)``
  holds the running per-destination counts across blocks.
* Per block: one-hot (block_tokens, E) on the VPU; an exclusive cumsum
  down the token axis gives within-block ranks; ``rank = within + carry``
  gathered via the same one-hot (a (bt,E)·(E,) contraction, MXU-eligible).
* E is the number of slots/experts (≤ a few hundred) so the carry and
  one-hot tiles are small; block_tokens = 1024 keeps the one-hot ≤ 2 MB
  for E ≤ 512.

The actual scatter into (E, capacity) buckets is done by XLA in ops.py —
a single known-index scatter is already optimal there; the kernel owns the
sequential rank computation that would otherwise serialise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _dispatch_kernel(dest_ref, rank_ref, counts_ref, carry_ref, *, num_dests: int,
                     num_blocks: int):
    tb = pl.program_id(0)

    @pl.when(tb == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    dest = dest_ref[...]  # (bt,) int32; invalid marked as >= num_dests or < 0
    bt = dest.shape[0]
    valid = (dest >= 0) & (dest < num_dests)
    onehot = (
        jnp.where(valid, dest, num_dests)[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (bt, num_dests), 1)
    ).astype(jnp.float32)
    incl = jnp.cumsum(onehot, axis=0)
    excl = incl - onehot                      # exclusive: earlier-in-block count
    within = jnp.sum(excl * onehot, axis=1)   # (bt,)
    base = jnp.sum(onehot * carry_ref[0][None, :], axis=1)
    rank_ref[...] = jnp.where(valid, (within + base).astype(jnp.int32), -1)
    carry_ref[...] = carry_ref[...] + incl[-1][None, :]

    @pl.when(tb == num_blocks - 1)
    def _emit_counts():
        counts_ref[...] = carry_ref[...]


@functools.partial(
    jax.jit, static_argnames=("num_dests", "block_tokens", "interpret")
)
def dispatch_ranks_pallas(
    dest: jax.Array,  # (T,) int32
    num_dests: int,
    *,
    block_tokens: int = 1024,
    interpret: bool = True,
):
    (t,) = dest.shape
    block_tokens = min(block_tokens, max(t, 1))
    pad = (-t) % block_tokens
    if pad:
        dest = jnp.concatenate([dest, jnp.full((pad,), -1, dest.dtype)])
    num_blocks = dest.shape[0] // block_tokens

    rank, counts = pl.pallas_call(
        functools.partial(
            _dispatch_kernel, num_dests=num_dests, num_blocks=num_blocks
        ),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_tokens,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block_tokens,), lambda i: (i,)),
            pl.BlockSpec((1, num_dests), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dest.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((1, num_dests), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, num_dests), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(dest.astype(jnp.int32))
    return rank[:t], counts[0].astype(jnp.int32)
