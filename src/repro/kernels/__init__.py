# Pallas TPU kernels for the paper's compute hot-spots.
#
# Each subpackage has:
#   <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
#   ops.py    — the jit'd public wrapper (interpret=True on CPU)
#   ref.py    — pure-jnp oracle used by the allclose test sweeps
#
# Mapping to the paper (DESIGN.md §8):
#   histogram       — §4.1 local statistics K^(i) (the communication mechanism)
#   segment_reduce  — the Reduce "run" phase over bucket-file layout (§4.4)
#   moe_dispatch    — the shuffle "copy": counting-sort of tokens by slot
#   coded_shuffle   — XOR multicast encode/decode (Coded MapReduce, 1512.01625)
#   flash_attention — keeps train_4k/prefill_32k compute-bound (roofline)

INTERPRET = True  # this container is CPU-only; flip to False on real TPU
