"""Fused gather + sorted segment-sum kernel — the pipelined Reduce body.

The chunked shuffle→reduce engine (``repro.core.mapreduce``) receives a
chunk's pairs in bucket layout after the all-to-all "copy". Before this
kernel existed the "sort" phase materialised a rank-ordered copy of the
received values in HBM (``values[order]``) and a second pass segment-summed
it. This kernel fuses the two: each program *gathers* its token block's
rows through the schedule's sort order and reduces them in the same pass —
one HBM read of the values, no sorted intermediate.

Semantics: row ``t`` of the logical sorted stream is
``values[gather_idx[t]]`` with segment id ``seg_ids[t]``; ``seg_ids`` is
non-decreasing and ids outside ``[0, num_segments)`` are padding.

    out[s] = sum_{t : seg_ids[t] == s} values[gather_idx[t]]

TPU design
----------
Same diagonal-band tiling as ``kernels/segment_reduce`` (sortedness makes
all but a band of the (segment_blocks, token_blocks) grid a no-op), plus
the in-kernel gather:

* grid = (segment_blocks, token_blocks), token axis innermost/sequential,
  accumulating into the same output tile across visits;
* each program loads the ``(block_tokens,)`` id + index slabs and gathers
  ``block_tokens`` rows from the VMEM-resident value table, then computes
  the one-hot ``P^T @ v`` matmul on the MXU exactly like segment_reduce;
* the value table is mapped whole into VMEM (index_map pins block (0, 0)),
  which bounds N·V·4 B to a few MB — the engine calls this per pipeline
  *chunk*, whose slab is sized by ``plan_chunks`` to be a fraction of the
  job, so the bound holds by construction. (A scalar-prefetch + per-block
  DMA variant lifts the bound; not needed at current chunk sizes.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _fused_kernel(seg_ref, idx_ref, val_ref, out_ref, *, block_segs: int):
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg0 = pl.program_id(0) * block_segs
    seg = seg_ref[...]  # (bt,) int32, sorted globally (padding ids are big)
    lo = seg[0]
    hi = seg[-1]

    @pl.when((hi >= seg0) & (lo < seg0 + block_segs))
    def _work():
        rows = jnp.take(val_ref[...], idx_ref[...], axis=0)  # fused gather
        local = seg[:, None] - seg0
        onehot = (
            local
            == jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], block_segs), 1)
        ).astype(rows.dtype)
        out_ref[...] += jnp.dot(
            onehot.T, rows, preferred_element_type=out_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "block_tokens", "block_segs", "interpret"),
)
def fused_gather_segment_reduce_pallas(
    values: jax.Array,       # (N, V) — unsorted value table
    gather_idx: jax.Array,   # (N,) int32 — sort order into ``values``
    seg_ids: jax.Array,      # (N,) int32 — segment of stream row t, sorted
    num_segments: int,
    *,
    block_tokens: int = 512,
    block_segs: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Fused ``out[s] = Σ_{t: seg_ids[t]==s} values[gather_idx[t]]`` on TPU.

    Args/shapes: ``values (N, V)`` unsorted value table (any float dtype);
    ``gather_idx (N,) int32`` sort order into ``values``; ``seg_ids (N,)
    int32`` per sorted-stream row, **non-decreasing**, with ids outside
    ``[0, num_segments)`` acting as padding. Returns ``(num_segments, V)``
    float32 (MXU accumulation dtype).

    Invariants: sortedness of ``seg_ids`` is what makes the diagonal-band
    grid correct — unsorted ids silently mis-assign blocks; the engine
    guarantees it by ordering on pipeline rank. ``block_tokens`` /
    ``block_segs`` trade VMEM for grid size; ``interpret=True`` runs the
    kernel in interpret mode (CPU tests).
    """
    n, v = values.shape
    # block_tokens is NOT shrunk to n: the per-block dot's f32 association
    # depends on the reduction length, so a fixed block size keeps outputs
    # invariant to the slab's padded length — two engine modes feeding the
    # same valid stream at different slab sizes (e.g. coded vs uncoded
    # shuffle) must reduce bit-identically. Short slabs pad up to one block.
    block_segs = min(block_segs, num_segments)
    pad = (-n) % block_tokens
    if pad:
        gather_idx = jnp.concatenate(
            [gather_idx, jnp.zeros((pad,), gather_idx.dtype)]
        )
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((pad,), num_segments, seg_ids.dtype)]
        )
    pad_segs = (-num_segments) % block_segs
    nseg_padded = num_segments + pad_segs

    grid = (nseg_padded // block_segs, seg_ids.shape[0] // block_tokens)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, block_segs=block_segs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tokens,), lambda s, t: (t,)),
            pl.BlockSpec((block_tokens,), lambda s, t: (t,)),
            pl.BlockSpec((n, v), lambda s, t: (0, 0)),  # whole table in VMEM
        ],
        out_specs=pl.BlockSpec((block_segs, v), lambda s, t: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((nseg_padded, v), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(seg_ids.astype(jnp.int32), gather_idx.astype(jnp.int32), values)
    return out[:num_segments]
