"""Fused shuffle→reduce kernel package: Pallas kernel, jit wrapper, jnp oracle."""

from repro.kernels.fused_shuffle_reduce.ops import fused_shuffle_reduce  # noqa: F401
