from repro.kernels.fused_shuffle_reduce.ops import fused_shuffle_reduce  # noqa: F401
