"""Public wrapper for the fused shuffle→reduce kernel.

``fused_shuffle_reduce`` is the Reduce "sort"+"run" of one pipeline chunk
in a single pass: gather the chunk's received pairs through the schedule's
sort order and segment-sum them per operation cluster.

Two execution paths behind one signature:

* ``use_kernel=True``  — the Pallas kernel (interpret-mode on CPU);
* ``use_kernel=False`` — the pure-jnp fallback, identical math, safe under
  ``jax.vmap`` (the engine's CPU backend maps slots with vmap, where a
  pallas_call has no batching rule).
"""

from __future__ import annotations

import jax

from repro import kernels as _k
from repro.kernels.fused_shuffle_reduce.fused_shuffle_reduce import (
    fused_gather_segment_reduce_pallas,
)
from repro.kernels.fused_shuffle_reduce.ref import fused_gather_segment_reduce_ref


def fused_shuffle_reduce(
    values: jax.Array,       # (N, V) unsorted value table
    gather_idx: jax.Array,   # (N,) int32 sort order into ``values``
    seg_ids: jax.Array,      # (N,) int32 segment per sorted stream row
    num_segments: int,
    *,
    use_kernel: bool = True,
) -> jax.Array:
    """Gather-by-order + sorted segment-sum, fused. Returns (S, V) f32."""
    if use_kernel:
        return fused_gather_segment_reduce_pallas(
            values, gather_idx, seg_ids, num_segments, interpret=_k.INTERPRET
        )
    return fused_gather_segment_reduce_ref(
        values, gather_idx, seg_ids, num_segments
    )
