"""Pure-jnp oracle for the fused gather + segment-sum kernel."""

import jax
import jax.numpy as jnp


def fused_gather_segment_reduce_ref(values, gather_idx, seg_ids, num_segments: int):
    """out[s] = sum over stream rows t with seg_ids[t]==s of values[gather_idx[t]]."""
    rows = jnp.take(values, gather_idx.astype(jnp.int32), axis=0)
    seg = jnp.where(
        (seg_ids >= 0) & (seg_ids < num_segments), seg_ids, num_segments
    )
    return jax.ops.segment_sum(
        rows.astype(jnp.float32), seg, num_segments=num_segments + 1
    )[:-1]
