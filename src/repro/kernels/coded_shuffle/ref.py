"""Pure-jnp oracle for the XOR word-combine kernel."""

import jax.numpy as jnp


def xor_words_ref(a, b):
    """Elementwise ``a ^ b`` on int32/uint32 word slabs (the whole op)."""
    if a.shape != b.shape or a.dtype != b.dtype:
        raise ValueError(
            f"xor_words needs matching operands, got {a.shape}/{a.dtype} "
            f"vs {b.shape}/{b.dtype}"
        )
    return jnp.bitwise_xor(a, b)
