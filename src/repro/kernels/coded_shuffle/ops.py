"""Public wrapper for the coded-shuffle XOR kernel + payload word packing.

``xor_words`` is the multicast encode *and* decode of the coded shuffle:
senders XOR the two destination slabs of a multicast pair into one
packet; receivers XOR the packet against the slab they reconstruct from
locally-replicated map data. Two execution paths behind one signature:

* ``use_kernel=True``  — the Pallas kernel (interpret-mode on CPU);
* ``use_kernel=False`` — the pure-jnp fallback, identical bits, safe
  under ``jax.vmap`` (the engine's CPU backend maps slots with vmap,
  where a pallas_call has no batching rule).

The packing helpers give the engine a single word-level wire format:
float payloads (f32/bf16) and quantized bytes (int8/fp8) are bit-cast
into int32 words, XOR-combined, and bit-cast back — XOR on the word view
is XOR on the underlying payload bits, so decode is exact for every
payload dtype.

Identical-sort wire contract (Coded MapReduce, arXiv 1512.01625): a
packet only decodes because sender and receiver rebuild the *same* slab
from replicated records — every sort that shapes this wire (the engine's
ragged counting-sort spill and the receiver's ``(src, j)`` re-order in
``core.mapreduce``) must be explicitly stable, never stable-by-default.
``repro.analysis --check determinism`` certifies this statically on the
traced coded programs; see docs/ANALYSIS.md.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import kernels as _k
from repro.kernels.coded_shuffle.coded_shuffle import xor_words_pallas
from repro.kernels.coded_shuffle.ref import xor_words_ref

_WORD = jnp.int32
_BYTES_PER_WORD = 4


def xor_words(a: jax.Array, b: jax.Array, *, use_kernel: bool = True) -> jax.Array:
    """Elementwise ``a ^ b`` over (N, W) int32/uint32 word slabs."""
    if use_kernel:
        return xor_words_pallas(a, b, interpret=_k.INTERPRET)
    return xor_words_ref(a, b)


def packed_width(v_dim: int, dtype) -> int:
    """Words per row when packing ``(N, v_dim)`` of ``dtype`` into int32."""
    itemsize = jnp.dtype(dtype).itemsize
    group = _BYTES_PER_WORD // itemsize
    return -(-v_dim // group)


def pack_payload_words(x: jax.Array) -> jax.Array:
    """Bit-cast an ``(N, V)`` payload into ``(N, W)`` int32 words.

    Lanes are grouped ``4 // itemsize`` at a time (f32 → 1 lane/word,
    bf16 → 2, int8/fp8 → 4); ``V`` is zero-padded up to a whole group so
    padding bits are zero and XOR-neutral. Exact round-trip via
    :func:`unpack_payload_words` for every supported dtype.
    """
    n, v = x.shape
    itemsize = jnp.dtype(x.dtype).itemsize
    if itemsize > _BYTES_PER_WORD:
        raise ValueError(f"payload dtype {x.dtype} wider than a word")
    group = _BYTES_PER_WORD // itemsize
    pad = (-v) % group
    if pad:
        x = jnp.concatenate([x, jnp.zeros((n, pad), x.dtype)], axis=1)
    if group == 1:
        return jax.lax.bitcast_convert_type(x, _WORD)
    grouped = x.reshape(n, (v + pad) // group, group)
    return jax.lax.bitcast_convert_type(grouped, _WORD)


def unpack_payload_words(words: jax.Array, dtype, v_dim: int) -> jax.Array:
    """Invert :func:`pack_payload_words` back to ``(N, v_dim)`` of ``dtype``."""
    n, w = words.shape
    itemsize = jnp.dtype(dtype).itemsize
    group = _BYTES_PER_WORD // itemsize
    if w != packed_width(v_dim, dtype):
        raise ValueError(
            f"word slab width {w} does not match v_dim={v_dim} of {dtype}"
        )
    x = jax.lax.bitcast_convert_type(words, dtype)
    if group > 1:
        x = x.reshape(n, w * group)
    return x[:, :v_dim]
