"""XOR word-combine kernel — the coded-shuffle multicast encoder/decoder.

Coded MapReduce (Li et al., arXiv 1512.01625) replaces unicast shuffle
slabs with multicast packets: a sender XOR-combines the two destination
slabs it holds for a multicast pair, and each receiver XORs the packet
against the slab it can reconstruct from its locally-replicated map data
to recover the slab meant for it. Because ``A ⊕ B ⊕ B = A`` holds on bit
patterns, the decode is *exact* — the engine's bit-identity contract
survives coding by construction.

This kernel is the one compute primitive of that scheme: elementwise XOR
over int32/uint32 *word* views of the payload slabs (float payloads are
bit-cast to words before combining — see ``ops.pack_payload_words``).
Encode and decode are the same operation, so one kernel serves both
sides of the wire.

TPU design
----------
Embarrassingly parallel VPU work: grid over row blocks, each program
XORs one ``(block_rows, words)`` tile resident in VMEM. No reductions,
no cross-block state — ``dimension_semantics=("parallel",)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _xor_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.bitwise_xor(a_ref[...], b_ref[...])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def xor_words_pallas(
    a: jax.Array,            # (N, W) int32 or uint32 payload words
    b: jax.Array,            # (N, W) same shape/dtype as ``a``
    *,
    block_rows: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Elementwise ``a ^ b`` over word slabs. Returns ``(N, W)`` words.

    Args: ``a``/``b`` must share an integer word dtype (int32 or uint32 —
    the bit-cast views produced by ``ops.pack_payload_words``) and shape.
    ``block_rows`` trades VMEM tile size for grid length;
    ``interpret=True`` runs in interpret mode (CPU tests).
    """
    if a.shape != b.shape or a.dtype != b.dtype:
        raise ValueError(
            f"xor_words needs matching operands, got {a.shape}/{a.dtype} "
            f"vs {b.shape}/{b.dtype}"
        )
    if not jnp.issubdtype(a.dtype, jnp.integer):
        raise ValueError(f"xor_words operates on word views, got {a.dtype}")
    n, w = a.shape
    block_rows = min(block_rows, max(n, 1))
    pad = (-n) % block_rows
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad, w), a.dtype)])
        b = jnp.concatenate([b, jnp.zeros((pad, w), b.dtype)])
    grid = (a.shape[0] // block_rows,)
    out = pl.pallas_call(
        _xor_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], w), a.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(a, b)
    return out[:n]
