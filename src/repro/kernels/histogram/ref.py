"""Pure-jnp oracle for the histogram kernel."""

import jax.numpy as jnp
import jax


def histogram_ref(ids, weights, num_bins: int):
    ids = ids.astype(jnp.int32)
    w = weights.astype(jnp.float32)
    # Out-of-range ids contribute nothing (kernel pads with id = -1).
    seg = jnp.where((ids >= 0) & (ids < num_bins), ids, num_bins)
    return jax.ops.segment_sum(w, seg, num_segments=num_bins + 1)[:-1]
