"""Public wrapper for the histogram kernel (OS4M local statistics)."""

from __future__ import annotations

import jax

from repro import kernels as _k
from repro.kernels.histogram.histogram import histogram_pallas


def histogram(ids: jax.Array, weights: jax.Array, num_bins: int) -> jax.Array:
    """Weighted histogram of integer ids; the K^(i) vector of paper eq. 4-1."""
    return histogram_pallas(
        ids.reshape(-1), weights.reshape(-1), num_bins, interpret=_k.INTERPRET
    )
