"""Weighted histogram kernel — the paper's local statistics ``K^(i)`` (§4.1).

Counts (optionally weighted) occurrences of integer ids into ``num_bins``
bins. This is the per-shard half of OS4M's communication mechanism: each
shard computes its own key-distribution vector which is then ``psum``'d
over the mesh (the TaskTracker→JobTracker aggregation tree).

TPU design
----------
The scatter-add a GPU would use has no efficient TPU analogue (no fast
random-access HBM atomics); the TPU-native formulation is a *one-hot
compare + reduction* that runs on the VPU over VMEM tiles:

* grid = (token_blocks, bin_blocks) — tokens are tiled so the id/weight
  slab fits VMEM; bins are tiled so the one-hot compare matrix
  ``(block_tokens, block_bins)`` stays within a few MB of VMEM.
* Each program builds ``onehot[t, b] = (ids[t] == bin0 + b)`` and reduces
  ``sum_t onehot * w[t]`` into its output tile. The token-block grid axis
  is innermost and marked "arbitrary" so the accumulation across token
  blocks is a sequential revisit of the same output tile (standard Pallas
  accumulation pattern: zero it on the first visit).

Block sizes default to (1024 tokens × 1024 bins): 1024×1024 f32 one-hot is
4 MB — the working set, plus the 4 KB id/weight slabs, fits v5e VMEM
(~16 MB/core) with headroom for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _histogram_kernel(ids_ref, w_ref, out_ref, *, block_bins: int):
    tb = pl.program_id(1)  # token-block index (innermost, sequential)

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bin0 = pl.program_id(0) * block_bins
    ids = ids_ref[...]  # (block_tokens,)
    w = w_ref[...]      # (block_tokens,)
    # One-hot compare against this program's bin window; VPU-friendly.
    local = ids[:, None] - bin0
    onehot = (local == jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_bins), 1))
    out_ref[...] += jnp.sum(jnp.where(onehot, w[:, None], 0.0), axis=0)


@functools.partial(
    jax.jit, static_argnames=("num_bins", "block_tokens", "block_bins", "interpret")
)
def histogram_pallas(
    ids: jax.Array,
    weights: jax.Array,
    num_bins: int,
    *,
    block_tokens: int = 1024,
    block_bins: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """``out[b] = sum_t weights[t] * (ids[t] == b)`` for b in [0, num_bins)."""
    (n,) = ids.shape
    block_tokens = min(block_tokens, max(n, 1))
    block_bins = min(block_bins, num_bins)
    # Pad tokens up to a block multiple; padded ids point outside every bin.
    pad = (-n) % block_tokens
    if pad:
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, ids.dtype)])
        weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])
    pad_bins = (-num_bins) % block_bins
    nbins_padded = num_bins + pad_bins

    grid = (nbins_padded // block_bins, ids.shape[0] // block_tokens)
    out = pl.pallas_call(
        functools.partial(_histogram_kernel, block_bins=block_bins),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tokens,), lambda b, t: (t,)),
            pl.BlockSpec((block_tokens,), lambda b, t: (t,)),
        ],
        out_specs=pl.BlockSpec((block_bins,), lambda b, t: (b,)),
        out_shape=jax.ShapeDtypeStruct((nbins_padded,), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ids.astype(jnp.int32), weights.astype(jnp.float32))
    return out[:num_bins]
