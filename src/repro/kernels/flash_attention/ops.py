"""Public wrappers for attention: flash kernel (prefill/train) + decode path.

``flash_attention`` is the Pallas kernel. ``decode_attention`` is the
one-new-token path: at q_len = 1 the op is HBM-bandwidth-bound (stream the
KV cache once); a blocked MXU kernel buys nothing, so it is expressed as
einsums XLA fuses into a single pass. Both share the oracle in ref.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import kernels as _k
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, sm_scale: float | None = None,
                    block_q: int = 512, block_k: int = 512):
    """(B, Hq, T, D) x (B, Hkv, S, D)^2 -> (B, Hq, T, D)."""
    return flash_attention_pallas(
        q, k, v, causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, interpret=_k.INTERPRET,
    )


def decode_attention(q, k_cache, v_cache, cache_len, *, sm_scale: float | None = None):
    """Single-step attention against a (B, Hkv, S, D) cache; q is (B, Hq, 1, D).

    ``cache_len`` may be a scalar or (B,) vector of valid cache lengths.
    """
    b, hq, _, d = q.shape
    _, hkv, s, _ = k_cache.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, group, d)
    logits = jnp.einsum(
        "bhgd,bhsd->bhgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * sm_scale
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(cache_len), (b,))[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, d).astype(q.dtype)
