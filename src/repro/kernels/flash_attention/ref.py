"""Pure-jnp oracle for flash attention (causal + GQA)."""

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    # Expand kv heads to match q heads (reference only; kernel never does).
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((t, s), bool), k=s - t)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", p, v.astype(jnp.float32)).astype(q.dtype)
