"""Flash attention (online-softmax) Pallas kernel, causal + GQA aware.

Role in the OS4M port: attention is the dominant FLOP producer of the
assigned LM architectures; keeping train_4k / prefill_32k *compute-bound*
(§Roofline) requires never materialising the (T, S) score matrix in HBM.

TPU design
----------
* grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis is innermost
  and sequential ("arbitrary"), carrying the online-softmax state in VMEM
  scratch across kv visits:
    acc (block_q, head_dim) f32 — unnormalised output accumulator
    m, l (block_q, 128) f32     — running row max / normaliser
      (lane-replicated to match the (8, 128) vreg tile; column 0 is the
      value, replication keeps broadcasts register-shaped)
* Per program: q-tile (block_q, d) and kv-tiles (block_k, d) live in VMEM;
  the two matmuls (q @ k^T and p @ v) hit the MXU with d and block_k both
  multiples of 128.
* GQA is handled in the BlockSpec index maps: query head ``h`` reads kv
  head ``h // (Hq // Hkv)`` — no kv replication in HBM.
* Causality is block-sparse: kv blocks entirely above the diagonal are
  skipped with ``pl.when`` (no MXU work, no HBM traffic beyond the slab
  prefetch), which halves causal FLOPs. The diagonal block applies the
  triangular mask; key padding is masked via absolute indices.

Default tiles (block_q = block_k = 512, d = 128): q/k/v slabs 128 KB each
+ one (512, 512) f32 score tile = 1 MB — comfortable VMEM residency with
double buffering. ``block_k`` is the knob that trades VMEM for fewer
sequential kv steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30
_LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, block_q: int, block_k: int, causal: bool, sm_scale: float,
    kv_len: int, num_kv_blocks: int, q_offset: int,
):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ``q_offset`` aligns queries to the *end* of the kv axis (suffix
    # alignment: query i sits at absolute position q_offset + i), which is
    # what chunked prefill against a KV cache needs.
    q0 = qb * block_q + q_offset
    k0 = kb * block_k

    # Causal block-sparsity: skip kv blocks strictly above the diagonal.
    run = (k0 <= q0 + block_q - 1) if causal else True

    @pl.when(run)
    def _work():
        q = q_ref[0, 0]  # (bq, d)
        k = k_ref[0, 0]  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)

        # Key-padding mask (absolute) + causal mask on the diagonal band.
        kv_idx = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kv_idx < kv_len
        if causal:
            q_idx = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask &= kv_idx <= q_idx
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                       # (bq,)
        m_cur = jnp.max(s, axis=1)                 # (bq,)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)            # rescale factor
        p = jnp.exp(s - m_new[:, None])            # (bq, bk)
        p = jnp.where(mask, p, 0.0)

        l_ref[...] = l_ref[...] * alpha[:, None] + jnp.sum(p, axis=1)[:, None]
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(kb == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        norm = jnp.where(l > 0.0, 1.0 / jnp.where(l > 0.0, l, 1.0), 0.0)
        o_ref[0, 0] = (acc_ref[...] * norm[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "sm_scale"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, T, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, t)
    block_k = min(block_k, s)
    pad_q = (-t) % block_q
    pad_k = (-s) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    tq, sk = t + pad_q, s + pad_k
    grid = (b, hq, tq // block_q, sk // block_k)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_q=block_q, block_k=block_k, causal=causal,
            sm_scale=float(sm_scale), kv_len=s, num_kv_blocks=grid[3],
            q_offset=s - t,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qb, kb: (b_, h, qb, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, qb, kb: (b_, h // group, kb, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, qb, kb: (b_, h // group, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qb, kb: (b_, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :t, :]
