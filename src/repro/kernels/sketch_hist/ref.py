"""Pure-jnp oracle for the count-min sketch kernel."""

import jax
import jax.numpy as jnp


def sketch_hist_ref(ids, weights, multipliers, width: int):
    """``out[r, b] = sum_t w[t] * ((multipliers[r] * ids[t]) >> shift == b)``.

    The same multiply-shift hash as the kernel, one segment-sum per row.
    """
    if width < 2 or width & (width - 1):
        raise ValueError(f"width must be a power of two >= 2, got {width}")
    shift = 32 - (width.bit_length() - 1)
    ids_u = ids.reshape(-1).astype(jnp.uint32)
    w = weights.reshape(-1).astype(jnp.float32)

    def one_row(mult):
        bins = ((ids_u * mult) >> shift).astype(jnp.int32)
        return jax.ops.segment_sum(w, bins, num_segments=width)

    return jax.vmap(one_row)(multipliers.astype(jnp.uint32))
