"""Count-min sketch kernel — compressed local statistics (ROADMAP item).

The exact `K^(i)` histogram (``kernels/histogram``) scales with the
number of operation clusters ``n``; the sketch replaces it with a
``(depth, width)`` counter grid where ``width`` is a power of two far
below ``n``. Each of the ``depth`` rows hashes every cluster id through
an independent multiply-shift hash ``h_r(x) = (a_r * x) >> (32 -
log2(width))`` (odd multiplier ``a_r``) and accumulates the pair weight
into the hashed bin. Reading the sketch takes the **min over rows** —
every row's cell is the true count plus non-negative collision mass, so
estimates only ever overestimate (the count-min guarantee the planner's
send capacities rely on; see ``core/stats_provider.py``).

TPU design
----------
Same one-hot compare + reduction formulation as the histogram kernel
(no TPU scatter-add), with the hash computed in-register per row:

* grid = (depth, bin_blocks, token_blocks) — rows and bin windows are
  "parallel"; the token-block axis is innermost and "arbitrary" so
  accumulation across token blocks sequentially revisits one output
  tile (zeroed on the first visit).
* Each program hashes its token slab with its row's multiplier (uint32
  wraparound multiply + logical shift — the VPU does both), builds
  ``onehot[t, b] = (h_r(ids[t]) == bin0 + b)`` and reduces
  ``sum_t onehot * w[t]`` into its ``(1, block_bins)`` output tile.

Default blocks (1024 tokens × 1024 bins) keep the f32 one-hot at 4 MB —
comfortably inside v5e VMEM next to the id/weight slabs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _sketch_kernel(ids_ref, w_ref, mult_ref, out_ref, *,
                   block_bins: int, shift: int):
    tb = pl.program_id(2)  # token-block index (innermost, sequential)

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]   # (block_tokens,)
    w = w_ref[...]       # (block_tokens,)
    mult = mult_ref[0]   # this row's odd multiplier (uint32)
    # Multiply-shift hash: uint32 multiply wraps mod 2^32, the logical
    # right shift keeps the top log2(width) bits — h_r(x) in [0, width).
    hashed = ((ids.astype(jnp.uint32) * mult) >> shift).astype(jnp.int32)
    bin0 = pl.program_id(1) * block_bins
    local = hashed[:, None] - bin0
    onehot = (local == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_bins), 1))
    out_ref[...] += jnp.sum(
        jnp.where(onehot, w[:, None], 0.0), axis=0)[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("width", "block_tokens", "block_bins", "interpret"),
)
def sketch_hist_pallas(
    ids: jax.Array,
    weights: jax.Array,
    multipliers: jax.Array,
    width: int,
    *,
    block_tokens: int = 1024,
    block_bins: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """``out[r, b] = sum_t weights[t] * (h_r(ids[t]) == b)``; (depth, width).

    ``width`` must be a power of two >= 2 (the hash is a top-bits
    extract); ``multipliers`` is the (depth,) uint32 vector of odd
    hash multipliers.
    """
    (n,) = ids.shape
    (depth,) = multipliers.shape
    if width < 2 or width & (width - 1):
        raise ValueError(f"width must be a power of two >= 2, got {width}")
    shift = 32 - (width.bit_length() - 1)
    block_tokens = min(block_tokens, max(n, 1))
    block_bins = min(block_bins, width)  # both powers of two: divides evenly
    # Pad tokens up to a block multiple; padded entries carry zero weight
    # (a padded id hashes to SOME bin, the weight keeps it from counting).
    pad = (-n) % block_tokens
    if pad:
        ids = jnp.concatenate([ids, jnp.zeros((pad,), ids.dtype)])
        weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])

    grid = (depth, width // block_bins, ids.shape[0] // block_tokens)
    return pl.pallas_call(
        functools.partial(_sketch_kernel, block_bins=block_bins, shift=shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tokens,), lambda r, b, t: (t,)),
            pl.BlockSpec((block_tokens,), lambda r, b, t: (t,)),
            pl.BlockSpec((1,), lambda r, b, t: (r,)),
        ],
        out_specs=pl.BlockSpec((1, block_bins), lambda r, b, t: (r, b)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ids.astype(jnp.int32), weights.astype(jnp.float32),
      multipliers.astype(jnp.uint32))
