"""Public wrapper for the count-min sketch kernel (compressed statistics)."""

from __future__ import annotations

import jax

from repro import kernels as _k
from repro.kernels.sketch_hist.sketch_hist import sketch_hist_pallas


def sketch_hist(ids: jax.Array, weights: jax.Array, multipliers: jax.Array,
                width: int) -> jax.Array:
    """Weighted count-min counters (depth, width) of integer ids."""
    return sketch_hist_pallas(
        ids.reshape(-1), weights.reshape(-1), multipliers, width,
        interpret=_k.INTERPRET,
    )
