from repro.kernels.sketch_hist.ops import sketch_hist  # noqa: F401
