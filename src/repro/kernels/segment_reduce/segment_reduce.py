"""Sorted segment-sum kernel — the Reduce "run" phase (paper §4.4).

After the shuffle ("copy") and the sort phase, a Reduce slot holds its
pairs ordered by operation-cluster id (the "bucket file" layout). The run
phase aggregates each cluster's values:  ``out[s] = sum_{t: seg[t]==s} v[t]``.

TPU design
----------
On a GPU this is a scatter-add; on TPU we exploit the *sortedness*: a
token block only ever touches the contiguous window of segments
``[seg[t0], seg[t1]]``. We tile as

* grid = (segment_blocks, token_blocks)  (token axis innermost/sequential,
  accumulating into the same output tile across visits),
* each program loads a ``(block_tokens, V)`` value slab and the matching
  ``(block_tokens,)`` id slab into VMEM, builds the one-hot matrix
  ``P[t, s] = (seg[t] == s0 + s)`` and computes ``P^T @ v`` — an MXU
  matmul of shape ``(block_segs, block_tokens) x (block_tokens, V)``.
* Programs whose segment window is disjoint from the token block's
  ``[min_id, max_id]`` range skip the matmul entirely (``pl.when``), so
  the work done is ~``O(N * V)`` despite the 2D grid — the sorted layout
  makes all but a diagonal band of the grid a no-op.

Default tiles: 512 tokens × 512 segments × V≤128 ⇒ one-hot 1 MB +
values 256 KB, well inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _segsum_kernel(seg_ref, val_ref, out_ref, *, block_segs: int):
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg0 = pl.program_id(0) * block_segs
    seg = seg_ref[...]  # (bt,) int32, sorted globally (padded with big id)
    lo = seg[0]         # sortedness ⇒ block range is [seg[0], seg[-1]]
    hi = seg[-1]

    @pl.when((hi >= seg0) & (lo < seg0 + block_segs))
    def _work():
        local = seg[:, None] - seg0  # (bt, 1)
        onehot = (
            local
            == jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], block_segs), 1)
        ).astype(val_ref.dtype)
        # (bs, bt) @ (bt, V) on the MXU.
        out_ref[...] += jnp.dot(
            onehot.T, val_ref[...], preferred_element_type=out_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "block_tokens", "block_segs", "interpret"),
)
def segment_reduce_sorted_pallas(
    values: jax.Array,       # (N, V) — sorted by seg_ids
    seg_ids: jax.Array,      # (N,) int32, non-decreasing
    num_segments: int,
    *,
    block_tokens: int = 512,
    block_segs: int = 512,
    interpret: bool = True,
) -> jax.Array:
    n, v = values.shape
    block_tokens = min(block_tokens, max(n, 1))
    block_segs = min(block_segs, num_segments)
    pad = (-n) % block_tokens
    if pad:
        values = jnp.concatenate([values, jnp.zeros((pad, v), values.dtype)])
        # Padded ids sit past every real segment (keeps sortedness).
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((pad,), num_segments, seg_ids.dtype)]
        )
    pad_segs = (-num_segments) % block_segs
    nseg_padded = num_segments + pad_segs

    grid = (nseg_padded // block_segs, seg_ids.shape[0] // block_tokens)
    out = pl.pallas_call(
        functools.partial(_segsum_kernel, block_segs=block_segs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tokens,), lambda s, t: (t,)),
            pl.BlockSpec((block_tokens, v), lambda s, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((block_segs, v), lambda s, t: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((nseg_padded, v), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(seg_ids.astype(jnp.int32), values)
    return out[:num_segments]
