from repro.kernels.segment_reduce.ops import segment_reduce_sorted  # noqa: F401
