"""Public wrapper for the sorted segment-sum kernel (Reduce "run" phase)."""

from __future__ import annotations

import jax

from repro import kernels as _k
from repro.kernels.segment_reduce.segment_reduce import segment_reduce_sorted_pallas


def segment_reduce_sorted(
    values: jax.Array, seg_ids: jax.Array, num_segments: int
) -> jax.Array:
    """Segment sum over inputs already sorted by ``seg_ids`` (bucket layout)."""
    return segment_reduce_sorted_pallas(
        values, seg_ids, num_segments, interpret=_k.INTERPRET
    )
