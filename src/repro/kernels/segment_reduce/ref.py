"""Pure-jnp oracle for the sorted segment-sum kernel."""

import jax
import jax.numpy as jnp


def segment_reduce_sorted_ref(values, seg_ids, num_segments: int):
    seg = jnp.where(
        (seg_ids >= 0) & (seg_ids < num_segments), seg_ids, num_segments
    )
    return jax.ops.segment_sum(
        values.astype(jnp.float32), seg, num_segments=num_segments + 1
    )[:-1]
