"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Two modes:

* default — the continuous-batching engine (OS4M lane scheduling) on
  synthetic requests with the arch's smoke twin; reports lane balance and
  throughput for os4m vs the hash baseline.
* ``--steady-state N`` — the MapReduce serving loop: ONE persistent
  :class:`~repro.core.mapreduce.MapReduceJob` with a
  :class:`~repro.core.schedule_cache.ReusePolicy` runs N batches of a
  stationary workload (with an optional injected distribution shift),
  amortizing a single host plan over the whole steady state. Reports the
  replan rate, per-batch wall time, and drift telemetry — the serving-
  scale deployment story of ROADMAP.md.

Heterogeneity knobs (both modes): ``--slot-slowdown i:factor`` injects a
straggler — the factor is a **wall-clock multiplier**: slot/lane ``i``
takes ``factor``× the nominal time (``3:2`` makes slot 3 twice as slow;
``3:0.5`` twice as fast). In steady-state mode the job's online speed
estimator detects it from wave timings and replans (``speed_drift``); in
engine mode the lane is admitted proportionally less decode work
(relative speed ``1/factor``). ``--schedule-snapshot p.json``
warm-starts the steady-state job from a persisted
:class:`~repro.core.schedule_cache.CachedSchedule` (skipping the cold
replan); ``--save-snapshot p.json`` writes the final plan back.

Elastic mesh (steady-state): ``--slot-slowdown i:0`` declares slot ``i``
dead before the run; ``--checkpoint-waves`` persists phase-B progress at
wave granularity; ``--kill-at-wave i:w`` kills slot ``i`` mid-batch just
before wave ``w`` — only the unfinished waves replay on the survivors,
and outputs stay bit-identical to an uninterrupted run.

Timing source (steady-state): ``--backend shard_map`` places one Reduce
slot per device (needs ``--lanes`` ≤ available devices, e.g. under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and the job then
feeds the estimator *measured* per-device phase-B wave clocks instead of
the synthetic model — on-device tick stamps inside the overlapped
pipeline (``kernels/wave_timer``; host-fenced waves only where no tick
source exists) — and injected slowdowns scale the measured seconds.
Engine mode: ``--replan-on-drift`` turns on adaptive lane metering AND
mid-run replanning of the waiting queues when a lane's measured speed
drifts (``Engine.maybe_replan_waiting``).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple


def steady_state_loop(
    job,
    batches: Iterable,
    on_batch: Optional[Callable[[int, Any, float], None]] = None,
) -> Dict[str, Any]:
    """Serve ``batches`` through one persistent job, amortizing the plan.

    ``job`` is a :class:`~repro.core.mapreduce.MapReduceJob`, normally
    configured with ``reuse=ReusePolicy(...)`` so the host scheduler runs
    only on drift/age events; the loop itself is policy-agnostic (pass a
    no-reuse job to measure the always-replan baseline). ``on_batch`` is
    called as ``on_batch(index, result, wall_seconds)`` after each batch.

    Returns telemetry: per-batch ``walls``/``reused``/``reasons``/
    ``drifts``, the job's ``schedule_cache`` counters (when reuse is on),
    and ``jit_misses`` — executables traced over the loop (steady state
    ⇒ flat after warmup).
    """
    walls: List[float] = []
    reused: List[bool] = []
    reasons: List[str] = []
    drifts: List[Optional[float]] = []
    for i, batch in enumerate(batches):
        t0 = time.perf_counter()
        res = job.run(batch)
        wall = time.perf_counter() - t0
        walls.append(wall)
        reused.append(res.reused)
        reasons.append(res.plan_reason)
        drifts.append(res.drift)
        if on_batch is not None:
            on_batch(i, res, wall)
    out: Dict[str, Any] = {
        "batches": len(walls),
        "walls": walls,
        "reused": reused,
        "reasons": reasons,
        "drifts": drifts,
        "jit_misses": job.jit_misses,
    }
    if job.schedule_cache is not None:
        out["cache"] = job.schedule_cache.stats()
    return out


def parse_slowdowns(specs: Optional[List[str]]) -> List[Tuple[int, float]]:
    """Parse repeated ``--slot-slowdown i:factor`` flags into (slot, factor).

    The factor is a wall-clock multiplier (2 = twice as slow), matching
    :meth:`repro.core.mapreduce.MapReduceJob.set_slot_slowdown`. A factor
    of exactly ``0`` declares the slot/lane **dead** (elastic mesh): the
    job marks it failed and every future plan assigns it nothing.
    """
    out: List[Tuple[int, float]] = []
    for spec in specs or []:
        try:
            slot_s, factor_s = spec.split(":", 1)
            slot, factor = int(slot_s), float(factor_s)
        except ValueError as exc:
            raise SystemExit(
                f"--slot-slowdown expects i:factor (e.g. 3:2), got {spec!r}"
            ) from exc
        if factor < 0:
            raise SystemExit(
                f"--slot-slowdown factor must be >= 0 (0 = dead slot), "
                f"got {factor}")
        out.append((slot, factor))
    return out


def parse_kills(specs: Optional[List[str]]) -> List[Tuple[int, int]]:
    """Parse repeated ``--kill-at-wave i:w`` flags into (slot, wave).

    Arms a mid-batch fault injection: slot ``i`` dies just before phase-B
    wave ``w`` of the first batch executes — matching
    :meth:`repro.core.mapreduce.MapReduceJob.set_slot_failure` with
    ``at_wave``. Requires ``--checkpoint-waves``.
    """
    out: List[Tuple[int, int]] = []
    for spec in specs or []:
        try:
            slot_s, wave_s = spec.split(":", 1)
            slot, wave = int(slot_s), int(wave_s)
        except ValueError as exc:
            raise SystemExit(
                f"--kill-at-wave expects i:w (e.g. 3:2), got {spec!r}"
            ) from exc
        if wave < 0:
            raise SystemExit(f"--kill-at-wave wave must be >= 0, got {wave}")
        out.append((slot, wave))
    return out


def _steady_state_main(args) -> None:
    """The ``--steady-state`` mode: MapReduce serving with schedule reuse."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core.mapreduce import MapReduceConfig, MapReduceJob
    from repro.core.schedule_cache import ReusePolicy

    slots, K, n = args.lanes, 4096, 64
    slowdowns = parse_slowdowns(args.slot_slowdown)
    kills = parse_kills(args.kill_at_wave)
    if kills and not args.checkpoint_waves:
        raise SystemExit("--kill-at-wave requires --checkpoint-waves")

    def make_batch(seed: int, alpha: float):
        rng = np.random.default_rng(seed)
        keys = (rng.zipf(alpha, size=(slots, K)) % 2003).astype(np.int32)
        vals = np.ones((slots, K, 4), np.float32)
        valid = np.ones((slots, K), bool)
        return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))

    def batches():
        for i in range(args.steady_state):
            drifted = args.drift_at >= 0 and i >= args.drift_at
            yield make_batch(i, 1.9 if drifted else 1.25)

    mesh = None
    if args.backend == "shard_map":
        from jax.sharding import Mesh

        devices = jax.devices()
        if len(devices) < slots:
            raise SystemExit(
                f"--backend shard_map needs >= {slots} devices, have "
                f"{len(devices)} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={slots})")
        mesh = Mesh(np.asarray(devices[:slots]), ("mr_slots",))
    job = MapReduceJob(
        lambda s: s,
        MapReduceConfig(
            num_slots=slots, num_clusters=n, scheduler=args.scheduler,
            # Stragglers are detected online from wave timings — measured
            # per-device clocks on shard_map (estimation always on there:
            # a real mesh can have genuinely slow devices without any
            # injection), synthetic slowdown-driven timings on vmap.
            estimate_speeds=bool(slowdowns) or args.backend == "shard_map",
            # Wave checkpointing owns the fenced program structure, so it
            # pins the synthetic timing model (measured mode is the other
            # owner; the two are mutually exclusive by construction).
            measure_timings=False if args.checkpoint_waves else None,
            checkpoint_waves=args.checkpoint_waves,
            stats=args.stats,
            stream_prefix=args.stream_prefix,
            reuse=ReusePolicy(max_drift=args.max_drift,
                              max_age=args.max_age,
                              revalidate_every=args.revalidate_every,
                              max_speed_drift=args.max_speed_drift),
        ),
        backend=args.backend,
        mesh=mesh,
    )
    for slot, factor in slowdowns:
        if not 0 <= slot < slots:
            raise SystemExit(f"--slot-slowdown slot {slot} out of range "
                             f"[0, {slots})")
        job.set_slot_slowdown(slot, factor)
    for slot, wave in kills:
        if not 0 <= slot < slots:
            raise SystemExit(f"--kill-at-wave slot {slot} out of range "
                             f"[0, {slots})")
        job.set_slot_failure(slot, at_wave=wave)
    job.on_mesh_change = lambda ev: print(f"  mesh event: {ev}")
    if args.schedule_snapshot:
        with open(args.schedule_snapshot) as f:
            job.load_snapshot(json.load(f))
        print(f"warm start: loaded schedule snapshot {args.schedule_snapshot}")
    tele = steady_state_loop(
        job, batches(),
        on_batch=lambda i, res, w: print(
            f"  batch {i:3d}: {'reuse ' if res.reused else 'REPLAN'} "
            f"({res.plan_reason:11s}) drift="
            f"{'-' if res.drift is None else f'{res.drift:.3f}'} "
            f"wall={w * 1e3:.1f} ms"),
    )
    cache = tele["cache"]
    steady = [w for w, r in zip(tele["walls"], tele["reused"]) if r]
    print(f"\nsteady state: {cache['reuses']}/{cache['batches']} batches "
          f"reused one plan (replan rate {cache['replan_rate']:.2f}, "
          f"{cache['drift_checks']} drift checks, "
          f"{cache['speed_replans']} speed replans, "
          f"{tele['jit_misses']} executables traced)")
    if steady:
        print(f"median reused-batch wall: {np.median(steady) * 1e3:.1f} ms")
    if args.checkpoint_waves and job.last_checkpoint_wave is not None:
        print(f"wave checkpoints: cursor {job.last_checkpoint_wave}, "
              f"{job.last_replayed_waves} waves replayed on the last batch"
              + (f", {len(job.mesh_events)} mesh events"
                 if job.mesh_events else ""))
    if slowdowns and job.speed_estimator is not None:
        est = job.speed_estimator.speeds()
        if est is not None:
            if job.last_wave_timings is not None:
                from repro.kernels.wave_timer import ops as wt_ops

                source = ("measured wave clocks, on-device ticks"
                          if wt_ops.available()
                          else "measured wave clocks, host-fenced fallback")
            else:
                source = "synthetic timing model"
            print(f"estimated slot speeds ({source}): "
                  + " ".join(f"{s:.2f}" for s in est))
    if args.save_snapshot and job.schedule_cache.snapshot is not None:
        with open(args.save_snapshot, "w") as f:
            json.dump(job.schedule_cache.snapshot.to_json(), f)
        print(f"saved schedule snapshot -> {args.save_snapshot}")


def main():
    """CLI entry point (see module docstring for the two modes)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--scheduler", default=None,
                    help="default: os4m (engine mode), auto (steady-state mode)")
    ap.add_argument("--steady-state", type=int, default=0, metavar="N",
                    help="serve N MapReduce batches through one reused plan")
    ap.add_argument("--backend", default="vmap",
                    choices=("vmap", "shard_map"),
                    help="steady-state mode: shard_map = one slot per device "
                         "+ measured per-device phase-B timings")
    ap.add_argument("--replan-on-drift", action="store_true",
                    help="engine mode: adaptive lane metering + mid-run "
                         "replan of waiting queues on measured speed drift")
    ap.add_argument("--drift-at", type=int, default=-1, metavar="K",
                    help="steady-state mode: shift the key distribution at batch K")
    ap.add_argument("--max-drift", type=float, default=0.15)
    ap.add_argument("--max-age", type=int, default=None)
    ap.add_argument("--revalidate-every", type=int, default=1)
    ap.add_argument("--max-speed-drift", type=float, default=0.25,
                    help="replan when a slot's measured speed moves this much")
    ap.add_argument("--slot-slowdown", action="append", metavar="I:FACTOR",
                    help="inject a straggler: slot/lane I takes FACTOR x the "
                         "nominal wall-clock (2 = twice as slow; repeatable, "
                         "e.g. 3:2; 0 = the slot/lane is DEAD)")
    ap.add_argument("--checkpoint-waves", action="store_true",
                    help="steady-state mode: persist phase-B progress at "
                         "wave granularity so a mid-batch slot death "
                         "replays only the unfinished waves")
    ap.add_argument("--kill-at-wave", action="append", metavar="I:W",
                    help="fault injection: slot I dies just before phase-B "
                         "wave W of the first batch (repeatable; requires "
                         "--checkpoint-waves)")
    ap.add_argument("--schedule-snapshot", default=None, metavar="PATH",
                    help="steady-state mode: warm-start from a persisted "
                         "CachedSchedule JSON (skips the cold replan)")
    ap.add_argument("--save-snapshot", default=None, metavar="PATH",
                    help="steady-state mode: write the final plan's "
                         "CachedSchedule JSON on exit")
    ap.add_argument("--jobs", type=int, default=1,
                    help="engine mode: spread the requests round-robin over "
                         "N job ids — admission becomes the R||C_max "
                         "multi-job path (weighted completion order, "
                         "per-job lane-speed rows)")
    ap.add_argument("--job-weights", default=None, metavar="W0,W1,...",
                    help="comma-separated ΣwC priority weight per job id "
                         "(default: all 1.0)")
    ap.add_argument("--max-concurrent-jobs", type=int, default=None,
                    metavar="K",
                    help="admit at most K jobs per plan wave; later jobs "
                         "queue strictly behind the earlier wave")
    ap.add_argument("--stats", default="exact", choices=("exact", "sketch"),
                    help="statistics layer: exact histograms, or count-min "
                         "sketch planning (steady-state mode: O(sketch) "
                         "plan inputs; engine mode: sketch-budgeted "
                         "admission). Outputs are bit-identical either way")
    ap.add_argument("--stream-prefix", type=float, default=None,
                    metavar="FRAC",
                    help="steady-state mode with --stats sketch: plan wave 1 "
                         "from a sketch of the first FRAC of each shard's "
                         "pairs, refine the tail waves when the rest lands")
    args = ap.parse_args()

    if args.steady_state > 0:
        if args.scheduler is None:
            args.scheduler = "auto"   # steady-state default: cost-model pick
        _steady_state_main(args)
        return
    if args.scheduler is None:
        args.scheduler = "os4m"
    if args.stream_prefix is not None:
        raise SystemExit("--stream-prefix applies to --steady-state mode "
                         "(MapReduce batches) only")

    import numpy as np
    import jax

    from repro.configs import get_smoke
    from repro.models.model import init_model
    from repro.nn import layers as L
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg = get_smoke(args.arch)
    params, _ = L.split(init_model(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        # zipf-skewed decode budgets: the operation-load skew of Fig 1a
        budget = int(np.clip(rng.zipf(1.5) * 4, 4, args.max_len - plen - 2))
        reqs.append(Request(
            rid=i, prompt=rng.integers(3, cfg.vocab, plen).astype(np.int32),
            max_new=budget, job=i % max(args.jobs, 1)))

    job_weights = None
    if args.job_weights:
        ws = [float(w) for w in args.job_weights.split(",")]
        job_weights = {j: w for j, w in enumerate(ws)}

    lane_speeds = None
    slowdowns = parse_slowdowns(args.slot_slowdown)
    if slowdowns:
        lane_speeds = np.ones(args.lanes)
        for lane, factor in slowdowns:
            if not 0 <= lane < args.lanes:
                raise SystemExit(f"--slot-slowdown lane {lane} out of range")
            # Factor is a wall-clock multiplier; lane speed is its inverse
            # — and factor 0 is a dead lane (speed exactly 0.0).
            lane_speeds[lane] = 1.0 / factor if factor > 0 else 0.0
    eng = Engine(cfg, params, EngineConfig(
        lanes=args.lanes, max_len=args.max_len, scheduler=args.scheduler,
        lane_speeds=lane_speeds,
        adaptive=args.replan_on_drift,
        replan_on_drift=args.replan_on_drift,
        max_concurrent_jobs=args.max_concurrent_jobs,
        job_weights=job_weights,
        stats=args.stats))
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"scheduler={args.scheduler}: {len(done)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s), "
          f"lane balance ratio {eng.last_balance_ratio:.3f}, "
          f"finish ratio {eng.last_finish_ratio:.3f}"
          + (f", {eng.replans} mid-run replans" if args.replan_on_drift
             else ""))
    if args.jobs > 1:
        for j in range(args.jobs):
            jd = [r for r in done if r.job == j]
            jt = sum(len(r.output) for r in jd)
            print(f"  job {j}: {len(jd)} requests, {jt} tokens, "
                  f"weight {job_weights.get(j, 1.0) if job_weights else 1.0}")


if __name__ == "__main__":
    main()
