"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching engine (OS4M lane scheduling) on synthetic
requests with the arch's smoke twin; reports lane balance and throughput
for os4m vs the hash baseline.
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--scheduler", default="os4m")
    args = ap.parse_args()

    import numpy as np
    import jax

    from repro.configs import get_smoke
    from repro.models.model import init_model
    from repro.nn import layers as L
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg = get_smoke(args.arch)
    params, _ = L.split(init_model(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        # zipf-skewed decode budgets: the operation-load skew of Fig 1a
        budget = int(np.clip(rng.zipf(1.5) * 4, 4, args.max_len - plen - 2))
        reqs.append(Request(
            rid=i, prompt=rng.integers(3, cfg.vocab, plen).astype(np.int32),
            max_new=budget))

    eng = Engine(cfg, params, EngineConfig(
        lanes=args.lanes, max_len=args.max_len, scheduler=args.scheduler))
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"scheduler={args.scheduler}: {len(done)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s), "
          f"lane balance ratio {eng.last_balance_ratio:.3f}")


if __name__ == "__main__":
    main()
