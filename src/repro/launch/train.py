"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the smoke twin of the chosen arch on
synthetic data (the production mesh path is exercised by dryrun.py); on a
real fleet the same driver runs the full config (--full) under the
production mesh.
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full config on the production mesh (TPU fleet)")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_cli")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--scheduler", default="os4m",
                    help="packing scheduler: os4m | lpt | hash")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke
    from repro.data import packing
    from repro.data.synthetic import CorpusConfig, token_batches
    from repro.launch.mesh import make_production_mesh, single_device_mesh
    from repro.models.config import Shape
    from repro.train.loop import Trainer, TrainerConfig
    from repro.train.optim import OptConfig

    if args.full:
        cfg = get_config(args.arch)
        mesh = make_production_mesh()
    else:
        cfg = get_smoke(args.arch)
        mesh = single_device_mesh()
    shape = Shape("cli", "train", args.seq, args.batch)

    trainer = Trainer(
        cfg, shape, mesh,
        opt_cfg=OptConfig(lr=args.lr, warmup_steps=10, decay_steps=args.steps),
        tcfg=TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25,
                           replan_interval=10))
    if args.resume and trainer.try_resume():
        print(f"resumed from step {trainer.step}")

    corpus = CorpusConfig(vocab=cfg.vocab)
    packer = lambda docs, b, s: packing.pack_documents(
        docs, b, s, scheduler=args.scheduler)
    batches = token_batches(corpus, seed=0, batch=args.batch,
                            seq_len=args.seq, packer=packer)

    def log(step, m):
        print(f"step {step:5d}  loss {m.get('loss', float('nan')):.4f}  "
              f"gnorm {m.get('grad_norm', 0):.3f}  lr {m.get('lr', 0):.2e}"
              + (f"  balance {m['balance_ratio']:.3f}"
                 if "balance_ratio" in m else ""))

    trainer.run(batches, args.steps, on_metrics=log)
    trainer.save()
    print(f"done at step {trainer.step}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
