import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init). 512 placeholder host devices back both the 16×16
single-pod mesh and the 2×16×16 multi-pod mesh.

Per cell this script:
  1. builds the production mesh (launch/mesh.py),
  2. builds the step function + ShapeDtypeStruct inputs (launch/steps.py) —
     no allocation anywhere,
  3. ``jit(...).lower(...).compile()``,
  4. prints ``compiled.memory_analysis()`` (proves it fits per chip) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline),
  5. parses collective bytes from the optimized HLO (launch/hlo_analysis),
  6. appends a JSON record to --out (read by benchmarks/roofline.py and
     EXPERIMENTS.md §Dry-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             opts=None) -> dict:
    import jax

    from repro.configs import get_config
    from repro.launch import hlo_analysis as H
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step_for_shape
    from repro.models.config import SHAPES, shape_applicable

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "skipped", "reason": why}
        _append(out_dir, rec)
        print(f"[skip] {arch} × {shape_name}: {why}")
        return rec

    # Dry-run numeric conventions: bf16 params/compute/logits; bf16
    # optimizer moments for the ≥200B MoE archs (ZeRO + low-precision
    # state — DESIGN.md §7).
    overrides = {"logit_dtype": "bfloat16"}
    if opts:
        overrides.update(opts)
    cfg = dataclasses.replace(cfg, **overrides)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    kw = {}
    if shape.kind == "train":
        from repro.train.optim import OptConfig
        n_params = cfg.param_count()
        moment_dtype = "bfloat16" if n_params > 1e11 else "float32"
        kw["opt_cfg"] = OptConfig(moment_dtype=moment_dtype)
        # Microbatch so activations/dispatch buffers fit 16 GB HBM.
        kw["microbatches"] = 8 if n_params > 1e11 else (
            2 if n_params > 5e9 else 1)
        if cfg.parallelism == "fsdp":
            # full-mesh batch sharding needs the whole global batch
            kw["microbatches"] = 1

    t0 = time.time()
    step, example = build_step_for_shape(cfg, mesh, shape, **kw)
    with mesh:
        lowered = step.lower(*example)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    print(f"== {arch} × {shape_name} on "
          f"{'2x16x16' if multi_pod else '16x16'} ==")
    print(f"memory_analysis: {mem}")
    print(f"cost_analysis: flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")

    # Loop-aware analysis of the optimized per-device SPMD program (XLA's
    # cost_analysis counts while bodies once — see hlo_analysis docstring).
    hlo = compiled.as_text()
    analysis = H.analyze_hlo(hlo, default_trip=cfg.n_layers)
    terms = H.RooflineTerms(
        flops=analysis["flops"], hbm_bytes=analysis["hbm_bytes"],
        collective_bytes=analysis["collective_bytes"], chips=chips)

    # MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), per device.
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
    mults = 6.0 if shape.kind == "train" else 2.0
    model_flops = mults * cfg.active_param_count() * tokens / chips

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "chips": chips,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "microbatches": kw.get("microbatches", 1),
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_memory_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                              + getattr(mem, "temp_size_in_bytes", 0)),
        "cost_flops_xla_loopless": float(cost.get("flops", 0.0)),
        "cost_bytes_xla_loopless": float(cost.get("bytes accessed", 0.0)),
        "hbm_bytes_parsed_pessimistic": analysis["hbm_bytes_parsed"],
        "collective_bytes_total": analysis["collective_bytes"],
        "collective_bytes_by_type": analysis["collective_bytes_by_type"],
        "collective_count_by_type": analysis["collective_count_by_type"],
        "roofline": terms.as_dict(),
        "model_flops_per_chip": model_flops,
        "useful_flops_ratio": (model_flops / terms.flops) if terms.flops else 0.0,
        "model_params": cfg.param_count(),
        "model_active_params": cfg.active_param_count(),
    }
    _append(out_dir, rec)
    print(f"roofline: {terms.as_dict()}")
    print(f"[ok] compile={t_compile:.1f}s "
          f"temp/chip={rec['temp_size_bytes']/2**30:.2f} GiB "
          f"args/chip={rec['argument_size_bytes']/2**30:.2f} GiB")
    return rec


def _append(out_dir: Path, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=2))


def _done(out_dir: Path, arch, shape, mesh) -> bool:
    f = out_dir / f"{arch}_{shape}_{mesh}.json"
    if not f.exists():
        return False
    try:
        return json.loads(f.read_text()).get("status") in ("ok", "skipped")
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides, e.g. --set parallelism=fsdp")
    args = ap.parse_args()
    out_dir = Path(args.out)
    opts = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        opts[k] = v

    from repro.configs import ARCH_IDS, ALIASES
    from repro.models.config import SHAPES

    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        arch = ALIASES.get(args.arch, args.arch)
        cells = [(arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch, shape in cells:
            if args.skip_done and _done(out_dir, arch, shape, mesh_name):
                print(f"[done] {arch} × {shape} × {mesh_name}")
                continue
            try:
                run_cell(arch, shape, multi_pod, out_dir, opts=opts or None)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape, mesh_name, str(e)[:200]))
                _append(out_dir, {"arch": arch, "shape": shape,
                                  "mesh": mesh_name, "status": "failed",
                                  "error": str(e)[:500]})
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
