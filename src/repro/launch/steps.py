"""Step builders + input specs for training and serving.

Everything here is geared to both real execution (examples/tests on small
meshes) and the allocation-free multi-pod dry-run:
``build_*_step`` returns ``(jitted_fn, example_inputs)`` where the example
inputs are ShapeDtypeStructs with NamedShardings attached — calling
``jitted_fn.lower(*example_inputs)`` compiles the production program
without allocating anything (the shannon/kernels input_specs pattern).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, Shape
from repro.models import model as MDL
from repro.nn import layers as L
from repro.nn.sharding import MeshAxes, make_shardings
from repro.train.optim import OptConfig, adamw_step, init_opt

__all__ = [
    "param_specs", "input_specs", "cache_specs",
    "build_train_step", "build_prefill_step", "build_decode_step",
    "build_step_for_shape",
]


def _dp_axes(mesh: Mesh, cfg: Optional[ModelConfig] = None):
    axes = MeshAxes.from_mesh(mesh)
    if cfg is not None and cfg.parallelism == "fsdp":
        return tuple(axes.data) + (axes.model,)
    return axes.data


def _dp_size(mesh: Mesh, cfg: Optional[ModelConfig] = None) -> int:
    s = 1
    for a in _dp_axes(mesh, cfg):
        s *= mesh.shape[a]
    return s


def _div(dim: int, mesh: Mesh, axes) -> Optional[Any]:
    """axes if they divide dim, else None (replicate)."""
    if axes is None:
        return None
    flat = tuple(axes) if isinstance(axes, (tuple, list)) else (axes,)
    size = 1
    for a in flat:
        size *= mesh.shape[a]
    if dim % size != 0 or dim == 0:
        return None
    return axes if isinstance(axes, (tuple, list, str)) else axes


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# Abstract params / optimizer / cache with shardings
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig, mesh: Mesh, seed: int = 0):
    """(shapes, logical, shardings) for the model parameters."""
    from repro.nn.sharding import default_rules

    key = jax.random.PRNGKey(seed)
    ptree = jax.eval_shape(lambda k: MDL.init_model(k, cfg, mesh), key)
    shapes, logical = L.split(ptree)
    rules = default_rules(MeshAxes.from_mesh(mesh), cfg.parallelism)
    shardings = make_shardings(shapes, logical, mesh, rules)
    return shapes, logical, shardings


def opt_specs(param_shapes, param_shardings, opt_cfg: OptConfig, mesh: Mesh):
    shapes = jax.eval_shape(lambda p: init_opt(p, opt_cfg), param_shapes)
    shardings = {
        "m": param_shardings,
        "v": param_shardings,
        "step": _ns(mesh),
    }
    return shapes, shardings


def _with_sharding(shapes, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    """Cache ShapeDtypeStructs + shardings: batch → dp, seq → model."""
    shapes = jax.eval_shape(
        functools.partial(MDL.init_cache, cfg, batch, max_len, dtype))
    axes = MeshAxes.from_mesh(mesh)
    dp, model = axes.data, axes.model

    def spec_for(path, leaf):
        shape = leaf.shape
        # Identify (batch, seq) dims by convention per cache family.
        names = [None] * len(shape)
        keys = jax.tree_util.keystr(path)
        if "'ssm'" in keys and "mamba" in keys:
            names[2] = "batch"                       # (G,K,B,H,P,N)
        elif "'conv'" in keys and "mamba" in keys:
            names[2] = "batch"
        elif "mlstm" in keys and "'cell'" in keys:
            names[2] = "batch"                       # (G,per,B,...)
        elif "mlstm" in keys and "'conv'" in keys:
            names[2] = "batch"
        elif "slstm" in keys:
            names[1] = "batch"                       # (G,B,nh,hd)
        elif "c_kv" in keys or "k_pe" in keys:
            names[1], names[2] = "batch", "seq"      # (L,B,S,d)
        elif "cross" in keys:
            names[1], names[2] = "batch", "seq"      # (L,B,enc,kv,hd)
        else:
            names[1], names[2] = "batch", "seq"      # (L,B,S,kv,hd) / (G,B,S,..)
        spec = []
        for d, nm in zip(shape, names):
            if nm == "batch":
                spec.append(_div(d, mesh, dp))
            elif nm == "seq":
                spec.append(_div(d, mesh, model))
            else:
                spec.append(None)
        return NamedSharding(mesh, P(*spec))

    shardings = jax.tree_util.tree_map_with_path(spec_for, shapes)
    return shapes, shardings


# ---------------------------------------------------------------------------
# Batch / token input specs
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: Shape, mesh: Mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (with shardings) for every model input."""
    dp = _dp_axes(mesh, cfg)
    b = shape.global_batch
    bspec = _div(b, mesh, dp)
    out: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        t_text = shape.seq_len - (cfg.n_patches or 0)
        out["tokens"] = jax.ShapeDtypeStruct(
            (b, t_text), jnp.int32, sharding=_ns(mesh, bspec, None))
        if cfg.n_patches:
            out["extra_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), jnp.bfloat16,
                sharding=_ns(mesh, bspec, None, None))
        if cfg.enc_dec:
            out["extra_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_len, cfg.d_model), jnp.bfloat16,
                sharding=_ns(mesh, bspec, None, None))
    else:  # decode: one new token against a seq_len cache
        out["tokens"] = jax.ShapeDtypeStruct(
            (b, 1), jnp.int32, sharding=_ns(mesh, bspec, None))
    return out


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: Shape,
                     opt_cfg: OptConfig = OptConfig(),
                     max_load_ratio: float = 1.0, donate: bool = True,
                     microbatches: int = 1,
                     moe_pipeline_chunks: Optional[int] = None):
    """Returns (jitted train_step, example_args).

    ``microbatches > 1`` splits the global batch and accumulates gradients
    (f32, param-sharded) across a ``lax.scan`` — activation/dispatch
    footprint scales down by the factor while the optimizer step stays
    one-per-step. This is also the compute/comm overlap point: each
    microbatch's gradient reduction overlaps the next microbatch's
    forward in the XLA schedule.

    ``moe_pipeline_chunks`` overrides the MoE layers' chunked-dispatch
    pipelining (``MoEArgs.pipeline_chunks``): >1 splits each MoE
    all-to-all into that many capacity slabs, overlapping expert FFN with
    the next slab's "copy" (the §4.4 pipeline applied to token dispatch).
    """
    if moe_pipeline_chunks is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, pipeline_chunks=int(moe_pipeline_chunks)))
    mb_batch = shape.global_batch // max(microbatches, 1)
    moe_cap = MDL.moe_capacity_for_shape(
        cfg, mb_batch, shape.seq_len, mesh, max_load_ratio)
    n_moe = cfg.n_layers - cfg.first_k_dense if cfg.moe is not None else 0

    def loss_for(p, tokens, extra, placements):
        out = MDL.forward(
            p, cfg, tokens=tokens, extra_embed=extra, mesh=mesh,
            mode="train", placements=placements, moe_capacity=moe_cap)
        lg = out.logits
        npch = cfg.n_patches or 0
        loss = MDL.lm_loss(lg[:, npch:-1], tokens[:, 1:])
        aux = (out.stats or {}).get("aux_loss", 0.0)
        extras = {k: v for k, v in (out.stats or {}).items()}
        return loss + aux, (loss, extras)

    def train_step(params, opt_state, batch, placements):
        if microbatches <= 1:
            (total, (loss, extras)), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch["tokens"],
                                        batch.get("extra_embed"), placements)
        else:
            toks = batch["tokens"].reshape(
                (microbatches, mb_batch) + batch["tokens"].shape[1:])
            extra = batch.get("extra_embed")
            if extra is not None:
                extra = extra.reshape((microbatches, mb_batch) + extra.shape[1:])

            def mb_body(acc, mb):
                g_acc, tot_acc, loss_acc = acc
                t_mb = mb[0] if extra is not None else mb
                e_mb = mb[1] if extra is not None else None
                (tot, (loss, _)), g = jax.value_and_grad(
                    loss_for, has_aux=True)(params, t_mb, e_mb, placements)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, tot_acc + tot, loss_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (toks, extra) if extra is not None else toks
            (grads, total, loss), _ = jax.lax.scan(
                mb_body, (g0, jnp.float32(0), jnp.float32(0)), xs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            total, loss = total / microbatches, loss / microbatches
            extras = {}
        params, opt_state, om = adamw_step(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "total_loss": total, **om}
        if "expert_counts" in extras:
            metrics["expert_counts"] = extras["expert_counts"]
            metrics["overflow"] = extras["overflow"]
        return params, opt_state, metrics

    pshapes, plogical, pshard = param_specs(cfg, mesh)
    oshapes, oshard = opt_specs(pshapes, pshard, opt_cfg, mesh)
    batch = input_specs(cfg, shape, mesh)
    if cfg.moe is not None:
        placements = jax.ShapeDtypeStruct(
            (n_moe, 2, cfg.moe.num_experts), jnp.int32, sharding=_ns(mesh))
    else:
        placements = None

    jitted = jax.jit(
        train_step,
        donate_argnums=(0, 1) if donate else (),
    )
    example = (
        _with_sharding(pshapes, pshard),
        _with_sharding(oshapes, oshard),
        batch,
        placements,
    )
    return jitted, example


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: Shape,
                       cache_dtype=jnp.bfloat16):
    """Prefill: run the prompt, return (last-token logits, filled cache)."""
    moe_cap = MDL.moe_capacity_for_shape(
        cfg, shape.global_batch, shape.seq_len, mesh)

    def prefill_step(params, batch, cache):
        out = MDL.forward(
            params, cfg, tokens=batch["tokens"],
            extra_embed=batch.get("extra_embed"), mesh=mesh, mode="prefill",
            cache=cache, cache_pos=jnp.int32(0), moe_capacity=moe_cap)
        return out.logits[:, -1:], out.cache

    pshapes, _, pshard = param_specs(cfg, mesh)
    batch = input_specs(cfg, shape, mesh)
    cshapes, cshard = cache_specs(cfg, mesh, shape.global_batch,
                                  shape.seq_len, cache_dtype)
    jitted = jax.jit(prefill_step, donate_argnums=(2,))
    example = (_with_sharding(pshapes, pshard), batch,
               _with_sharding(cshapes, cshard))
    return jitted, example


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: Shape,
                      cache_dtype=jnp.bfloat16):
    """One new token with a KV cache of seq_len (serve_step)."""
    moe_cap = MDL.moe_capacity_for_shape(cfg, shape.global_batch, 1, mesh)

    def decode_step(params, cache, batch, pos):
        out = MDL.forward(
            params, cfg, tokens=batch["tokens"], mesh=mesh, mode="decode",
            cache=cache, cache_pos=pos, moe_capacity=moe_cap)
        return out.logits, out.cache

    pshapes, _, pshard = param_specs(cfg, mesh)
    batch = input_specs(cfg, shape, mesh)
    cshapes, cshard = cache_specs(cfg, mesh, shape.global_batch,
                                  shape.seq_len, cache_dtype)
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=_ns(mesh))
    jitted = jax.jit(decode_step, donate_argnums=(1,))
    example = (_with_sharding(pshapes, pshard),
               _with_sharding(cshapes, cshard), batch, pos)
    return jitted, example


def build_step_for_shape(cfg: ModelConfig, mesh: Mesh, shape: Shape, **kw):
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape)
    return build_decode_step(cfg, mesh, shape)
