"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (jax locks the device count on first init —
dryrun.py must set XLA_FLAGS before any jax call).

* single-pod:  16 × 16 = 256 chips, axes ("data", "model")
* multi-pod:   2 × 16 × 16 = 512 chips, axes ("pod", "data", "model")

The "pod" axis is pure data parallelism across pods (DCN-class links);
"data" is in-pod DP/FSDP; "model" carries TP/EP/sequence sharding.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """General mesh helper (tests / small CPU meshes)."""
    return compat.make_mesh(shape, axes)


def single_device_mesh() -> Mesh:
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
