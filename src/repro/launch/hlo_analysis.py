"""Roofline-term extraction from compiled dry-run artifacts.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so a
scan-over-layers program under-reports FLOPs/bytes by ~n_layers×
(verified empirically: flops barely change from L=2 to L=8). This module
therefore parses the optimized HLO text itself:

* computations are split out and weighted by loop trip count — a
  computation reached through a while-loop body (or nested scans)
  inherits the product of trip counts via call-graph propagation;
* FLOPs: every ``dot`` contributes 2·numel(out)·K (K = product of its
  lhs contracting dims, shapes resolved through a per-computation symbol
  table including fusion parameters); elementwise/reduce ops contribute
  numel(out);
* HBM bytes: for every instruction in a non-fusion-internal computation,
  operand bytes + output bytes (fusion internals stay in
  registers/VMEM — the fusion call's own operands/outputs are the HBM
  traffic, which is exactly XLA's fusion memory model);
* collective bytes: output sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (−start only for
  async pairs), same loop weighting.

Validated in tests/test_hlo_analysis.py against closed-form matmul and
scan programs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = [
    "CollectiveStats", "parse_collectives", "analyze_hlo",
    "RooflineTerms", "roofline_terms", "HW",
]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_GROUP_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def _parse_def(line: str):
    """Parse '%name = TYPE op(operands...), attrs' robustly.

    Handles tuple types containing ``/*index=N*/`` comments (which embed
    '=' and break naive regexes). Returns (name, type_str, op, operands_str)
    or None.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):          # tuple type: balance parens
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, rest2 = rest[:end + 1], rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest2 = rest[:sp], rest[sp:]
    mo = _OP_RE.match(rest2)
    if not mo:
        return None
    op = mo.group(1)
    args_start = rest2.index("(", mo.start(1))
    depth = 0
    end = len(rest2)
    for i in range(args_start, len(rest2)):
        if rest2[i] == "(":
            depth += 1
        elif rest2[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = rest2[args_start + 1:end]
    return name, type_str, op, operands
_CALLEE_SINGLE_RE = re.compile(r"(?:to_apply|calls|body|condition)=%?([\w.\-]+)")
_CALLEE_LIST_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "tanh", "rsqrt", "sqrt", "power", "negate", "abs", "select",
    "compare", "and", "or", "xor", "convert", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "reduce", "clamp",
}

_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_GROUP_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_numel(shape_str: str) -> int:
    n_total = 0
    for _, dims in _SHAPE_GROUP_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        n_total += n
    return n_total


def _first_shape_dims(shape_str: str) -> Optional[List[int]]:
    m = _SHAPE_GROUP_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d.strip()]


@dataclasses.dataclass
class _Comp:
    name: str
    lines: List[str]
    symtab: Dict[str, str]          # instr name -> type string
    fusion_internal: bool = False


def _split_computations(hlo_text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    current: Optional[_Comp] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip()) if ("->" in line and "{" in line) else None
        if hdr:
            current = _Comp(hdr.group(1), [], {})
            comps[current.name] = current
            # computation parameters: "name: TYPE" pairs
            for part in hdr.group(2).split(","):
                if ":" in part:
                    pname, ptype = part.split(":", 1)
                    current.symtab[pname.strip().lstrip("%")] = ptype.strip()
            continue
        if current is None:
            continue
        current.lines.append(line)
        d = _parse_def(line)
        if d:
            current.symtab[d[0]] = d[1].strip()
    return comps


def _call_weights(comps: Dict[str, _Comp], default_trip: int) -> Dict[str, float]:
    """Weight per computation = product of enclosing loop trip counts."""
    trip_re = re.compile(r'trip_count["\s:=]+(\d+)')
    known_trip_re = re.compile(r'known_trip_count[^\d]*(\d+)')
    # direct call edges: (caller, callee, is_loop_body, trip)
    edges: List[Tuple[str, str, float]] = []
    for comp in comps.values():
        for line in comp.lines:
            is_while = re.search(r"[=\s]while\(", line) is not None
            trip = 1.0
            if is_while:
                tm = known_trip_re.search(line) or trip_re.search(line)
                trip = float(tm.group(1)) if tm else float(default_trip)
            callees = list(_CALLEE_SINGLE_RE.findall(line))
            for grp in _CALLEE_LIST_RE.findall(line):
                callees.extend(c.strip().lstrip("%") for c in grp.split(","))
            for callee in callees:
                if callee in comps:
                    # condition computations run trip+1 times; treat as trip
                    edges.append((comp.name, callee, trip if is_while else 1.0))
            if "fusion" in line and "calls=" in line:
                for m in re.finditer(r"calls=%?([\w.\-]+)", line):
                    if m.group(1) in comps:
                        comps[m.group(1)].fusion_internal = True

    weight: Dict[str, float] = {}
    entry = None
    for name in comps:
        if entry is None:
            entry = name
    # find entry: computation never called
    callees = {c for _, c, _ in edges}
    roots = [n for n in comps if n not in callees]
    for r in roots:
        weight[r] = 1.0
    for _ in range(32):
        changed = False
        for caller, callee, trip in edges:
            w = weight.get(caller, 0.0) * trip
            if w > weight.get(callee, 0.0):
                weight[callee] = w
                changed = True
        if not changed:
            break
    return weight


def _dot_flops(comp: _Comp, out_type: str, operands: str, line: str) -> float:
    out_numel = _shape_numel(out_type)
    ops = _OPERAND_RE.findall(operands)
    if not ops:
        return 0.0
    lhs_shape = comp.symtab.get(ops[0])
    if lhs_shape is None:
        return 2.0 * out_numel  # unknown K; undercount deliberately
    lhs_dims = _first_shape_dims(lhs_shape) or []
    cm = _CONTRACT_RE.search(line)
    k = 1
    if cm:
        for idx in cm.group(1).split(","):
            idx = idx.strip()
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_numel * k


# Ops whose output (and for dots/custom-calls, operands) represent genuine
# HBM streaming in the TPU memory model. Everything else (converts, copies,
# selects, bitcasts, small elementwise fusions) is assumed fused/elided by a
# TPU backend — the "model" byte count. The "parsed" count keeps everything
# XLA-CPU actually materialised (pessimistic bound).
_MODEL_TRAFFIC_OUT = {
    "dynamic-slice", "gather", "reduce", "reduce-window", "broadcast",
    "dynamic-update-slice", "scatter", "sort", "concatenate", "pad",
    "slice",
}


def analyze_hlo(hlo_text: str, default_trip: int = 1,
                kernel_attention: bool = False) -> Dict[str, float]:
    """Loop-aware FLOPs / HBM bytes / collective bytes from optimized HLO.

    ``kernel_attention=True`` models replacing the XLA blocked-attention
    path with the Pallas flash kernel: dots whose output is a ≥5-D f32
    score/probability block (the (b, hkv, g, t, bk) tensors) stop counting
    their (t×s)-sized operands/outputs toward HBM — on TPU those tiles
    live in VMEM — while their FLOPs are kept (halved for the causal skip
    is reported separately by the caller).
    """
    comps = _split_computations(hlo_text)
    weight = _call_weights(comps, default_trip)

    flops = 0.0
    hbm_parsed = 0.0
    hbm_model = 0.0
    coll_bytes: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    coll_count: Dict[str, float] = {c: 0 for c in _COLLECTIVES}

    def _operand_bytes(comp, operands):
        b = 0
        shapes = []
        for ref in _OPERAND_RE.findall(operands):
            t = comp.symtab.get(ref)
            if t:
                b += _shape_bytes(t)
                shapes.append(t)
        return b, shapes

    for comp in comps.values():
        w = weight.get(comp.name, 1.0)
        for line in comps[comp.name].lines:
            d = _parse_def(line)
            if not d:
                continue
            _, out_type, op, operands = d
            out_type = out_type.strip()
            if op == "dot":
                flops += w * _dot_flops(comp, out_type, operands, line)
            elif op in _ELEMENTWISE:
                flops += w * _shape_numel(out_type)
            is_coll = False
            for c in _COLLECTIVES:
                if op == c or op == c + "-start":
                    b = _shape_bytes(out_type)
                    coll_bytes[c] += w * b
                    coll_count[c] += w
                    is_coll = True
                    break
            if comp.fusion_internal or op in _NO_TRAFFIC or op.endswith("-done"):
                continue
            b_out = _shape_bytes(out_type)
            b_in, in_shapes = _operand_bytes(comp, operands)
            hbm_parsed += w * (b_out + b_in)
            if is_coll:
                continue  # collective traffic is its own roofline term
            if op in ("dot", "custom-call"):
                if kernel_attention and op == "dot":
                    dims = _first_shape_dims(out_type) or []
                    if len(dims) >= 5:
                        # attention score/out tile: VMEM-resident in kernel;
                        # charge only non-(t×s) operands (q/k/v slabs).
                        small_ops = sum(
                            _shape_bytes(t) for t in in_shapes
                            if len(_first_shape_dims(t) or []) < 5)
                        hbm_model += w * small_ops
                        continue
                hbm_model += w * (b_out + b_in)
            elif op in _MODEL_TRAFFIC_OUT:
                hbm_model += w * b_out
    return {
        "flops": flops,
        "hbm_bytes": hbm_model,
        "hbm_bytes_parsed": hbm_parsed,
        "collective_bytes": sum(coll_bytes.values()),
        "collective_bytes_by_type": coll_bytes,
        "collective_count_by_type": coll_count,
    }


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: Dict[str, float]
    count_by_type: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_type.values())


def parse_collectives(hlo_text: str, default_trip: int = 1) -> CollectiveStats:
    a = analyze_hlo(hlo_text, default_trip)
    return CollectiveStats(a["collective_bytes_by_type"],
                           a["collective_count_by_type"])


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e-class constants)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # B/s per chip
    ici_bw: float = 50e9            # B/s per link (~per chip usable)


@dataclasses.dataclass
class RooflineTerms:
    flops: float                    # per-device HLO FLOPs
    hbm_bytes: float
    collective_bytes: float
    chips: int
    hw: HW = dataclasses.field(default_factory=HW)

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "step_time_lower_bound_s": self.step_time,
        }


def roofline_terms_from_hlo(hlo_text: str, chips: int, default_trip: int = 1,
                            hw: HW = HW()) -> RooflineTerms:
    """All three terms from the optimized per-device SPMD program."""
    a = analyze_hlo(hlo_text, default_trip)
    return RooflineTerms(
        flops=a["flops"], hbm_bytes=a["hbm_bytes"],
        collective_bytes=a["collective_bytes"], chips=chips, hw=hw,
    )


def roofline_terms(cost_analysis: dict, collectives: CollectiveStats,
                   chips: int, hw: HW = HW()) -> RooflineTerms:
    flops = float(cost_analysis.get("flops", 0.0))
    bytes_accessed = float(cost_analysis.get("bytes accessed", 0.0))
    return RooflineTerms(
        flops=flops, hbm_bytes=bytes_accessed,
        collective_bytes=collectives.total_bytes / max(chips, 1),
        chips=chips, hw=hw,
    )
