"""Version-adaptive jax API shims.

The substrate targets the current jax API (``jax.shard_map``,
``jax.lax.pvary``, ``jax.sharding.AxisType``) but must also run on the
0.4.x line this container ships. Every call site goes through this module
so the divergence lives in exactly one place.

* :func:`shard_map` — ``jax.shard_map`` when present, else
  ``jax.experimental.shard_map.shard_map``; the replication-check kwarg is
  translated (``check_vma`` new / ``check_rep`` old).
* :func:`pvary` — device-variance annotation; identity where the
  primitive does not exist (older jax infers variance itself).
* :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types`` when the
  installed jax knows about explicit axis types, plain otherwise.
"""

from __future__ import annotations

import inspect

import jax
import numpy as np

__all__ = ["shard_map", "pvary", "make_mesh", "tpu_compiler_params"]


if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _check_kwarg = (
        "check_vma"
        if "check_vma" in inspect.signature(jax.shard_map).parameters
        else "check_rep"
    )
else:  # jax < 0.6: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore

    _check_kwarg = "check_rep"


def shard_map(fn, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions (replication check off by default)."""
    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_check_kwarg: check},
    )


def pvary(x, axis_name):
    """Mark ``x`` device-varying over ``axis_name`` (no-op on older jax)."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_name)
    return x


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the CompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    shape, axes = tuple(shape), tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    devices = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)
