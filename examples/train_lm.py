"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

A shrunk SmolLM (d_model 512, 12 layers, 49k vocab ≈ 90M params) on the
synthetic corpus with OS4M packing, AdamW + cosine schedule, atomic
checkpoints, and resume-on-restart. CPU-sized batches keep this runnable
in minutes; pass --steps 300 for the full run.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import time

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
args = ap.parse_args()

from repro.configs import get_config
from repro.data import packing
from repro.data.synthetic import CorpusConfig, token_batches
from repro.launch.mesh import single_device_mesh
from repro.models.config import Shape
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optim import OptConfig

# ~100M-param config of the smollm family.
base = get_config("smollm-360m")
cfg = dataclasses.replace(
    base, name="smollm-100m", n_layers=12, d_model=512, n_heads=8, n_kv=4,
    d_ff=1536, param_dtype="float32", compute_dtype="float32",
    logit_dtype="float32")
print(f"model: {cfg.name}  params ~{cfg.param_count() / 1e6:.0f}M")

trainer = Trainer(
    cfg, Shape("e2e", "train", args.seq, args.batch), single_device_mesh(),
    opt_cfg=OptConfig(lr=6e-4, warmup_steps=20, decay_steps=args.steps),
    tcfg=TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10))
if trainer.try_resume():
    print(f"resumed from step {trainer.step}")

corpus = CorpusConfig(vocab=cfg.vocab, zipf_alpha=1.1)
batches = token_batches(
    corpus, seed=0, batch=args.batch, seq_len=args.seq,
    packer=lambda d, b, s: packing.pack_documents(d, b, s, scheduler="os4m"))

t0 = time.time()
hist = trainer.run(batches, args.steps - trainer.step,
                   on_metrics=lambda s, m: print(
                       f"step {s:4d}  loss {m['loss']:.4f}  "
                       f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}"))
trainer.save()
dt = time.time() - t0
tok = args.steps * args.batch * args.seq
print(f"\nfinal loss {hist[-1][1]['loss']:.4f} "
      f"({tok / max(dt, 1e-9):.0f} tok/s on CPU); "
      f"checkpoints in {args.ckpt_dir}")
