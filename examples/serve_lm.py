"""Continuous-batching serving with OS4M lane scheduling.

Serves a batch of synthetic requests (zipf-skewed decode budgets — the
operation-load skew of paper Fig 1a) through the engine under the hash
baseline and the OS4M schedule, and reports lane balance + step counts.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np

import jax

from repro.configs import get_smoke
from repro.models.model import init_model
from repro.nn import layers as L
from repro.serve.engine import Engine, EngineConfig, Request

cfg = get_smoke("llama3-8b")
params, _ = L.split(init_model(jax.random.PRNGKey(0), cfg))

rng = np.random.default_rng(0)
reqs = []
for i in range(24):
    plen = int(rng.integers(4, 16))
    budget = int(np.clip(rng.zipf(1.5) * 3, 3, 48))
    reqs.append(Request(
        rid=i, prompt=rng.integers(3, cfg.vocab, plen).astype(np.int32),
        max_new=budget))
total_budget = sum(r.max_new for r in reqs)
print(f"{len(reqs)} requests, decode budgets 3..48 (total {total_budget})")

for sched in ("hash", "os4m"):
    eng = Engine(cfg, params, EngineConfig(lanes=4, max_len=96,
                                           scheduler=sched, eos=-1))
    fresh = [Request(r.rid, r.prompt, r.max_new) for r in reqs]
    t0 = time.time()
    done = eng.run(fresh)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"  {sched:5s}: {toks} tokens in {dt:5.1f}s  "
          f"lane balance ratio {eng.last_balance_ratio:.3f}")
print("(lower balance ratio = lanes finish together; the OS4M plan is the "
      "paper's global schedule, hash is eq. 3-1)")
