"""OS4M expert placement on a live MoE model (the technique end-to-end).

Trains a small deepseek-class MoE on skewed synthetic data; the router
develops hot experts, the in-step communication mechanism collects the
per-expert key distribution, and the balancer periodically re-solves
P||C_max, physically re-placing expert weights. The balancer is
**drift-gated** (``balancer_max_drift``): on steady routing it keeps the
live placement instead of re-solving every interval. Prints the balance
ratio of the baseline (contiguous/eq. 3-1 class) vs the OS4M placement,
then runs a steady-state serving loop through ONE persistent
``MapReduceJob`` whose schedule is reused across batches.

Run:  PYTHONPATH=src python examples/moe_balance.py
"""

import numpy as np

from repro.configs import get_smoke
from repro.data.synthetic import CorpusConfig, token_batches
from repro.launch.mesh import single_device_mesh
from repro.models.config import Shape
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optim import OptConfig
from repro.core.balancer import schedule_balanced_cardinality

cfg = get_smoke("deepseek-v2-236b")
print(f"arch: {cfg.name} — {cfg.moe.num_experts} experts, "
      f"top-{cfg.moe.top_k}, {cfg.first_k_dense} dense layer(s)")

trainer = Trainer(
    cfg, Shape("moe", "train", 64, 4), single_device_mesh(),
    opt_cfg=OptConfig(lr=2e-3, warmup_steps=5, decay_steps=60),
    tcfg=TrainerConfig(ckpt_dir="/tmp/moe_balance_ckpt", ckpt_every=1000,
                       replan_interval=10, balancer_max_drift=0.1,
                       log_every=10))
batches = token_batches(CorpusConfig(vocab=cfg.vocab, zipf_alpha=1.3),
                        seed=0, batch=4, seq_len=64)
trainer.run(batches, 30, on_metrics=lambda s, m: print(
    f"  step {s}: loss {m['loss']:.3f}"
    + (f"  balance {m['balance_ratio']:.3f} (baseline "
       f"{m['baseline_ratio']:.3f})" if "balance_ratio" in m else "")))

# Offline: what the placement is worth at production scale.
print("\nproduction-scale placement (160 experts on 16 shards, "
      "zipf expert loads):")
rng = np.random.default_rng(0)
loads = (np.arange(1, 161, dtype=float) ** -0.6)
rng.shuffle(loads)
ideal = loads.sum() / 16
base = np.bincount(np.arange(160) // 10, weights=loads, minlength=16).max()
a = schedule_balanced_cardinality(loads, 16, 10)
bal = np.bincount(a, weights=loads, minlength=16).max()
print(f"  contiguous placement capacity: {base / ideal:.3f}x ideal")
print(f"  OS4M placement capacity:       {bal / ideal:.3f}x ideal")
print(f"  padded-compute saving:         {100 * (1 - bal / base):.1f}%")

# Steady-state serving: ONE persistent job + reuse policy over the token →
# expert stream (instead of constructing and planning a job per batch).
print("\nsteady-state serving (schedule reuse over the routing stream):")
import jax.numpy as jnp

from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.core.schedule_cache import ReusePolicy

slots, toks = 4, 2048
serve_job = MapReduceJob(
    lambda s: s,
    MapReduceConfig(num_slots=slots, num_clusters=32, scheduler="auto",
                    reuse=ReusePolicy(max_drift=0.2)),
    backend="vmap")
r = np.random.default_rng(0)
for i in range(8):
    alpha = 0.6 if i < 6 else 1.1      # routing skew shifts at batch 6
    expert_of_tok = (r.zipf(1 + alpha, size=(slots, toks)) % 160).astype(np.int32)
    res = serve_job.run((jnp.asarray(expert_of_tok),
                         jnp.asarray(np.ones((slots, toks, 1), np.float32)),
                         jnp.asarray(np.ones((slots, toks), bool))))
    print(f"  batch {i}: {'reuse ' if res.reused else 'REPLAN'} "
          f"({res.plan_reason}) balance={res.schedule.balance_ratio:.3f}")
stats = serve_job.schedule_cache.stats()
print(f"  one plan served {stats['reuses']}/{stats['batches']} batches "
      f"(replan rate {stats['replan_rate']:.2f}, "
      f"{serve_job.jit_misses} executables traced)")
