"""PUMA-analog Inverted Index on the OS4M MapReduce engine.

Builds a word → document-count index over the synthetic corpus, comparing
the default hash partitioner against the OS4M schedule — the engine-level
reproduction of the paper's headline benchmark (II), including the
pipelined reduce and the §4.3 network-cost model.

Run:  PYTHONPATH=src python examples/inverted_index.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core.clustering import recommended_num_clusters
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.data.synthetic import CorpusConfig, documents

SLOTS = 8
PAIRS_PER_SHARD = 4096

corpus = CorpusConfig(vocab=8192, zipf_alpha=1.15)
docs = documents(corpus, seed=7, start=0, count=256)

# Map phase input: (doc_id, token) pairs, sharded across Map slots.
pairs = []
for did, d in enumerate(docs):
    for tok in np.unique(d):          # II emits (word, doc) once per doc
        pairs.append((tok, did))
rng = np.random.default_rng(0)
rng.shuffle(pairs)
pairs = pairs[: min(len(pairs) // SLOTS, PAIRS_PER_SHARD) * SLOTS]
keys = np.asarray([p[0] for p in pairs], np.int32).reshape(SLOTS, -1)
vals = np.ones((SLOTS, keys.shape[1], 1), np.float32)  # count 1 per doc
valid = np.ones(keys.shape, bool)


def map_fn(shard):
    k, v, ok = shard
    return k, v, ok


n_clusters = recommended_num_clusters(SLOTS)  # §5.4: 6–16x slots
print(f"inverted index: {len(pairs)} (word, doc) pairs, {SLOTS} slots, "
      f"{n_clusters} operation clusters")
for sched in ("hash", "os4m"):
    job = MapReduceJob(map_fn, MapReduceConfig(
        num_slots=SLOTS, num_clusters=n_clusters, scheduler=sched,
        pipeline_chunks=4), backend="vmap")
    res = job.run((jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    top = np.argsort(-res.counts)[:5]
    print(f"  {sched:5s}: balance={res.schedule.balance_ratio:.3f} "
          f"rel-std={res.schedule.rel_std:.3f} "
          f"net={res.network_cost.total / 1e6:.2f} MB "
          f"top-cluster loads={res.counts[top].astype(int).tolist()}")
