"""Quickstart: OS4M in 60 seconds.

1. Schedule skewed Reduce operations (hash vs the paper's BSS scheduler).
2. Run a keyed MapReduce word-count on the JAX engine with both schedules,
   then through the chunked double-buffered pipeline vs the sequential
   (Hadoop-style) phase B — outputs must be bit-identical.
3. Train a tiny LM for a few steps with OS4M-packed batches.

Run:  PYTHONPATH=src python examples/quickstart.py  (or just python after
``pip install -e .``)
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import scheduler as S
from repro.core.mapreduce import MapReduceConfig, MapReduceJob

print("== 1. P||C_max scheduling (paper §3.2/§4.2) ==")
rng = np.random.default_rng(0)
loads = rng.zipf(1.3, 480).clip(1, 20_000).astype(float)  # skewed op loads
hash_s = S.schedule_hash(loads, 30, keys=np.arange(480))
bss_s = S.schedule_bss(loads, 30)                  # the paper's algorithm
print(f"hash  max-load/ideal = {hash_s.balance_ratio:.3f}   (eq. 3-1 baseline)")
print(f"os4m  max-load/ideal = {bss_s.balance_ratio:.3f}   (BSS, eta=0.002)")

print("\n== 2. Keyed MapReduce on the JAX engine ==")
m, K = 4, 256
keys = (rng.zipf(1.3, size=(m, K)) % 1000).astype(np.int32)
vals = np.ones((m, K, 1), np.float32)
valid = np.ones((m, K), bool)

def map_fn(shard):
    k, v, ok = shard
    return k, v, ok

for sched in ("hash", "os4m", "auto"):
    job = MapReduceJob(map_fn, MapReduceConfig(
        num_slots=m, num_clusters=24, scheduler=sched), backend="vmap")
    res = job.run((jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    picked = f" -> {res.strategy}" if sched == "auto" else ""
    print(f"{sched:5s}{picked}: wordcount total={res.values.sum():.0f}  "
          f"balance={res.schedule.balance_ratio:.3f}  "
          f"net-overhead={res.network_cost.total / 1e3:.1f} KB")

print("\n== 2b. Pipelined vs sequential phase B (§4.4) ==")
batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))
engine_res = {}
for pipelined in (False, True):
    job = MapReduceJob(map_fn, MapReduceConfig(
        num_slots=m, num_clusters=24, scheduler="os4m",
        pipelined=pipelined, pipeline_chunks=4), backend="vmap")
    engine_res[pipelined] = job.run(batch)
bit_identical = (np.array_equal(engine_res[True].values,
                                engine_res[False].values)
                 and np.array_equal(engine_res[True].counts,
                                    engine_res[False].counts))
print(f"chunked double-buffered engine == sequential barrier: "
      f"bit_identical={bit_identical}")
assert bit_identical

print("\n== 2c. Serving loop: one plan, many batches (schedule reuse) ==")
from repro.core.schedule_cache import ReusePolicy

serve_job = MapReduceJob(map_fn, MapReduceConfig(
    num_slots=m, num_clusters=24, scheduler="auto",
    reuse=ReusePolicy(max_drift=0.25, max_age=64)), backend="vmap")
for i in range(6):                       # stationary traffic: plan once
    r = np.random.default_rng(100 + i)
    b_keys = (r.zipf(1.3, size=(m, K)) % 1000).astype(np.int32)
    res = serve_job.run((jnp.asarray(b_keys), jnp.asarray(vals),
                         jnp.asarray(valid)))
    print(f"batch {i}: {'reuse ' if res.reused else 'REPLAN'} "
          f"({res.plan_reason}) drift="
          f"{'-' if res.drift is None else f'{res.drift:.3f}'}")
r = np.random.default_rng(999)           # the workload shifts…
b_keys = (r.zipf(2.2, size=(m, K)) % 1000).astype(np.int32)
res = serve_job.run((jnp.asarray(b_keys), jnp.asarray(vals),
                     jnp.asarray(valid)))
print(f"shifted batch: {'reuse' if res.reused else 'REPLAN'} "
      f"({res.plan_reason}) drift={res.drift:.3f}")
stats = serve_job.schedule_cache.stats()
print(f"steady state: {stats['reuses']}/{stats['batches']} batches reused "
      f"one plan ({serve_job.jit_misses} executables traced; "
      f"replan rate {stats['replan_rate']:.2f})")
assert stats["replans"] == 2             # cold start + the injected shift

print("\n== 3. Tiny LM training with OS4M-packed batches ==")
from repro.configs import get_smoke
from repro.data.synthetic import CorpusConfig, token_batches
from repro.launch.mesh import single_device_mesh
from repro.models.config import Shape
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optim import OptConfig

cfg = get_smoke("smollm-360m")
trainer = Trainer(cfg, Shape("quick", "train", 64, 4), single_device_mesh(),
                  opt_cfg=OptConfig(lr=3e-3, warmup_steps=2, decay_steps=20),
                  tcfg=TrainerConfig(ckpt_dir="/tmp/quickstart_ckpt",
                                     ckpt_every=100))
batches = token_batches(CorpusConfig(vocab=cfg.vocab), seed=0, batch=4,
                        seq_len=64)
hist = trainer.run(batches, 10,
                   on_metrics=lambda s, m: print(
                       f"  step {s}: loss {m['loss']:.3f}"))
print(f"loss {hist[0][1]['loss']:.3f} -> {hist[-1][1]['loss']:.3f}")
