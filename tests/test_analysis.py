"""Tests for the static contract analyzer (src/repro/analysis).

Three layers, mirroring the analyzer's own proof obligations:

* **Real targets are green** — every traced phase-B variant the repo
  ships (vmap + shard_map, coded r=2, quantized, measured stamps, fenced
  waves) and every real planner snapshot must produce zero findings: the
  analyzer certifies the shipped engine, it does not cry wolf.
* **Mutations are caught** — each seeded violation must be caught by the
  *intended* checker with the *intended* rule and non-empty evidence
  (an analyzer that has never failed anything proves nothing).
* **Properties** — the plan validator accepts whatever the real planner
  emits across random histograms, speed vectors (including dead slots),
  and geometries where the replication factor does not divide the slot
  count.
"""

import io

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.analysis import (
    conventions,
    determinism,
    mutations,
    overlap,
    plan_checks,
    allowlist,
)
from repro.analysis import jaxpr_graph as jg
from repro.analysis import targets as tgt
from repro.analysis.__main__ import run as run_analysis
from repro.analysis.report import CHECKER_BITS, Finding, Report
from repro.core import mapreduce as mr


@pytest.fixture(scope="module")
def phase_b():
    return tgt.phase_b_targets()


@pytest.fixture(scope="module")
def plans():
    return tgt.plan_targets()


# ---------------------------------------------------------------------------
# Real targets are green
# ---------------------------------------------------------------------------


class TestRealTargetsGreen:
    def test_variant_coverage(self, phase_b):
        names = {t.name for t in phase_b}
        expected = {
            "sequential", "pipelined", "pipelined-kernels",
            "pipelined-int8", "coded-r2", "coded-r2-int8",
            "timed-sequential", "timed-pipelined",
            "checkpointed-wave-copy", "checkpointed-wave-run",
        }
        assert expected <= names
        if len(jax.devices()) >= tgt.M:
            assert "shard_map-pipelined" in names

    def test_overlap_clean(self, phase_b):
        findings = overlap.check_overlap(phase_b)
        assert findings == [], "\n".join(f.render() for f in findings)

    def test_determinism_clean(self, phase_b):
        findings = determinism.check_determinism(phase_b)
        assert findings == [], "\n".join(f.render() for f in findings)

    def test_plans_clean(self, plans):
        names = {name for name, _ in plans}
        assert {"lpt-uniform", "os4m-pipelined", "lpt-straggler",
                "lpt-dead-slot", "coded-r2"} <= names
        findings = plan_checks.check_plans(plans)
        assert findings == [], "\n".join(f.render() for f in findings)

    def test_conventions_clean(self):
        findings = conventions.lint_tree(conventions.default_root())
        assert findings == [], "\n".join(f.render() for f in findings)

    def test_timed_targets_contain_stamps(self, phase_b):
        timed = [t for t in phase_b if t.timed]
        assert timed, "no timed variants traced"
        for t in timed:
            assert t.graph.by_prim("io_callback"), t.name

    def test_coded_targets_contain_xor_and_stable_sorts(self, phase_b):
        coded = [t for t in phase_b if t.coded]
        assert coded, "no coded variants traced"
        for t in coded:
            assert t.graph.by_prim("xor"), t.name
            sorts = t.graph.by_prim("sort")
            assert sorts, t.name
            assert all(n.eqn.params.get("is_stable") for n in sorts), t.name


# ---------------------------------------------------------------------------
# Mutation suite: every seeded violation caught, with evidence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "case", mutations._CASES, ids=[c[0] for c in mutations._CASES])
def test_mutation_caught_by_intended_checker(case):
    name, checker, rule, fn = case
    findings = fn()
    hits = [f for f in findings if f.checker == checker and f.rule == rule]
    assert hits, (f"{name}: expected [{checker}:{rule}], got "
                  + ("; ".join(f"[{f.checker}:{f.rule}]" for f in findings)
                     or "nothing"))
    for f in hits:
        assert len(f.evidence) > 0, f"{name}: finding carries no evidence"
        assert f.render().count("\n") >= 1, "evidence must render as lines"


def test_self_test_harness_roll_up():
    results = mutations.run_self_tests()
    assert mutations.self_tests_ok(results)
    assert len(results) == len(mutations._CASES)
    checkers = {r.checker for r in results}
    assert checkers == {"overlap", "determinism", "plan", "conventions"}


# ---------------------------------------------------------------------------
# Graph + report unit behaviour
# ---------------------------------------------------------------------------


class TestEqnGraph:
    def test_sorts_found_inside_pjit(self):
        """jnp.argsort lowers into a pjit sub-jaxpr; the flattened graph
        must still expose the sort equation (and its stability flag)."""
        import jax.numpy as jnp

        def body(x):
            return x[jnp.argsort(x[:, 0], stable=True)]

        closed = jg.trace_sharded(
            body, (jax.ShapeDtypeStruct((4, 8), jnp.float32),), mr.AXIS, 4)
        g = jg.EqnGraph(closed)
        sorts = g.by_prim("sort")
        assert sorts and sorts[0].eqn.params["is_stable"] is True
        assert not any(n.prim == "pjit" for n in g.nodes)

    def test_path_evidence_is_readable(self):
        import jax.numpy as jnp
        from jax import lax

        def body(x):
            a = lax.all_to_all(x, mr.AXIS, 0, 0)
            b = lax.all_to_all(a * 2.0, mr.AXIS, 0, 0)
            return b

        g = jg.EqnGraph(jg.trace_sharded(
            body, (jax.ShapeDtypeStruct((4, 8), jnp.float32),), mr.AXIS, 4))
        a2a = [n.id for n in g.by_prim("all_to_all")]
        chain = g.find_path(a2a[0], a2a[1])
        assert chain[0] == a2a[0] and chain[-1] == a2a[1]
        lines = g.describe_path(chain)
        assert len(lines) == len(chain)
        assert "all_to_all" in lines[0] and "all_to_all" in lines[-1]

    def test_resolve_callback_unwraps_registered_body(self):
        from repro.kernels.wave_timer import ops as wt_ops

        qual = allowlist.qualname_of(wt_ops._host_stamp)
        assert allowlist.is_allowed(qual)
        assert qual.endswith("._host_stamp")

    def test_wave_timer_bodies_registered(self):
        names = allowlist.allowed_names()
        assert any(n.endswith("._host_stamp") for n in names)
        assert any(n.endswith("._host_stamp_through") for n in names)
        assert any(n.endswith("._host_ticks") for n in names)


class TestReport:
    def test_exit_code_is_bitmask(self):
        r = Report()
        r.extend("overlap", [Finding("overlap", "r", "t", "s", ["e"])])
        r.extend("plan", [Finding("plan", "r", "t", "s", ["e"])])
        r.extend("determinism", [])
        assert r.exit_code() == CHECKER_BITS["overlap"] | CHECKER_BITS["plan"]
        assert not r.ok

    def test_unknown_checker_rejected(self):
        with pytest.raises(ValueError):
            Finding("typo", "r", "t", "s")

    def test_render_names_failures(self):
        r = Report()
        r.extend("overlap", [])
        r.extend("plan", [Finding("plan", "dead-slot-loaded", "t", "s", ["e"])])
        text = r.render()
        assert "overlap" in text and "ok" in text
        assert "[plan:dead-slot-loaded]" in text


# ---------------------------------------------------------------------------
# Plan-validator properties (real planner across random inputs)
# ---------------------------------------------------------------------------


def _snapshot(m, n, seed, speeds=None, chunks=1, replication=1):
    cfg = mr.MapReduceConfig(
        num_slots=m, num_clusters=n, scheduler="lpt",
        pipeline_chunks=chunks, speeds=speeds,
        shuffle_replication=replication)
    job = mr.MapReduceJob(lambda s: s, cfg)
    rng = np.random.default_rng(seed)
    hist = rng.integers(1, 64, size=(m, n)).astype(np.float64)
    k = int(np.ceil(hist.sum(axis=1).max()))
    return job._plan(hist, hist.sum(axis=0), k)


class TestPlanProperties:
    @given(st.integers(2, 6), st.integers(6, 20), st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_random_plans_validate_clean(self, m, n, seed):
        snap = _snapshot(m, n, seed, chunks=min(3, n))
        assert plan_checks.validate_snapshot(snap, "prop") == []

    @given(st.integers(3, 6), st.integers(8, 20), st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_dead_slot_plans_validate_clean(self, m, n, seed):
        """A dead slot (speed 0.0) must end up with exactly zero work —
        and the validator must agree that it did."""
        speeds = [1.0] * m
        speeds[seed % m] = 0.0
        snap = _snapshot(m, n, seed, speeds=tuple(speeds))
        assert plan_checks.validate_snapshot(snap, "prop-dead") == []
        dead = seed % m
        assert not np.any(np.asarray(snap.schedule.assignment) == dead)

    @pytest.mark.parametrize("m", [2, 3, 5, 7])
    def test_pairing_valid_when_r_does_not_divide_m(self, m):
        """π covers every other slot for any m >= 2 — including odd m,
        where r=2 does not divide the slot count."""
        assert plan_checks.validate_pairing(m, 2, f"m={m}") == []

    def test_pairing_rejects_single_slot(self):
        findings = plan_checks.validate_pairing(1, 2, "m=1")
        assert [f.rule for f in findings] == ["invalid-pairing"]

    @given(st.integers(3, 6), st.integers(8, 20), st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_coded_plans_validate_clean(self, m, n, seed):
        snap = _snapshot(m, n, seed, replication=2)
        assert snap.waves.replication == 2
        assert plan_checks.validate_snapshot(snap, "prop-coded") == []


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------


class TestCLI:
    def test_run_plan_checker_exits_zero(self):
        out = io.StringIO()
        assert run_analysis(check="plan", out=out) == 0
        text = out.getvalue()
        assert "plan" in text and "ok" in text

    def test_run_rejects_unknown_checker(self):
        with pytest.raises(ValueError):
            run_analysis(check="nonsense")

    def test_main_exits_with_bitmask_zero(self):
        from repro.analysis.__main__ import main

        with pytest.raises(SystemExit) as ei:
            main(["--check", "plan"])
        assert ei.value.code == 0
