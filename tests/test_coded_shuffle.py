"""Coded shuffle (Coded MapReduce, arXiv 1512.01625): kernel + engine.

Four layers of coverage, smallest to largest:

* the XOR word kernel against its jnp oracle over sizes and word dtypes;
* payload word packing round-trips for every wire dtype the engine
  ships (f32 / bf16 bit-casts, int8 / fp8 quantized bytes) — XOR on the
  packed view must be XOR on the payload bits;
* encode→decode round-trips under ``jit`` and under ``shard_map`` over
  a real 8-device mesh (the collective context the engine runs in);
* end-to-end bit-identity of coded (``shuffle_replication=2``) vs
  uncoded job outputs — on vmap with ``r ∤ m`` (m=7), on shard_map
  (m=8), quantized and not — plus the wire-accounting fields and the
  config validation surface.

The ``plan_waves`` chunks>clusters clamp rides along (same PR).
"""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import pipeline
from repro.core.mapreduce import (_FP8_DTYPE, MapReduceConfig, MapReduceJob)
from repro.kernels.coded_shuffle.ops import (pack_payload_words,
                                             packed_width,
                                             unpack_payload_words,
                                             xor_words)
from repro.kernels.coded_shuffle.ref import xor_words_ref


# ---------------------------------------------------------------------------
# XOR word kernel vs oracle
# ---------------------------------------------------------------------------


def _words(rng, shape, word_dtype):
    raw = rng.integers(0, 2**32, shape, dtype=np.uint32)
    return jnp.asarray(raw.view(np.int32)).astype(word_dtype)


@pytest.mark.parametrize("n,w", [(1, 1), (7, 3), (64, 8), (129, 5)])
@pytest.mark.parametrize("word_dtype", [jnp.int32, jnp.uint32])
def test_xor_kernel_matches_ref_sweep(rng, n, w, word_dtype):
    a = _words(rng, (n, w), word_dtype)
    b = _words(rng, (n, w), word_dtype)
    got = xor_words(a, b, use_kernel=True)
    ref = xor_words_ref(a, b)
    assert got.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_xor_self_inverse(rng):
    """x ^ y ^ y == x — the property decode relies on."""
    x = _words(rng, (33, 4), jnp.int32)
    y = _words(rng, (33, 4), jnp.int32)
    back = xor_words(xor_words(x, y, use_kernel=True), y, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# ---------------------------------------------------------------------------
# Payload packing round-trips (the wire dtypes)
# ---------------------------------------------------------------------------


def _payload(rng, dtype, n=37, v=5):
    if dtype == jnp.int8:
        return jnp.asarray(rng.integers(-127, 128, (n, v)), jnp.int8)
    x = jnp.asarray(rng.standard_normal((n, v)), jnp.float32)
    return x.astype(dtype)


_WIRE_DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8] + (
    [_FP8_DTYPE] if _FP8_DTYPE is not None else [])


@pytest.mark.parametrize("dtype", _WIRE_DTYPES)
@pytest.mark.parametrize("v", [1, 4, 5, 8])
def test_pack_unpack_round_trip(rng, dtype, v):
    x = _payload(rng, dtype, v=v)
    words = pack_payload_words(x)
    assert words.shape == (x.shape[0], packed_width(v, dtype))
    assert words.dtype == jnp.int32
    back = unpack_payload_words(words, dtype, v)
    # bit-level equality: compare the raw byte views, NaN-safe
    np.testing.assert_array_equal(
        np.asarray(back).view(np.uint8), np.asarray(x).view(np.uint8))


@pytest.mark.parametrize("dtype", _WIRE_DTYPES)
def test_xor_decode_on_packed_payloads(rng, dtype):
    """Encode two payload slabs, XOR, XOR one out — the other survives."""
    a, b = _payload(rng, dtype), _payload(rng, dtype)
    packet = xor_words(pack_payload_words(a), pack_payload_words(b),
                       use_kernel=True)
    dec = unpack_payload_words(
        xor_words(packet, pack_payload_words(b), use_kernel=True),
        dtype, a.shape[1])
    np.testing.assert_array_equal(
        np.asarray(dec).view(np.uint8), np.asarray(a).view(np.uint8))


def test_pack_rejects_mismatched_width(rng):
    words = pack_payload_words(_payload(rng, jnp.float32, v=5))
    with pytest.raises(ValueError):
        unpack_payload_words(words, jnp.float32, 7)


# ---------------------------------------------------------------------------
# Round-trips in the engine's execution contexts
# ---------------------------------------------------------------------------


def test_decode_round_trip_under_jit(rng):
    a, b = _payload(rng, jnp.float32), _payload(rng, jnp.float32)

    @jax.jit
    def round_trip(a, b):
        pa, pb = pack_payload_words(a), pack_payload_words(b)
        packet = xor_words(pa, pb, use_kernel=True)
        return unpack_payload_words(xor_words(packet, pb, use_kernel=True),
                                    jnp.float32, a.shape[1])

    np.testing.assert_array_equal(np.asarray(round_trip(a, b)),
                                  np.asarray(a))


def test_decode_round_trip_under_shard_map(rng, mesh8):
    """Each device XORs against its own slab — decode stays per-shard."""
    from repro import compat
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh((8,), ("s",))
    a = jnp.asarray(rng.standard_normal((8, 16, 4)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 16, 4)), jnp.float32)

    def shard(a, b):
        pa = pack_payload_words(a[0])
        pb = pack_payload_words(b[0])
        packet = xor_words(pa, pb, use_kernel=False)
        dec = unpack_payload_words(
            xor_words(packet, pb, use_kernel=False), jnp.float32, 4)
        return dec[None]

    fn = jax.jit(compat.shard_map(
        shard, mesh=mesh, in_specs=(P("s"), P("s")), out_specs=P("s")))
    np.testing.assert_array_equal(np.asarray(fn(a, b)), np.asarray(a))


# ---------------------------------------------------------------------------
# End-to-end: coded job == uncoded job, to the bit
# ---------------------------------------------------------------------------


def _identity_map(shard):
    k, v, ok = shard
    return k, v, ok


def _batch(rng, m, K, V, n_keys=503):
    keys = (rng.zipf(1.4, size=(m, K)) % n_keys).astype(np.int32)
    vals = rng.random((m, K, V)).astype(np.float32)
    valid = rng.random((m, K)) > 0.1
    return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))


def _run(batch, m, n, *, replication=1, quantize=None, pipelined=True,
         reduce_op="sum", use_kernels=False, backend="vmap", mesh=None):
    cfg = MapReduceConfig(
        num_slots=m, num_clusters=n, scheduler="os4m", pipelined=pipelined,
        pipeline_chunks=3, use_kernels=use_kernels,
        shuffle_replication=replication, quantize_shuffle=quantize,
        reduce_op=reduce_op)
    return MapReduceJob(_identity_map, cfg, backend=backend,
                        mesh=mesh).run(batch)


@pytest.mark.parametrize("pipelined", [True, False])
@pytest.mark.parametrize("reduce_op", ["sum", "max", "count"])
def test_coded_bit_identical_vmap_r_not_dividing_m(rng, pipelined, reduce_op):
    """r=2 on m=7 slots — the pair placement wraps, outputs stay exact."""
    m, n = 7, 20
    batch = _batch(rng, m, 96, 5)
    r1 = _run(batch, m, n, pipelined=pipelined, reduce_op=reduce_op)
    r2 = _run(batch, m, n, pipelined=pipelined, reduce_op=reduce_op,
              replication=2)
    assert r1.overflow == 0 and r2.overflow == 0
    np.testing.assert_array_equal(np.asarray(r1.values),
                                  np.asarray(r2.values))
    np.testing.assert_array_equal(np.asarray(r1.counts),
                                  np.asarray(r2.counts))


def test_coded_bit_identical_vmap_kernel_path(rng):
    m, n = 8, 20
    batch = _batch(rng, m, 96, 5)
    r1 = _run(batch, m, n, use_kernels=True)
    r2 = _run(batch, m, n, use_kernels=True, replication=2)
    np.testing.assert_array_equal(np.asarray(r1.values),
                                  np.asarray(r2.values))


def test_coded_bit_identical_shard_map(rng, mesh8):
    m, n = 8, 24
    batch = _batch(rng, m, 64, 4)
    r1 = _run(batch, m, n, backend="shard_map", mesh=mesh8)
    r2 = _run(batch, m, n, backend="shard_map", mesh=mesh8, replication=2)
    assert r1.overflow == 0 and r2.overflow == 0
    np.testing.assert_array_equal(np.asarray(r1.values),
                                  np.asarray(r2.values))
    np.testing.assert_array_equal(np.asarray(r1.counts),
                                  np.asarray(r2.counts))


@pytest.mark.parametrize("quantize", ["int8"] + (
    ["fp8"] if _FP8_DTYPE is not None else []))
def test_quantized_coded_matches_quantized_uncoded(rng, quantize):
    """Coding must be transparent: coded(q) == uncoded(q), bit for bit."""
    m, n = 8, 20
    batch = _batch(rng, m, 96, 5)
    r1 = _run(batch, m, n, quantize=quantize)
    r2 = _run(batch, m, n, quantize=quantize, replication=2)
    np.testing.assert_array_equal(np.asarray(r1.values),
                                  np.asarray(r2.values))
    # random floats do not survive 8-bit encode exactly, and the job says so
    assert r1.quantize_exact is False and r2.quantize_exact is False


def test_quantize_exact_flag_true_on_representable_values(rng):
    """Integer payloads in [-127, 127] round-trip int8 exactly."""
    m, K, n = 4, 64, 12
    keys = rng.integers(0, 200, (m, K)).astype(np.int32)
    vals = rng.integers(-127, 128, (m, K, 3)).astype(np.float32)
    valid = np.ones((m, K), bool)
    batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))
    res = _run(batch, m, n, quantize="int8")
    assert res.quantize_exact is True
    # and the quantized outputs match the unquantized job exactly
    ref = _run(batch, m, n)
    np.testing.assert_array_equal(np.asarray(res.values),
                                  np.asarray(ref.values))


def test_wire_accounting_fields(rng):
    m, n = 8, 20
    batch = _batch(rng, m, 96, 5)
    r1 = _run(batch, m, n)
    r2 = _run(batch, m, n, replication=2)
    for r in (r1, r2):
        assert r.shuffle_bytes is not None and r.shuffle_bytes > 0
        assert r.shuffle_rows is not None and r.shuffle_rows > 0
        assert r.shuffle_pairs is not None and r.shuffle_pairs > 0
    # uncoded ships no replicas; coded accounts them separately
    assert r1.replication_bytes == 0
    assert r2.replication_bytes > 0
    # the schedule (hence the set of non-local pairs) is shared
    assert r1.shuffle_pairs == r2.shuffle_pairs
    # coding must not *grow* the wire volume on this workload
    assert r2.shuffle_bytes < r1.shuffle_bytes


def test_quantized_wire_bytes_shrink(rng):
    m, n = 8, 20
    batch = _batch(rng, m, 96, 5)
    full = _run(batch, m, n)
    q = _run(batch, m, n, quantize="int8")
    assert q.shuffle_bytes < full.shuffle_bytes


# ---------------------------------------------------------------------------
# Config validation surface
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kwargs", [
    dict(shuffle_replication=3),
    dict(shuffle_replication=0),
    dict(num_slots=1, shuffle_replication=2),
    dict(shuffle_replication=2, checkpoint_waves=True),
    dict(quantize_shuffle="int4"),
    dict(quantize_shuffle="int8", checkpoint_waves=True),
])
def test_config_validation_raises(kwargs):
    base = dict(num_slots=4, num_clusters=8)
    base.update(kwargs)
    with pytest.raises(ValueError):
        MapReduceJob(_identity_map, MapReduceConfig(**base), backend="vmap")


# ---------------------------------------------------------------------------
# plan_waves: replication metadata + chunks > clusters clamp
# ---------------------------------------------------------------------------


def test_wave_plan_carries_replication_through_json():
    loads = [3.0, 1.0, 2.0, 5.0]
    assign = np.array([0, 1, 0, 1])
    plan = pipeline.plan_waves(loads, assign, 2, 2, replication=2)
    assert plan.replication == 2
    assert pipeline.WavePlan.from_json(plan.to_json()).replication == 2
    # pre-coded snapshots (no key) default to the unicast wire format
    legacy = plan.to_json()
    del legacy["replication"]
    assert pipeline.WavePlan.from_json(legacy).replication == 1


def test_plan_waves_clamps_excess_chunks_and_warns_once(monkeypatch):
    monkeypatch.setattr(pipeline, "_warned_excess_chunks", False)
    loads = [4.0, 2.0, 1.0]
    assign = np.array([0, 1, 0])
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        plan = pipeline.plan_waves(loads, assign, 2, num_chunks=10)
    assert plan.num_chunks <= 3              # clamped, no empty waves
    assert (plan.chunk_of_cluster < plan.num_chunks).all()
    assert len(caught) == 1 and "clamping" in str(caught[0].message)
    with warnings.catch_warnings(record=True) as again:
        warnings.simplefilter("always")
        pipeline.plan_waves(loads, assign, 2, num_chunks=10)
    assert len(again) == 0                   # warn-once
