"""Pluggable statistics layer: sketch planning, prefix ingestion, exactness.

The contract under test (see ``docs/STATISTICS.md``):

* count-min estimates are **overestimate-only** — property-swept over
  random workloads, widths, and depths;
* plans built from a sketch stay close to exact plans (makespan bound)
  and the planner's ``_plan`` input is O(depth * width), not O(records);
* job *outputs* are bit-identical between exact and sketch statistics —
  including the forced-overflow escape hatch replay, on the vmap and
  shard_map backends, and for streaming-prefix plans;
* the f32 saturation guard (counts >= 2**24) falls back to safe caps for
  exact histograms *and* sketch cells;
* provider identity survives the ``CachedSchedule`` JSON round-trip.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import stats_provider as sp
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.core.schedule_cache import CachedSchedule


def _identity_map(shard):
    return shard


def _job(sched="lpt", m=4, n=16, backend="vmap", mesh=None, **kw):
    cfg = MapReduceConfig(num_slots=m, num_clusters=n, scheduler=sched, **kw)
    return MapReduceJob(_identity_map, cfg, backend=backend, mesh=mesh)


def _inputs(rng, m, K, n, zipf=None):
    if zipf is None:
        keys = rng.integers(0, n, (m, K)).astype(np.int32)
    else:
        keys = (rng.zipf(zipf, size=(m, K)) % 997).astype(np.int32)
    vals = rng.random((m, K, 2)).astype(np.float32)
    valid = np.ones((m, K), bool)
    return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))


# ---------------------------------------------------------------------------
# Overestimate-only property
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 400),
       st.sampled_from([64, 128, 256]), st.integers(2, 5))
def test_sketch_overestimate_only(seed, n_keys, width, depth):
    """est(c) >= true(c) for every cluster: collisions only ever add."""
    rng = np.random.default_rng(seed)
    n = 24
    ids = rng.integers(0, n, n_keys)
    w = (rng.random(n_keys) * 3).astype(np.float32)
    prov = sp.SketchStats(n, width=width, depth=depth)
    state = np.asarray(jax.device_get(
        prov.collect(jnp.asarray(ids, jnp.int32), jnp.asarray(w))))
    est = prov.to_dense(state)
    exact = np.bincount(ids, weights=w.astype(np.float64), minlength=n)
    assert est.shape == (n,)
    assert np.all(est + 1e-3 >= exact), (est - exact).min()
    # total mass is conserved per row, so key_dist never loses weight
    assert float(prov.key_dist(state).sum()) + 1e-2 >= float(exact.sum())


def test_exact_provider_is_identity(rng):
    """ExactStats must not touch dtype or values (golden-pinned plans)."""
    prov = sp.ExactStats(8)
    hist = rng.random((4, 8)).astype(np.float32)
    assert prov.to_dense(hist).dtype == np.float32
    np.testing.assert_array_equal(prov.to_dense(hist), hist)
    np.testing.assert_array_equal(prov.from_dense(hist), hist)
    np.testing.assert_array_equal(prov.key_dist(hist), hist.sum(axis=0))


# ---------------------------------------------------------------------------
# Plan quality + O(sketch) planner input
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dist", ["zipf", "uniform"])
def test_sketch_plan_makespan_close_to_exact(rng, dist):
    """A generously-wide sketch plans within 25% of the exact makespan."""
    m, K, n = 4, 4096, 64
    zipf = 1.3 if dist == "zipf" else None
    keys, _vals, _valid = _inputs(rng, m, K, n, zipf=zipf)
    keys = np.abs(np.asarray(keys)) % n
    hist = np.stack([np.bincount(keys[i], minlength=n) for i in range(m)]
                    ).astype(np.float64)

    exact_job = _job(m=m, n=n)
    exact_plan = exact_job._plan(hist, None, K)

    sk_job = _job(m=m, n=n, stats="sketch", sketch_width=1024, sketch_depth=4)
    state = sk_job._stats.from_dense(hist)
    # the planner sees O(depth * width) cells, never the K records
    assert state.shape == (m, sk_job._stats.state_size)
    sk_plan = sk_job._plan(state, None, K)

    def makespan(plan):
        return float(np.asarray(plan.schedule.slot_loads).max())

    assert makespan(sk_plan) <= 1.25 * makespan(exact_plan) + 1e-9
    assert sk_plan.stats_provider == "sketch"
    assert sk_plan.stats_overestimate


# ---------------------------------------------------------------------------
# Bit-identity: outputs never depend on the statistics backend
# ---------------------------------------------------------------------------


def _assert_same_outputs(res_a, res_b):
    np.testing.assert_array_equal(np.asarray(res_a.values),
                                  np.asarray(res_b.values))
    np.testing.assert_array_equal(np.asarray(res_a.counts),
                                  np.asarray(res_b.counts))


@pytest.mark.parametrize("sched", ["lpt", "os4m"])
def test_sketch_outputs_bit_identical_vmap(rng, sched):
    m, K, n = 4, 256, 16
    inputs = _inputs(rng, m, K, n, zipf=1.3)
    res_exact = _job(sched=sched, m=m, n=n).run(inputs)
    res_sketch = _job(sched=sched, m=m, n=n, stats="sketch",
                      sketch_width=256).run(inputs)
    _assert_same_outputs(res_exact, res_sketch)
    assert res_sketch.overflow == 0


def test_prefix_planned_outputs_match_full_planned(rng):
    m, K, n = 4, 256, 16
    inputs = _inputs(rng, m, K, n)
    res_full = _job(m=m, n=n, stats="sketch", sketch_width=256).run(inputs)
    res_prefix = _job(m=m, n=n, stats="sketch", sketch_width=256,
                      stream_prefix=0.25).run(inputs)
    _assert_same_outputs(res_full, res_prefix)
    assert res_prefix.overflow == 0


def test_forced_overflow_escape_hatch_replays_bit_identical(rng):
    """Prefix that has never seen the tail-hot cluster: wave-1 cap is far
    too small, the first execution overflows, and the hatch re-executes
    with safe caps — outputs still bit-identical to exact statistics."""
    m, K, n = 4, 1024, 64
    cut = K // 4
    keys = np.empty((m, K), np.int32)
    choices = np.array([c for c in range(n) if c != 3], np.int32)
    keys[:, :cut] = rng.choice(choices, size=(m, cut))
    keys[:, cut:] = 3                      # tail is all one hot cluster
    vals = rng.random((m, K, 2)).astype(np.float32)
    valid = np.ones((m, K), bool)
    inputs = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))

    sk_job = _job(m=m, n=n, stats="sketch", sketch_width=128, sketch_depth=4,
                  stream_prefix=0.25)
    res_sketch = sk_job.run(inputs)
    assert sk_job.capacity_fallbacks == 1   # the hatch actually fired
    assert res_sketch.overflow == 0         # ... and cured the overflow

    res_exact = _job(m=m, n=n).run(inputs)
    _assert_same_outputs(res_exact, res_sketch)


def test_sketch_outputs_bit_identical_shard_map(rng, mesh8):
    m, K, n = 8, 128, 12
    inputs = _inputs(rng, m, K, n, zipf=1.4)
    res_exact = _job(m=m, n=n, backend="shard_map", mesh=mesh8).run(inputs)
    res_sketch = _job(m=m, n=n, backend="shard_map", mesh=mesh8,
                      stats="sketch", sketch_width=256).run(inputs)
    _assert_same_outputs(res_exact, res_sketch)
    assert res_sketch.overflow == 0


# ---------------------------------------------------------------------------
# f32 saturation guard (counts >= 2**24)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stats", ["exact", "sketch"])
def test_saturated_counts_fall_back_to_safe_caps(stats):
    """A count at 2**24 is no longer integer-exact in f32 — and a
    saturated sketch cell voids the overestimate guarantee — so every
    cap must fall back to the safe k_per_shard bound."""
    m, n, k_per_shard = 4, 16, 4096
    hist = np.ones((m, n), np.float64)
    hist[0, 0] = float(2 ** 24) + 10.0      # saturated counter

    job = _job(m=m, n=n, stats=stats)
    state = job._stats.from_dense(hist) if stats == "sketch" else hist
    planned = job._plan(state, None, k_per_shard)
    assert planned.capacity == k_per_shard
    assert all(int(c) == k_per_shard for c in planned.chunk_caps)

    # contrast: the same shape without saturation sizes caps tighter
    hist[0, 0] = 100.0
    state = job._stats.from_dense(hist) if stats == "sketch" else hist
    tight = job._plan(state, None, k_per_shard)
    assert tight.capacity < k_per_shard


# ---------------------------------------------------------------------------
# JSON round-trip of provider state
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip_preserves_provider(rng):
    m, K, n = 4, 512, 16
    hist = rng.integers(1, 50, (m, n)).astype(np.float64)
    job = _job(m=m, n=n, stats="sketch", sketch_width=128)
    planned = job._plan(job._stats.from_dense(hist), None, K)

    d1 = planned.to_json()
    snap2 = CachedSchedule.from_json(d1)
    assert snap2.to_json() == d1            # fixed point
    assert snap2.stats_provider == "sketch"
    assert snap2.stats_params == job._stats.params()
    assert snap2.stats_overestimate == planned.stats_overestimate
    assert snap2.caps_estimated == planned.caps_estimated
    np.testing.assert_array_equal(np.asarray(snap2.local_hist),
                                  np.asarray(planned.local_hist))
    # the sketch's explicit key_dist travels too (cells can't rebuild it)
    np.testing.assert_allclose(
        np.asarray(snap2.key_dist),
        job._stats.key_dist(np.asarray(planned.local_hist)))


def test_invalid_configs_rejected():
    with pytest.raises(ValueError, match="stream_prefix"):
        _job(stats="exact", stream_prefix=0.5)
    with pytest.raises(ValueError, match="stream_prefix"):
        _job(stats="sketch", stream_prefix=1.5)
    with pytest.raises(ValueError):
        sp.make_provider("bogus", 8)
