"""Shared fixtures + offline-collection shims.

Two things live here besides fixtures:

* A **hypothesis shim**: the property-test modules import
  ``from hypothesis import given, settings, strategies as st`` at module
  scope, which used to make the whole suite fail collection on machines
  without the package. When hypothesis is absent we install a minimal
  stand-in into ``sys.modules`` *before* test modules are imported
  (conftest runs first), degrading every property test to a small sweep of
  fixed-seed examples. With hypothesis installed, the real package wins.
* No XLA_FLAGS device-count override — smoke tests and benches run on the
  single real CPU device; CI sets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the
  shard_map tests exercise 8 virtual devices (see ``mesh8``).
"""

import importlib.util
import inspect
import sys
import types

import numpy as np
import pytest


# ---------------------------------------------------------------------------
# Hypothesis shim (fixed-seed example mode when the package is missing).
# ---------------------------------------------------------------------------


def _install_hypothesis_shim() -> None:
    if importlib.util.find_spec("hypothesis") is not None:
        return  # real hypothesis available — use it

    class _Strategy:
        """A draw(rng) closure; just enough surface for this repo's tests."""

        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def floats(min_value=0.0, max_value=1.0, allow_nan=False,
               allow_infinity=False, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: float(lo + (hi - lo) * rng.random()))

    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]

        return _Strategy(draw)

    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    _EXAMPLES = 12  # fixed-seed sweeps per property test in degraded mode

    def given(*strategies):
        """Drawn values fill the *rightmost* parameters (hypothesis rule);
        leading parameters (``self``, pytest fixtures) pass through. The
        wrapper's ``__signature__`` hides the drawn parameters so pytest
        does not look for fixtures of those names."""

        def decorate(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            kept = params[: len(params) - len(strategies)]

            def wrapper(*args, **kwargs):
                budget = getattr(fn, "_shim_max_examples", _EXAMPLES)
                for seed in range(min(budget, _EXAMPLES)):
                    rng = np.random.default_rng(seed)
                    drawn = tuple(s.draw(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__signature__ = sig.replace(parameters=kept)
            wrapper.hypothesis_shim = True
            return wrapper

        return decorate

    def settings(max_examples=None, deadline=None, **_kw):
        def decorate(fn):
            if max_examples is not None:
                # @settings sits under @given here, so it tags the original
                # fn, which @given's wrapper reads at call time.
                fn._shim_max_examples = int(max_examples)
            return fn

        return decorate

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = lambda condition: None
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.lists = lists
    strategies.sampled_from = sampled_from
    strategies.booleans = booleans
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_shim()

import jax  # noqa: E402  (after the shim: jax import is slow, order is free)

from repro import compat  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def mesh8():
    """A (2, 4) mesh when 8 host devices are available, else skip."""
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return compat.make_mesh((2, 4), ("data", "model"))
