import numpy as np
import pytest

import jax

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# run on the single real CPU device; only launch/dryrun.py forces 512.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def mesh8():
    """A (2, 4) mesh when 8 host devices are available, else skip."""
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
