"""Optimizer, checkpoint/restart, compression, packing, data pipeline."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.data import packing
from repro.data.synthetic import CorpusConfig, documents, token_batches
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train.optim import OptConfig, adamw_step, init_opt, lr_at


class TestOptim:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        ocfg = OptConfig(lr=0.2, weight_decay=0.0, warmup_steps=1,
                         decay_steps=10_000, clip_norm=0)
        opt = init_opt(params, ocfg)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, opt, _ = adamw_step(params, g, opt, ocfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_clip_bounds_update(self):
        params = {"w": jnp.zeros(4)}
        ocfg = OptConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
        opt = init_opt(params, ocfg)
        g = {"w": jnp.full(4, 1e6)}
        _, _, m = adamw_step(params, g, opt, ocfg)
        assert float(m["grad_norm"]) > 1e5  # reported raw

    def test_lr_schedule_warmup_and_decay(self):
        ocfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                         min_lr_ratio=0.1)
        assert float(lr_at(jnp.int32(5), ocfg)) == pytest.approx(0.5)
        assert float(lr_at(jnp.int32(10), ocfg)) == pytest.approx(1.0)
        assert float(lr_at(jnp.int32(100), ocfg)) == pytest.approx(0.1)

    def test_bf16_moments(self):
        params = {"w": jnp.ones(8)}
        ocfg = OptConfig(moment_dtype="bfloat16")
        opt = init_opt(params, ocfg)
        assert opt["m"]["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_save_load_roundtrip(self, tmp_path):
        params = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        opt = {"m": jax.tree.map(jnp.zeros_like, params),
               "v": jax.tree.map(jnp.ones_like, params),
               "step": jnp.int32(7)}
        ckpt.save(tmp_path, 7, params, opt, extra={"arch": "t"})
        state, extra = ckpt.load(tmp_path, 7, {"params": params, "opt": opt})
        assert extra["arch"] == "t"
        np.testing.assert_allclose(state["params"]["a"], params["a"])
        assert int(state["opt"]["step"]) == 7

    def test_keep_k_gc(self, tmp_path):
        params = {"a": jnp.zeros(2)}
        for s in [1, 2, 3, 4, 5]:
            ckpt.save(tmp_path, s, params, keep=2)
        assert ckpt.latest_step(tmp_path) == 5
        steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir())
        assert steps == [4, 5]

    def test_atomic_no_tmp_left(self, tmp_path):
        ckpt.save(tmp_path, 1, {"a": jnp.zeros(2)})
        assert not list(tmp_path.glob("*.tmp"))

    def test_resume_after_simulated_failure(self, tmp_path):
        """Trainer-style restart: state at the last checkpoint survives."""
        from repro.configs import get_smoke
        from repro.launch.mesh import single_device_mesh
        from repro.models.config import Shape
        from repro.train.loop import Trainer, TrainerConfig

        cfg = get_smoke("smollm-360m")
        t = Trainer(cfg, Shape("t", "train", 16, 2), single_device_mesh(),
                    tcfg=TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                       log_every=100))
        toks = np.random.default_rng(0).integers(
            0, cfg.vocab, (2, 16)).astype(np.int32)
        t.run(iter([toks] * 4), 4)
        step_before = t.step
        # simulate a crash: new trainer, resume
        t2 = Trainer(cfg, Shape("t", "train", 16, 2), single_device_mesh(),
                     tcfg=TrainerConfig(ckpt_dir=str(tmp_path)))
        assert t2.try_resume()
        assert t2.step == 4 and step_before == 4


class TestCompression:
    @given(st.integers(0, 20))
    @settings(max_examples=20, deadline=None)
    def test_int8_bounded_error(self, seed):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.standard_normal(256), jnp.float32)
        c, err = comp.compress_leaf(g)
        back = comp.decompress_leaf(c)
        assert float(jnp.abs(back - g).max()) <= float(c.scale) / 2 + 1e-6
        np.testing.assert_allclose(np.asarray(back + err), np.asarray(g),
                                   atol=1e-5)

    def test_error_feedback_unbiased_over_steps(self):
        """Accumulated EF-compressed gradients track the true sum."""
        rng = np.random.default_rng(0)
        true_sum = np.zeros(64)
        applied = np.zeros(64)
        err = {"g": jnp.zeros(64)}
        for _ in range(50):
            g = rng.standard_normal(64).astype(np.float32) * 0.01
            true_sum += g
            c, err = comp.compress_tree({"g": jnp.asarray(g)}, err)
            applied += np.asarray(comp.decompress_tree(c)["g"])
        resid = np.abs(true_sum - applied).max()
        assert resid < 0.01, resid


class TestPackingData:
    def test_packing_os4m_beats_hash(self, rng):
        docs = [np.ones(int(l), np.int32)
                for l in np.clip(rng.lognormal(4.5, 1.0, 400), 4, 2000)]
        _, s_hash = packing.pack_documents(docs, 16, 512, scheduler="hash")
        _, s_os4m = packing.pack_documents(docs, 16, 512, scheduler="os4m")
        assert s_os4m.efficiency >= s_hash.efficiency - 1e-9

    def test_packing_conserves_tokens(self, rng):
        docs = [rng.integers(3, 100, int(l)).astype(np.int32)
                for l in rng.integers(4, 300, 50)]
        total = sum(d.shape[0] for d in docs)
        out, stats = packing.pack_documents(docs, 8, 256, scheduler="os4m")
        assert stats.real_tokens + stats.dropped_tokens == total
        assert out.shape == (8, 256)

    def test_documents_deterministic(self):
        cfg = CorpusConfig()
        a = documents(cfg, seed=1, start=5, count=3)
        b = documents(cfg, seed=1, start=5, count=3)
        for x, y in zip(a, b):
            assert np.array_equal(x, y)

    def test_token_batches_shape(self):
        cfg = CorpusConfig(vocab=128)
        it = token_batches(cfg, seed=0, batch=4, seq_len=64)
        batch = next(it)
        assert batch.shape == (4, 64)
        assert batch.max() < 128
