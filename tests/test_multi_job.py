"""Multi-job R||C_max: property suite, brute oracle, coordinator, engine.

Covers the ISSUE 7 acceptance criteria:

* **oracle cross-check** — ``schedule_brute(proc_times=...)`` equals a
  naive exhaustive enumeration on ≥ 200 random tiny instances, and every
  heuristic lands in ``[opt, m·opt]``;
* **rank-1 reproduction** — a rank-1 matrix built from power-of-two
  speeds makes every ``proc_times=`` strategy reproduce its ``speeds=``
  assignment bit for bit (the delegation contract that keeps the
  Q||C_max behaviour pinned), including dead slots;
* **golden pin** — the ``"proc": true`` fixtures in
  ``tests/data/golden_assignments.json`` reproduce exactly;
* **coordinator** — WSPT admission beats FIFO on ΣwᵢCᵢ, tenant caches
  never collide, and interleaving N jobs on one mesh is bit-identical
  to running each alone (vmap and shard_map, straggler kill mid-batch,
  8→6 resize between batches);
* **engine** — multi-job admission uses each job's own lane-speed row,
  and ``maybe_replan_waiting`` fires on per-job drift the global meter
  cannot see (the ISSUE 7 regression fix).
"""

import json
import pathlib
import itertools

import numpy as np
import pytest
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from repro.core import scheduler as S
from repro.core import simulator as sim
from repro.core import pipeline as pipe
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.core.multi_job import MultiJobCoordinator
from repro.core.schedule_cache import ReusePolicy

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_assignments.json"

# Power-of-two speed alphabet: binary scaling is lossless in IEEE-754, so
# a rank-1 matrix built from these factorises exactly and the delegated
# Q||C_max path sees bit-identical inputs.
POW2 = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0]

R_STRATEGIES = ("lpt", "multifit", "unrelated")


def _random_matrix(rng, n, m, p_inf=0.0):
    """A random (n, m) processing-time matrix, optionally with +inf holes."""
    p = rng.uniform(0.5, 10.0, size=(n, m))
    if p_inf > 0:
        mask = rng.random((n, m)) < p_inf
        for j in range(n):
            if mask[j].all():
                mask[j, rng.integers(m)] = False
        p[mask] = np.inf
    return p


def _naive_opt(p, m):
    """Exhaustive R||C_max optimum over all m^n assignments."""
    n = p.shape[0]
    best = np.inf
    for combo in itertools.product(range(m), repeat=n):
        finish = np.zeros(m)
        ok = True
        for j, k in enumerate(combo):
            if not np.isfinite(p[j, k]):
                ok = False
                break
            finish[k] += p[j, k]
        if ok:
            best = min(best, finish.max())
    return best


def _makespan(p, assignment):
    n, m = p.shape
    finish = np.zeros(m)
    for j, k in enumerate(assignment):
        finish[k] += p[j, k]
    return finish.max()


# ---------------------------------------------------------------------------
# (a) oracle cross-check: brute == exhaustive on ≥ 200 random instances.
# ---------------------------------------------------------------------------


def test_brute_matches_exhaustive_oracle_200_instances():
    rng = np.random.default_rng(7)
    checked = 0
    for trial in range(220):
        n = int(rng.integers(2, 6))
        m = int(rng.integers(2, 4))
        p = _random_matrix(rng, n, m, p_inf=0.1 if trial % 3 == 0 else 0.0)
        loads = np.ones(n)
        opt = _naive_opt(p, m)
        got = S.schedule_brute(loads, m, proc_times=p)
        assert got.makespan == pytest.approx(opt, rel=1e-12), (trial, p)
        # heuristics: never better than opt, never worse than m·opt
        for name in R_STRATEGIES:
            mk = _makespan(p, S.get_scheduler(name)(
                loads, m, proc_times=p).assignment)
            assert opt - 1e-9 <= mk <= m * opt + 1e-9, (trial, name)
        checked += 1
    assert checked >= 200


def test_brute_rank1_matches_exhaustive_with_dead_slot():
    """The rank-1 delegation path is also *optimal* (vs the R oracle)."""
    rng = np.random.default_rng(11)
    for trial in range(30):
        n, m = int(rng.integers(2, 6)), 3
        loads = rng.integers(1, 30, n).astype(float)
        speeds = np.asarray([1.0, 2.0, 0.0])  # slot 2 dead
        p = S.rank1_proc_times(loads, speeds, m)
        opt = _naive_opt(p, m)
        got = S.schedule_brute(loads, m, proc_times=p)
        assert got.makespan == pytest.approx(opt, rel=1e-12)
        assert not np.any(got.assignment == 2)


# ---------------------------------------------------------------------------
# (b) rank-1 bit-identity: proc_times round-trips through the Q path.
# ---------------------------------------------------------------------------


@settings(max_examples=12)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=4, max_value=40),
       st.integers(min_value=2, max_value=8),
       st.booleans())
def test_rank1_pow2_bit_identical_to_speeds(seed, n, m, with_dead):
    rng = np.random.default_rng(seed)
    loads = rng.zipf(1.3, n).clip(1, 20_000).astype(float)
    speeds = rng.choice(POW2, size=m)
    if with_dead and m > 2:
        speeds[rng.integers(m)] = 0.0
    p = S.rank1_proc_times(loads, speeds, m)
    assert S.factor_rank1_proc_times(p) is not None
    for name in ("lpt", "multifit"):
        fn = S.get_scheduler(name)
        a_q = fn(loads, m, speeds=speeds)
        a_r = fn(loads, m, proc_times=p)
        assert np.array_equal(a_q.assignment, a_r.assignment), name
        assert np.array_equal(a_q.slot_finish, a_r.slot_finish), name
    a_q = S.schedule_hash(loads, m, keys=np.arange(n), speeds=speeds)
    a_r = S.schedule_hash(loads, m, keys=np.arange(n), proc_times=p)
    assert np.array_equal(a_q.assignment, a_r.assignment)
    nb = min(n, 9)
    b_q = S.schedule_brute(loads[:nb], m, speeds=speeds)
    b_r = S.schedule_brute(loads[:nb], m, proc_times=p[:nb])
    assert np.array_equal(b_q.assignment, b_r.assignment)


def test_speeds_and_proc_times_are_mutually_exclusive():
    loads = np.ones(4)
    p = S.rank1_proc_times(loads, np.ones(2), 2)
    with pytest.raises(ValueError, match="not both"):
        S.schedule_lpt(loads, 2, speeds=np.ones(2), proc_times=p)


def test_proc_times_validation():
    with pytest.raises(ValueError):
        S.normalize_proc_times(np.asarray([[1.0, np.nan]]), 1, 2)
    with pytest.raises(ValueError):
        S.normalize_proc_times(np.asarray([[-1.0, 2.0]]), 1, 2)
    with pytest.raises(ValueError):  # an op with no usable slot
        S.normalize_proc_times(np.asarray([[np.inf, np.inf]]), 1, 2)


# ---------------------------------------------------------------------------
# (c) property sweep: R strategies beat hash, respect dead slots.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_r_strategies_beat_hash(seed):
    rng = np.random.default_rng(seed)
    n, m = 80, 6
    loads = rng.zipf(1.3, n).clip(1, 20_000).astype(float)
    p = _random_matrix(rng, n, m) * loads[:, None]
    hash_mk = _makespan(p, S.schedule_hash(
        loads, m, keys=np.arange(n), proc_times=p).assignment)
    for name in R_STRATEGIES:
        sched = S.get_scheduler(name)(loads, m, proc_times=p)
        assert _makespan(p, sched.assignment) <= hash_mk + 1e-9, name
        assert ((sched.assignment >= 0) & (sched.assignment < m)).all()


@pytest.mark.parametrize("name", R_STRATEGIES + ("hash", "brute"))
def test_dead_column_never_assigned(name):
    rng = np.random.default_rng(3)
    n, m, dead = 12, 4, 2
    loads = rng.integers(1, 50, n).astype(float)
    p = rng.uniform(1.0, 5.0, size=(n, m)) * loads[:, None]
    p[:, dead] = np.inf
    kw = {"keys": np.arange(n)} if name == "hash" else {}
    fn = S.schedule_brute if name == "brute" else S.get_scheduler(name)
    sched = fn(loads, m, proc_times=p, **kw)
    assert not np.any(sched.assignment == dead)
    assert sched.slot_speeds[dead] == 0.0
    assert np.isfinite(sched.makespan)


def test_per_op_incompatibility_respected():
    """+inf entries (not whole columns) are per-op constraints."""
    loads = np.asarray([10.0, 10.0, 10.0])
    p = np.asarray([[1.0, np.inf], [np.inf, 1.0], [1.0, 1.0]]) * 10.0
    for name in R_STRATEGIES + ("brute",):
        fn = S.schedule_brute if name == "brute" else S.get_scheduler(name)
        a = fn(loads, 2, proc_times=p).assignment
        assert a[0] == 0 and a[1] == 1, name


# ---------------------------------------------------------------------------
# (d) golden pin: the "proc": true fixtures reproduce exactly.
# ---------------------------------------------------------------------------


def test_golden_proc_assignments_unchanged():
    golden = json.loads(GOLDEN.read_text())
    seen = 0
    for key, case in golden.items():
        if not case.get("proc"):
            continue
        rng = np.random.default_rng(case["seed"])
        n, m = case["n"], case["m"]
        loads = rng.zipf(1.3, n).clip(1, 20_000).astype(float)
        if case["rank1"]:
            p = S.rank1_proc_times(loads, np.asarray(case["speeds"]), m)
        else:
            p = rng.uniform(0.5, 4.0, size=(n, m)) * loads[:, None]
            mask = rng.random((n, m)) < 0.15
            for j in range(n):
                if mask[j].all():
                    mask[j, rng.integers(m)] = False
            p[mask] = np.inf
        for name, want in case["assignments"].items():
            if name == "brute":
                nb = len(want)
                got = S.schedule_brute(loads[:nb], m,
                                       proc_times=p[:nb]).assignment
            elif name == "hash":
                got = S.schedule_hash(loads, m, keys=np.arange(n),
                                      proc_times=p).assignment
            else:
                got = S.get_scheduler(name)(loads, m,
                                            proc_times=p).assignment
            assert np.array_equal(got, np.asarray(want)), (key, name)
        seen += 1
    assert seen >= 4


def test_golden_rank1_fixtures_match_speeds_path():
    """The pinned rank-1 fixtures are literally the Q||C_max assignments."""
    golden = json.loads(GOLDEN.read_text())
    for key, case in golden.items():
        if not (case.get("proc") and case.get("rank1")):
            continue
        rng = np.random.default_rng(case["seed"])
        loads = rng.zipf(1.3, case["n"]).clip(1, 20_000).astype(float)
        speeds = np.asarray(case["speeds"])
        for name in ("lpt", "multifit"):
            got = S.get_scheduler(name)(loads, case["m"],
                                        speeds=speeds).assignment
            assert np.array_equal(got, case["assignments"][name]), (key, name)


# ---------------------------------------------------------------------------
# (e) WSPT / weighted completion primitives.
# ---------------------------------------------------------------------------


def test_wspt_is_optimal_for_weighted_completion():
    """Smith's rule beats every other permutation (1||ΣwC exactness)."""
    rng = np.random.default_rng(5)
    for _ in range(20):
        k = int(rng.integers(2, 6))
        times = rng.uniform(0.5, 10.0, k)
        weights = rng.uniform(0.5, 5.0, k)
        best = sim.weighted_completion_time(
            times, weights, order=sim.wspt_order(times, weights))
        for perm in itertools.permutations(range(k)):
            alt = sim.weighted_completion_time(
                times, weights, order=np.asarray(perm))
            assert best <= alt + 1e-9


def test_wspt_order_is_deterministic_on_ties():
    order = sim.wspt_order(np.asarray([2.0, 2.0, 2.0]),
                           np.asarray([1.0, 1.0, 1.0]))
    assert order.tolist() == [0, 1, 2]   # stable: FIFO tie-break


# ---------------------------------------------------------------------------
# (f) coscheduled waves.
# ---------------------------------------------------------------------------


def _wave_plan(num_clusters, num_slots, chunks, seed=0):
    rng = np.random.default_rng(seed)
    loads = rng.zipf(1.3, num_clusters).clip(1, 100).astype(float)
    sched = S.schedule_lpt(loads, num_slots)
    return pipe.plan_waves(loads, sched.assignment, num_slots, chunks)


def test_coschedule_waves_preserves_per_job_order():
    plans = [_wave_plan(24, 4, 3, seed=0), _wave_plan(18, 4, 4, seed=1),
             _wave_plan(12, 4, 2, seed=2)]
    issue = pipe.coschedule_waves(plans)
    # every (job, wave) appears exactly once
    assert sorted(issue) == sorted(
        (j, w) for j, pl in enumerate(plans) for w in range(pl.num_chunks))
    # within a job, waves issue in order
    for j, pl in enumerate(plans):
        ws = [w for (jj, w) in issue if jj == j]
        assert ws == list(range(pl.num_chunks))


def test_coschedule_overlap_metrics():
    # strict alternation = full overlap; single job = none
    assert pipe.coschedule_overlap([(0, 0), (1, 0), (0, 1), (1, 1)]) == 1.0
    assert pipe.coschedule_overlap([(0, 0), (0, 1), (0, 2)]) == 0.0
    assert pipe.coschedule_overlap([(0, 0)]) == 0.0
    plans = [_wave_plan(24, 4, 3, seed=0), _wave_plan(18, 4, 3, seed=1)]
    overlap = pipe.coschedule_overlap(pipe.coschedule_waves(plans))
    assert overlap >= 0.5   # round-robin alternates while both are live


# ---------------------------------------------------------------------------
# (g) the coordinator: admission, isolation, bit-identity.
# ---------------------------------------------------------------------------


def _identity_map(shard):
    return shard


def _make_job(m=8, n=48, chunks=0, checkpoint=False, reuse=None,
              backend="vmap", mesh=None):
    return MapReduceJob(
        _identity_map,
        MapReduceConfig(num_slots=m, num_clusters=n, scheduler="bss",
                        pipeline_chunks=chunks,
                        checkpoint_waves=checkpoint, reuse=reuse),
        backend=backend, mesh=mesh)


def _batch(seed=0, m=8, K=256, V=4, n_keys=337):
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(1.25, size=(m, K)) % n_keys).astype(np.int32)
    vals = rng.random((m, K, V)).astype(np.float32)
    return (jnp.asarray(keys), jnp.asarray(vals), jnp.ones((m, K), bool))


class TestCoordinator:
    def test_add_job_validates(self):
        co = MultiJobCoordinator(num_slots=8)
        co.add_job("a", _make_job())
        with pytest.raises(ValueError, match="already admitted"):
            co.add_job("a", _make_job())
        with pytest.raises(ValueError, match="weight"):
            co.add_job("b", _make_job(), weight=0.0)
        with pytest.raises(ValueError, match="slots"):
            co.add_job("c", _make_job(m=4))

    def test_r_matrix_shape_and_dead_column(self):
        co = MultiJobCoordinator(num_slots=8)
        co.add_job("a", _make_job())
        co.add_job("b", _make_job())
        co["b"].job.set_slot_failure(5)
        R = co.r_matrix(loads=[1.0, 1.0])
        assert R.shape == (2, 8)
        assert np.isfinite(R[0]).all()
        assert np.isinf(R[1, 5]) and np.isfinite(np.delete(R[1], 5)).all()

    def test_wspt_admission_puts_heavy_short_job_first(self):
        co = MultiJobCoordinator(num_slots=8)
        co.add_job("long", _make_job(), weight=1.0)
        co.add_job("short", _make_job(), weight=4.0)
        co["long"].observe_batch_seconds(4.0)
        co["short"].observe_batch_seconds(1.0)
        co.submit("long", _batch(0))
        co.submit("short", _batch(1))
        assert co.plan_admission("wspt") == ["short", "long"]
        assert co.plan_admission("fifo") == ["long", "short"]
        assert (co.planned_weighted_completion("wspt")
                <= co.planned_weighted_completion("fifo") + 1e-9)

    def test_tenant_caches_never_collide(self):
        policy = ReusePolicy(max_age=8)
        co = MultiJobCoordinator(num_slots=8, policy=policy)
        for name, seed in (("a", 0), ("b", 1), ("c", 2)):
            co.add_job(name, _make_job(reuse=policy))
            co.submit(name, _batch(seed))
            co.submit(name, _batch(seed + 10))
        out = co.run_queue(order="fifo")
        stats = out["cache"]
        assert stats["tenants"] == 3
        assert stats["collisions"] == 0
        # each tenant really planned + reused through its own cache
        for name in ("a", "b", "c"):
            per = stats["per_tenant"][name]
            assert per["batches"] == 2

    def test_run_queue_measures_weighted_completion(self):
        co = MultiJobCoordinator(num_slots=8)
        co.add_job("x", _make_job(), weight=2.0)
        co.add_job("y", _make_job(), weight=1.0)
        co.submit("x", _batch(3))
        co.submit("y", _batch(4))
        out = co.run_queue()
        assert set(out["completions"]) == {"x", "y"}
        assert all(c is not None and c > 0 for c in
                   out["completions"].values())
        assert out["weighted_completion"] > 0
        assert out["cache"]["collisions"] == 0

    def test_interleaved_bit_identical_to_solo_vmap(self):
        batches = {"a": [_batch(0), _batch(1)], "b": [_batch(2), _batch(3)]}
        solo = {}
        for name in batches:
            job = _make_job()
            solo[name] = [job.run(b) for b in batches[name]]
        co = MultiJobCoordinator(num_slots=8)
        for name in batches:
            co.add_job(name, _make_job())
            for b in batches[name]:
                co.submit(name, b)
        co.run_interleaved()           # a, b, a, b
        for name in batches:
            got = co[name].results
            for r_solo, r_co in zip(solo[name], got):
                np.testing.assert_array_equal(
                    np.asarray(r_solo.values), np.asarray(r_co.values))
                np.testing.assert_array_equal(
                    np.asarray(r_solo.counts), np.asarray(r_co.counts))

    def test_interleaved_bit_identical_under_mid_batch_kill(self):
        """A straggler kill mid-batch in one job never leaks into another."""
        def fresh(kill):
            job = _make_job(chunks=4, checkpoint=True)
            if kill:
                job.set_slot_failure(3, at_wave=1)
            return job
        solo_a = fresh(kill=True).run(_batch(5, K=512))
        solo_b = fresh(kill=False).run(_batch(6, K=512))
        co = MultiJobCoordinator(num_slots=8)
        co.add_job("a", fresh(kill=True))
        co.add_job("b", fresh(kill=False))
        co.submit("a", _batch(5, K=512))
        co.submit("b", _batch(6, K=512))
        out = dict(co.run_interleaved(sequence=["a", "b"]))
        np.testing.assert_array_equal(np.asarray(solo_a.values),
                                      np.asarray(out["a"].values))
        np.testing.assert_array_equal(np.asarray(solo_b.values),
                                      np.asarray(out["b"].values))

    def test_interleaved_bit_identical_across_resize(self):
        """8→6 resize between batches: solo vs sharing the coordinator."""
        batches = [_batch(7, m=8), _batch(8, m=6)]
        solo_job = _make_job()
        solo_job.run(batches[0])
        solo_job.resize(6)
        solo = solo_job.run(batches[1])
        co = MultiJobCoordinator(num_slots=8)
        co.add_job("a", _make_job())
        co.add_job("b", _make_job())
        co.submit("b", _batch(9))
        out0 = dict(co.run_interleaved(sequence=["b"]))
        co["a"].job.run(batches[0])
        co["a"].job.resize(6)
        res1 = co["a"].job.run(batches[1])
        np.testing.assert_array_equal(np.asarray(solo.values),
                                      np.asarray(res1.values))
        assert "b" in out0

    def test_interleaved_bit_identical_to_solo_shard_map(self, mesh8):
        batches = {"a": [_batch(0)], "b": [_batch(2)]}
        solo = {}
        for name in batches:
            job = _make_job(backend="shard_map", mesh=mesh8)
            solo[name] = [job.run(b) for b in batches[name]]
        co = MultiJobCoordinator(num_slots=8)
        for name in batches:
            co.add_job(name, _make_job(backend="shard_map", mesh=mesh8))
            for b in batches[name]:
                co.submit(name, b)
        co.run_interleaved()
        for name in batches:
            for r_solo, r_co in zip(solo[name], co[name].results):
                np.testing.assert_array_equal(
                    np.asarray(r_solo.values), np.asarray(r_co.values))
                np.testing.assert_array_equal(
                    np.asarray(r_solo.counts), np.asarray(r_co.counts))


# ---------------------------------------------------------------------------
# (h) engine: per-job lane rows + the maybe_replan_waiting regression.
# ---------------------------------------------------------------------------


@pytest.fixture
def plan_engine():
    from repro.configs import get_smoke
    from repro.serve.engine import Engine, EngineConfig

    def make(**ecfg_kw):
        return Engine(get_smoke("smollm-360m"), None, EngineConfig(**ecfg_kw))
    return make


def _reqs(loads, jobs=None, rid0=0):
    from repro.serve.engine import Request
    out = []
    for i, load in enumerate(loads):
        r = Request(rid=rid0 + i, prompt=np.zeros(4, np.int32),
                    max_new=int(load))
        if jobs is not None:
            r.job = jobs[i]
        out.append(r)
    return out


class TestEngineMultiJob:
    def test_single_job_path_unchanged(self, plan_engine):
        """All requests on one job id plan exactly like before the change."""
        eng_new = plan_engine(lanes=4, scheduler="os4m")
        eng_ref = plan_engine(lanes=4, scheduler="os4m")
        reqs_a = _reqs([10, 20, 30, 40, 50])                 # default job=0
        reqs_b = _reqs([10, 20, 30, 40, 50], jobs=[7] * 5)   # one job id ≠ 0
        lanes_a = {r.rid: r.lane for q in
                   eng_new.plan(reqs_a).values() for r in q}
        lanes_b = {r.rid: r.lane for q in
                   eng_ref.plan(reqs_b).values() for r in q}
        assert lanes_a == lanes_b

    def test_each_job_plans_on_its_own_row(self, plan_engine):
        eng = plan_engine(lanes=4, adaptive=True)
        # job 0: lane 3 is 4x slow; job 1: lane 0 is 4x slow
        eng.observe_job_lane_times(0, [100, 100, 100, 25], [1, 1, 1, 1])
        eng.observe_job_lane_times(1, [25, 100, 100, 100], [1, 1, 1, 1])
        R = eng.r_matrix([0, 1])
        assert R.shape == (2, 4)
        assert R[0, 3] == R.max() and R[1, 0] == R.max()
        by = eng.plan(_reqs([40, 40, 40, 40, 40, 40],
                            jobs=[0, 0, 0, 1, 1, 1]))
        for lane, q in by.items():
            for r in q:
                slow = 3 if r.job == 0 else 0
                assert lane != slow, (lane, r.job)

    def test_wspt_weight_orders_admission(self, plan_engine):
        eng = plan_engine(lanes=2, job_weights={0: 1.0, 1: 8.0})
        by = eng.plan(_reqs([30, 30, 30, 30], jobs=[0, 0, 1, 1]))
        for q in by.values():
            if len(q) == 2:   # heavy job 1 queued ahead of job 0
                assert [r.job for r in q] == [1, 0]

    def test_max_concurrent_jobs_caps_wave(self, plan_engine):
        eng = plan_engine(lanes=2, max_concurrent_jobs=1,
                          job_weights={0: 4.0})
        by = eng.plan(_reqs([30, 30, 30, 30], jobs=[0, 0, 1, 1]))
        for q in by.values():
            assert [r.job for r in q] == [0, 1]

    def test_replan_fires_on_per_job_drift(self, plan_engine):
        """Regression: the global meter alone used to gate replans.

        Here the *global* meter has no observations at all — the
        pre-fix code returned False unconditionally — while job 0's own
        row drifts far past the threshold.
        """
        eng = plan_engine(lanes=4, adaptive=True)
        eng.observe_job_lane_times(0, [100, 100, 100, 25], [1, 1, 1, 1])
        by = eng.plan(_reqs([40, 40, 40, 40], jobs=[0, 0, 0, 0]))
        queues = {k: list(v) for k, v in by.items()}
        for _ in range(3):   # flip job 0's slow lane: 3 → 0
            eng.observe_job_lane_times(0, [25, 100, 100, 100], [1, 1, 1, 1])
        assert eng.maybe_replan_waiting(queues)
        assert eng.replans == 1
        assert eng.last_replan_drift > eng.ecfg.max_speed_drift
        for lane, q in queues.items():
            for r in q:
                assert lane != 0

    def test_no_replan_when_rows_stable(self, plan_engine):
        eng = plan_engine(lanes=4, adaptive=True)
        eng.observe_job_lane_times(0, [100, 100, 100, 100], [1, 1, 1, 1])
        by = eng.plan(_reqs([40, 40, 40, 40], jobs=[0, 0, 0, 0]))
        queues = {k: list(v) for k, v in by.items()}
        eng.observe_job_lane_times(0, [100, 100, 100, 100], [1, 1, 1, 1])
        assert not eng.maybe_replan_waiting(queues)
        assert eng.replans == 0

    def test_dead_lane_propagates_to_job_meters(self, plan_engine):
        eng = plan_engine(lanes=4, adaptive=True)
        eng.observe_job_lane_times(0, [100, 100, 100, 100], [1, 1, 1, 1])
        eng.set_lane_failure(2)
        assert eng.lane_speeds(job=0)[2] == 0.0
        assert np.isinf(eng.r_matrix([0])[0, 2])
        by = eng.plan(_reqs([10, 10, 10], jobs=[0, 0, 1]))
        assert not by[2]
