"""Sharding rules, input specs, and a scaled-down dry-run integration test
(the production 512-device dry-run runs via ``python -m repro.launch.dryrun``;
here we exercise the same machinery on an 8-device host mesh)."""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.nn.sharding import MeshAxes, logical_to_pspec


class TestLogicalSharding:
    def test_divisible_dims_shard(self, mesh8):
        spec = logical_to_pspec(("embed", "mlp"), (64, 128), mesh8)
        assert spec == P(("data",), "model")

    def test_non_divisible_falls_back(self, mesh8):
        # 30 % 4 != 0 on the model axis -> replicated
        spec = logical_to_pspec(("embed", "heads"), (64, 30), mesh8)
        assert spec == P(("data",), None)

    def test_axis_used_once(self, mesh8):
        spec = logical_to_pspec(("vocab", "heads"), (64, 64), mesh8)
        # both want "model"; second dim must not reuse it
        assert spec == P("model", None)

    def test_mesh_axes_multi_pod_shape(self):
        # synthesize the axis split without building a 512-dev mesh
        axes = MeshAxes(data=("pod", "data"), model="model")
        assert axes.data == ("pod", "data")


@pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v2-236b",
                                  "zamba2-2.7b", "whisper-base"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_small_mesh_dryrun_cells(mesh8, arch, kind):
    """lower+compile every step kind for representative smoke archs."""
    from repro.configs import get_smoke
    from repro.launch.steps import build_step_for_shape
    from repro.models.config import Shape

    cfg = get_smoke(arch)
    shape = Shape("t", kind, 64, 8)
    kw = {}
    step, ex = build_step_for_shape(cfg, mesh8, shape, **kw)
    with mesh8:
        compiled = step.lower(*ex).compile()
    cost = compiled.cost_analysis()
    assert cost.get("flops", 0) > 0
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0


def test_input_specs_cover_all_model_inputs(mesh8):
    from repro.configs import get_smoke
    from repro.launch.steps import input_specs
    from repro.models.config import Shape

    cfg = get_smoke("qwen2-vl-7b")
    spec = input_specs(cfg, Shape("t", "train", 64, 8), mesh8)
    assert "tokens" in spec and "extra_embed" in spec
    assert spec["tokens"].shape == (8, 64 - cfg.n_patches)
    assert spec["extra_embed"].shape == (8, cfg.n_patches, cfg.d_model)

    wcfg = get_smoke("whisper-base")
    spec = input_specs(wcfg, Shape("t", "train", 64, 8), mesh8)
    assert spec["extra_embed"].shape == (8, wcfg.enc_len, wcfg.d_model)


def test_elastic_checkpoint_reshard(mesh8, tmp_path):
    """Save on one mesh topology, restore onto another (elastic restart)."""
    from repro.configs import get_smoke
    from repro.models.model import init_model
    from repro.nn import layers as L
    from repro.nn.sharding import make_shardings
    from repro.train import checkpoint as ckpt

    cfg = get_smoke("llama3-8b")
    params, logical = L.split(init_model(jax.random.PRNGKey(0), cfg))
    sh8 = make_shardings(params, logical, mesh8)
    params8 = jax.device_put(params, sh8)
    ckpt.save(tmp_path, 1, params8)

    from repro import compat
    mesh2 = compat.make_mesh((4, 2), ("data", "model"))
    sh2 = make_shardings(params, logical, mesh2)
    state, _ = ckpt.load(tmp_path, 1, {"params": params},
                         shardings={"params": sh2})
    for a, b in zip(jax.tree.leaves(params8), jax.tree.leaves(state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)
