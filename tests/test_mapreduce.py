"""MapReduce engine end-to-end vs a numpy oracle (faithful reproduction)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.mapreduce import MapReduceConfig, MapReduceJob


def _identity_map(shard):
    k, v, ok = shard
    return k, v, ok


def _numpy_reduce(keys, vals, valid, n_clusters, op="sum"):
    cids = np.abs(keys) % n_clusters
    out = np.zeros((n_clusters, vals.shape[-1]))
    counts = np.zeros(n_clusters)
    flat_c = cids.reshape(-1)
    flat_v = vals.reshape(-1, vals.shape[-1])
    flat_ok = valid.reshape(-1)
    for c, v, ok in zip(flat_c, flat_v, flat_ok):
        if not ok:
            continue
        counts[c] += 1
        if op == "sum":
            out[c] += v
    return out, counts


@pytest.mark.parametrize("sched", ["hash", "lpt", "os4m"])
@pytest.mark.parametrize("pipelined", [True, False])
def test_wordcount_matches_oracle(rng, sched, pipelined):
    m, K, V, n = 4, 128, 2, 16
    keys = (rng.zipf(1.3, size=(m, K)) % 997).astype(np.int32)
    vals = rng.random((m, K, V)).astype(np.float32)
    valid = rng.random((m, K)) > 0.1
    job = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, scheduler=sched, pipelined=pipelined),
        backend="vmap")
    res = job.run((jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    expect, counts = _numpy_reduce(keys, vals, valid, n)
    np.testing.assert_allclose(res.values, expect, atol=1e-4)
    np.testing.assert_allclose(res.counts, counts)
    assert res.overflow == 0
    # the schedule really partitions the clusters
    assert ((res.schedule.assignment >= 0)
            & (res.schedule.assignment < m)).all()


def test_os4m_schedule_better_than_hash(rng):
    m, K, n = 8, 512, 64
    keys = (rng.zipf(1.25, size=(m, K)) % 4099).astype(np.int32)
    vals = np.ones((m, K, 1), np.float32)
    valid = np.ones((m, K), bool)
    ratios = {}
    for sched in ["hash", "os4m"]:
        job = MapReduceJob(_identity_map, MapReduceConfig(
            num_slots=m, num_clusters=n, scheduler=sched), backend="vmap")
        res = job.run((jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
        ratios[sched] = res.schedule.balance_ratio
    assert ratios["os4m"] <= ratios["hash"] + 1e-9


def test_reduce_op_max(rng):
    m, K, n = 2, 64, 8
    keys = rng.integers(0, 100, (m, K)).astype(np.int32)
    vals = rng.random((m, K, 1)).astype(np.float32)
    valid = np.ones((m, K), bool)
    job = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, reduce_op="max"), backend="vmap")
    res = job.run((jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    cids = np.abs(keys) % n
    for c in range(n):
        mask = cids == c
        if mask.any():
            np.testing.assert_allclose(res.values[c, 0],
                                       vals[mask][:, 0].max(), atol=1e-5)


def test_shard_map_backend_matches_vmap(rng, mesh8):
    """Same job on the shard_map backend over a real 8-device mesh."""
    m, K, V, n = 8, 64, 2, 12
    keys = (rng.zipf(1.4, size=(m, K)) % 503).astype(np.int32)
    vals = rng.random((m, K, V)).astype(np.float32)
    valid = np.ones((m, K), bool)
    res_v = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n), backend="vmap").run(
        (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    res_s = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n), backend="shard_map",
        mesh=mesh8).run(
        (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    np.testing.assert_allclose(res_v.values, res_s.values, atol=1e-4)
