"""P||C_max scheduler unit + property tests (paper §3.2/§4.2)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bss, scheduler as S

loads_strategy = st.lists(
    st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
              allow_infinity=False),
    min_size=1, max_size=64)


@given(loads_strategy, st.integers(1, 16))
@settings(max_examples=200, deadline=None)
def test_every_scheduler_assigns_every_operation(loads, m):
    loads = np.asarray(loads)
    for name in ["hash", "lpt", "multifit", "bss"]:
        sched = S.get_scheduler(name)(loads, m) if name != "hash" \
            else S.schedule_hash(loads, m)
        assert sched.assignment.shape == (len(loads),)
        assert ((sched.assignment >= 0) & (sched.assignment < m)).all()
        # conservation: slot loads sum to total load
        assert np.isclose(sched.slot_loads.sum(), loads.sum())


@given(loads_strategy, st.integers(1, 16))
@settings(max_examples=200, deadline=None)
def test_max_load_at_least_ideal_and_biggest(loads, m):
    loads = np.asarray(loads)
    for name in ["lpt", "multifit", "bss"]:
        sched = S.get_scheduler(name)(loads, m)
        assert sched.max_load >= loads.sum() / m - 1e-6
        assert sched.max_load >= loads.max() - 1e-6


@given(st.lists(st.floats(0.0, 1e6, allow_nan=False, allow_infinity=False),
                min_size=1, max_size=10),
       st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_lpt_graham_bound(loads, m):
    """LPT is a (4/3 − 1/3m)-approximation of the true optimum [Gr69]."""
    loads = np.asarray(loads)
    opt = S.schedule_brute(loads, m).max_load
    sched = S.schedule_lpt(loads, m)
    assert sched.max_load <= (4 / 3 - 1 / (3 * m)) * opt + 1e-6


@given(st.lists(st.integers(1, 50), min_size=2, max_size=10),
       st.integers(2, 4))
@settings(max_examples=60, deadline=None)
def test_bss_close_to_brute_force(loads, m):
    """The paper's near-optimality claim on exhaustive tiny instances."""
    loads = np.asarray(loads, dtype=float)
    opt = S.schedule_brute(loads, m)
    got = S.schedule_bss(loads, m, eta=0.002)
    # eta=0.002 => within 0.2% of optimal, paper §5 point 5 (+tiny slack
    # for the greedy last-slot remainder).
    assert got.max_load <= opt.max_load * 1.35 + 1e-6
    # and never worse than plain LPT
    assert got.max_load <= S.schedule_lpt(loads, m).max_load + 1e-6


def test_bss_beats_hash_on_skew(rng):
    loads = rng.zipf(1.3, 480).astype(float)
    hash_s = S.schedule_hash(loads, 30, keys=np.arange(480))
    bss_s = S.schedule_bss(loads, 30)
    assert bss_s.balance_ratio <= hash_s.balance_ratio
    # Fig 6: OS4M max-load/ideal close to 1 when no single op dominates
    if loads.max() < loads.sum() / 30:
        assert bss_s.balance_ratio < 1.2


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=30),
       st.integers(1, 2000))
@settings(max_examples=100, deadline=None)
def test_bss_exact_subset_closest(units, target):
    """Exact BSS: no other subset is closer to the target."""
    got = bss.subset_closest_to_target(units, target)
    sum_got = sum(units[i] for i in got)
    # exhaustive check on small instances only
    if len(units) <= 12:
        best = min(
            (abs(sum(units[i] for i in range(len(units)) if (mask >> i) & 1)
                 - target)
             for mask in range(1 << len(units))))
        assert abs(sum_got - target) == best


@given(st.lists(st.floats(0.0, 1e4, allow_nan=False), min_size=1,
                max_size=100),
       st.floats(1.0, 1e5), st.floats(0.001, 0.1))
@settings(max_examples=100, deadline=None)
def test_bss_approx_indices_valid(loads, target, eta):
    got = bss.bss_approx(loads, target, eta=eta)
    assert len(set(got)) == len(got)
    assert all(0 <= i < len(loads) for i in got)


def test_lpt_assign_jax_matches_host():
    import jax.numpy as jnp

    loads = np.asarray([5, 3, 8, 1, 9, 2, 7, 4], float)
    assign, slot_loads = S.lpt_assign_jax(jnp.asarray(loads), 3)
    host = S.schedule_lpt(loads, 3)
    got = np.bincount(np.asarray(assign), weights=loads, minlength=3)
    assert np.isclose(sorted(got)[-1], host.max_load)
