"""The measured-mesh feedback subsystem (ISSUE 4 tentpole, ISSUE 5 rework).

* shard_map phase B runs the SAME overlapped pipeline as unmeasured mode
  with **on-device wave tick stamps** (``kernels/wave_timer``) feeding
  the estimator (synthetic model retired); outputs stay bit-identical to
  the vmap reference; the host-fenced executor survives as the explicit
  no-tick-source fallback;
* an injected slowdown on the measured path triggers a ``speed_drift``
  replan; measured speeds ride ``CachedSchedule.to_json`` round trips;
* slowdown factors are **wall-clock multipliers** (2.0 ⇒ twice as slow)
  on both the measured and the synthetic path (ISSUE 5 bugfix);
* ``shard_ready_seconds`` attributes completion in completion order — an
  out-of-order straggler no longer poisons later slots (ISSUE 5 bugfix);
* zero-second / degenerate observations never reach the estimator
  (ISSUE 5 bugfix);
* a wave with an idle slot (no clusters assigned) survives;
* the schedule-cache drift check is device-resident on shard_map (the
  baseline ``K^(i)`` is uploaded once, sharded, and reused);
* :mod:`repro.core.mesh_timing` unit behaviour (no mesh needed).

Mesh tests follow the repo convention: skip below 8 host devices (CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Timing *magnitudes* on the CI container are contention noise (8 virtual
devices over ~2 cores), so assertions about measured speeds use strong
injected factors and generous margins; reuse-mechanics tests disable the
speed-drift trigger outright (``max_speed_drift=1e9``) so honest
measurement noise cannot flake them.
"""

import json
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import mesh_timing as mt
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.core.schedule_cache import CachedSchedule, ReusePolicy, drift_metric
from repro.kernels.wave_timer import ops as wt_ops


def _mesh(m):
    from jax.sharding import Mesh

    if len(jax.devices()) < m:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return Mesh(np.asarray(jax.devices()[:m]), ("mr_slots",))


def _batch(seed, m, K=512, key_mod=503, alpha=1.25):
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(alpha, size=(m, K)) % key_mod).astype(np.int32)
    vals = np.ones((m, K, 4), np.float32)
    valid = np.ones((m, K), bool)
    return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))


def _measured_job(m, mesh, n=24, **cfg_kw):
    cfg_kw.setdefault("reuse", ReusePolicy(max_drift=0.3, max_speed_drift=0.25))
    return MapReduceJob(
        lambda s: s,
        MapReduceConfig(num_slots=m, num_clusters=n, scheduler="bss",
                        pipeline_chunks=3, estimate_speeds=True, **cfg_kw),
        backend="shard_map", mesh=mesh)


# ---------------------------------------------------------------------------
# Config resolution / validation (no mesh needed).
# ---------------------------------------------------------------------------


def test_measure_timings_requires_shard_map():
    with pytest.raises(ValueError):
        MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=2, num_clusters=8, estimate_speeds=True,
            measure_timings=True), backend="vmap")


def test_measure_timings_requires_estimator():
    mesh = _mesh(1) if len(jax.devices()) >= 1 else None
    with pytest.raises(ValueError):
        MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=1, num_clusters=8, measure_timings=True),
            backend="shard_map", mesh=mesh)


def test_vmap_job_stays_on_synthetic_model():
    job = MapReduceJob(lambda s: s, MapReduceConfig(
        num_slots=4, num_clusters=16, estimate_speeds=True), backend="vmap")
    assert not job._measure_timings
    job.run(_batch(0, 4, K=256, key_mod=97))
    assert job.last_wave_timings is None        # synthetic path
    assert job.speed_estimator.observations == 1


# ---------------------------------------------------------------------------
# WaveTimings / shard_ready_seconds units.
# ---------------------------------------------------------------------------


class TestWaveTimings:
    def test_accumulates_and_sums(self):
        t = mt.WaveTimings.empty(3, 2)
        t.record(0, [0.1, 0.2, 0.3])
        t.record(1, [0.4, 0.1, 0.0])
        assert np.allclose(t.slot_seconds(), [0.5, 0.3, 0.3])

    def test_observation_applies_injected_slowdown(self):
        """ISSUE 5 bugfix pin: the slowdown factor is a wall-clock
        MULTIPLIER — a 2x factor yields 2x the measured seconds (the old
        code divided, so "slowdown 2" made the slot look faster)."""
        t = mt.WaveTimings.empty(2, 1)
        t.record(0, [1.0, 1.0])
        t.slot_work = np.asarray([10.0, 10.0])
        work, secs = t.observation(np.asarray([1.0, 2.0]))
        # the 2x-slow slot reports DOUBLE the measured wall-clock
        assert np.allclose(secs, [1.0, 2.0])
        assert np.allclose(work, [10.0, 10.0])

    def test_from_ticks_round_trip(self):
        """(slots, waves, 2) start/end stamps become per-wave seconds."""
        base = 1_000_000
        ticks = np.asarray([
            [[base, base + 100], [base + 200, base + 500]],
            [[base, base + 400], [base + 400, base + 400]],
        ], np.int64)
        t = mt.WaveTimings.from_ticks(ticks, 1e-9)
        assert t.valid
        assert np.allclose(t.seconds, [[100e-9, 300e-9], [400e-9, 0.0]])
        assert np.allclose(t.slot_seconds(), [400e-9, 400e-9])

    def test_from_ticks_wrapped_stamp_is_invalid_not_negative(self):
        ticks = np.asarray([[[100, 40]]], np.int64)   # end < start: wrap/fault
        t = mt.WaveTimings.from_ticks(ticks, 1e-9)
        assert not t.valid
        assert (t.seconds >= 0).all()

    def test_from_ticks_validates_shape(self):
        with pytest.raises(ValueError):
            mt.WaveTimings.from_ticks(np.zeros((4, 2)), 1e-9)

    def test_shard_ready_seconds_fallback_single_device(self):
        arr = jnp.ones((8, 4))       # one addressable shard < num_slots
        secs = mt.shard_ready_seconds([arr], 4, time.perf_counter())
        assert secs.shape == (4,)
        assert (secs >= 0).all()


class _FakeBuf:
    """A device buffer that becomes ready at a wall-clock deadline.

    ``pollable=False`` drops the ``is_ready`` attribute entirely, standing
    in for runtimes whose buffers cannot report readiness.
    """

    def __init__(self, ready_at: float, pollable: bool = True):
        self._ready_at = ready_at
        if pollable:
            self.is_ready = lambda: time.perf_counter() >= self._ready_at

    def block_until_ready(self):
        delay = self._ready_at - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        return self


class _FakeShard:
    def __init__(self, row_start: int, data: _FakeBuf):
        self.index = (slice(row_start, row_start + 2),)
        self.data = data


class _FakeArray:
    """Duck-typed sharded array: 4 slots x 2 rows, per-slot readiness."""

    def __init__(self, ready_at, pollable: bool = True):
        self.shape = (8, 4)
        self.addressable_shards = [
            _FakeShard(2 * s, _FakeBuf(t, pollable))
            for s, t in enumerate(ready_at)
        ]


class TestCompletionOrderAwait:
    def test_fast_shard_does_not_inherit_straggler_timestamp(self):
        """ISSUE 5 regression: slots are stamped in COMPLETION order. With
        an injected straggler at slot 0 and instantly-ready slots 1..3,
        the old serial slot-id-order await charged every later slot the
        straggler's ~80 ms; completion-order polling stamps them early."""
        t0 = time.perf_counter()
        straggle = 0.08
        arr = _FakeArray([t0 + straggle, t0, t0, t0])
        secs = mt.shard_ready_seconds([arr], 4, t0)
        assert secs[0] >= straggle * 0.9          # the straggler earns its bound
        for fast in (1, 2, 3):
            assert secs[fast] < straggle * 0.5, (
                f"slot {fast} inherited the straggler's timestamp: {secs}")

    def test_out_of_order_completion_attributed_per_slot(self):
        """Completion times in reverse slot order come back per-slot."""
        t0 = time.perf_counter()
        deadlines = [t0 + 0.06, t0 + 0.04, t0 + 0.02, t0]
        secs = mt.shard_ready_seconds([_FakeArray(deadlines)], 4, t0)
        assert np.all(np.diff(secs) < 0)          # slot 3 first, slot 0 last
        assert secs[0] >= 0.05

    def test_unpollable_buffers_use_serial_await(self):
        """Buffers without is_ready degrade to the serial slot-order await
        (documented upper-bound attribution) instead of crashing."""
        t0 = time.perf_counter()
        arr = _FakeArray([t0 + 0.01] * 4, pollable=False)
        secs = mt.shard_ready_seconds([arr], 4, t0)
        assert (secs >= 0.009).all()


# ---------------------------------------------------------------------------
# The measured loop on a mesh.
# ---------------------------------------------------------------------------


class TestMeasuredMesh:
    m = 8

    def test_measured_timings_drive_estimator_and_replan(self):
        """Measured per-device tick clocks (not synthetic) update the
        estimator; an injected slowdown trips a speed_drift replan;
        outputs stay bit-identical to the unperturbed vmap reference
        throughout — all WITHOUT wave fencing (the overlapped program)."""
        mesh = _mesh(self.m)
        # Key drift must not mask the straggler trigger: with a tight
        # max_drift a zipf batch can trip a "drift" replan at the same
        # batch as the injected slowdown, absorbing the speed change into
        # the new plan before the speed check ever fires.
        job = _measured_job(self.m, mesh,
                            reuse=ReusePolicy(max_drift=0.8,
                                              max_speed_drift=0.25))
        ref = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=24, scheduler="bss",
            pipeline_chunks=3), backend="vmap")
        assert job._measure_timings
        reasons = []
        for i in range(7):
            if i == 3:
                job.set_slot_slowdown(1, 3.0)    # slot 1 now 3x slower
            r = job.run(_batch(i, self.m))
            v = ref.run(_batch(i, self.m))
            assert np.array_equal(np.asarray(r.values), np.asarray(v.values))
            assert np.array_equal(np.asarray(r.counts), np.asarray(v.counts))
            reasons.append(r.plan_reason)
        # the first contact flipped the job to external/measured mode:
        # the synthetic model can never dilute the estimate again
        assert job._external_timings
        assert job.last_wave_timings is not None
        assert job.last_wave_timings.seconds.shape[0] == self.m
        # measured batches accumulated observations
        assert job.speed_estimator.observations >= 2
        # injected straggler detected from measured seconds -> replan
        assert job.schedule_cache.speed_replans >= 1
        assert "speed_drift" in reasons
        sp = job.speed_estimator.speeds()
        assert sp[1] < 0.85                      # slot 1 visibly slow
        assert sp[1] == sp.min()

    def test_tick_path_first_batch_is_already_valid(self):
        """On-device tick stamps execute with the program, AFTER
        compilation — so (unlike the fenced fallback) even the first,
        freshly traced batch is a valid speed sample."""
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh)
        assert wt_ops.available()                # this container: CPU callback
        job.run(_batch(0, self.m))
        assert job.last_wave_timings is not None
        assert job.last_wave_timings.valid
        assert job.speed_estimator.observations == 1

    def test_fenced_fallback_skips_compiled_waves(self):
        """With the tick source forced off, the measured executor falls
        back to host-fenced timing, which must keep skipping batches
        whose timed waves traced/compiled (compilation is not a speed
        signal)."""
        mesh = _mesh(self.m)
        with wt_ops.force_backend("none"):
            job = _measured_job(self.m, mesh)
            job.run(_batch(0, self.m))
            # batch 0 traced/compiled its wave programs -> measured, invalid
            assert job.last_wave_timings is not None
            assert not job.last_wave_timings.valid
            assert job.speed_estimator.observations == 0
            job.run(_batch(1, self.m))
            assert job.last_wave_timings.valid
            assert job.speed_estimator.observations == 1

    def test_idle_slot_wave_survives(self):
        """A schedule that leaves one slot without clusters still executes,
        measures, and reduces correctly (capacity-shaped waves pad)."""
        mesh = _mesh(self.m)
        # fewer clusters than slots => some slots hold no cluster
        job = _measured_job(self.m, mesh, n=5,
                            reuse=ReusePolicy(max_drift=0.5))
        ref = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=5, scheduler="bss",
            pipeline_chunks=3), backend="vmap")
        for i in range(3):
            b = _batch(i, self.m, key_mod=5)
            r, v = job.run(b), ref.run(b)
            idle = np.setdiff1d(np.arange(self.m),
                                np.unique(r.schedule.assignment))
            assert idle.size > 0                 # the fixture is real
            assert np.array_equal(np.asarray(r.values), np.asarray(v.values))
            assert np.array_equal(np.asarray(r.counts), np.asarray(v.counts))
        sp = job.speed_estimator.speeds(default_ones=True)
        assert np.isfinite(sp).all()

    def test_measured_speeds_roundtrip_through_snapshot_json(self):
        """Measured speeds land in the replanned snapshot and survive
        CachedSchedule.to_json round trips."""
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh)
        job.set_slot_slowdown(2, 3.0)
        for i in range(6):
            r = job.run(_batch(i, self.m))
            if r.plan_reason == "speed_drift":
                break
        snap = job.schedule_cache.snapshot
        assert not np.allclose(snap.slot_speeds, 1.0)   # measured, non-nominal
        clone = CachedSchedule.from_json(json.loads(json.dumps(snap.to_json())))
        assert np.allclose(clone.slot_speeds, snap.slot_speeds)
        assert np.array_equal(clone.schedule.assignment,
                              snap.schedule.assignment)

    def test_sequential_phase_b_measured_single_wave(self):
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh, pipelined=False,
                            reuse=ReusePolicy(max_drift=0.5))
        ref = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=24, scheduler="bss",
            pipelined=False), backend="vmap")
        for i in range(2):
            b = _batch(i, self.m)
            r, v = job.run(b), ref.run(b)
            assert np.array_equal(np.asarray(r.values), np.asarray(v.values))
        assert job.last_wave_timings.seconds.shape == (self.m, 1)


# ---------------------------------------------------------------------------
# Device-resident drift check.
# ---------------------------------------------------------------------------


class TestDeviceResidentDrift:
    m = 8

    # Reuse-mechanics tests: the speed-drift trigger is disabled (huge
    # threshold) so honest measurement noise on the shared-core CI mesh
    # cannot replan mid-test and swap the snapshot under the assertions.
    policy = ReusePolicy(max_drift=0.3, max_speed_drift=1e9)

    def test_baseline_uploaded_once_and_reused(self):
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh, reuse=self.policy)
        assert job.schedule_cache.drift_fn is not None
        job.run(_batch(0, self.m))
        snap = job.schedule_cache.snapshot
        assert snap._hist_dev is None            # nothing checked yet
        job.run(_batch(1, self.m))
        dev = snap._hist_dev
        assert dev is not None                   # uploaded by the check...
        job.run(_batch(2, self.m))
        assert snap._hist_dev is dev             # ...and NOT re-uploaded
        # the resident baseline is sharded over the mesh, one row per device
        assert len(dev.addressable_shards) == self.m

    def test_sharded_drift_matches_host_metric(self):
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh, reuse=self.policy)
        job.run(_batch(0, self.m))
        r = job.run(_batch(1, self.m))
        snap = job.schedule_cache.snapshot
        fresh = np.asarray([np.bincount(
            np.abs(np.asarray(_batch(1, self.m)[0][i])) % 24, minlength=24)
            for i in range(self.m)], np.float32)
        want = float(drift_metric(snap.local_hist.astype(np.float32),
                                  fresh, "l1"))
        assert r.drift == pytest.approx(want, abs=1e-5)

    def test_vmap_jobs_have_no_drift_fn(self):
        job = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=4, num_clusters=16, reuse=ReusePolicy()), backend="vmap")
        assert job.schedule_cache.drift_fn is None


# ---------------------------------------------------------------------------
# ISSUE 5 bugfix pins: zero-second guard + slowdown factor direction.
# ---------------------------------------------------------------------------


class TestZeroSecondGuard:
    def test_estimator_skips_zero_and_nonfinite_seconds(self):
        from repro.core.slot_speeds import SlotSpeedEstimator

        est = SlotSpeedEstimator(4)
        est.update(np.ones(4), np.zeros(4))            # all-zero seconds
        assert est.observations == 0
        assert est.speeds() is None                    # still "no data"
        est.update(np.ones(4), [np.inf, np.nan, -1.0, 0.0])
        assert est.observations == 0
        # a mixed batch only folds in the usable slot
        est.update(np.ones(4), [0.0, 0.5, 0.0, np.inf])
        assert est.observations == 1
        sp = est.speeds()
        assert np.isfinite(sp).all() and (sp > 0).all()

    def test_empty_wave_timings_never_reach_estimator(self):
        """WaveTimings.empty(m, 0) (and any all-zero batch) must not flip
        the job to external-measurement mode or count as an observation —
        the old code fed seconds == 0 straight to the estimator."""
        job = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=4, num_clusters=16, estimate_speeds=True),
            backend="vmap")
        planned = _fake_plan(job)
        job._observe_measured(mt.WaveTimings.empty(4, 0), planned)
        assert not job._external_timings
        assert job.speed_estimator.observations == 0
        assert job.speed_estimator.speeds() is None

    def test_all_invalid_batch_is_skipped(self):
        job = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=4, num_clusters=16, estimate_speeds=True),
            backend="vmap")
        planned = _fake_plan(job)
        t = mt.WaveTimings.empty(4, 2)
        t.record(0, [0.1, 0.2, 0.3, 0.4])
        t.valid = False                                # compile-polluted
        job._observe_measured(t, planned)
        assert not job._external_timings
        assert job.speed_estimator.observations == 0


def _fake_plan(job):
    """A minimal CachedSchedule for observe tests (no batch executed)."""
    key_dist = np.ones(job.cfg.num_clusters)
    local = np.tile(key_dist / job.cfg.num_slots, (job.cfg.num_slots, 1))
    return job._plan(local, key_dist, 128)


class TestSlowdownDirection:
    """ISSUE 5 bugfix pin: a 2x slowdown factor yields 2x measured seconds
    (and hence ~0.5x estimated speed) on BOTH timing paths."""

    def test_measured_path_two_x_factor_doubles_seconds(self):
        t = mt.WaveTimings.empty(3, 2)
        t.record(0, [1.0, 1.0, 1.0])
        t.record(1, [0.5, 0.5, 0.5])
        t.slot_work = np.full(3, 6.0)
        _, base = t.observation(None)
        _, faulted = t.observation(np.asarray([1.0, 2.0, 1.0]))
        assert np.allclose(faulted / base, [1.0, 2.0, 1.0])

    def test_synthetic_path_two_x_factor_halves_speed(self):
        job = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=4, num_clusters=16, scheduler="bss",
            estimate_speeds=True, speed_ewma=1.0), backend="vmap")
        job.set_slot_slowdown(1, 2.0)
        job.run(_batch(0, 4, K=256, key_mod=97))
        sp = job.speed_estimator.speeds()
        # synthetic rate_j = work/(work*factor) = 1/factor exactly
        assert sp[1] / sp[0] == pytest.approx(0.5)
        assert sp[1] == sp.min()

    def test_both_paths_agree_on_direction(self):
        """The measured observation and the synthetic model move the SAME
        way for the same factor (the old code had them inverted)."""
        # measured: factor 2 doubles seconds -> rate halves
        t = mt.WaveTimings.empty(2, 1)
        t.record(0, [1.0, 1.0])
        t.slot_work = np.asarray([4.0, 4.0])
        work, secs = t.observation(np.asarray([1.0, 2.0]))
        measured_ratio = (work[1] / secs[1]) / (work[0] / secs[0])
        # synthetic: same factor through the job's model
        job = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=2, num_clusters=8, scheduler="bss",
            estimate_speeds=True, speed_ewma=1.0), backend="vmap")
        job.set_slot_slowdown(1, 2.0)
        job.run(_batch(0, 2, K=128, key_mod=7))
        sp = job.speed_estimator.speeds()
        synthetic_ratio = sp[1] / sp[0]
        assert measured_ratio == pytest.approx(0.5)
        assert synthetic_ratio == pytest.approx(0.5)
