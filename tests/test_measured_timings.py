"""The measured-mesh feedback subsystem (ISSUE 4 tentpole).

* shard_map phase B with per-wave fences delivers **measured** per-device
  wall clocks to the estimator (synthetic model retired), outputs stay
  bit-identical to the fused/overlapped path and to the vmap reference;
* an injected slowdown on the measured path triggers a ``speed_drift``
  replan; measured speeds ride ``CachedSchedule.to_json`` round trips;
* a wave with an idle slot (no clusters assigned) survives;
* the schedule-cache drift check is device-resident on shard_map (the
  baseline ``K^(i)`` is uploaded once, sharded, and reused);
* :mod:`repro.core.mesh_timing` unit behaviour (no mesh needed).

Mesh tests follow the repo convention: skip below 8 host devices (CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import mesh_timing as mt
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.core.schedule_cache import CachedSchedule, ReusePolicy, drift_metric


def _mesh(m):
    from jax.sharding import Mesh

    if len(jax.devices()) < m:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return Mesh(np.asarray(jax.devices()[:m]), ("mr_slots",))


def _batch(seed, m, K=512, key_mod=503, alpha=1.25):
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(alpha, size=(m, K)) % key_mod).astype(np.int32)
    vals = np.ones((m, K, 4), np.float32)
    valid = np.ones((m, K), bool)
    return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))


def _measured_job(m, mesh, n=24, **cfg_kw):
    cfg_kw.setdefault("reuse", ReusePolicy(max_drift=0.3, max_speed_drift=0.25))
    return MapReduceJob(
        lambda s: s,
        MapReduceConfig(num_slots=m, num_clusters=n, scheduler="bss",
                        pipeline_chunks=3, estimate_speeds=True, **cfg_kw),
        backend="shard_map", mesh=mesh)


# ---------------------------------------------------------------------------
# Config resolution / validation (no mesh needed).
# ---------------------------------------------------------------------------


def test_measure_timings_requires_shard_map():
    with pytest.raises(ValueError):
        MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=2, num_clusters=8, estimate_speeds=True,
            measure_timings=True), backend="vmap")


def test_measure_timings_requires_estimator():
    mesh = _mesh(1) if len(jax.devices()) >= 1 else None
    with pytest.raises(ValueError):
        MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=1, num_clusters=8, measure_timings=True),
            backend="shard_map", mesh=mesh)


def test_vmap_job_stays_on_synthetic_model():
    job = MapReduceJob(lambda s: s, MapReduceConfig(
        num_slots=4, num_clusters=16, estimate_speeds=True), backend="vmap")
    assert not job._measure_timings
    job.run(_batch(0, 4, K=256, key_mod=97))
    assert job.last_wave_timings is None        # synthetic path
    assert job.speed_estimator.observations == 1


# ---------------------------------------------------------------------------
# WaveTimings / shard_ready_seconds units.
# ---------------------------------------------------------------------------


class TestWaveTimings:
    def test_accumulates_and_sums(self):
        t = mt.WaveTimings.empty(3, 2)
        t.record(0, [0.1, 0.2, 0.3])
        t.record(1, [0.4, 0.1, 0.0])
        assert np.allclose(t.slot_seconds(), [0.5, 0.3, 0.3])

    def test_observation_applies_injected_slowdown(self):
        t = mt.WaveTimings.empty(2, 1)
        t.record(0, [1.0, 1.0])
        t.slot_work = np.asarray([10.0, 10.0])
        work, secs = t.observation(np.asarray([1.0, 0.5]))
        # the 0.5x slot reports DOUBLE the measured wall-clock
        assert np.allclose(secs, [1.0, 2.0])
        assert np.allclose(work, [10.0, 10.0])

    def test_shard_ready_seconds_fallback_single_device(self):
        import time

        arr = jnp.ones((8, 4))       # one addressable shard < num_slots
        secs = mt.shard_ready_seconds([arr], 4, time.perf_counter())
        assert secs.shape == (4,)
        assert (secs >= 0).all()


# ---------------------------------------------------------------------------
# The measured loop on a mesh.
# ---------------------------------------------------------------------------


class TestMeasuredMesh:
    m = 8

    def test_measured_timings_drive_estimator_and_replan(self):
        """Measured per-device clocks (not synthetic) update the estimator;
        an injected slowdown trips a speed_drift replan; outputs stay
        bit-identical to the unperturbed vmap reference throughout."""
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh)
        ref = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=24, scheduler="bss",
            pipeline_chunks=3), backend="vmap")
        assert job._measure_timings
        reasons = []
        for i in range(7):
            if i == 3:
                job.set_slot_slowdown(1, 0.5)
            r = job.run(_batch(i, self.m))
            v = ref.run(_batch(i, self.m))
            assert np.array_equal(np.asarray(r.values), np.asarray(v.values))
            assert np.array_equal(np.asarray(r.counts), np.asarray(v.counts))
            reasons.append(r.plan_reason)
        # the first contact flipped the job to external/measured mode:
        # the synthetic model can never dilute the estimate again
        assert job._external_timings
        assert job.last_wave_timings is not None
        assert job.last_wave_timings.seconds.shape[0] == self.m
        # measured batches accumulated observations
        assert job.speed_estimator.observations >= 2
        # injected straggler detected from measured seconds -> replan
        assert job.schedule_cache.speed_replans >= 1
        assert "speed_drift" in reasons
        sp = job.speed_estimator.speeds()
        assert sp[1] < 0.85                      # slot 1 visibly slow
        assert sp[1] == sp.min()

    def test_compiled_waves_are_not_fed_to_estimator(self):
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh)
        job.run(_batch(0, self.m))
        # batch 0 traced/compiled its wave programs -> measured but invalid
        assert job.last_wave_timings is not None
        assert not job.last_wave_timings.valid
        assert job.speed_estimator.observations == 0
        job.run(_batch(1, self.m))
        assert job.last_wave_timings.valid
        assert job.speed_estimator.observations == 1

    def test_idle_slot_wave_survives(self):
        """A schedule that leaves one slot without clusters still executes,
        measures, and reduces correctly (capacity-shaped waves pad)."""
        mesh = _mesh(self.m)
        # fewer clusters than slots => some slots hold no cluster
        job = _measured_job(self.m, mesh, n=5,
                            reuse=ReusePolicy(max_drift=0.5))
        ref = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=5, scheduler="bss",
            pipeline_chunks=3), backend="vmap")
        for i in range(3):
            b = _batch(i, self.m, key_mod=5)
            r, v = job.run(b), ref.run(b)
            idle = np.setdiff1d(np.arange(self.m),
                                np.unique(r.schedule.assignment))
            assert idle.size > 0                 # the fixture is real
            assert np.array_equal(np.asarray(r.values), np.asarray(v.values))
            assert np.array_equal(np.asarray(r.counts), np.asarray(v.counts))
        sp = job.speed_estimator.speeds(default_ones=True)
        assert np.isfinite(sp).all()

    def test_measured_speeds_roundtrip_through_snapshot_json(self):
        """Measured speeds land in the replanned snapshot and survive
        CachedSchedule.to_json round trips."""
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh)
        job.set_slot_slowdown(2, 0.5)
        for i in range(6):
            r = job.run(_batch(i, self.m))
            if r.plan_reason == "speed_drift":
                break
        snap = job.schedule_cache.snapshot
        assert not np.allclose(snap.slot_speeds, 1.0)   # measured, non-nominal
        clone = CachedSchedule.from_json(json.loads(json.dumps(snap.to_json())))
        assert np.allclose(clone.slot_speeds, snap.slot_speeds)
        assert np.array_equal(clone.schedule.assignment,
                              snap.schedule.assignment)

    def test_sequential_phase_b_measured_single_wave(self):
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh, pipelined=False,
                            reuse=ReusePolicy(max_drift=0.5))
        ref = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=24, scheduler="bss",
            pipelined=False), backend="vmap")
        for i in range(2):
            b = _batch(i, self.m)
            r, v = job.run(b), ref.run(b)
            assert np.array_equal(np.asarray(r.values), np.asarray(v.values))
        assert job.last_wave_timings.seconds.shape == (self.m, 1)


# ---------------------------------------------------------------------------
# Device-resident drift check.
# ---------------------------------------------------------------------------


class TestDeviceResidentDrift:
    m = 8

    def test_baseline_uploaded_once_and_reused(self):
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh)
        assert job.schedule_cache.drift_fn is not None
        job.run(_batch(0, self.m))
        snap = job.schedule_cache.snapshot
        assert snap._hist_dev is None            # nothing checked yet
        job.run(_batch(1, self.m))
        dev = snap._hist_dev
        assert dev is not None                   # uploaded by the check...
        job.run(_batch(2, self.m))
        assert snap._hist_dev is dev             # ...and NOT re-uploaded
        # the resident baseline is sharded over the mesh, one row per device
        assert len(dev.addressable_shards) == self.m

    def test_sharded_drift_matches_host_metric(self):
        mesh = _mesh(self.m)
        job = _measured_job(self.m, mesh)
        job.run(_batch(0, self.m))
        r = job.run(_batch(1, self.m))
        snap = job.schedule_cache.snapshot
        fresh = np.asarray([np.bincount(
            np.abs(np.asarray(_batch(1, self.m)[0][i])) % 24, minlength=24)
            for i in range(self.m)], np.float32)
        want = float(drift_metric(snap.local_hist.astype(np.float32),
                                  fresh, "l1"))
        assert r.drift == pytest.approx(want, abs=1e-5)

    def test_vmap_jobs_have_no_drift_fn(self):
        job = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=4, num_clusters=16, reuse=ReusePolicy()), backend="vmap")
        assert job.schedule_cache.drift_fn is None
