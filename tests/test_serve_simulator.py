"""Serving engine e2e + cluster simulator sanity + HLO analyzer checks."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.simulator import PUMA_BENCHMARKS, simulate_job
from repro.models.model import init_model
from repro.nn import layers as L
from repro.serve.engine import Engine, EngineConfig, Request


@pytest.fixture(scope="module")
def smoke_engine_setup():
    cfg = get_smoke("llama3-8b")
    params, _ = L.split(init_model(jax.random.PRNGKey(0), cfg))
    return cfg, params


class TestEngine:
    def test_serves_all_requests(self, smoke_engine_setup, rng):
        cfg, params = smoke_engine_setup
        reqs = [Request(rid=i,
                        prompt=rng.integers(3, cfg.vocab, 6).astype(np.int32),
                        max_new=int(rng.integers(2, 8)))
                for i in range(6)]
        eng = Engine(cfg, params, EngineConfig(lanes=2, max_len=48))
        done = eng.run(reqs)
        assert len(done) == 6
        for r in done:
            assert r.output is not None and 1 <= len(r.output) <= r.max_new

    def test_lane_plan_balances(self, smoke_engine_setup, rng):
        cfg, params = smoke_engine_setup
        loads = rng.zipf(1.4, 40).clip(1, 50)
        reqs = [Request(rid=i, prompt=np.ones(4, np.int32),
                        max_new=int(l)) for i, l in enumerate(loads)]
        eng_h = Engine(cfg, params, EngineConfig(lanes=4, scheduler="hash"))
        eng_o = Engine(cfg, params, EngineConfig(lanes=4, scheduler="os4m"))
        eng_h.plan(list(reqs))
        eng_o.plan(list(reqs))
        assert eng_o.last_balance_ratio <= eng_h.last_balance_ratio + 1e-9

    def test_engine_output_matches_greedy_reference(self, smoke_engine_setup,
                                                    rng):
        """Engine tokens == straight greedy decode of the same model."""
        from repro.models.model import forward, init_cache

        cfg, params = smoke_engine_setup
        prompt = rng.integers(3, cfg.vocab, 5).astype(np.int32)
        eng = Engine(cfg, params, EngineConfig(lanes=2, max_len=32, eos=-1))
        done = eng.run([Request(rid=0, prompt=prompt, max_new=4)])
        got = done[0].output

        cache = init_cache(cfg, 1, 32, dtype=jnp.float32)
        o = forward(params, cfg, tokens=jnp.asarray(prompt[None]),
                    mode="prefill", cache=cache, cache_pos=jnp.int32(0))
        ref = [int(jnp.argmax(o.logits[0, -1]))]
        cache = o.cache
        pos = len(prompt)
        for _ in range(3):
            o = forward(params, cfg,
                        tokens=jnp.asarray([[ref[-1]]], jnp.int32),
                        mode="decode", cache=cache, cache_pos=jnp.int32(pos))
            cache = o.cache
            pos += 1
            ref.append(int(jnp.argmax(o.logits[0, -1])))
        assert got == ref, (got, ref)


class TestSimulator:
    @pytest.mark.parametrize("bench", list(PUMA_BENCHMARKS))
    def test_os4m_faster_on_all_benchmarks(self, bench):
        """Paper Fig 14: OS4M < Hadoop for every case (size M as spot check)."""
        h = simulate_job(bench, "M", "hadoop")
        o = simulate_job(bench, "M", "os4m")
        assert o.job_duration < h.job_duration
        assert o.avg_map_duration < h.avg_map_duration  # Fig 8

    def test_balance_ratio_improves(self):
        h = simulate_job("RII", "S", "hadoop")
        o = simulate_job("RII", "S", "os4m")
        assert o.balance_ratio < h.balance_ratio  # Fig 1b vs Fig 5

    def test_map_waves_flat_for_os4m(self):
        """Fig 9: OS4M's map progress is linear; Hadoop's decelerates."""
        o = simulate_job("II", "S", "os4m")
        h = simulate_job("II", "S", "hadoop")
        ot = np.diff([t for t, _ in o.map_progress])
        ht = np.diff([t for t, _ in h.map_progress])
        assert np.allclose(ot, ot[0])          # constant wave time
        assert ht[-1] > ht[0]                  # growing contention


class TestHloAnalyzer:
    def test_matmul_flops_exact(self):
        from repro.launch.hlo_analysis import analyze_hlo

        m, k, n = 128, 64, 32
        c = jax.jit(lambda a, b: a @ b).lower(
            jnp.ones((m, k)), jnp.ones((k, n))).compile()
        a = analyze_hlo(c.as_text())
        assert a["flops"] == pytest.approx(2 * m * n * k, rel=0.05)

    def test_scan_loop_weighting(self):
        from repro.launch.hlo_analysis import analyze_hlo

        m = 64

        def f(x, ws):
            return jax.lax.scan(lambda x, w: (x @ w, None), x, ws)[0]

        for L_ in [2, 8]:
            c = jax.jit(f).lower(jnp.ones((m, m)),
                                 jnp.ones((L_, m, m))).compile()
            a = analyze_hlo(c.as_text())
            assert a["flops"] == pytest.approx(2 * m ** 3 * L_, rel=0.1)

    def test_collectives_counted(self, mesh8):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.hlo_analysis import analyze_hlo

        def f(x):
            y = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh8, P("data", "model")))
            return y.sum()

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                                 sharding=NamedSharding(mesh8, P(None, None)))
        with mesh8:
            c = jax.jit(f).lower(x).compile()
        a = analyze_hlo(c.as_text())
        assert a["collective_bytes"] >= 0  # parses without error
