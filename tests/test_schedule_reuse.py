"""Schedule reuse with drift detection (the steady-state serving subsystem).

Covers the PR's acceptance surface:
* stationary batch stream → the job plans exactly once, replays the cached
  schedule, and the jit cache records **zero retraces after warmup**;
* a shifted zipf distribution trips the drift metric and forces a replan;
* ``max_age`` forces revalidation regardless of drift;
* reused-schedule outputs stay **bit-identical** to an always-replan job;
* drift-metric properties, revalidation cadence, overflow fallback, the
  simulator's replan-benefit cost model, and the serve steady-state loop.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import pipeline as pipe
from repro.core import schedule_cache as sc
from repro.core import simulator as sim
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.core.schedule_cache import ReusePolicy, drift_metric
from repro.launch.serve import steady_state_loop


def _identity_map(shard):
    return shard


def _batch(seed, m=4, K=2048, V=2, key_mod=997, alpha=1.25):
    """Integer-valued f32 pairs: bit-exact under any summation order."""
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(alpha, size=(m, K)) % key_mod).astype(np.int32)
    vals = rng.integers(0, 8, size=(m, K, V)).astype(np.float32)
    valid = np.ones((m, K), bool)
    return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))


def _job(policy, m=4, n=32, scheduler="bss", **cfg_kw):
    return MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, scheduler=scheduler, reuse=policy,
        **cfg_kw), backend="vmap")


# ---------------------------------------------------------------------------
# Drift metric
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["l1", "chi2"])
def test_drift_metric_zero_on_identical(kind):
    h = np.asarray([[3.0, 5.0, 2.0], [1.0, 1.0, 8.0]])
    assert float(drift_metric(h, h, kind)) == pytest.approx(0.0, abs=1e-6)
    # scale invariance: batch-size change alone is zero drift
    assert float(drift_metric(h, 7.0 * h, kind)) == pytest.approx(0.0, abs=1e-5)


@pytest.mark.parametrize("kind", ["l1", "chi2"])
def test_drift_metric_one_on_disjoint(kind):
    p = np.asarray([1.0, 0.0, 0.0, 0.0])
    q = np.asarray([0.0, 0.0, 1.0, 0.0])
    assert float(drift_metric(p, q, kind)) == pytest.approx(1.0, abs=1e-5)


def test_drift_metric_is_max_over_shards():
    same = np.asarray([1.0, 1.0])
    p = np.stack([same, np.asarray([2.0, 0.0])])
    q = np.stack([same, np.asarray([0.0, 2.0])])
    # shard 0 identical, shard 1 disjoint -> max rules
    assert float(drift_metric(p, q, "l1")) == pytest.approx(1.0, abs=1e-5)


def test_drift_metric_rejects_unknown_kind():
    with pytest.raises(ValueError):
        drift_metric(np.ones(3), np.ones(3), "kl")


def test_reuse_policy_validates():
    with pytest.raises(ValueError):
        ReusePolicy(max_drift=-0.1)
    with pytest.raises(ValueError):
        ReusePolicy(revalidate_every=0)
    with pytest.raises(ValueError):
        ReusePolicy(metric="cosine")


# ---------------------------------------------------------------------------
# Steady state: reuse, zero retraces, bit-identity
# ---------------------------------------------------------------------------


def test_stationary_batches_replan_exactly_once():
    job = _job(ReusePolicy(max_drift=0.2))
    results = [job.run(_batch(seed)) for seed in range(10)]
    stats = job.schedule_cache.stats()
    assert stats["replans"] == 1 and stats["reuses"] == 9
    assert results[0].plan_reason == "cold" and not results[0].reused
    assert all(r.reused and r.plan_reason == "ok" for r in results[1:])
    assert all(r.drift is not None and r.drift <= 0.2 for r in results[1:])
    assert all(r.overflow == 0 for r in results)


def test_zero_retraces_after_warmup():
    """The phase-B jit cache must hit on every reused batch."""
    job = _job(ReusePolicy(max_drift=0.2))
    job.run(_batch(0))
    warm_misses = job.jit_misses       # phase A + phase B compile
    for seed in range(1, 10):
        job.run(_batch(seed))
    assert job.jit_misses == warm_misses
    assert len(job._jit_cache) == 2    # one phase-A, one phase-B executable


def test_shifted_zipf_triggers_replan():
    job = _job(ReusePolicy(max_drift=0.15))
    for seed in range(3):
        job.run(_batch(seed, alpha=1.25))
    shifted = job.run(_batch(99, alpha=2.2))
    assert not shifted.reused
    assert shifted.plan_reason == "drift"
    assert shifted.drift > 0.15
    assert job.schedule_cache.stats()["replans"] == 2
    # back on the new distribution: the fresh snapshot is reused again
    after = job.run(_batch(100, alpha=2.2))
    assert after.reused


def test_max_age_forces_revalidation():
    """age >= max_age replans even when the distribution never moved."""
    job = _job(ReusePolicy(max_drift=1.0, max_age=2))
    reasons = [job.run(_batch(0)).plan_reason for _ in range(7)]
    # plan at 0; reuse ages 0,1; age==2 forces replan at 3; repeat.
    assert reasons == ["cold", "ok", "ok", "max_age", "ok", "ok", "max_age"]
    assert job.schedule_cache.stats()["replans"] == 3


def test_revalidate_every_skips_drift_checks():
    job = _job(ReusePolicy(max_drift=0.5, revalidate_every=3))
    for seed in range(7):
        job.run(_batch(seed))
    stats = job.schedule_cache.stats()
    # 6 post-plan batches, drift computed on every 3rd -> 2 checks
    assert stats["drift_checks"] == 2
    assert stats["replans"] == 1


def test_reused_outputs_bit_identical_to_fresh_plan():
    """Replaying a cached schedule must not change a single bit."""
    reuse_job = _job(ReusePolicy(max_drift=0.25))
    fresh_job = _job(None)
    for seed in list(range(6)) + [50, 51]:        # stationary then shifted
        alpha = 1.25 if seed < 50 else 2.2
        r = reuse_job.run(_batch(seed, alpha=alpha))
        f = fresh_job.run(_batch(seed, alpha=alpha))
        assert np.array_equal(r.values, f.values)
        assert np.array_equal(r.counts, f.counts)
    assert reuse_job.schedule_cache.stats()["reuses"] > 0


def test_overflow_on_reused_plan_forces_replan_and_exact_outputs():
    """Sub-threshold drift that still overflows the cached capacities must
    replan + re-execute (outputs exact), not silently drop pairs."""
    m, K, n = 2, 64, 4
    def mk(counts):
        # counts: pairs per cluster, identical on both shards
        keys = np.concatenate([np.full(c, cl, np.int32)
                               for cl, c in enumerate(counts)])
        keys = np.stack([keys, keys])
        vals = np.ones((m, K, 1), np.float32)
        return (jnp.asarray(keys), jnp.asarray(vals),
                jnp.asarray(np.ones((m, K), bool)))

    job = _job(ReusePolicy(max_drift=0.5, capacity_slack=0.0),
               m=m, n=n, pipelined=False)
    job.run(mk([16, 16, 16, 16]))
    # concentrate cluster 0 (drift 0.375 < 0.5) past the cached capacity
    res = job.run(mk([40, 8, 8, 8]))
    assert res.plan_reason == "overflow" and not res.reused
    assert res.overflow == 0                      # re-executed exactly
    assert job.schedule_cache.capacity_fallbacks == 1
    assert float(res.counts[0]) == 2 * 40


def test_capacity_slack_absorbs_small_drift():
    """With headroom, the same concentration replays without fallback."""
    m, K, n = 2, 64, 4
    def mk(counts):
        keys = np.concatenate([np.full(c, cl, np.int32)
                               for cl, c in enumerate(counts)])
        keys = np.stack([keys, keys])
        vals = np.ones((m, K, 1), np.float32)
        return (jnp.asarray(keys), jnp.asarray(vals),
                jnp.asarray(np.ones((m, K), bool)))

    job = _job(ReusePolicy(max_drift=0.5, capacity_slack=2.0),
               m=m, n=n, pipelined=False)
    job.run(mk([16, 16, 16, 16]))
    res = job.run(mk([40, 8, 8, 8]))
    assert res.reused and res.overflow == 0
    assert job.schedule_cache.capacity_fallbacks == 0


# ---------------------------------------------------------------------------
# Speed-drift edge: a one-sided None must be conservative
# ---------------------------------------------------------------------------


def test_estimator_reset_forces_revalidation_of_speed_built_plan():
    """ISSUE 4 bugfix: ``speed_drift(ref, None)`` used to substitute
    all-ones for the missing side, so an estimator ``reset()`` silently
    reported near-zero drift and a plan built from measured speeds was
    never revalidated. A one-sided None against non-nominal speeds is now
    ``inf`` -> replan."""
    job = _job(ReusePolicy(max_drift=0.9, max_speed_drift=0.25),
               estimate_speeds=True, speed_ewma=1.0)
    job.set_slot_slowdown(1, 2.0)   # wall-clock multiplier: 2x slow
    reasons = [job.run(_batch(i)).plan_reason for i in range(3)]
    # cold plan (nominal speeds), then the measured straggler replans
    assert reasons[0] == "cold" and "speed_drift" in reasons[1:]
    snap = job.schedule_cache.snapshot
    assert not np.allclose(snap.slot_speeds, 1.0)   # plan carries measured speeds
    # the estimator forgets everything -> current speeds become None
    job.speed_estimator.reset()
    job._external_timings = True                    # keep synthetic model out
    res = job.run(_batch(3))
    assert not res.reused
    assert res.plan_reason == "speed_drift"
    assert res.speed_drift == float("inf")


def test_no_estimation_jobs_still_reuse_with_none_speeds():
    """Jobs that never measure (plan speeds nominal, fresh None) keep
    reusing — the conservative rule only bites when the plan embodied a
    measured heterogeneity claim."""
    job = _job(ReusePolicy(max_drift=0.5))
    results = [job.run(_batch(s)) for s in range(4)]
    assert all(r.reused for r in results[1:])
    assert all(r.speed_drift == 0.0 for r in results[1:])


# ---------------------------------------------------------------------------
# Snapshot + wave plan serialization
# ---------------------------------------------------------------------------


def test_cached_schedule_roundtrips_through_json():
    job = _job(ReusePolicy())
    job.run(_batch(0))
    snap = job.schedule_cache.snapshot
    back = sc.CachedSchedule.from_json(snap.to_json())
    assert np.array_equal(back.schedule.assignment, snap.schedule.assignment)
    assert np.array_equal(back.waves.rank_of_cluster,
                          snap.waves.rank_of_cluster)
    assert np.array_equal(back.waves.chunk_of_cluster,
                          snap.waves.chunk_of_cluster)
    assert back.chunk_caps == snap.chunk_caps
    assert back.capacity == snap.capacity
    assert np.array_equal(back.local_hist, snap.local_hist)


def test_plan_waves_matches_engine_invariants():
    rng = np.random.default_rng(0)
    loads = rng.zipf(1.4, 48).astype(float)
    assignment = rng.integers(0, 4, 48).astype(np.int32)
    plan = pipe.plan_waves(loads, assignment, 4, 4)
    # dense chunk ids, every cluster in exactly one chunk
    assert set(np.unique(plan.chunk_of_cluster)) == set(range(plan.num_chunks))
    members = np.concatenate(
        [plan.chunk_members(c) for c in range(plan.num_chunks)])
    assert sorted(members.tolist()) == list(range(48))
    # rank is a permutation in increasing-load order
    by_rank = np.argsort(plan.rank_of_cluster)
    assert (np.diff(loads[by_rank]) >= -1e-12).all()


# ---------------------------------------------------------------------------
# Cost model: replan benefit + cost gate
# ---------------------------------------------------------------------------


def test_estimate_replan_benefit_positive_under_heavy_drift():
    rng = np.random.default_rng(0)
    old = rng.zipf(1.3, 64).clip(1, 5000).astype(float)
    from repro.core import scheduler as S
    cached = S.schedule_bss(old, 8)
    drifted = np.roll(old, 17) * rng.uniform(0.2, 5.0, 64)
    rep = sim.estimate_replan_benefit(drifted, cached)
    assert set(rep) == {"stale_makespan", "fresh_cost", "fresh_strategy",
                        "benefit"}
    assert rep["stale_makespan"] > 0
    assert rep["benefit"] == pytest.approx(
        rep["stale_makespan"] - rep["fresh_cost"])


def test_estimate_replan_benefit_nonpositive_when_stationary():
    """On the distribution it was planned from, a near-optimal schedule
    leaves no room for a fresh plan to win net of scheduling overhead."""
    rng = np.random.default_rng(1)
    loads = rng.zipf(1.3, 64).clip(1, 5000).astype(float)
    from repro.core import scheduler as S
    cached = S.schedule_bss(loads, 8)
    rep = sim.estimate_replan_benefit(loads, cached)
    assert rep["benefit"] <= 1e-9


def test_cost_gate_keeps_stale_schedule_when_replan_not_worth_it():
    """auto + cost_gate: drift trips, the simulator says the stale plan is
    still competitive -> reuse, with the drift baseline re-anchored."""
    job = _job(ReusePolicy(max_drift=0.01, cost_gate=True), scheduler="auto")
    job.run(_batch(0))
    res = job.run(_batch(1))          # sampling noise alone trips 0.01
    if res.reused:                     # gate held the plan
        assert res.plan_reason == "cost_gate"
        assert res.replan_benefit is not None
        assert res.replan_benefit["benefit"] <= 0.0
        # baseline was refreshed: the same batch now scores ~zero drift
        again = job.run(_batch(1))
        assert again.reused and again.drift < 0.01
    else:                              # gate agreed with the drift signal
        assert res.replan_benefit is not None
        assert res.replan_benefit["benefit"] > 0.0


# ---------------------------------------------------------------------------
# shard_map backend (8 virtual devices; CI sets XLA_FLAGS)
# ---------------------------------------------------------------------------


def test_reuse_on_shard_map_backend_matches_vmap():
    """The on-device drift check + replay must work over a real mesh."""
    import jax

    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from jax.sharding import Mesh

    m, K, n = 8, 512, 24
    mesh = Mesh(np.asarray(jax.devices()).reshape(m), ("mr_slots",))
    job = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, pipeline_chunks=3,
        reuse=ReusePolicy(max_drift=0.3)), backend="shard_map", mesh=mesh)
    vjob = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, pipeline_chunks=3), backend="vmap")
    for seed in range(4):
        r = job.run(_batch(seed, m=m, K=K, key_mod=503))
        v = vjob.run(_batch(seed, m=m, K=K, key_mod=503))
        assert np.array_equal(np.asarray(r.values), np.asarray(v.values))
    assert job.schedule_cache.stats()["replans"] == 1


# ---------------------------------------------------------------------------
# Serving loop
# ---------------------------------------------------------------------------


def test_steady_state_loop_amortizes_one_plan():
    job = _job(ReusePolicy(max_drift=0.2))
    seen = []
    tele = steady_state_loop(
        job, (_batch(s) for s in range(6)),
        on_batch=lambda i, res, w: seen.append((i, res.reused)))
    assert tele["batches"] == 6
    assert tele["reused"] == [False] + [True] * 5
    assert tele["cache"]["replans"] == 1
    assert seen == [(0, False)] + [(i, True) for i in range(1, 6)]
    assert len(tele["walls"]) == 6


def test_steady_state_loop_works_without_reuse_policy():
    job = _job(None)
    tele = steady_state_loop(job, (_batch(s) for s in range(3)))
    assert tele["batches"] == 3
    assert "cache" not in tele
    assert tele["reused"] == [False] * 3
