"""The chunked double-buffered shuffle→reduce engine + fused kernel.

Covers the PR's acceptance surface:
* pipelined phase B == sequential phase B **bit-exactly** on fixed seeds
  (integer-valued f32 inputs make every summation order exact);
* ``plan_chunks`` invariants — every operation exactly once, chunk walk in
  increasing-load order, chunk count bounds;
* the fused gather+segment-reduce kernel vs its jnp oracle across dtypes;
* the ``auto`` strategy: picks a candidate, never balances worse than hash,
  and reports per-candidate cost estimates.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import pipeline as pipe
from repro.core import simulator as sim
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.kernels.fused_shuffle_reduce.ops import fused_shuffle_reduce
from repro.kernels.fused_shuffle_reduce.ref import fused_gather_segment_reduce_ref
from repro.kernels.moe_dispatch.ops import (dispatch_to_buckets,
                                            dispatch_to_buckets_chunked,
                                            plan_capacity_slabs)


def _identity_map(shard):
    return shard


def _int_job_inputs(rng, m, K, V, key_mod):
    """Integer-valued f32 pairs: bit-exact under any summation order."""
    keys = (rng.zipf(1.3, size=(m, K)) % key_mod).astype(np.int32)
    vals = rng.integers(0, 8, size=(m, K, V)).astype(np.float32)
    valid = rng.random((m, K)) > 0.1
    return keys, vals, valid


# ---------------------------------------------------------------------------
# Pipelined == sequential, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sched", ["hash", "os4m", "auto"])
@pytest.mark.parametrize("chunks", [2, 4, 7])
def test_pipelined_bit_identical_to_sequential(rng, sched, chunks):
    m, K, V, n = 4, 256, 3, 24
    keys, vals, valid = _int_job_inputs(rng, m, K, V, 997)
    batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))
    results = {}
    for pipelined in (True, False):
        job = MapReduceJob(_identity_map, MapReduceConfig(
            num_slots=m, num_clusters=n, scheduler=sched,
            pipelined=pipelined, pipeline_chunks=chunks), backend="vmap")
        results[pipelined] = job.run(batch)
    assert np.array_equal(results[True].values, results[False].values)
    assert np.array_equal(results[True].counts, results[False].counts)
    assert results[True].overflow == 0
    assert results[False].overflow == 0


def test_pipelined_bit_identical_with_kernels(rng):
    """The fused-kernel path must agree bit-for-bit too (f32 accum both)."""
    m, K, V, n = 4, 128, 2, 16
    keys, vals, valid = _int_job_inputs(rng, m, K, V, 509)
    batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))
    outs = []
    for use_kernels in (False, True):
        job = MapReduceJob(_identity_map, MapReduceConfig(
            num_slots=m, num_clusters=n, scheduler="os4m",
            pipelined=True, pipeline_chunks=3, use_kernels=use_kernels),
            backend="vmap")
        outs.append(job.run(batch))
    assert np.array_equal(outs[0].values, outs[1].values)
    assert np.array_equal(outs[0].counts, outs[1].counts)


def test_reduce_op_max_pipelined_matches_sequential(rng):
    m, K, n = 2, 64, 8
    keys = rng.integers(0, 100, (m, K)).astype(np.int32)
    # All-negative values ⇒ every cluster's true max is negative
    # (regression: a maximum() chunk merge clamped negative maxima at the
    # zero-initialised accumulator, returning all zeros).
    vals = rng.integers(-1000, -1, (m, K, 1)).astype(np.float32)
    valid = np.ones((m, K), bool)
    batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))
    res = {}
    for pipelined in (True, False):
        job = MapReduceJob(_identity_map, MapReduceConfig(
            num_slots=m, num_clusters=n, reduce_op="max",
            pipelined=pipelined), backend="vmap")
        res[pipelined] = job.run(batch)
    assert np.array_equal(res[True].values, res[False].values)
    assert res[True].values.min() < 0      # the negative maxima survived


def test_pipelined_preserves_value_dtype(rng):
    """bf16 payloads come back bf16 from both phase-B paths (regression:
    the pipelined accumulator was hardcoded f32)."""
    m, K, n = 4, 128, 12
    keys = rng.integers(0, 300, (m, K)).astype(np.int32)
    vals = jnp.asarray(rng.integers(0, 4, (m, K, 2)), jnp.bfloat16)
    valid = jnp.ones((m, K), bool)
    batch = (jnp.asarray(keys), vals, valid)
    dtypes = {}
    vals_sum = {}
    for pipelined in (True, False):
        job = MapReduceJob(_identity_map, MapReduceConfig(
            num_slots=m, num_clusters=n, pipelined=pipelined),
            backend="vmap")
        res = job.run(batch)
        dtypes[pipelined] = res.values.dtype
        vals_sum[pipelined] = float(np.asarray(res.values, np.float32).sum())
    assert dtypes[True] == dtypes[False]
    assert vals_sum[True] == vals_sum[False] > 0


# ---------------------------------------------------------------------------
# plan_chunks invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("num_chunks", [1, 3, 8])
def test_plan_chunks_partition_and_order(seed, num_chunks):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 60))
    loads = rng.zipf(1.4, n).astype(float)
    chunks = pipe.plan_chunks(loads, num_chunks, "increasing")
    # every operation exactly once
    flat = np.concatenate(chunks)
    assert sorted(flat.tolist()) == list(range(n))
    # chunk count bounds
    assert 1 <= len(chunks) <= min(num_chunks, n)
    # increasing-load order: within each chunk AND across chunk boundaries
    ordered = loads[flat]
    assert (np.diff(ordered) >= -1e-12).all()


def test_plan_chunks_balances_load():
    loads = np.ones(64)
    chunks = pipe.plan_chunks(loads, 4, "increasing")
    sizes = [len(c) for c in chunks]
    assert len(chunks) == 4
    assert max(sizes) - min(sizes) <= 1


def test_engine_chunk_walk_is_increasing_load_per_slot(rng):
    """Each Reduce slot's waves see non-decreasing per-wave operation load."""
    m, K, n = 4, 512, 32
    keys, vals, valid = _int_job_inputs(rng, m, K, 2, 2003)
    job = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, scheduler="os4m", pipeline_chunks=4),
        backend="vmap")
    res = job.run((jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    # Reconstruct the wave plan the engine used.
    key_dist = res.key_distribution
    for d in range(m):
        members = np.nonzero(res.schedule.assignment == d)[0]
        if members.size < 2:
            continue
        waves = pipe.plan_chunks(key_dist[members], 4, "increasing")
        flat = np.concatenate(waves)
        ordered = key_dist[members][flat]
        assert (np.diff(ordered) >= -1e-12).all()


# ---------------------------------------------------------------------------
# Fused kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("n,s,v", [(64, 16, 4), (500, 37, 8), (1024, 600, 16)])
def test_fused_shuffle_reduce_dtype_sweep(rng, dtype, n, s, v):
    vals = jnp.asarray(rng.standard_normal((n, v)), dtype)
    seg_unsorted = rng.integers(0, s, n).astype(np.int32)
    order = np.argsort(seg_unsorted, kind="stable").astype(np.int32)
    seg_sorted = jnp.asarray(seg_unsorted[order])
    got = fused_shuffle_reduce(vals, jnp.asarray(order), seg_sorted, s,
                               use_kernel=True)
    ref = fused_gather_segment_reduce_ref(vals, jnp.asarray(order),
                                          seg_sorted, s)
    atol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_fused_fallback_matches_kernel(rng):
    n, s, v = 300, 25, 4
    vals = jnp.asarray(rng.standard_normal((n, v)), jnp.float32)
    seg = np.sort(rng.integers(0, s, n)).astype(np.int32)
    order = jnp.asarray(rng.permutation(n).astype(np.int32))
    # padding rows (seg == s) must be dropped by both paths
    seg[-5:] = s
    a = fused_shuffle_reduce(vals, order, jnp.asarray(seg), s, use_kernel=True)
    b = fused_shuffle_reduce(vals, order, jnp.asarray(seg), s, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# Auto strategy
# ---------------------------------------------------------------------------


def test_auto_strategy_resolves_and_reports_costs(rng):
    m, K, n = 4, 256, 24
    keys, vals, valid = _int_job_inputs(rng, m, K, 2, 997)
    job = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, scheduler="auto"), backend="vmap")
    res = job.run((jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    assert res.strategy in ("hash", "lpt", "multifit", "bss")
    assert set(res.strategy_costs) == {"hash", "lpt", "multifit", "bss"}
    # the pick is the argmin of its own cost table
    assert res.strategy_costs[res.strategy] == min(res.strategy_costs.values())
    # and never balances worse than the hash baseline
    hash_job = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, scheduler="hash"), backend="vmap")
    hash_res = hash_job.run(
        (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid)))
    assert res.schedule.balance_ratio <= hash_res.schedule.balance_ratio + 1e-9


def test_pick_strategy_prefers_balance_on_skew():
    rng = np.random.default_rng(0)
    loads = rng.zipf(1.3, 480).clip(1, 20_000).astype(float)
    name, schedule, costs = sim.pick_strategy(loads, 30)
    assert name != "hash"            # skewed: hash pays for its imbalance
    assert schedule.balance_ratio < 1.2
    assert costs["hash"] > costs[name]


def test_estimate_reduce_time_monotone_in_imbalance():
    loads = np.asarray([100.0] * 32)
    from repro.core import scheduler as S
    balanced = S.schedule_lpt(loads, 4)
    skewed = S.Schedule.from_assignment(np.zeros(32, np.int32), loads, 4)
    assert (sim.estimate_reduce_time(loads, skewed)
            > sim.estimate_reduce_time(loads, balanced))


# ---------------------------------------------------------------------------
# shard_map backend (8 virtual devices; CI sets XLA_FLAGS)
# ---------------------------------------------------------------------------


def test_shard_map_repeated_runs_and_match_vmap(rng):
    """The jit cache must serve the shard_map backend across run() calls
    (regression: a cache hit used to skip the arg-flattening step)."""
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from jax.sharding import Mesh

    m, K, n = 8, 64, 12
    keys, vals, valid = _int_job_inputs(rng, m, K, 2, 503)
    batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))
    mesh = Mesh(np.asarray(jax.devices()).reshape(m), ("mr_slots",))
    job = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, pipeline_chunks=3),
        backend="shard_map", mesh=mesh)
    r1 = job.run(batch)
    r2 = job.run(batch)     # cache hit — must not retrace/crash
    assert np.array_equal(r1.values, r2.values)
    vres = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=m, num_clusters=n, pipeline_chunks=3),
        backend="vmap").run(batch)
    assert np.array_equal(np.asarray(vres.values), np.asarray(r1.values))


def test_jit_cache_bounded_across_distributions(rng):
    """Distinct key distributions produce distinct phase-B statics; the
    LRU bound must keep the executable cache finite."""
    job = MapReduceJob(_identity_map, MapReduceConfig(
        num_slots=4, num_clusters=32, scheduler="bss", pipeline_chunks=4),
        backend="vmap")
    for seed in range(6):
        r = np.random.default_rng(seed)
        keys = (r.zipf(1.3, size=(4, 256)) % 997).astype(np.int32)
        vals = np.ones((4, 256, 2), np.float32)
        ok = np.ones((4, 256), bool)
        job.run((jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(ok)))
    assert len(job._jit_cache) <= job._jit_cache_max


def test_moe_chunked_overflow_parity_binding_capacity(mesh8):
    """When expert capacity binds, chunked dispatch must drop exactly as
    many tokens per expert as single-shot (carry-based global ranks) —
    regression: per-slab ranks let chunking keep a different count."""
    import dataclasses

    from repro.nn import layers as L
    from repro.nn.moe import MoEArgs, init_moe, moe

    base = MoEArgs(num_experts=8, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=1.0, strategy="a2a")
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), base, mesh8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 16)) + 2.0  # skewed
    _, s1 = moe(params, x, args=base, mesh=mesh8)
    _, s4 = moe(params, x,
                args=dataclasses.replace(base, pipeline_chunks=4), mesh=mesh8)
    assert int(s1["overflow"]) > 0          # capacity actually binds
    assert int(s4["overflow"]) == int(s1["overflow"])


def test_moe_chunked_matches_unchunked_default_capacity(mesh8):
    """pipeline_chunks is an overlap-only optimization: at the *default*
    capacity_factor it must neither drop extra tokens nor change outputs
    (regression: per-expert capacity was sized from the slab, not the
    full receive buffer)."""
    import dataclasses

    from repro.nn import layers as L
    from repro.nn.moe import MoEArgs, init_moe, moe

    base = MoEArgs(num_experts=8, top_k=2, d_model=16, d_ff=32,
                   strategy="a2a")   # capacity_factor default
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), base, mesh8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 16))
    y1, s1 = moe(params, x, args=base, mesh=mesh8)
    y4, s4 = moe(params, x,
                 args=dataclasses.replace(base, pipeline_chunks=4),
                 mesh=mesh8)
    assert int(s4["overflow"]) == int(s1["overflow"])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)


# ---------------------------------------------------------------------------
# Chunked dispatch helpers (MoE path)
# ---------------------------------------------------------------------------


def test_plan_capacity_slabs_cover_capacity():
    for cap, chunks in [(64, 4), (7, 3), (1, 4), (16, 1), (5, 8)]:
        slabs = plan_capacity_slabs(cap, chunks)
        covered = []
        for s, z in slabs:
            covered.extend(range(s, s + z))
        assert covered == list(range(cap))
        assert len(slabs) <= max(1, min(chunks, cap))


def test_dispatch_chunked_matches_unchunked(rng):
    t, e, cap = 512, 8, 96
    dest = rng.integers(-1, e, t).astype(np.int32)
    vals = rng.standard_normal((t, 4)).astype(np.float32)
    full, counts, ovf = dispatch_to_buckets(
        jnp.asarray(vals), jnp.asarray(dest), e, cap)
    slabs, counts_c, ovf_c = dispatch_to_buckets_chunked(
        jnp.asarray(vals), jnp.asarray(dest), e, cap, 4)
    np.testing.assert_allclose(np.concatenate([np.asarray(s) for s in slabs],
                                              axis=1), np.asarray(full))
    assert np.array_equal(np.asarray(counts), np.asarray(counts_c))
    assert int(ovf) == int(ovf_c)
